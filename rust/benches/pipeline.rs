//! Real-engine benchmarks (L3 hot path 3): PJRT stage execution,
//! tensor<->literal conversion, optimizer step, AllReduce, and one
//! full end-to-end HPP round of the compiled LM.
//!
//! Requires a `--features pjrt` build with a real xla binding plus
//! `make artifacts` (skips gracefully otherwise).

fn main() {
    #[cfg(not(feature = "pjrt"))]
    eprintln!("pipeline bench needs the live engine: cargo bench --features pjrt");
    #[cfg(feature = "pjrt")]
    live::run();
}

#[cfg(feature = "pjrt")]
mod live {
    use std::path::PathBuf;

    use asteroid::data::{DataSource, LmTask};
    use asteroid::model::from_manifest::Manifest;
    use asteroid::pipeline::collective::GroupComm;
    use asteroid::pipeline::{train, Optimizer, OptimizerCfg, TrainOpts};
    use asteroid::planner::plan::{Plan, Stage};
    use asteroid::runtime::{Runtime, Tensor};
    use asteroid::util::bench::Bencher;

    pub fn run() {
        let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(manifest) = Manifest::load(&artifacts) else {
            eprintln!("artifacts/ missing — run `make artifacts` first; skipping pipeline bench");
            return;
        };
        let lm = manifest.model("lm").unwrap().clone();
        let mut b = Bencher::default();

        // Host-side primitives.
        let t = Tensor::zeros_f32(&[8, 64, 128]);
        b.bench("tensor_to_literal_256KB", || t.to_literal().unwrap());
        let lit = t.to_literal().unwrap();
        b.bench("tensor_from_literal_256KB", || Tensor::from_literal(&lit).unwrap());

        let mut params = vec![0.01f32; 1_000_000];
        let grads = vec![0.001f32; 1_000_000];
        let mut opt = Optimizer::new(OptimizerCfg::sgd(0.05), &[1_000_000]);
        b.bench("optimizer_sgd_1M_params", || {
            opt.step(&mut [&mut params], &[&grads]);
        });

        let comm = GroupComm::new(1, 0.0);
        let local = vec![1.0f32; 1_000_000];
        b.bench("allreduce_identity_1M", || comm.allreduce_sum(&local));

        // PJRT stage executions (the per-micro-batch hot path).
        let rt = Runtime::load(&lm, &["block_fwd", "block_bwd"]).unwrap();
        let sig = rt.signature("block_fwd").unwrap().clone();
        let inputs: Vec<Tensor> = sig
            .inputs
            .iter()
            .map(|s| Tensor::zeros_f32(&s.shape))
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        b.bench("pjrt_block_fwd", || rt.execute("block_fwd", &refs).unwrap());

        let sigb = rt.signature("block_bwd").unwrap().clone();
        let binputs: Vec<Tensor> = sigb
            .inputs
            .iter()
            .map(|s| Tensor::zeros_f32(&s.shape))
            .collect();
        let brefs: Vec<&Tensor> = binputs.iter().collect();
        b.bench("pjrt_block_bwd", || rt.execute("block_bwd", &brefs).unwrap());

        // One full 2-stage HPP round (amortised over steps).
        let micro = lm.microbatch;
        let vocab = lm.cfg_usize("vocab").unwrap();
        let seq = lm.cfg_usize("seq").unwrap();
        let nl = lm.layers.len();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![micro], kp: 3 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![micro], kp: 1 },
            ],
            microbatch: micro,
            num_micro: 4,
        };
        let mut data = LmTask::new(vocab, seq, micro, 1);
        let t0 = std::time::Instant::now();
        let steps = 6;
        let stats = train(
            &artifacts,
            "lm",
            &plan,
            &TrainOpts { steps, log_every: 0, ..Default::default() },
            &mut data,
        )
        .unwrap();
        println!(
            "{:<44} {:>12.3} s/round (incl. startup {:.1}s total; {:.1} samples/s steady)",
            "e2e_hpp_round_2stage",
            stats.round_secs.iter().sum::<f64>() / stats.round_secs.len() as f64,
            t0.elapsed().as_secs_f64(),
            stats.samples_per_sec,
        );
        let _ = data.next_microbatch();
    }
}
