//! Simulator benchmarks (L3 hot path 2): events/second of the
//! discrete-event engine across plan shapes — every repro table runs
//! through these loops hundreds of times.

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::model::zoo;
use asteroid::planner::dp::{plan_hpp, PlannerConfig};
use asteroid::planner::plan::{Plan, Stage};
use asteroid::profiler::ProfileTable;
use asteroid::sim::simulate_round;
use asteroid::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();

    // Planned heterogeneous pipelines.
    for (model, env) in [(zoo::efficientnet_b1(), "C"), (zoo::mobilenet_v2(), "B")] {
        let cluster = ClusterSpec::env(env, 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(2048, 32);
        let plan = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default())
            .unwrap()
            .plan;
        b.bench(&format!("sim_round/{}@{env}", model.name), || {
            simulate_round(&table, &cluster, &model, &plan)
        });
    }

    // Scaling in micro-batch count (event volume ~ M x stages).
    let cluster = ClusterSpec::nanos(8, 100.0);
    let model = zoo::mobilenet_v2();
    let table = ProfileTable::new(&cluster, &model);
    let nl = model.num_layers();
    for m in [16usize, 64, 256] {
        let mut plan = Plan {
            stages: (0..8)
                .map(|s| Stage {
                    layers: (s * nl / 8, (s + 1) * nl / 8),
                    devices: vec![s],
                    alloc: vec![32],
                    kp: 1,
                })
                .collect(),
            microbatch: 32,
            num_micro: m,
        };
        plan.apply_default_kp();
        b.bench(&format!("sim_round/8stage_m{m}"), || {
            simulate_round(&table, &cluster, &model, &plan)
        });
    }
}
