//! Schedule IR benchmarks: generation + simulator pricing on an
//! 8-device / 8-stage plan (the shape the repro tables hammer), now
//! per policy so the bubble-ratio trajectory is tracked across PRs.
//!
//! Uses the in-repo `util::bench::Bencher` harness (criterion is not
//! vendored offline; benches run with `harness = false`).  On exit the
//! results are recorded to `BENCH_schedule.json` at the repo root —
//! timing rows per policy plus a deterministic `policies` section with
//! each policy's priced round latency and mean bubble fraction:
//!
//!     cargo bench --bench schedule

use asteroid::config::ClusterSpec;
use asteroid::model::zoo;
use asteroid::planner::plan::{Plan, Stage};
use asteroid::profiler::ProfileTable;
use asteroid::schedule::{builtin_policies, policy_by_name, Schedule};
use asteroid::sim::{price_policy, price_schedule, simulate_round};
use asteroid::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();

    // 8 homogeneous devices, 8 single-device stages, M = 64.
    let cluster = ClusterSpec::nanos(8, 100.0);
    let model = zoo::mobilenet_v2();
    let table = ProfileTable::new(&cluster, &model);
    let nl = model.num_layers();
    let mut plan = Plan {
        stages: (0..8)
            .map(|s| Stage {
                layers: (s * nl / 8, (s + 1) * nl / 8),
                devices: vec![s],
                alloc: vec![32],
                kp: 1,
            })
            .collect(),
        microbatch: 32,
        num_micro: 64,
    };
    plan.apply_default_kp();

    // Per-policy timing rows: IR generation and event-accurate pricing.
    for policy in builtin_policies() {
        b.bench(&format!("schedule_build/{}/8dev_8stage_m64", policy.name()), || {
            Schedule::for_sim(&plan, &model, policy)
        });
        let sched = Schedule::for_sim(&plan, &model, policy);
        b.bench(&format!("price_schedule/{}/8dev_8stage_m64", policy.name()), || {
            price_schedule(&sched, &table, &cluster, &model, &plan)
        });
    }

    let sched = Schedule::for_sim(&plan, &model, builtin_policies()[0]);
    b.bench("schedule_validate/8dev_8stage_m64", || sched.validate());
    // End-to-end wrapper (build + price), the planner sim_select path.
    b.bench("simulate_round/8dev_8stage_m64", || {
        simulate_round(&table, &cluster, &model, &plan)
    });

    // Deterministic per-policy quality rows: priced round latency and
    // mean bubble fraction over the plan's devices — the numbers whose
    // trajectory (async below zb-h1 below 1f1b-kp, gpipe above) later
    // PRs watch.  Priced through `price_policy` so bounded-staleness
    // policies report their steady-state figures.
    let policy_rows: Vec<String> = builtin_policies()
        .iter()
        .map(|policy| {
            let sim = price_policy(&table, &cluster, &model, &plan, *policy);
            let devs = plan.devices();
            let mean_bubble: f64 =
                devs.iter().map(|&d| sim.bubble_fraction[d]).sum::<f64>() / devs.len() as f64;
            format!(
                "    {{\"policy\": \"{}\", \"round_latency_s\": {:e}, \
                 \"mean_bubble_fraction\": {:.6}}}",
                policy.name(),
                sim.round_latency,
                mean_bubble
            )
        })
        .collect();

    // Staleness sweep: how the bounded-staleness budget trades stash
    // memory for bubble elimination on the same plan (deterministic —
    // priced, not timed).
    let staleness_rows: Vec<String> = [0usize, 1, 2, 3]
        .iter()
        .map(|&s| {
            let policy = policy_by_name(&format!("async:{s}")).unwrap();
            let sim = price_policy(&table, &cluster, &model, &plan, policy);
            format!(
                "    {{\"policy\": \"{}\", \"max_staleness\": {s}, \
                 \"round_latency_s\": {:e}, \"round_bubble_ratio\": {:.6}, \
                 \"rounds_priced\": {}}}",
                policy.name(),
                sim.round_latency,
                sim.round_bubble_ratio,
                sim.rounds_priced
            )
        })
        .collect();

    // ---- record the trajectory ----------------------------------------
    let rows: Vec<String> = b
        .results
        .iter()
        .map(|r| {
            format!(
                "    {{\"name\": \"{}\", \"mean_s\": {:e}, \"p50_s\": {:e}, \
                 \"p95_s\": {:e}, \"samples\": {}, \"iters_per_sample\": {}}}",
                r.name, r.per_iter_s.mean, r.per_iter_s.p50, r.per_iter_s.p95,
                r.per_iter_s.n, r.iters
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"schedule\",\n  \"shape\": \"8dev_8stage_m64\",\n  \
         \"results\": [\n{}\n  ],\n  \"policies\": [\n{}\n  ],\n  \
         \"staleness\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
        policy_rows.join(",\n"),
        staleness_rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_schedule.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("recorded {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}
