//! Schedule IR benchmarks: generation + simulator pricing on an
//! 8-device / 8-stage plan (the shape the repro tables hammer), now
//! per policy so the bubble-ratio trajectory is tracked across PRs —
//! plus fleet-scale planning rows (128/512/2048 synthetic devices)
//! whose 512-device total is CI-gated against `plan_budget.budget_s`.
//!
//! Uses the in-repo `util::bench::Bencher` harness (criterion is not
//! vendored offline; benches run with `harness = false`).  On exit the
//! results are recorded to `BENCH_schedule.json` at the repo root —
//! timing rows per policy plus a deterministic `policies` section with
//! each policy's priced round latency and mean bubble fraction:
//!
//!     cargo bench --bench schedule

use asteroid::codec::{Codec, CodecSpec};
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::model::{zoo, ModelDesc};
use asteroid::planner::plan::{Plan, Stage};
use asteroid::planner::{
    plan_hpp, plan_hpp_incremental, plan_hpp_incremental_join, plan_hpp_subset,
    plan_hpp_with_state, PlannerConfig,
};
use asteroid::profiler::ProfileTable;
use asteroid::schedule::{builtin_policies, policy_by_name, Schedule};
use asteroid::sim::{price, simulate_round, PriceRequest};
use asteroid::util::bench::{synthetic_fleet, Bencher};

/// The 512-device wall-clock budget asserted by CI: mean
/// `plan_hpp/fleet512` + `schedule_build/fleet512` must stay under it.
const FLEET_BUDGET_S: f64 = 120.0;

/// Hand-built 8-stage fleet plan: layers split evenly, devices split
/// evenly across stages, each stage's micro-batch spread one sample at
/// a time (surplus devices carry a zero share — legal, and exactly the
/// shape a 32-sample micro takes on a 256-device stage).
fn fleet_plan(model: &ModelDesc, n: usize, cfg: &TrainConfig) -> Plan {
    let nl = model.num_layers();
    let stages = 8;
    let per = n / stages;
    let mb = cfg.microbatch;
    let mut plan = Plan {
        stages: (0..stages)
            .map(|s| {
                let mut alloc = vec![mb / per; per];
                for a in alloc.iter_mut().take(mb % per) {
                    *a += 1;
                }
                Stage {
                    layers: (s * nl / stages, (s + 1) * nl / stages),
                    devices: (s * per..(s + 1) * per).collect(),
                    alloc,
                    kp: 1,
                }
            })
            .collect(),
        microbatch: mb,
        num_micro: cfg.num_microbatches(),
    };
    plan.apply_default_kp();
    plan
}

fn main() {
    let mut b = Bencher::default();

    // 8 homogeneous devices, 8 single-device stages, M = 64.
    let cluster = ClusterSpec::nanos(8, 100.0);
    let model = zoo::mobilenet_v2();
    let table = ProfileTable::new(&cluster, &model);
    let nl = model.num_layers();
    let mut plan = Plan {
        stages: (0..8)
            .map(|s| Stage {
                layers: (s * nl / 8, (s + 1) * nl / 8),
                devices: vec![s],
                alloc: vec![32],
                kp: 1,
            })
            .collect(),
        microbatch: 32,
        num_micro: 64,
    };
    plan.apply_default_kp();

    // Per-policy timing rows: IR generation and event-accurate pricing.
    for policy in builtin_policies() {
        b.bench(&format!("schedule_build/{}/8dev_8stage_m64", policy.name()), || {
            Schedule::for_sim(&plan, &model, policy)
        });
        let sched = Schedule::for_sim(&plan, &model, policy);
        b.bench(&format!("price_schedule/{}/8dev_8stage_m64", policy.name()), || {
            price(&PriceRequest::new(&table, &cluster, &model, &plan).schedule(&sched))
        });
    }

    let sched = Schedule::for_sim(&plan, &model, builtin_policies()[0]);
    b.bench("schedule_validate/8dev_8stage_m64", || sched.validate());
    // End-to-end wrapper (build + price), the planner sim_select path.
    b.bench("simulate_round/8dev_8stage_m64", || {
        simulate_round(&table, &cluster, &model, &plan)
    });

    // Deterministic per-policy quality rows: priced round latency and
    // mean bubble fraction over the plan's devices — the numbers whose
    // trajectory (async below zb-h1 below 1f1b-kp, gpipe above) later
    // PRs watch.  Priced through `sim::price` so bounded-staleness
    // policies report their steady-state figures.
    let policy_rows: Vec<String> = builtin_policies()
        .iter()
        .map(|policy| {
            let sim = price(&PriceRequest::new(&table, &cluster, &model, &plan).policy(*policy));
            let devs = plan.devices();
            let mean_bubble: f64 =
                devs.iter().map(|&d| sim.bubble_fraction[d]).sum::<f64>() / devs.len() as f64;
            format!(
                "    {{\"policy\": \"{}\", \"round_latency_s\": {:e}, \
                 \"mean_bubble_fraction\": {:.6}}}",
                policy.name(),
                sim.round_latency,
                mean_bubble
            )
        })
        .collect();

    // Staleness sweep: how the bounded-staleness budget trades stash
    // memory for bubble elimination on the same plan (deterministic —
    // priced, not timed).
    let staleness_rows: Vec<String> = [0usize, 1, 2, 3]
        .iter()
        .map(|&s| {
            let policy = policy_by_name(&format!("async:{s}")).unwrap();
            let sim = price(&PriceRequest::new(&table, &cluster, &model, &plan).policy(policy));
            format!(
                "    {{\"policy\": \"{}\", \"max_staleness\": {s}, \
                 \"round_latency_s\": {:e}, \"round_bubble_ratio\": {:.6}, \
                 \"rounds_priced\": {}}}",
                policy.name(),
                sim.round_latency,
                sim.round_bubble_ratio,
                sim.rounds_priced
            )
        })
        .collect();

    // Per-codec data-plane rows on the heterogeneous env-C chain
    // (deterministic — priced, not timed): each codec plans its own
    // wire-aware cut points, then the chosen plan is priced both at
    // wire size (the codec's real round) and at fp32 (the logical
    // bytes the same plan would move uncompressed), so the recorded
    // compression ratio and latency win are explicit.
    let codec_rows: Vec<String> = {
        let ccluster = ClusterSpec::env("C", 100.0).unwrap();
        let ctable = ProfileTable::new(&ccluster, &model);
        let ccfg = TrainConfig::new(256, 16);
        let policy = builtin_policies()[0];
        Codec::ALL
            .iter()
            .map(|&c| {
                let spec = CodecSpec::uniform(c);
                let cpc = PlannerConfig { codec: spec, ..PlannerConfig::default() };
                let out = plan_hpp(&ctable, &ccluster, &model, &ccfg, &cpc).unwrap();
                let base = PriceRequest::new(&ctable, &ccluster, &model, &out.plan)
                    .policy(policy);
                let wire = price(&base.codec(spec));
                let logical = price(&base.codec(CodecSpec::default()));
                format!(
                    "    {{\"codec\": \"{}\", \"round_latency_s\": {:e}, \
                     \"wire_bytes_per_round\": {}, \"logical_bytes_per_round\": {}}}",
                    c.name(),
                    wire.round_latency,
                    wire.bytes_on_network,
                    logical.bytes_on_network
                )
            })
            .collect()
    };

    // ---- fleet-scale rows (tentpole: planning at 128/512/2048) --------
    // Single-iteration sampling: one fleet plan is seconds, not micros,
    // so calibration would only multiply the wall-clock.  The 2048 rows
    // track the headroom shape; only the 512 sum is budget-gated.
    let mut fb = Bencher { warmup_s: 0.0, sample_target_s: 0.0, samples: 2, results: vec![] };
    let fleet_cfg = TrainConfig::new(2048, 64);
    let pc = PlannerConfig::default();
    let default_policy = builtin_policies()[0];
    for n in [128usize, 512, 2048] {
        let fleet = synthetic_fleet(n, 100.0);
        let ftable = ProfileTable::new(&fleet, &model);
        fb.bench(&format!("plan_hpp/fleet{n}"), || {
            plan_hpp(&ftable, &fleet, &model, &fleet_cfg, &pc).unwrap()
        });
        let fplan = fleet_plan(&model, n, &fleet_cfg);
        fb.bench(&format!("schedule_build/fleet{n}"), || {
            Schedule::for_sim(&fplan, &model, default_policy)
        });
    }
    // Replan after losing one device: full rebuild vs the incremental
    // fast path.  Losing the *head* of the planner's device order keeps
    // every DP suffix intact (best case); losing the tail invalidates
    // all of them (worst case — the fast path's floor).
    for n in [128usize, 512] {
        let fleet = synthetic_fleet(n, 100.0);
        let ftable = ProfileTable::new(&fleet, &model);
        let (_, state) = plan_hpp_with_state(&ftable, &fleet, &model, &fleet_cfg, &pc).unwrap();
        let head = state.order()[0];
        let tail = *state.order().last().unwrap();
        let keep: Vec<usize> = state.order().iter().copied().filter(|&d| d != head).collect();
        fb.bench(&format!("replan_full/fleet{n}"), || {
            plan_hpp_subset(&ftable, &fleet, &model, &fleet_cfg, &pc, &keep).unwrap()
        });
        fb.bench(&format!("replan_incremental_best/fleet{n}"), || {
            plan_hpp_incremental(&state, &ftable, &fleet, &model, &fleet_cfg, &pc, head).unwrap()
        });
        fb.bench(&format!("replan_incremental_worst/fleet{n}"), || {
            plan_hpp_incremental(&state, &ftable, &fleet, &model, &fleet_cfg, &pc, tail).unwrap()
        });
        // A device rejoins the shrunk fleet (churn rejoin): full subset
        // rebuild over the restored membership vs the join fast path
        // re-expanding the shrunk DP state.
        let kept = plan_hpp_subset(&ftable, &fleet, &model, &fleet_cfg, &pc, &keep).unwrap().1;
        let all: Vec<usize> = state.order().to_vec();
        fb.bench(&format!("replan_join_full/fleet{n}"), || {
            plan_hpp_subset(&ftable, &fleet, &model, &fleet_cfg, &pc, &all).unwrap()
        });
        fb.bench(&format!("replan_join_incremental/fleet{n}"), || {
            plan_hpp_incremental_join(&kept, &ftable, &fleet, &model, &fleet_cfg, &pc, head)
                .unwrap()
        });
    }
    let measured_s = fb.mean_of("plan_hpp/fleet512").unwrap()
        + fb.mean_of("schedule_build/fleet512").unwrap();

    // ---- record the trajectory ----------------------------------------
    let row = |r: &asteroid::util::bench::BenchResult| {
        format!(
            "    {{\"name\": \"{}\", \"mean_s\": {:e}, \"p50_s\": {:e}, \
             \"p95_s\": {:e}, \"samples\": {}, \"iters_per_sample\": {}}}",
            r.name, r.per_iter_s.mean, r.per_iter_s.p50, r.per_iter_s.p95,
            r.per_iter_s.n, r.iters
        )
    };
    let rows: Vec<String> = b.results.iter().map(row).collect();
    let plan_rows: Vec<String> = fb.results.iter().map(row).collect();
    let json = format!(
        "{{\n  \"bench\": \"schedule\",\n  \"shape\": \"8dev_8stage_m64\",\n  \
         \"note\": \"plan rows are fleet-scale (synthetic_fleet topology); \
         plan_budget gates plan_hpp/fleet512 + schedule_build/fleet512 in CI\",\n  \
         \"results\": [\n{}\n  ],\n  \"policies\": [\n{}\n  ],\n  \
         \"staleness\": [\n{}\n  ],\n  \"codecs\": [\n{}\n  ],\n  \
         \"plan\": [\n{}\n  ],\n  \
         \"plan_budget\": {{\"name\": \"fleet512_plan_plus_build\", \
         \"budget_s\": {FLEET_BUDGET_S}, \"measured_s\": {measured_s:e}}}\n}}\n",
        rows.join(",\n"),
        policy_rows.join(",\n"),
        staleness_rows.join(",\n"),
        codec_rows.join(",\n"),
        plan_rows.join(",\n")
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_schedule.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!("recorded {path}"),
        Err(e) => eprintln!("could not record {path}: {e}"),
    }
}
