//! Planner micro-benchmarks (L3 hot path 1): Algorithm 2 over the
//! paper models / environments, Algorithm 1 allocation, and the cost
//! model primitives — the loops §Perf optimises.

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::model::zoo;
use asteroid::planner::alloc::{allocate_microbatch, AllocOpts};
use asteroid::planner::cost::{plan_steps, round_latency};
use asteroid::planner::dp::{plan_hpp, PlannerConfig};
use asteroid::profiler::ProfileTable;
use asteroid::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();

    // Algorithm 2 end-to-end per model on Env C (Table 7's workload).
    for model in zoo::all() {
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(2048, 32);
        b.bench(&format!("alg2_plan_env_c/{}", model.name), || {
            plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap()
        });
    }

    // Algorithm 1 allocation on a heterogeneous group.
    let cluster = ClusterSpec::env("C", 100.0).unwrap();
    let model = zoo::efficientnet_b1();
    let table = ProfileTable::new(&cluster, &model);
    let cfg = TrainConfig::new(2048, 32);
    let devices: Vec<usize> = vec![0, 1, 3];
    b.bench("alg1_allocate_microbatch", || {
        allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, 60, &devices, 32, 3,
            AllocOpts::default(),
        )
        .unwrap()
    });

    // Cost-model primitives.
    let plan = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default())
        .unwrap()
        .plan;
    b.bench("cost_plan_steps", || plan_steps(&table, &cluster, &model, &plan));
    let steps = plan_steps(&table, &cluster, &model, &plan);
    b.bench("cost_round_latency", || round_latency(&steps, 64));
    b.bench("profile_range_query", || table.time_fwd_bwd(0, 10, 90, 17));
}
