//! Eq. (3) memory model: per-stage peak footprint under 1F1B.
//!
//!   Mem_p(beta) = Mem^(MOD) + Mem^(OPT) + K_p * Mem^(ACT)(beta)
//!
//! * MOD — stage weights plus accumulated gradients (2x weight bytes);
//! * OPT — optimizer state (momentum = 1x, Adam = 2x weight bytes);
//! * ACT — intermediate activations of ONE in-flight micro-batch; K_p
//!   micro-batches are resident before strict 1F1B kicks in.
//!
//! A bounded-staleness policy (`AsyncPipe`) extends the equation with
//! a fourth term: the weight-version **stash** — one stage-weight
//! snapshot pinned per in-flight micro-batch beyond the live copy, so
//! every backward can run against the version its forward read.
//! [`stage_memory_for_policy`] charges it via
//! `SchedulePolicy::weight_stash_copies`.

use crate::config::{DeviceSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::schedule::SchedulePolicy;

/// Memory components of one stage for a given per-device batch `beta`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageMemory {
    /// Stage weights plus accumulated gradients (2x weight bytes).
    pub model_bytes: u64,
    /// Optimizer state (momentum = 1x, Adam = 2x weight bytes).
    pub optimizer_bytes: u64,
    /// Activations of one in-flight micro-batch at this `beta`.
    pub activation_bytes_per_mb: u64,
    /// In-flight micro-batch bound charged (the *effective* K_p).
    pub kp: usize,
    /// Weight-version stash copies of a bounded-staleness policy (0
    /// for synchronous policies).
    pub weight_stash_bytes: u64,
}

impl StageMemory {
    /// Total Eq. 3 peak: fixed (model + optimizer + stash) plus the
    /// K_p-windowed activation residency.
    pub fn total(&self) -> u64 {
        self.model_bytes
            + self.optimizer_bytes
            + self.weight_stash_bytes
            + self.kp as u64 * self.activation_bytes_per_mb
    }
}

/// Compute Eq. (3) for layers [i, j) at per-device batch `beta`.
pub fn stage_memory(
    model: &ModelDesc,
    cfg: &TrainConfig,
    i: usize,
    j: usize,
    beta: usize,
    kp: usize,
) -> StageMemory {
    let w = model.weight_bytes_range(i, j);
    // weights + accumulated gradients
    let model_bytes = 2 * w;
    let optimizer_bytes = (cfg.optimizer_mem_factor * w as f64) as u64;
    // stage input (needed for the rematerialising BP) + every layer's
    // output activation, per in-flight micro-batch sample
    let input = if i == 0 {
        model.input_bytes
    } else {
        model.boundary_bytes(i)
    };
    let act_per_sample = model.act_bytes_range(i, j) + input;
    StageMemory {
        model_bytes,
        optimizer_bytes,
        activation_bytes_per_mb: act_per_sample * beta as u64,
        kp,
        weight_stash_bytes: 0,
    }
}

/// Eq. (3) under a schedule policy: the in-flight bound is the
/// policy's *effective* K_p, not the plan's raw warm-up depth.  A
/// fill-drain policy holds every micro of the round (O(M) residency,
/// Fig. 15(b)); charging raw `stage.kp` for it under-counts the peak
/// by (M - K_p) activations and lets the planner emit OOM plans — the
/// bug this function exists to close.  1F1B-family policies clamp to
/// the same value as before, so default plans are unchanged.
///
/// A bounded-staleness policy additionally charges its weight-stash
/// copies (`weight_stash_copies` x stage weight bytes): every
/// in-flight micro beyond the live weights pins one stage-weight
/// snapshot so its backward can run against the version its forward
/// read.
#[allow(clippy::too_many_arguments)]
pub fn stage_memory_for_policy(
    model: &ModelDesc,
    cfg: &TrainConfig,
    i: usize,
    j: usize,
    beta: usize,
    stage_kp: usize,
    n_micros: usize,
    policy: &dyn SchedulePolicy,
) -> StageMemory {
    let mut mem = stage_memory(model, cfg, i, j, beta, policy.effective_kp(stage_kp, n_micros));
    mem.weight_stash_bytes =
        policy.weight_stash_copies(stage_kp, n_micros) as u64 * model.weight_bytes_range(i, j);
    mem
}

/// Largest per-device batch that fits the device budget (the `bs_d`
/// bound of Algorithm 1, line 7).  `kp` is the *effective* in-flight
/// bound and `stash_copies` the policy's extra weight-stash copies
/// (callers apply `SchedulePolicy::effective_kp` /
/// `weight_stash_copies` first; both are batch-independent fixed
/// costs except the K_p activation term).  Returns 0 when even the
/// fixed cost (weights + optimizer + stash) exceeds the budget.
pub fn max_batch_under_budget(
    model: &ModelDesc,
    cfg: &TrainConfig,
    i: usize,
    j: usize,
    kp: usize,
    stash_copies: usize,
    dev: &DeviceSpec,
) -> usize {
    let m1 = stage_memory(model, cfg, i, j, 1, kp);
    let fixed = m1.model_bytes
        + m1.optimizer_bytes
        + stash_copies as u64 * model.weight_bytes_range(i, j);
    if fixed >= dev.mem_bytes {
        return 0;
    }
    let per_sample = (kp as u64 * m1.activation_bytes_per_mb).max(1);
    ((dev.mem_bytes - fixed) / per_sample) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceKind, DeviceSpec, TrainConfig};
    use crate::model::zoo;

    #[test]
    fn memory_scales_with_kp() {
        // Fig. 15(b): larger K_p means proportionally more activation
        // memory, constant weight/optimizer memory.
        let m = zoo::mobilenet_v2();
        let cfg = TrainConfig::new(256, 8);
        let a = stage_memory(&m, &cfg, 0, 20, 8, 1);
        let b = stage_memory(&m, &cfg, 0, 20, 8, 5);
        assert_eq!(a.model_bytes, b.model_bytes);
        assert!(b.total() > a.total());
        assert_eq!(
            b.total() - a.total(),
            4 * a.activation_bytes_per_mb // (5-1) extra in-flight micro-batches
        );
    }

    #[test]
    fn activations_dominate_early_cnn_stages() {
        // Fig. 5: activation memory is the main contributor for CNNs.
        let m = zoo::mobilenet_v2();
        let cfg = TrainConfig::new(256, 32);
        let cut = m.num_layers() / 3;
        let s = stage_memory(&m, &cfg, 0, cut, 32, 3);
        assert!(
            s.kp as u64 * s.activation_bytes_per_mb > s.model_bytes + s.optimizer_bytes,
            "act {} vs fixed {}",
            s.kp as u64 * s.activation_bytes_per_mb,
            s.model_bytes + s.optimizer_bytes
        );
    }

    #[test]
    fn raw_kp_undercounts_fill_drain_peak_memory() {
        // Regression for the Eq. 3 accounting bug: a GPipe fill-drain
        // round holds all M micro-batches in flight, but the old model
        // charged the stage's raw K_p — under-counting the peak by
        // (M - K_p) activation sets.
        use crate::schedule::{GpipeFillDrain, OneFOneBKp, ZeroBubbleH1};
        let m = zoo::mobilenet_v2();
        let cfg = TrainConfig::new(256, 8); // M = 32
        let n_micros = cfg.num_microbatches();
        let raw = stage_memory(&m, &cfg, 0, 20, 8, 1);
        let gp = stage_memory_for_policy(&m, &cfg, 0, 20, 8, 1, n_micros, &GpipeFillDrain);
        assert_eq!(gp.kp, n_micros);
        assert!(gp.total() > raw.total(), "old model under-counts fill-drain");
        assert_eq!(
            gp.total() - raw.total(),
            (n_micros as u64 - 1) * raw.activation_bytes_per_mb
        );
        // 1F1B-family policies charge the clamped warm-up depth — the
        // planner's default behaviour is unchanged.
        let one = stage_memory_for_policy(&m, &cfg, 0, 20, 8, 3, n_micros, &OneFOneBKp);
        assert_eq!(one, stage_memory(&m, &cfg, 0, 20, 8, 3));
        let zb = stage_memory_for_policy(&m, &cfg, 0, 20, 8, 3, n_micros, &ZeroBubbleH1);
        assert_eq!(zb.kp, 3);
    }

    #[test]
    fn async_staleness_charges_weight_stash_copies() {
        // The stash ring pins one stage-weight snapshot per in-flight
        // micro beyond the live copy: window - 1 copies, on top of the
        // widened K_p + sigma activation residency.
        use crate::schedule::{AsyncPipe, OneFOneBKp};
        let m = zoo::mobilenet_v2();
        let cfg = TrainConfig::new(256, 8); // M = 32
        let n_micros = cfg.num_microbatches();
        let sync = stage_memory_for_policy(&m, &cfg, 0, 20, 8, 3, n_micros, &OneFOneBKp);
        assert_eq!(sync.weight_stash_bytes, 0);
        let a = AsyncPipe { max_staleness: 2 };
        let asy = stage_memory_for_policy(&m, &cfg, 0, 20, 8, 3, n_micros, &a);
        assert_eq!(asy.kp, 5); // K_p + sigma
        let w = m.weight_bytes_range(0, 20);
        assert_eq!(asy.weight_stash_bytes, 4 * w); // window 5 -> 4 copies
        assert_eq!(
            asy.total() - sync.total(),
            2 * sync.activation_bytes_per_mb + 4 * w
        );
        // The stash is a fixed cost in the batch-size bound too.
        use crate::config::{DeviceKind, DeviceSpec};
        let nano = DeviceSpec::of_kind(DeviceKind::JetsonNano, 0);
        let plain = max_batch_under_budget(&m, &cfg, 0, 20, 5, 0, &nano);
        let stashed = max_batch_under_budget(&m, &cfg, 0, 20, 5, 4, &nano);
        assert!(stashed <= plain);
    }

    #[test]
    fn max_batch_monotone_in_memory() {
        let m = zoo::mobilenet_v2();
        let cfg = TrainConfig::new(256, 8);
        let nano = DeviceSpec::of_kind(DeviceKind::JetsonNano, 0);
        let nx = DeviceSpec::of_kind(DeviceKind::JetsonNX, 1);
        let nl = m.num_layers();
        let b_nano = max_batch_under_budget(&m, &cfg, 0, nl, 3, 0, &nano);
        let b_nx = max_batch_under_budget(&m, &cfg, 0, nl, 3, 0, &nx);
        assert!(b_nx > b_nano, "nx {b_nx} vs nano {b_nano}");
        assert!(b_nano > 0);
    }

    #[test]
    fn max_batch_zero_when_weights_exceed_budget() {
        let m = zoo::bert_small(); // ~115 MB weights
        let cfg = TrainConfig::new(256, 8);
        let mut tiny = DeviceSpec::of_kind(DeviceKind::JetsonNano, 0);
        tiny.mem_bytes = 10 * 1024 * 1024; // 10 MiB
        assert_eq!(
            max_batch_under_budget(&m, &cfg, 0, m.num_layers(), 1, 0, &tiny),
            0
        );
    }

    #[test]
    fn adam_costs_more_than_sgd() {
        let m = zoo::mobilenet_v2();
        let mut cfg = TrainConfig::new(256, 8);
        let sgd = stage_memory(&m, &cfg, 0, 10, 8, 1);
        cfg.optimizer_mem_factor = 2.0;
        let adam = stage_memory(&m, &cfg, 0, 10, 8, 1);
        assert!(adam.optimizer_bytes > sgd.optimizer_bytes);
        assert_eq!(adam.optimizer_bytes, 2 * sgd.optimizer_bytes);
    }

    #[test]
    fn first_stage_counts_model_input() {
        let m = zoo::resnet50(); // big 224x224 input
        let cfg = TrainConfig::new(256, 4);
        let s0 = stage_memory(&m, &cfg, 0, 5, 4, 1);
        let s1 = stage_memory(&m, &cfg, 5, 10, 4, 1);
        // The first stage stashes raw images; bytes must reflect that.
        assert!(s0.activation_bytes_per_mb > 0);
        assert!(s1.activation_bytes_per_mb > 0);
    }
}
