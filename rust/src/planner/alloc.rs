//! Algorithm 1: allocation of a micro-batch's samples across the
//! resource-diverse devices of one stage group (Eq. 7-9).
//!
//! Two phases, exactly as the paper:
//!  1. *Memory-aware balancing* — recursively distribute samples in
//!     proportion to each device's computing capacity v_d (Eq. 9) while
//!     respecting the per-device memory budget;
//!  2. *Straggler workload offloading* — because execution time is
//!     non-linear in batch size, proportional allocation is suboptimal;
//!     iteratively move one block of samples from the slowest device to
//!     the fastest device with spare memory until the straggler stops
//!     improving.

use anyhow::{bail, Result};

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::memory::max_batch_under_budget;
use crate::profiler::ProfileTable;

/// Planner behaviour switches (Fig. 15(a) ablations).
#[derive(Debug, Clone, Copy)]
pub struct AllocOpts {
    /// Respect per-device memory budgets (off = naive planner).
    pub memory_aware: bool,
    /// Use per-device capacities (off = treat devices as homogeneous).
    pub heterogeneity_aware: bool,
    /// Run phase 2 (straggler offloading).
    pub straggler_offload: bool,
    /// Extra per-device stage-weight copies charged against the Eq. 3
    /// budget — the weight-version stash of a bounded-staleness
    /// schedule policy (0 for synchronous policies).  The planner
    /// derives it from `SchedulePolicy::weight_stash_copies`.
    pub stash_copies: usize,
}

impl Default for AllocOpts {
    fn default() -> Self {
        AllocOpts {
            memory_aware: true,
            heterogeneity_aware: true,
            straggler_offload: true,
            stash_copies: 0,
        }
    }
}

/// Allocate `b` samples of one micro-batch across `devices` running
/// layers [i, j) with warm-up depth `kp`.  Returns per-device sample
/// counts (parallel to `devices`).
#[allow(clippy::too_many_arguments)]
pub fn allocate_microbatch(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    i: usize,
    j: usize,
    devices: &[usize],
    b: usize,
    kp: usize,
    opts: AllocOpts,
) -> Result<Vec<usize>> {
    assert!(!devices.is_empty());
    let n = devices.len();

    // Per-device ceiling bs_d from the Eq. (3) budget.
    let limit: Vec<usize> = devices
        .iter()
        .map(|&d| {
            if opts.memory_aware {
                max_batch_under_budget(model, cfg, i, j, kp, opts.stash_copies, &cluster.devices[d])
            } else {
                usize::MAX
            }
        })
        .collect();

    // Capacity v_d of Eq. (9): inverse FP+BP latency at full micro-batch.
    let cap: Vec<f64> = devices
        .iter()
        .map(|&d| {
            if opts.heterogeneity_aware {
                table.capacity(d, i, j, b.max(1))
            } else {
                1.0
            }
        })
        .collect();

    // ---------------------------------------------------- phase 1
    let mut alloc = vec![0usize; n];
    let mut remaining = b;
    while remaining > 0 {
        // Devices that still have memory headroom.
        let active: Vec<usize> = (0..n).filter(|&k| alloc[k] < limit[k]).collect();
        if active.is_empty() {
            bail!(
                "out of memory: stage layers [{i},{j}) cannot fit micro-batch {b} \
                 on devices {devices:?} (limits {limit:?})"
            );
        }
        let cap_sum: f64 = active.iter().map(|&k| cap[k]).sum();
        let mut granted = 0usize;
        for &k in &active {
            let share = ((cap[k] / cap_sum) * remaining as f64).floor() as usize;
            let take = share.min(limit[k] - alloc[k]);
            alloc[k] += take;
            granted += take;
        }
        if granted == 0 {
            // Flooring starved everyone: grant 1 to the highest-capacity
            // device with headroom (keeps the recursion terminating).
            let k = *active
                .iter()
                .max_by(|&&a, &&b| cap[a].partial_cmp(&cap[b]).unwrap())
                .unwrap();
            alloc[k] += 1;
            granted = 1;
        }
        remaining -= granted.min(remaining);
    }

    // ---------------------------------------------------- phase 2
    if opts.straggler_offload && n > 1 {
        let block = (b / 16).max(1);
        let lat = |alloc: &[usize]| -> Vec<f64> {
            (0..n)
                .map(|k| table.time_fwd_bwd(devices[k], i, j, alloc[k]))
                .collect()
        };
        let max_iters = 4 * (b / block).max(1);
        for _ in 0..max_iters {
            let times = lat(&alloc);
            let straggler = argmax(&times);
            let old = times[straggler];
            // Fastest device with enough memory headroom.
            let recv = (0..n)
                .filter(|&k| k != straggler && alloc[k] + block <= limit[k])
                .min_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap());
            let Some(recv) = recv else { break };
            if alloc[straggler] < block {
                break;
            }
            alloc[straggler] -= block;
            alloc[recv] += block;
            let new_times = lat(&alloc);
            if new_times[argmax(&new_times)] >= old {
                // Offloading made the straggler worse: revert and stop.
                alloc[straggler] += block;
                alloc[recv] -= block;
                break;
            }
        }
    }

    debug_assert_eq!(alloc.iter().sum::<usize>(), b);
    Ok(alloc)
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, TrainConfig};
    use crate::model::zoo;
    use crate::profiler::ProfileTable;

    fn setup() -> (ClusterSpec, crate::model::ModelDesc, TrainConfig) {
        (
            ClusterSpec::env("C", 100.0).unwrap(), // NX, 2xTX2, 3xNano
            zoo::mobilenet_v2(),
            TrainConfig::new(256, 16),
        )
    }

    #[test]
    fn allocates_full_microbatch() {
        let (cluster, model, cfg) = setup();
        let table = ProfileTable::new(&cluster, &model);
        let devices = vec![0, 1, 3]; // NX, TX2, Nano
        let alloc = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, 20, &devices, 16, 3,
            AllocOpts::default(),
        )
        .unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 16);
    }

    #[test]
    fn faster_devices_get_more_samples() {
        let (cluster, model, cfg) = setup();
        let table = ProfileTable::new(&cluster, &model);
        let devices = vec![0, 3]; // NX vs Nano (~4.7x capacity gap)
        let alloc = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, 30, &devices, 32, 1,
            AllocOpts::default(),
        )
        .unwrap();
        assert!(alloc[0] > alloc[1], "NX {} vs Nano {}", alloc[0], alloc[1]);
    }

    #[test]
    fn homogeneous_flag_splits_evenly() {
        let (cluster, model, cfg) = setup();
        let table = ProfileTable::new(&cluster, &model);
        let devices = vec![0, 3];
        let opts = AllocOpts {
            heterogeneity_aware: false,
            straggler_offload: false,
            ..AllocOpts::default()
        };
        let alloc = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, 30, &devices, 32, 1, opts,
        )
        .unwrap();
        assert_eq!(alloc, vec![16, 16]);
    }

    #[test]
    fn straggler_offloading_improves_balance() {
        let (cluster, model, cfg) = setup();
        let table = ProfileTable::new(&cluster, &model);
        let devices = vec![0, 3];
        let base = AllocOpts { straggler_offload: false, ..AllocOpts::default() };
        let tuned = AllocOpts::default();
        let nl = model.num_layers();
        let a0 = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, nl, &devices, 64, 1, base,
        )
        .unwrap();
        let a1 = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, nl, &devices, 64, 1, tuned,
        )
        .unwrap();
        let worst = |a: &[usize]| -> f64 {
            devices
                .iter()
                .zip(a)
                .map(|(&d, &y)| table.time_fwd_bwd(d, 0, nl, y))
                .fold(0.0, f64::max)
        };
        assert!(worst(&a1) <= worst(&a0) + 1e-12, "{} vs {}", worst(&a1), worst(&a0));
    }

    #[test]
    fn memory_pressure_reported_as_oom() {
        let (mut cluster, model, cfg) = setup();
        // Shrink every device to a few MB: the full model can't fit.
        for d in &mut cluster.devices {
            d.mem_bytes = 4 * 1024 * 1024;
        }
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let r = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, nl, &[0, 1], 64, 4,
            AllocOpts::default(),
        );
        assert!(r.is_err());
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("out of memory"), "{msg}");
    }

    #[test]
    fn memory_unaware_never_ooms() {
        let (mut cluster, model, cfg) = setup();
        for d in &mut cluster.devices {
            d.mem_bytes = 1024;
        }
        let table = ProfileTable::new(&cluster, &model);
        let opts = AllocOpts { memory_aware: false, ..AllocOpts::default() };
        let nl = model.num_layers();
        let alloc =
            allocate_microbatch(&table, &cluster, &model, &cfg, 0, nl, &[0, 1], 64, 4, opts)
                .unwrap();
        assert_eq!(alloc.iter().sum::<usize>(), 64);
    }

    #[test]
    fn single_device_takes_all() {
        let (cluster, model, cfg) = setup();
        let table = ProfileTable::new(&cluster, &model);
        let alloc = allocate_microbatch(
            &table, &cluster, &model, &cfg, 0, 10, &[2], 16, 1,
            AllocOpts::default(),
        )
        .unwrap();
        assert_eq!(alloc, vec![16]);
    }
}
