//! HetPipe baseline: Hybrid *Data* Parallelism (HDP, paper §2.3 and
//! Fig. 2(a)).  Devices are partitioned into groups ("virtual workers"),
//! each group pipelines the FULL model internally (intra-group PP) and
//! groups exchange full-model gradients through a parameter server
//! (inter-group DP).  Communication volume follows Eq. (1):
//!
//!   V_HDP = 2*G*P + sum_i 2*beta_i*sum_j a_{i,j}        (G > 1)
//!
//! HetPipe is asynchronous in the original; for the throughput
//! comparison we model its steady-state round latency, and Fig. 14
//! applies the paper's observed staleness penalty to epochs-to-target.

use anyhow::{bail, Result};

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::cost::{round_latency, StepCost};
use crate::profiler::ProfileTable;

/// An HDP plan: device groups, per-group mini-batch shares, and the
/// internal pipeline cuts of each group.
#[derive(Debug, Clone)]
pub struct HdpPlan {
    /// Device groups (each a virtual worker running the full model).
    pub groups: Vec<Vec<usize>>,
    /// Mini-batch share (in micro-batches) per group; sums to M.
    pub micro_share: Vec<usize>,
    /// Layer cut bounds per group (len = group size + 1).
    pub cuts: Vec<Vec<usize>>,
    /// Predicted HPP... HDP-round latency in seconds.
    pub latency: f64,
    /// Predicted throughput, samples/s.
    pub throughput: f64,
    /// Eq. (1) communication volume per round, bytes.
    pub volume_bytes: u64,
}

/// Intra-group chain partition of the full model balanced by capacity
/// (same DP as the GPipe baseline but per group).
fn group_cuts(
    table: &ProfileTable,
    model: &ModelDesc,
    group: &[usize],
    b: usize,
) -> Option<Vec<usize>> {
    let n = group.len();
    let nl = model.num_layers();
    if nl < n {
        return None;
    }
    let inf = f64::INFINITY;
    let mut f = vec![vec![inf; nl + 1]; n + 1];
    let mut cut = vec![vec![0usize; nl + 1]; n + 1];
    f[0][0] = 0.0;
    for s in 1..=n {
        for l in s..=nl {
            for lp in (s - 1)..l {
                if f[s - 1][lp].is_infinite() {
                    continue;
                }
                let t = table.time_fwd_bwd(group[s - 1], lp, l, b);
                let v = f[s - 1][lp].max(t);
                if v < f[s][l] {
                    f[s][l] = v;
                    cut[s][l] = lp;
                }
            }
        }
    }
    let mut bounds = vec![nl];
    let mut l = nl;
    for s in (1..=n).rev() {
        l = cut[s][l];
        bounds.push(l);
    }
    bounds.reverse();
    Some(bounds)
}

/// Round latency of one group pipelining `m_i` micro-batches of size B
/// through its internal stages (plus inter-stage comm within the group).
fn group_round_latency(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    group: &[usize],
    cuts: &[usize],
    b: usize,
    m_i: usize,
) -> f64 {
    if m_i == 0 {
        return 0.0;
    }
    let mut steps: Vec<StepCost> = Vec::new();
    for s in 0..group.len() {
        if s > 0 {
            let bytes = model.boundary_bytes(cuts[s]) * b as u64;
            let bw = cluster.bandwidth[group[s - 1]][group[s]];
            let t = bytes as f64 / bw + cluster.latency_s;
            steps.push(StepCost { ef: t, eb: t, ta: 0.0, exec: false });
        }
        steps.push(StepCost {
            ef: table.time_fwd(group[s], cuts[s], cuts[s + 1], b),
            eb: table.time_bwd(group[s], cuts[s], cuts[s + 1], b),
            ta: 0.0,
            exec: true,
        });
    }
    round_latency(&steps, m_i)
}

/// Plan HetPipe HDP: enumerate contiguous partitions of the
/// memory-sorted device list into groups, balance mini-batch shares by
/// group capacity, pick the partition with the best round latency.
pub fn plan_hetpipe(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
) -> Result<HdpPlan> {
    let n = cluster.n();
    let b = cfg.microbatch;
    let m = cfg.num_microbatches();
    let p_bytes = model.total_weight_bytes();

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &c| {
        cluster.devices[c]
            .mem_bytes
            .cmp(&cluster.devices[a].mem_bytes)
            .then(a.cmp(&c))
    });

    let mut best: Option<HdpPlan> = None;
    // Contiguous partitions of `order` = bitmask over n-1 cut positions.
    for mask in 0u32..(1 << (n - 1)) {
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut cur = vec![order[0]];
        for i in 1..n {
            if mask & (1 << (i - 1)) != 0 {
                groups.push(std::mem::take(&mut cur));
            }
            cur.push(order[i]);
        }
        groups.push(cur);

        // Intra-group pipeline cuts; skip partitions whose groups can't
        // host the model.
        let cuts: Option<Vec<Vec<usize>>> = groups
            .iter()
            .map(|g| group_cuts(table, model, g, b))
            .collect();
        let Some(cuts) = cuts else { continue };

        // Mini-batch shares proportional to group capacity.
        let caps: Vec<f64> = groups
            .iter()
            .map(|g| {
                g.iter()
                    .map(|&d| table.capacity(d, 0, model.num_layers(), b))
                    .sum::<f64>()
            })
            .collect();
        let cap_sum: f64 = caps.iter().sum();
        let mut share: Vec<usize> = caps
            .iter()
            .map(|c| ((c / cap_sum) * m as f64).floor() as usize)
            .collect();
        let mut assigned: usize = share.iter().sum();
        // distribute remainder to the strongest groups
        while assigned < m {
            let k = (0..groups.len())
                .max_by(|&a, &c| caps[a].partial_cmp(&caps[c]).unwrap())
                .unwrap();
            share[k] += 1;
            assigned += 1;
        }

        // Group pipeline latencies + PS full-gradient exchange (2GP).
        let g_cnt = groups.len();
        let mut latency: f64 = 0.0;
        for (gi, g) in groups.iter().enumerate() {
            latency = latency
                .max(group_round_latency(table, cluster, model, g, &cuts[gi], b, share[gi]));
        }
        let ps_time = if g_cnt > 1 {
            // bidirectional full-model exchange per group through the PS
            // over the slowest involved link
            let min_bw = cluster.min_bandwidth(&order);
            2.0 * g_cnt as f64 * p_bytes as f64 / min_bw
        } else {
            0.0
        };
        latency += ps_time;

        // Eq. (1) volume.
        let volume = hdp_volume(model, &groups, &cuts, &share, b, p_bytes);

        let cand = HdpPlan {
            throughput: (b * m) as f64 / latency,
            groups,
            micro_share: share,
            cuts,
            latency,
            volume_bytes: volume,
        };
        if best.as_ref().map_or(true, |bst| cand.latency < bst.latency) {
            best = Some(cand);
        }
    }
    match best {
        Some(p) => Ok(p),
        None => bail!("hetpipe: no feasible grouping"),
    }
}

/// Eq. (1): V_HDP.
fn hdp_volume(
    model: &ModelDesc,
    groups: &[Vec<usize>],
    cuts: &[Vec<usize>],
    share: &[usize],
    b: usize,
    p_bytes: u64,
) -> u64 {
    let g = groups.len() as u64;
    let mut v: u64 = if g > 1 { 2 * g * p_bytes } else { 0 };
    for (gi, group) in groups.iter().enumerate() {
        let beta_i = (share[gi] * b) as u64;
        let intra: u64 = (1..group.len())
            .map(|s| model.boundary_bytes(cuts[gi][s]))
            .sum();
        v += 2 * beta_i * intra;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;

    fn fixture() -> (ClusterSpec, ModelDesc, ProfileTable, TrainConfig) {
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        (cluster, model, table, cfg)
    }

    #[test]
    fn covers_all_devices_and_microbatches() {
        let (cluster, model, table, cfg) = fixture();
        let plan = plan_hetpipe(&table, &cluster, &model, &cfg).unwrap();
        let mut devs: Vec<usize> = plan.groups.iter().flatten().copied().collect();
        devs.sort_unstable();
        assert_eq!(devs, (0..cluster.n()).collect::<Vec<_>>());
        assert_eq!(plan.micro_share.iter().sum::<usize>(), cfg.num_microbatches());
    }

    #[test]
    fn multi_group_pays_ps_exchange() {
        let (cluster, model, table, cfg) = fixture();
        let plan = plan_hetpipe(&table, &cluster, &model, &cfg).unwrap();
        if plan.groups.len() > 1 {
            // Eq. (1): volume must include the 2GP term.
            let floor = 2 * plan.groups.len() as u64 * model.total_weight_bytes();
            assert!(plan.volume_bytes >= floor);
        }
    }

    #[test]
    fn hdp_volume_exceeds_hpp_volume() {
        // Table 2: V_HDP is 1.9-2.7x V_HPP for the evaluation models.
        use crate::comm::hpp_volume;
        use crate::planner::dp::{plan_hpp, PlannerConfig};
        let (cluster, model, table, cfg) = fixture();
        let hdp = plan_hetpipe(&table, &cluster, &model, &cfg).unwrap();
        let hpp = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let v_hpp = hpp_volume(&model, &hpp.plan);
        assert!(
            hdp.volume_bytes > v_hpp,
            "HDP {} <= HPP {v_hpp}",
            hdp.volume_bytes
        );
    }

    #[test]
    fn single_device_cluster_is_one_group() {
        let cluster = ClusterSpec::env("A100", 0.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(64, 8);
        let plan = plan_hetpipe(&table, &cluster, &model, &cfg).unwrap();
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.volume_bytes, 0);
    }
}
