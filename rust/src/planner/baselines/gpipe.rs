//! GPipe-style pipeline parallelism baseline: one stage per device,
//! cuts chosen to balance per-stage *compute* only — GPipe's partitioner
//! "overlooks the sizes of intermediate tensors at partition points"
//! (paper §5.6), which is exactly the weakness Table 4 exposes.  The
//! paper grants the baseline heterogeneous workload balancing and our
//! 1F1B schedule, so stage times are balanced against per-device
//! capacity and K_p follows the ours policy.

use anyhow::{bail, Result};

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::cost::{plan_steps, round_latency};
use crate::planner::dp::PlanOutcome;
use crate::planner::plan::{kp_policy_ours, Plan, Stage};
use crate::profiler::ProfileTable;
use crate::schedule::{Schedule, SchedulePolicy};

/// Chain-partition the model into `n` single-device stages minimising
/// the max per-stage FP+BP time (compute only, no comm terms), for the
/// given round schedule policy.
pub fn plan_gpipe_pp(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    policy: &'static dyn SchedulePolicy,
) -> Result<PlanOutcome> {
    let t0 = std::time::Instant::now();
    let n = cluster.n();
    let nl = model.num_layers();
    if nl < n {
        bail!("model has fewer layers ({nl}) than devices ({n})");
    }
    let b = cfg.microbatch;

    // Devices in memory-desc order, matching the stage->device mapping
    // convention used throughout.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &c| {
        cluster.devices[c]
            .mem_bytes
            .cmp(&cluster.devices[a].mem_bytes)
            .then(a.cmp(&c))
    });

    // DP over (stages used, layers covered): f[s][l] = min over l' of
    // max(f[s-1][l'], t(dev_s, l'..l, B)).
    let inf = f64::INFINITY;
    let mut f = vec![vec![inf; nl + 1]; n + 1];
    let mut cut = vec![vec![0usize; nl + 1]; n + 1];
    f[0][0] = 0.0;
    for s in 1..=n {
        let dev = order[s - 1];
        for l in s..=nl {
            for lp in (s - 1)..l {
                if f[s - 1][lp].is_infinite() {
                    continue;
                }
                let t = table.time_fwd_bwd(dev, lp, l, b);
                let v = f[s - 1][lp].max(t);
                if v < f[s][l] {
                    f[s][l] = v;
                    cut[s][l] = lp;
                }
            }
        }
    }
    if f[n][nl].is_infinite() {
        bail!("gpipe partitioning failed");
    }

    // Reconstruct cuts.
    let mut bounds = vec![nl];
    let mut l = nl;
    for s in (1..=n).rev() {
        l = cut[s][l];
        bounds.push(l);
    }
    bounds.reverse(); // 0 = bounds[0] < ... < bounds[n] = nl

    let m = cfg.num_microbatches();
    let stages: Vec<Stage> = (0..n)
        .map(|s| Stage {
            layers: (bounds[s], bounds[s + 1]),
            devices: vec![order[s]],
            alloc: vec![b],
            kp: kp_policy_ours(n, s).min(m),
        })
        .collect();
    let plan = Plan { stages, microbatch: b, num_micro: m };
    plan.validate(model, cluster)?;
    let steps = plan_steps(table, cluster, model, &plan);
    let latency = round_latency(&steps, m);
    Ok(PlanOutcome {
        predicted_throughput: plan.samples_per_round() as f64 / latency,
        predicted_latency: latency,
        planning_time_s: t0.elapsed().as_secs_f64(),
        schedule: Schedule::for_sim(&plan, model, policy),
        policy,
        plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;

    #[test]
    fn pp_one_stage_per_device() {
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let out =
            plan_gpipe_pp(&table, &cluster, &model, &cfg, crate::schedule::DEFAULT_POLICY)
                .unwrap();
        assert_eq!(out.plan.num_stages(), 5);
        assert!(out.plan.stages.iter().all(|s| s.replicas() == 1));
        out.plan.validate(&model, &cluster).unwrap();
    }

    #[test]
    fn pp_balances_compute_across_heterogeneous_devices() {
        let cluster = ClusterSpec::env("C", 100.0).unwrap(); // NX..Nano
        let model = zoo::efficientnet_b1();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let out =
            plan_gpipe_pp(&table, &cluster, &model, &cfg, crate::schedule::DEFAULT_POLICY)
                .unwrap();
        // Per-stage compute times within ~4x of each other (perfect
        // balance impossible at layer granularity).
        let times: Vec<f64> = out
            .plan
            .stages
            .iter()
            .map(|s| table.time_fwd_bwd(s.devices[0], s.layers.0, s.layers.1, 16))
            .collect();
        let max = times.iter().cloned().fold(0.0, f64::max);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 4.0, "stage times {times:?}");
    }

    #[test]
    fn pp_suffers_on_cnn_over_slow_links() {
        // Table 4 / §5.2: PP cuts CNNs through huge feature maps, so
        // inter-stage comm dominates and Asteroid's HPP wins big.
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let model = zoo::resnet50();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(64, 4);
        let pp =
            plan_gpipe_pp(&table, &cluster, &model, &cfg, crate::schedule::DEFAULT_POLICY)
                .unwrap();
        let ours = crate::planner::dp::plan_hpp(
            &table,
            &cluster,
            &model,
            &cfg,
            &crate::planner::dp::PlannerConfig::default(),
        )
        .unwrap();
        assert!(
            ours.predicted_throughput > 1.5 * pp.predicted_throughput,
            "ours {} vs pp {}",
            ours.predicted_throughput,
            pp.predicted_throughput
        );
    }
}
