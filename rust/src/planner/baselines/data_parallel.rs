//! DP / EDDL baseline: every device replicates the whole model; the
//! micro-batch is balanced across devices (the paper grants baselines
//! heterogeneous workload balancing); gradients AllReduce once per
//! mini-batch.

use anyhow::Result;

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::alloc::{allocate_microbatch, AllocOpts};
use crate::planner::cost::{plan_steps, round_latency};
use crate::planner::dp::PlanOutcome;
use crate::planner::plan::{Plan, Stage};
use crate::profiler::ProfileTable;
use crate::schedule::{Schedule, SchedulePolicy};

/// Plan conventional data parallelism over all cluster devices, for
/// the given round schedule policy.
pub fn plan_dp(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    opts: AllocOpts,
    policy: &'static dyn SchedulePolicy,
) -> Result<PlanOutcome> {
    let t0 = std::time::Instant::now();
    let devices: Vec<usize> = (0..cluster.n()).collect();
    let nl = model.num_layers();
    // DP's warm-up depth is 1; the policy decides what that means for
    // residency (fill-drain still buffers the whole round, bounded
    // staleness adds its weight-stash copies).
    let kp = 1;
    let opts = AllocOpts {
        stash_copies: policy.weight_stash_copies(kp, cfg.num_microbatches()),
        ..opts
    };
    let alloc = allocate_microbatch(
        table,
        cluster,
        model,
        cfg,
        0,
        nl,
        &devices,
        cfg.microbatch,
        policy.effective_kp(kp, cfg.num_microbatches()),
        opts,
    )?;
    let plan = Plan {
        stages: vec![Stage { layers: (0, nl), devices, alloc, kp }],
        microbatch: cfg.microbatch,
        num_micro: cfg.num_microbatches(),
    };
    let steps = plan_steps(table, cluster, model, &plan);
    let latency = round_latency(&steps, plan.num_micro);
    Ok(PlanOutcome {
        predicted_throughput: plan.samples_per_round() as f64 / latency,
        predicted_latency: latency,
        planning_time_s: t0.elapsed().as_secs_f64(),
        schedule: Schedule::for_sim(&plan, model, policy),
        policy,
        plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;

    #[test]
    fn dp_single_stage_all_devices() {
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let out = plan_dp(
            &table,
            &cluster,
            &model,
            &cfg,
            AllocOpts::default(),
            crate::schedule::DEFAULT_POLICY,
        )
        .unwrap();
        assert_eq!(out.plan.num_stages(), 1);
        assert_eq!(out.plan.stages[0].devices.len(), 5);
        out.plan.validate(&model, &cluster).unwrap();
    }

    #[test]
    fn dp_pays_full_model_allreduce() {
        // The single step's T_a must charge the whole parameter set —
        // the communication wall of Fig. 1(left).
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let out = plan_dp(
            &table,
            &cluster,
            &model,
            &cfg,
            AllocOpts::default(),
            crate::schedule::DEFAULT_POLICY,
        )
        .unwrap();
        let steps = plan_steps(&table, &cluster, &model, &out.plan);
        let w = model.total_weight_bytes() as f64;
        let bw = cluster.min_bandwidth(&[0, 1, 2, 3, 4]);
        let expect = 2.0 * 4.0 * w / (5.0 * bw);
        assert!((steps[0].ta - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn dp_faster_on_faster_network() {
        let model = zoo::mobilenet_v2();
        let cfg = TrainConfig::new(256, 16);
        let c100 = ClusterSpec::env("A", 100.0).unwrap();
        let c1000 = ClusterSpec::env("A", 1000.0).unwrap();
        let t100 = ProfileTable::new(&c100, &model);
        let t1000 = ProfileTable::new(&c1000, &model);
        let s = plan_dp(
            &t100,
            &c100,
            &model,
            &cfg,
            AllocOpts::default(),
            crate::schedule::DEFAULT_POLICY,
        )
        .unwrap();
        let f = plan_dp(
            &t1000,
            &c1000,
            &model,
            &cfg,
            AllocOpts::default(),
            crate::schedule::DEFAULT_POLICY,
        )
        .unwrap();
        assert!(f.predicted_throughput > s.predicted_throughput);
    }
}
