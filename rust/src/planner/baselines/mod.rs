//! Baseline parallelism planners the paper compares against (§5.1):
//!
//! * **DP** — conventional data parallelism with heterogeneous workload
//!   balancing (the paper grants the baselines its balancing, §5.2);
//! * **EDDL** — DP on edge clusters (same architecture; kept as a named
//!   method for the Fig. 13 comparison);
//! * **PP (GPipe)** — layer pipeline, one stage per device, FLOPs-
//!   balanced cuts that ignore boundary-tensor sizes, 1F1B applied;
//! * **PipeDream** — HPP planner for homogeneous datacenter clusters:
//!   replication-aware but memory-unaware, comm-unaware in our synchro-
//!   nous comparison, and capacity-blind (homogeneous assumption);
//! * **Dapple** — synchronous HPP planner: comm-aware but homogeneous
//!   and memory-unaware;
//! * **HetPipe** — hybrid *data* parallelism (HDP): device groups as
//!   virtual workers running intra-group PP over the full model with a
//!   parameter-server full-gradient exchange per round (Eq. 1).

pub mod data_parallel;
pub mod gpipe;
pub mod hetpipe;

use std::fmt;
use std::str::FromStr;

use anyhow::Result;

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::alloc::AllocOpts;
use crate::planner::dp::{plan_hpp, PlanOutcome, PlannerConfig};
use crate::planner::plan::KpPolicy;
use crate::profiler::ProfileTable;
use crate::schedule::SchedulePolicy;

pub use data_parallel::plan_dp;
pub use gpipe::plan_gpipe_pp;
pub use hetpipe::{plan_hetpipe, HdpPlan};

/// Every comparable planning method.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Asteroid,
    OnDevice,
    DataParallel,
    Eddl,
    GpipePP,
    PipeDream,
    Dapple,
    HetPipe,
}

impl Method {
    /// Every method, in the paper's presentation order.
    pub const ALL: [Method; 8] = [
        Method::Asteroid,
        Method::OnDevice,
        Method::DataParallel,
        Method::Eddl,
        Method::GpipePP,
        Method::PipeDream,
        Method::Dapple,
        Method::HetPipe,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Asteroid => "Asteroid",
            Method::OnDevice => "On-Device",
            Method::DataParallel => "DP",
            Method::Eddl => "EDDL",
            Method::GpipePP => "PP",
            Method::PipeDream => "PipeDream",
            Method::Dapple => "Dapple",
            Method::HetPipe => "HetPipe",
        }
    }

    pub fn all_fig13() -> Vec<Method> {
        vec![
            Method::Eddl,
            Method::PipeDream,
            Method::Dapple,
            Method::HetPipe,
            Method::Asteroid,
        ]
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;

    /// Case-insensitive; accepts every `name()` plus the common
    /// spellings (`--method dp`, `--method gpipe`, ...).
    fn from_str(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "asteroid" | "ours" => Method::Asteroid,
            "on-device" | "ondevice" | "device" => Method::OnDevice,
            "dp" | "data-parallel" | "dataparallel" => Method::DataParallel,
            "eddl" => Method::Eddl,
            "pp" | "gpipe" | "gpipe-pp" => Method::GpipePP,
            "pipedream" => Method::PipeDream,
            "dapple" => Method::Dapple,
            "hetpipe" => Method::HetPipe,
            other => anyhow::bail!(
                "unknown method {other:?} (expected one of: asteroid, on-device, dp, \
                 eddl, pp, pipedream, dapple, hetpipe)"
            ),
        })
    }
}

/// PipeDream's planner emulated within our framework: homogeneous
/// capacity assumption, no memory constraint, no communication
/// modelling in the objective (see module docs).
pub fn plan_pipedream(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    policy: &'static dyn SchedulePolicy,
) -> Result<PlanOutcome> {
    let pc = PlannerConfig {
        alloc: AllocOpts {
            memory_aware: false,
            heterogeneity_aware: false,
            straggler_offload: false,
            ..AllocOpts::default()
        },
        comm_aware: false,
        max_stages: 8,
        kp_policy: KpPolicy::Ours,
        // Baselines pick by their own (approximate) cost model — the
        // paper's PipeDream/Dapple planners have no simulator check.
        sim_select: false,
        policy,
        ..PlannerConfig::default()
    };
    plan_hpp(table, cluster, model, cfg, &pc)
}

/// Dapple's planner emulated: synchronous + comm-aware, but homogeneous
/// and memory-unaware.
pub fn plan_dapple(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    policy: &'static dyn SchedulePolicy,
) -> Result<PlanOutcome> {
    let pc = PlannerConfig {
        alloc: AllocOpts {
            memory_aware: false,
            heterogeneity_aware: false,
            straggler_offload: false,
            ..AllocOpts::default()
        },
        comm_aware: true,
        max_stages: 8,
        kp_policy: KpPolicy::Ours,
        sim_select: false,
        policy,
        ..PlannerConfig::default()
    };
    plan_hpp(table, cluster, model, cfg, &pc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::planner::cost::predicted_throughput;

    #[test]
    fn asteroid_beats_blind_planners_on_heterogeneous_env() {
        // Fig. 13's qualitative claim: on a heterogeneous cluster the
        // heterogeneity-aware planner wins.
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);

        let ours = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        for (name, other) in [
            (
                "pipedream",
                plan_pipedream(&table, &cluster, &model, &cfg, crate::schedule::DEFAULT_POLICY),
            ),
            (
                "dapple",
                plan_dapple(&table, &cluster, &model, &cfg, crate::schedule::DEFAULT_POLICY),
            ),
        ] {
            let other = other.unwrap();
            // Evaluate BOTH plans under the true (heterogeneous) cost
            // model — the baseline planned blind, but physics applies.
            let t_ours = predicted_throughput(&table, &cluster, &model, &ours.plan);
            let t_other = predicted_throughput(&table, &cluster, &model, &other.plan);
            assert!(
                t_ours >= t_other * 0.999,
                "{name}: asteroid {t_ours} < {t_other}"
            );
        }
    }

    #[test]
    fn method_names_stable() {
        assert_eq!(Method::Asteroid.name(), "Asteroid");
        assert_eq!(Method::all_fig13().len(), 5);
    }

    #[test]
    fn method_display_fromstr_roundtrip() {
        for m in Method::ALL {
            let parsed: Method = m.to_string().to_ascii_lowercase().parse().unwrap();
            assert_eq!(parsed, m, "{m}");
        }
        assert!("warp-speed".parse::<Method>().is_err());
        assert_eq!("GPipe".parse::<Method>().unwrap(), Method::GpipePP);
        assert_eq!("DP".parse::<Method>().unwrap(), Method::DataParallel);
    }
}
