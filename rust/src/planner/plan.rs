//! HPP plan representation: stages, device groups, micro-batch
//! allocations and the step sequence (Fig. 4 / Fig. 7 of the paper).

use crate::config::ClusterSpec;
use crate::model::ModelDesc;

/// One pipeline stage: a contiguous slice of layers replicated over a
/// device group with a per-device micro-batch sample allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    /// Layer range [start, end).
    pub layers: (usize, usize),
    /// Device ids of the group G_s.
    pub devices: Vec<usize>,
    /// Micro-batch allocation Y_s: samples per device, parallel to
    /// `devices`, summing to the micro-batch size B.
    pub alloc: Vec<usize>,
    /// 1F1B warm-up depth K_p (number of FPs admitted before strict
    /// one-forward-one-backward).
    pub kp: usize,
}

impl Stage {
    pub fn num_layers(&self) -> usize {
        self.layers.1 - self.layers.0
    }

    pub fn replicas(&self) -> usize {
        self.devices.len()
    }
}

/// A full hybrid-pipeline-parallelism plan.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub stages: Vec<Stage>,
    /// Micro-batch size B.
    pub microbatch: usize,
    /// Micro-batches per HPP-Round, M.
    pub num_micro: usize,
}

impl Plan {
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// All device ids participating in the plan.
    pub fn devices(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.stages.iter().flat_map(|s| s.devices.clone()).collect();
        v.sort_unstable();
        v
    }

    /// Samples processed per HPP-Round (throughput numerator).
    pub fn samples_per_round(&self) -> usize {
        self.microbatch * self.num_micro
    }

    /// Apply the paper's K_p policy `K_p = 2(P - p) - 1` (§3.2), clamped
    /// to [1, M].
    pub fn apply_default_kp(&mut self) {
        let p_total = self.stages.len();
        for (p, s) in self.stages.iter_mut().enumerate() {
            s.kp = kp_policy_ours(p_total, p).min(self.num_micro).max(1);
        }
    }

    /// Validate structural invariants against a model + cluster.
    pub fn validate(&self, model: &ModelDesc, cluster: &ClusterSpec) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.stages.is_empty() {
            bail!("plan has no stages");
        }
        let mut cursor = 0;
        for (i, s) in self.stages.iter().enumerate() {
            if s.layers.0 != cursor {
                bail!("stage {i} starts at layer {} expected {cursor}", s.layers.0);
            }
            if s.layers.1 <= s.layers.0 {
                bail!("stage {i} empty layer range");
            }
            cursor = s.layers.1;
            if s.devices.is_empty() {
                bail!("stage {i} has no devices");
            }
            if s.devices.len() != s.alloc.len() {
                bail!("stage {i}: {} devices but {} allocs", s.devices.len(), s.alloc.len());
            }
            let total: usize = s.alloc.iter().sum();
            if total != self.microbatch {
                bail!("stage {i}: alloc sums to {total}, micro-batch is {}", self.microbatch);
            }
            for &d in &s.devices {
                if d >= cluster.n() {
                    bail!("stage {i}: device {d} out of range");
                }
            }
            if s.kp == 0 {
                bail!("stage {i}: K_p must be >= 1");
            }
        }
        if cursor != model.num_layers() {
            bail!("stages cover {cursor} layers, model has {}", model.num_layers());
        }
        // No device may serve two stages.
        let devs = self.devices();
        for w in devs.windows(2) {
            if w[0] == w[1] {
                bail!("device {} assigned to multiple stages", w[0]);
            }
        }
        Ok(())
    }

    /// Human-readable one-line description in the Fig. 12 style, e.g.
    /// `[X0,X1|L0-4] -> [T3|L4-9]`.
    pub fn describe(&self, cluster: &ClusterSpec) -> String {
        self.stages
            .iter()
            .map(|s| {
                let names: Vec<&str> = s
                    .devices
                    .iter()
                    .map(|&d| cluster.devices[d].name.as_str())
                    .collect();
                format!("[{}|L{}-{}]", names.join(","), s.layers.0, s.layers.1)
            })
            .collect::<Vec<_>>()
            .join(" -> ")
    }
}

/// The paper's K_p selection policy (ours): `K_p = 2(P-p) - 1`.
pub fn kp_policy_ours(p_total: usize, p: usize) -> usize {
    (2 * (p_total - p)).saturating_sub(1).max(1)
}

/// Ablation policies of Fig. 15(b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KpPolicy {
    /// (a) K_p = 2(P-p)
    TwoGapsPlusOne,
    /// (b) K_p = P-p
    Linear,
    /// (c) K_p = 2(P-p)+1
    TwoGapsPlusTwo,
    /// (ours) K_p = 2(P-p)-1
    Ours,
    /// GPipe-style backward-after-forward: K_p = M.
    AllForward,
}

impl KpPolicy {
    pub fn kp(&self, p_total: usize, p: usize, m: usize) -> usize {
        let v = match self {
            KpPolicy::TwoGapsPlusOne => 2 * (p_total - p),
            KpPolicy::Linear => p_total - p,
            KpPolicy::TwoGapsPlusTwo => 2 * (p_total - p) + 1,
            KpPolicy::Ours => kp_policy_ours(p_total, p),
            KpPolicy::AllForward => m,
        };
        v.clamp(1, m.max(1))
    }

    pub fn name(&self) -> &'static str {
        match self {
            KpPolicy::TwoGapsPlusOne => "a: 2(P-p)",
            KpPolicy::Linear => "b: P-p",
            KpPolicy::TwoGapsPlusTwo => "c: 2(P-p)+1",
            KpPolicy::Ours => "ours: 2(P-p)-1",
            KpPolicy::AllForward => "gpipe: M",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;

    fn plan2(model: &ModelDesc) -> Plan {
        let cut = model.num_layers() / 2;
        Plan {
            stages: vec![
                Stage { layers: (0, cut), devices: vec![0, 1], alloc: vec![4, 4], kp: 3 },
                Stage {
                    layers: (cut, model.num_layers()),
                    devices: vec![2],
                    alloc: vec![8],
                    kp: 1,
                },
            ],
            microbatch: 8,
            num_micro: 4,
        }
    }

    #[test]
    fn validates_good_plan() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        plan2(&model).validate(&model, &cluster).unwrap();
    }

    #[test]
    fn rejects_bad_plans() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A", 100.0).unwrap();

        let mut p = plan2(&model);
        p.stages[1].layers.1 -= 1; // incomplete coverage
        assert!(p.validate(&model, &cluster).is_err());

        let mut p = plan2(&model);
        p.stages[0].alloc = vec![4, 3]; // alloc sum mismatch
        assert!(p.validate(&model, &cluster).is_err());

        let mut p = plan2(&model);
        p.stages[1].devices = vec![0]; // device reuse
        assert!(p.validate(&model, &cluster).is_err());

        let mut p = plan2(&model);
        p.stages[1].devices = vec![99]; // unknown device
        p.stages[1].alloc = vec![8];
        assert!(p.validate(&model, &cluster).is_err());

        let mut p = plan2(&model);
        p.stages[0].kp = 0;
        assert!(p.validate(&model, &cluster).is_err());
    }

    #[test]
    fn kp_policy_values() {
        // 3-stage pipeline, M = 8: ours gives 5, 3, 1 (paper Fig. 4: K0=5,
        // K1=3, K2=1).
        assert_eq!(kp_policy_ours(3, 0), 5);
        assert_eq!(kp_policy_ours(3, 1), 3);
        assert_eq!(kp_policy_ours(3, 2), 1);
        assert_eq!(KpPolicy::Ours.kp(3, 0, 8), 5);
        assert_eq!(KpPolicy::TwoGapsPlusOne.kp(3, 0, 8), 6);
        assert_eq!(KpPolicy::Linear.kp(3, 0, 8), 3);
        assert_eq!(KpPolicy::TwoGapsPlusTwo.kp(3, 0, 8), 7);
        assert_eq!(KpPolicy::AllForward.kp(3, 0, 8), 8);
        // clamped to M
        assert_eq!(KpPolicy::TwoGapsPlusTwo.kp(5, 0, 4), 4);
    }

    #[test]
    fn default_kp_applied() {
        let model = zoo::mobilenet_v2();
        let mut p = plan2(&model);
        p.apply_default_kp();
        assert_eq!(p.stages[0].kp, 3); // 2*(2-0)-1 = 3
        assert_eq!(p.stages[1].kp, 1);
    }

    #[test]
    fn describe_readable() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let d = plan2(&model).describe(&cluster);
        assert!(d.contains("->"), "{d}");
        assert!(d.starts_with("[N0,N1|L0-"), "{d}");
    }
}
