//! The dominant-step cost model (Eqs. 4-6, 8, 11).
//!
//! An HPP-Round is abstracted as alternating *execution steps* (stage
//! FP/BP) and *communication steps* (inter-stage activation transfer).
//! Each step s carries its per-micro-batch forward time E_f^s, backward
//! time E_b^s, and AllReduce time T_a^s.  The round latency is governed
//! by the *dominant step* — the step whose Execution Phase is packed
//! with the fewest bubbles — from which every other step's Execution
//! Phase is inferred by shifting (Eq. 6).

use crate::codec::CodecSpec;
use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::plan::{Plan, Stage};
use crate::profiler::ProfileTable;

/// Per-step timing: E_f, E_b for one micro-batch plus AllReduce T_a.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCost {
    pub ef: f64,
    pub eb: f64,
    pub ta: f64,
    /// true for execution steps, false for communication steps.
    pub exec: bool,
}

impl StepCost {
    pub fn fb(&self) -> f64 {
        self.ef + self.eb
    }
}

/// E_f^s / E_b^s of an execution step (Eq. 8): the slowest device in
/// the group under its allocation.
pub fn exec_step_cost(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    stage: &Stage,
) -> StepCost {
    exec_step_cost_codec(table, cluster, model, stage, &CodecSpec::default())
}

/// [`exec_step_cost`] with the Eq. 5 AllReduce term priced on the
/// codec's *wire* bytes (compute times are codec-independent).
pub fn exec_step_cost_codec(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    stage: &Stage,
    codec: &CodecSpec,
) -> StepCost {
    let (i, j) = stage.layers;
    let (ef, eb) = exec_times_parts(table, i, j, &stage.devices, &stage.alloc);
    StepCost { ef, eb, ta: allreduce_time_codec(cluster, model, stage, codec), exec: true }
}

/// Slowest-device E_f/E_b over a device slice and its allocation
/// (Eq. 8's max), without constructing a `Stage`.  The fleet-scale DP
/// calls this directly on arena-owned slices.
pub fn exec_times_parts(
    table: &ProfileTable,
    i: usize,
    j: usize,
    devices: &[usize],
    alloc: &[usize],
) -> (f64, f64) {
    let mut ef: f64 = 0.0;
    let mut eb: f64 = 0.0;
    for (&d, &y) in devices.iter().zip(alloc) {
        ef = ef.max(table.time_fwd(d, i, j, y));
        eb = eb.max(table.time_bwd(d, i, j, y));
    }
    (ef, eb)
}

/// T_a^s (Eq. 5): ring AllReduce of the stage's weights over the
/// group's slowest link.
pub fn allreduce_time(cluster: &ClusterSpec, model: &ModelDesc, stage: &Stage) -> f64 {
    allreduce_time_codec(cluster, model, stage, &CodecSpec::default())
}

/// [`allreduce_time`] over the sync codec's wire bytes (fp32 is the
/// identity, so default-codec pricing is bit-identical to the
/// uncompressed model).
pub fn allreduce_time_codec(
    cluster: &ClusterSpec,
    model: &ModelDesc,
    stage: &Stage,
    codec: &CodecSpec,
) -> f64 {
    let w = codec.wire_sync_bytes(model.weight_bytes_range(stage.layers.0, stage.layers.1));
    let bw = if stage.devices.len() <= 1 {
        f64::INFINITY // unused: the g <= 1 early-out below fires first
    } else {
        cluster.min_bandwidth(&stage.devices)
    };
    allreduce_time_parts(w, stage.devices.len(), bw)
}

/// Eq. 5 from pre-resolved parts: stage weight bytes, group size, and
/// bottleneck intra-group bandwidth.  `allreduce_time` delegates here;
/// the DP calls it directly with prefix-summed weights and a memoized
/// bandwidth oracle so pricing a candidate stage is O(1).
pub fn allreduce_time_parts(weight_bytes: u64, group: usize, min_bw: f64) -> f64 {
    if group <= 1 {
        return 0.0;
    }
    (2 * (group - 1)) as f64 * weight_bytes as f64 / (group as f64 * min_bw)
}

/// E_f^s / E_b^s of the communication step between two adjacent stages:
/// the boundary activation tensor for one micro-batch over the
/// bottleneck inter-group link (gradient transfer is symmetric).
pub fn comm_step_cost(
    cluster: &ClusterSpec,
    model: &ModelDesc,
    from: &Stage,
    to: &Stage,
    microbatch: usize,
) -> StepCost {
    comm_step_cost_codec(cluster, model, from, to, microbatch, &CodecSpec::default())
}

/// [`comm_step_cost`] priced on the *wire* bytes of the codec assigned
/// to the boundary the transfer crosses — the term that lets the DP
/// pick different cut points when a link is cheap to compress.
pub fn comm_step_cost_codec(
    cluster: &ClusterSpec,
    model: &ModelDesc,
    from: &Stage,
    to: &Stage,
    microbatch: usize,
    codec: &CodecSpec,
) -> StepCost {
    let logical = model.boundary_bytes(from.layers.1) * microbatch as u64;
    let bytes = codec.wire_activation_bytes(from.layers.1, logical);
    let bw = cluster.group_bandwidth(&from.devices, &to.devices);
    comm_step_cost_parts(bytes, bw, cluster.latency_s)
}

/// Comm-step cost from pre-resolved parts (total boundary bytes for
/// one micro-batch, bottleneck cross-group bandwidth, link latency).
pub fn comm_step_cost_parts(bytes: u64, bw: f64, latency_s: f64) -> StepCost {
    let t = bytes as f64 / bw + latency_s;
    StepCost { ef: t, eb: t, ta: 0.0, exec: false }
}

/// Build the full step list (2P-1 steps) of a plan.
pub fn plan_steps(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
) -> Vec<StepCost> {
    plan_steps_codec(table, cluster, model, plan, &CodecSpec::default())
}

/// [`plan_steps`] with every byte-carrying term (comm steps, Eq. 5
/// AllReduce) priced on the codec's wire bytes.
pub fn plan_steps_codec(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    codec: &CodecSpec,
) -> Vec<StepCost> {
    let mut steps = Vec::with_capacity(plan.stages.len() * 2 - 1);
    for (p, stage) in plan.stages.iter().enumerate() {
        if p > 0 {
            steps.push(comm_step_cost_codec(
                cluster,
                model,
                &plan.stages[p - 1],
                stage,
                plan.microbatch,
                codec,
            ));
        }
        steps.push(exec_step_cost_codec(table, cluster, model, stage, codec));
    }
    steps
}

/// Index of the dominant step: maximises the aligned total
/// `M*(E_f+E_b) + sum_{i<s}(E_f^i + E_b^i)` (the paper's
/// fewest-bubbles criterion, cf. Eq. 11).
pub fn dominant_step(steps: &[StepCost], m: usize) -> usize {
    let mut best = 0;
    let mut best_val = f64::MIN;
    let mut prefix = 0.0;
    for (s, st) in steps.iter().enumerate() {
        let val = m as f64 * st.fb() + prefix;
        if val > best_val {
            best_val = val;
            best = s;
        }
        prefix += st.fb();
    }
    best
}

/// HPP-Round latency (Eq. 4): max over steps of T_w + T_e + T_a, with
/// T_w from Eq. 5 and T_e inferred from the dominant step via Eq. 6.
pub fn round_latency(steps: &[StepCost], m: usize) -> f64 {
    assert!(!steps.is_empty());
    let dm = dominant_step(steps, m);
    let te_dm = m as f64 * steps[dm].fb();

    let mut latency: f64 = 0.0;
    let mut tw = 0.0; // sum of E_f below s
    let mut shift = 0.0; // running sum of fb() below s
    let shift_dm: f64 = steps[..dm].iter().map(|s| s.fb()).sum();
    for st in steps.iter() {
        // Eq. 6: T_e^s = M*fb(dm) + (sum_{i=s}^{dm-1} fb)   for s < dm
        //               M*fb(dm) - (sum_{i=dm}^{s-1} fb)   for s >= dm
        let te = te_dm + (shift_dm - shift);
        latency = latency.max(tw + te + st.ta);
        tw += st.ef;
        shift += st.fb();
    }
    latency
}

/// Predicted training throughput in samples/second.
pub fn predicted_throughput(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
) -> f64 {
    predicted_throughput_codec(table, cluster, model, plan, &CodecSpec::default())
}

/// [`predicted_throughput`] under a codec spec (wire-byte pricing).
pub fn predicted_throughput_codec(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    codec: &CodecSpec,
) -> f64 {
    let steps = plan_steps_codec(table, cluster, model, plan, codec);
    let latency = round_latency(&steps, plan.num_micro);
    plan.samples_per_round() as f64 / latency
}

/// Per-device peak memory (bytes) under the plan and schedule policy —
/// used for OOM checks and the Fig. 15(b) memory reporting.  The
/// policy matters: fill-drain residency is O(M), not O(K_p).
pub fn plan_peak_memory(
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    policy: &dyn crate::schedule::SchedulePolicy,
) -> Vec<(usize, u64)> {
    use crate::planner::memory::stage_memory_for_policy;
    let mut out = Vec::new();
    for stage in &plan.stages {
        for (&d, &y) in stage.devices.iter().zip(&stage.alloc) {
            let mem = stage_memory_for_policy(
                model,
                cfg,
                stage.layers.0,
                stage.layers.1,
                y,
                stage.kp,
                plan.num_micro,
                policy,
            );
            out.push((d, mem.total()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, TrainConfig};
    use crate::model::zoo;
    use crate::planner::plan::Stage;

    fn fixture() -> (ClusterSpec, crate::model::ModelDesc, ProfileTable) {
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        (cluster, model, table)
    }

    fn mk_plan(model: &crate::model::ModelDesc) -> Plan {
        let nl = model.num_layers();
        let cut = nl / 2;
        let mut plan = Plan {
            stages: vec![
                Stage { layers: (0, cut), devices: vec![0, 1], alloc: vec![4, 4], kp: 1 },
                Stage { layers: (cut, nl), devices: vec![2], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        plan.apply_default_kp();
        plan
    }

    #[test]
    fn step_list_shape() {
        let (cluster, model, table) = fixture();
        let plan = mk_plan(&model);
        let steps = plan_steps(&table, &cluster, &model, &plan);
        assert_eq!(steps.len(), 3); // exec, comm, exec
        assert!(steps[0].exec && !steps[1].exec && steps[2].exec);
        assert!(steps[0].ta > 0.0, "2-device stage AllReduces");
        assert_eq!(steps[2].ta, 0.0, "single-device stage has no AllReduce");
    }

    #[test]
    fn allreduce_volume_matches_eq5() {
        let (cluster, model, _) = fixture();
        let stage = Stage { layers: (0, 10), devices: vec![0, 1, 2], alloc: vec![3, 3, 2], kp: 1 };
        let w = model.weight_bytes_range(0, 10) as f64;
        let bw = cluster.min_bandwidth(&[0, 1, 2]);
        let expect = 2.0 * 2.0 * w / (3.0 * bw);
        assert!((allreduce_time(&cluster, &model, &stage) - expect).abs() < 1e-12);
    }

    #[test]
    fn round_latency_single_stage() {
        // S = 1: latency = M * (E_f + E_b) + T_a.
        let steps = vec![StepCost { ef: 2.0, eb: 3.0, ta: 4.0, exec: true }];
        assert!((round_latency(&steps, 10) - (10.0 * 5.0 + 4.0)).abs() < 1e-12);
    }

    #[test]
    fn dominant_step_is_heaviest_when_uniform_prefix() {
        let steps = vec![
            StepCost { ef: 1.0, eb: 1.0, ta: 0.0, exec: true },
            StepCost { ef: 5.0, eb: 5.0, ta: 0.0, exec: false },
            StepCost { ef: 1.0, eb: 1.0, ta: 0.0, exec: true },
        ];
        assert_eq!(dominant_step(&steps, 4), 1);
    }

    #[test]
    fn round_latency_matches_hand_computation() {
        // Two equal exec steps + tiny comm: dominant = later exec step
        // (prefix breaks the tie toward the later step).
        let e = StepCost { ef: 1.0, eb: 2.0, ta: 0.0, exec: true };
        let c = StepCost { ef: 0.1, eb: 0.1, ta: 0.0, exec: false };
        let steps = vec![e, c, e];
        let m = 4;
        let dm = dominant_step(&steps, m);
        assert_eq!(dm, 2);
        // Step 0 spans the whole round: it starts first and its last BP
        // drains last.  T_e^0 = M*fb(dm) + (fb(0) + fb(1)) = 12 + 3.2;
        // T_w^0 = 0, so the round latency is 15.2.
        let lat = round_latency(&steps, m);
        assert!((lat - 15.2).abs() < 1e-9, "{lat}");
        // Equivalent closed form: M*fb(dm) + sum of fb before dm.
        let alt = m as f64 * steps[2].fb() + steps[0].fb() + steps[1].fb();
        assert!((lat - alt).abs() < 1e-9);
    }

    #[test]
    fn more_microbatches_increase_latency_sublinearly_per_sample() {
        let (cluster, model, table) = fixture();
        let plan = mk_plan(&model);
        let steps = plan_steps(&table, &cluster, &model, &plan);
        let l8 = round_latency(&steps, 8);
        let l16 = round_latency(&steps, 16);
        assert!(l16 > l8);
        // Per-sample cost shrinks with M (pipeline fills up).
        assert!(l16 / 16.0 < l8 / 8.0 + 1e-12);
    }

    #[test]
    fn codec_pricing_shrinks_byte_terms_only() {
        let (cluster, model, table) = fixture();
        let plan = mk_plan(&model);
        let fp = plan_steps(&table, &cluster, &model, &plan);
        let int8 = CodecSpec::uniform(crate::codec::Codec::Int8);
        let cp = plan_steps_codec(&table, &cluster, &model, &plan, &int8);
        // The comm step and the AllReduce term compress; compute times
        // are codec-independent.
        assert!(cp[1].ef < fp[1].ef, "comm step must price wire bytes");
        assert!(cp[0].ta < fp[0].ta, "2-device stage AllReduce must compress");
        assert_eq!(cp[0].ef, fp[0].ef);
        assert_eq!(cp[2].eb, fp[2].eb);
        // The identity spec is bit-identical to the uncompressed model.
        let id = plan_steps_codec(&table, &cluster, &model, &plan, &CodecSpec::default());
        assert_eq!(fp, id);
    }

    #[test]
    fn throughput_positive_and_finite() {
        let (cluster, model, table) = fixture();
        let plan = mk_plan(&model);
        let tp = predicted_throughput(&table, &cluster, &model, &plan);
        assert!(tp.is_finite() && tp > 0.0, "{tp}");
    }

    #[test]
    fn peak_memory_reports_every_device() {
        let (_, model, _) = fixture();
        let cfg = TrainConfig::new(64, 8);
        let plan = mk_plan(&model);
        let peaks = plan_peak_memory(&model, &cfg, &plan, crate::schedule::DEFAULT_POLICY);
        assert_eq!(peaks.len(), 3);
        assert!(peaks.iter().all(|&(_, m)| m > 0));
        // Fill-drain charges its true O(M) residency: strictly more
        // than the K_p-windowed default on every device.
        let gp = plan_peak_memory(&model, &cfg, &plan, &crate::schedule::GpipeFillDrain);
        for (a, b) in peaks.iter().zip(&gp) {
            assert_eq!(a.0, b.0);
            assert!(b.1 > a.1, "device {}: gpipe {} <= 1f1b {}", a.0, b.1, a.1);
        }
    }
}
