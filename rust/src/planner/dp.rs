//! Algorithm 2: dynamic-programming HPP planning (Eqs. 10-11).
//!
//! Q(l, n, p) is the optimal HPP-Round latency when slicing the *last*
//! `l` layers into `p` stages across the *last* `n` devices, devices
//! pre-sorted by memory capacity in descending order (the paper's
//! observation: earlier stages hold more activations, so they get the
//! larger-memory devices).  The recurrence extends an optimal
//! sub-pipeline with one new head stage replicated over the next
//! `n - n'` devices, re-evaluating the dominant step per Eq. (11).
//!
//! # Fleet scale
//!
//! The DP is arena-backed: cells are a flat dense table of
//! `(latency, node)` pairs and stage chains live in a parent-pointer
//! arena, so extending a sub-pipeline is O(1) — no per-candidate
//! `Vec<Stage>`/`Vec<StepCost>` clones — and the winning chains are
//! reconstructed into `Stage`s exactly once at the end.  Candidate
//! stages are screened with a closed-form lower bound on their Eq. 8
//! step cost before the (expensive) intra-stage allocation runs; the
//! bound is provably conservative and the comparison preserves the
//! exact DP's strict-`<` winner, so pruning never changes the emitted
//! plan.  Above [`PlannerConfig::exact_device_split_below`] devices
//! the group-size axis walks a geometric ladder ([`device_rungs`])
//! instead of every count.  Surviving stage prices are memoized in a
//! content-keyed [`StagePricer`] that persists inside [`DpState`],
//! which [`plan_hpp_incremental`] feeds back to replan a one-device
//! removal by reusing unaffected DP cells and prices bit-for-bit (see
//! ARCHITECTURE.md, "Planner at scale").

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::codec::CodecSpec;
use crate::comm::SyncMode;
use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::alloc::{allocate_microbatch, AllocOpts};
use crate::planner::cost::{comm_step_cost_parts, exec_times_parts, round_latency, StepCost};
use crate::planner::memory::stage_memory_for_policy;
use crate::planner::plan::{KpPolicy, Plan, Stage};
use crate::profiler::ProfileTable;
use crate::schedule::{Schedule, SchedulePolicy, DEFAULT_POLICY};

/// Planner behaviour configuration (ablations of Fig. 15(a)).
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    pub alloc: AllocOpts,
    /// Model inter-stage communication and AllReduce in the DP objective
    /// (off = naive planner that only balances compute).
    pub comm_aware: bool,
    pub max_stages: usize,
    pub kp_policy: KpPolicy,
    /// Validate the per-stage-count finalists with the event-accurate
    /// simulator and pick the best observed round latency.  The
    /// dominant-step model (Eq. 4-6) is an approximation ("practically
    /// effective", §3.3) — this final check removes its residual
    /// ranking errors at the cost of <= max_stages simulations.
    pub sim_select: bool,
    /// The round schedule policy this run plans *for*: memory budgets
    /// charge the policy's `effective_kp`, `sim_select` prices each
    /// finalist under it (picking the best (plan, policy) pair rather
    /// than assuming 1F1B), and the outcome's schedule is built with
    /// it.  `Planner::plan` overrides this field with the session's
    /// threaded policy, so `.schedule(..)` is authoritative; set it
    /// directly only when calling `plan_hpp` by hand.
    pub policy: &'static dyn SchedulePolicy,
    /// Clusters with at most this many devices evaluate every group
    /// size 1..=n on the DP's device axis (the exact regime,
    /// bit-identical to the pre-arena planner).  Larger fleets walk
    /// the [`device_rungs`] ladder instead — every count up to 16,
    /// then geometric — trading exhaustive group sizing for planning
    /// time that stays near-linear in fleet size.
    pub exact_device_split_below: usize,
    /// The wire codec the data plane will run under.  Every byte term
    /// in the DP objective — Eq. 5 AllReduce flats, the Eq. 6 boundary
    /// transfer — is priced at its *wire* size under this spec, so the
    /// DP legitimately picks different cut points when a cheaper wire
    /// format shifts the comm/compute balance.  The codec fingerprint
    /// is part of both the stage-price memo key and the DP state
    /// fingerprint, so memoized prices never alias across codecs.
    pub codec: CodecSpec,
    /// The collective topology the data plane will synchronise over.
    /// The Eq. 5 AllReduce term prices it: `Ring` transfers
    /// `2(g-1)/g * W` over the group's slowest link (the paper's
    /// formula), `DriverStar` pays the full `2W` per worker through
    /// the driver.  Like the codec, the mode is part of the stage-price
    /// memo key and the DP state fingerprint.
    pub sync: SyncMode,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            alloc: AllocOpts::default(),
            comm_aware: true,
            max_stages: 8,
            kp_policy: KpPolicy::Ours,
            sim_select: true,
            policy: DEFAULT_POLICY,
            exact_device_split_below: 32,
            codec: CodecSpec::default(),
            sync: SyncMode::default(),
        }
    }
}

/// Result of a planning run.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub plan: Plan,
    /// The chosen plan's explicit HPP-Round schedule (the run's policy,
    /// sample-sharded) — downstream layers consume this instead of
    /// re-deriving the op ordering from the plan.
    pub schedule: Schedule,
    /// The schedule policy the run planned for (carried so downstream
    /// layers never fall back to a hardcoded default).
    pub policy: &'static dyn SchedulePolicy,
    /// Predicted HPP-Round latency (seconds) from the *analytic*
    /// Eq. 4-6 dominant-step model.  Deliberately policy-blind: the
    /// paper's cost model assumes 1F1B-style overlap, and this field
    /// is kept as the analytic cross-check it always was.  The
    /// authoritative per-policy number is the event-accurate sim price
    /// (`schedule` through `sim::price`, what `sim_select`
    /// ranks and `RunReport::throughput` reports).
    pub predicted_latency: f64,
    /// Predicted throughput (samples/s) from the same analytic model
    /// (see `predicted_latency` for the policy-blindness caveat).
    pub predicted_throughput: f64,
    /// Wall-clock planning time (Table 7).
    pub planning_time_s: f64,
}

/// K_p as a function of the stage's distance-from-end q (q = 1 for the
/// last stage).  Within the DP only the suffix position is known; for
/// the paper's policy K_p = 2(P-p)-1 = 2q-1.
fn kp_from_end(policy: KpPolicy, q: usize, m: usize) -> usize {
    let v = match policy {
        KpPolicy::TwoGapsPlusOne => 2 * q,
        KpPolicy::Linear => q,
        KpPolicy::TwoGapsPlusTwo => 2 * q + 1,
        KpPolicy::Ours => 2 * q - 1,
        KpPolicy::AllForward => m,
    };
    v.clamp(1, m.max(1))
}

/// Memory-descending planning order over a device subset (the paper's
/// rule: earlier stages hold more activations, so they get the
/// larger-memory devices).  The tie-break is **total**: memory
/// descending, then peak FLOPS descending, then device id ascending —
/// equal devices therefore sort identically in every run and in every
/// subset, and removing one device never reorders the survivors.  The
/// incremental replan's cell-reuse equivalence proof relies on exactly
/// that stability.
pub fn sorted_device_order(cluster: &ClusterSpec, subset: &[usize]) -> Vec<usize> {
    let mut order: Vec<usize> = subset.to_vec();
    order.sort_by(|&a, &b| {
        let da = &cluster.devices[a];
        let db = &cluster.devices[b];
        db.mem_bytes
            .cmp(&da.mem_bytes)
            .then(db.peak_flops.partial_cmp(&da.peak_flops).unwrap())
            .then(a.cmp(&b))
    });
    order
}

/// The group-size ladder the DP walks on its device axis.  At or below
/// `exact_below` devices it is every count `1..=n` — the exact regime.
/// Above, it is every count up to 16, then a geometric (x1.25) ladder,
/// plus `n` itself.  Rung values below `n` come from a fixed,
/// fleet-size-independent set, so any sub-pipeline's candidate space
/// is identical across fleets sharing a device suffix — the property
/// the incremental replan's cell reuse needs.
pub fn device_rungs(n_total: usize, exact_below: usize) -> Vec<usize> {
    if n_total <= exact_below {
        return (1..=n_total).collect();
    }
    let mut rungs: Vec<usize> = (1..=16.min(n_total)).collect();
    let mut r = 20usize;
    while r < n_total {
        rungs.push(r);
        r = (r * 5) / 4;
    }
    rungs.push(n_total);
    rungs.sort_unstable();
    rungs.dedup();
    rungs
}

/// Content-addressed key of one priced stage candidate: layer range,
/// warm-up depth, micro-batch geometry, and the exact device-id group.
/// Keyed on device *ids* (not positions in the sorted order), so
/// entries stay valid across replans that remove devices and shift
/// every position.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct StageKey {
    i: u32,
    j: u32,
    kp: u32,
    b: u32,
    m: u32,
    /// Wire-codec fingerprint: the memoized T_a term prices compressed
    /// flats, so entries for different codecs must never alias.
    codec_fp: u64,
    /// Collective-topology tag: the memoized T_a term prices the sync
    /// mode's formula, so ring and driver-star entries must not alias.
    sync_tag: u8,
    devs: Box<[u32]>,
}

/// A memoized stage price: the Eq. 8 execution step cost (with Eq. 5
/// AllReduce) plus the peak Eq. 3 memory across the group under the
/// allocation `allocate_microbatch` chose.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PricedStage {
    pub cost: StepCost,
    pub peak_mem_bytes: u64,
}

/// Memoized stage pricer shared across DP candidates, the per-p
/// finalists, micro-batch sweep candidates (b and M are part of the
/// key), and incremental replans (device-id keys survive removal).
/// Only allocation-surviving candidates are stored — the lower-bound
/// screen keeps the table small — and a `None` value records that the
/// group OOMs, so infeasibility is memoized too.  A pricer is only
/// valid for one (model, cluster, policy, planner-flag) context;
/// [`DpState`] carries a fingerprint and cross-state reuse checks it.
#[derive(Debug, Clone, Default)]
pub struct StagePricer {
    memo: HashMap<StageKey, Option<PricedStage>>,
    /// sim_select pricing cache, threaded to `sim::price`.
    pub(crate) sim: crate::sim::PriceCache,
    hits: u64,
    misses: u64,
}

impl StagePricer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Distinct stage candidates priced (memo size).
    pub fn entries(&self) -> usize {
        self.memo.len()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Price one stage through the memo, resolving T_a (Eq. 5) from
    /// the cluster.  Returns the same `StepCost` as the un-memoized
    /// `allocate_microbatch` + `exec_step_cost` path, bit-for-bit —
    /// `tests/fleet_planning.rs` holds it to that; `None` means the
    /// group cannot fit the micro-batch.
    #[allow(clippy::too_many_arguments)]
    pub fn stage_cost(
        &mut self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        pc: &PlannerConfig,
        i: usize,
        j: usize,
        devices: &[usize],
        kp: usize,
    ) -> Option<StepCost> {
        let ta_raw = if devices.len() <= 1 {
            0.0
        } else {
            pc.sync.allreduce_time(
                pc.codec.wire_sync_bytes(model.weight_bytes_range(i, j)),
                devices.len(),
                cluster.min_bandwidth(devices),
            )
        };
        self.price(table, cluster, model, cfg, pc, i, j, devices, kp, ta_raw, None)
            .map(|p| p.cost)
    }

    /// Memo lookup (own table, then a compatible previous state's),
    /// falling back to a fresh allocation + pricing.  `ta_raw` is the
    /// Eq. 5 AllReduce time before `comm_aware` zeroing.
    #[allow(clippy::too_many_arguments)]
    fn price(
        &mut self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        pc: &PlannerConfig,
        i: usize,
        j: usize,
        devices: &[usize],
        kp: usize,
        ta_raw: f64,
        prev: Option<&StagePricer>,
    ) -> Option<PricedStage> {
        let key = StageKey {
            i: i as u32,
            j: j as u32,
            kp: kp as u32,
            b: cfg.microbatch as u32,
            m: cfg.num_microbatches() as u32,
            codec_fp: pc.codec.fingerprint(),
            sync_tag: pc.sync.tag(),
            devs: devices.iter().map(|&d| d as u32).collect(),
        };
        if let Some(hit) = self.memo.get(&key) {
            self.hits += 1;
            return *hit;
        }
        if let Some(p) = prev {
            if let Some(hit) = p.memo.get(&key) {
                self.hits += 1;
                self.memo.insert(key, *hit);
                return *hit;
            }
        }
        self.misses += 1;
        let priced = Self::compute(table, cluster, model, cfg, pc, i, j, devices, kp, ta_raw);
        self.memo.insert(key, priced);
        priced
    }

    #[allow(clippy::too_many_arguments)]
    fn compute(
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        pc: &PlannerConfig,
        i: usize,
        j: usize,
        devices: &[usize],
        kp: usize,
        ta_raw: f64,
    ) -> Option<PricedStage> {
        let m = cfg.num_microbatches();
        let b = cfg.microbatch;
        // Memory budgets charge the policy's true in-flight residency
        // (e.g. the whole round for fill-drain), not the raw warm-up —
        // plus the weight-version stash copies of a bounded-staleness
        // policy (Eq. 3's fourth term).
        let eff_kp = pc.policy.effective_kp(kp, m);
        let opts = AllocOpts { stash_copies: pc.policy.weight_stash_copies(kp, m), ..pc.alloc };
        let alloc =
            allocate_microbatch(table, cluster, model, cfg, i, j, devices, b, eff_kp, opts).ok()?;
        let (ef, eb) = exec_times_parts(table, i, j, devices, &alloc);
        let ta = if pc.comm_aware { ta_raw } else { 0.0 };
        let peak_mem_bytes = alloc
            .iter()
            .map(|&y| stage_memory_for_policy(model, cfg, i, j, y, kp, m, pc.policy).total())
            .max()
            .unwrap_or(0);
        Some(PricedStage { cost: StepCost { ef, eb, ta, exec: true }, peak_mem_bytes })
    }
}

/// Arena sentinel: "no node" / infeasible cell.
const NO_NODE: u32 = u32::MAX;

const ZERO_COMM: StepCost = StepCost { ef: 0.0, eb: 0.0, ta: 0.0, exec: false };

/// One stage in the parent-pointer arena.  `parent` points at the next
/// stage toward the pipeline tail (`NO_NODE` for the tail stage);
/// `comm` is the communication step between this stage and its parent.
/// `ds..de` are *positions in the sorted order*, not device ids.
#[derive(Debug, Clone, Copy)]
struct Node {
    i: u32,
    j: u32,
    ds: u32,
    de: u32,
    kp: u32,
    parent: u32,
    exec: StepCost,
    comm: StepCost,
}

/// One dense DP cell: best round latency + arena index of its head
/// stage (`NO_NODE` = infeasible / not computed).
#[derive(Debug, Clone, Copy)]
struct Cell {
    latency: f64,
    node: u32,
}

const EMPTY_CELL: Cell = Cell { latency: f64::INFINITY, node: NO_NODE };

/// Everything that must match before a previous [`DpState`]'s memo or
/// cells may be reused.  `b`/`m` live in the memo keys, so the pricer
/// is reusable across a micro-batch sweep (`memo_compatible`); cell
/// reuse additionally requires exact equality.
#[derive(Debug, Clone, Copy, PartialEq)]
struct StateFp {
    model_hash: u64,
    cluster_hash: u64,
    policy: &'static str,
    comm_aware: bool,
    max_stages: usize,
    kp_policy: KpPolicy,
    memory_aware: bool,
    heterogeneity_aware: bool,
    straggler_offload: bool,
    exact_below: usize,
    opt_mem_bits: u64,
    codec_fp: u64,
    sync: SyncMode,
    b: usize,
    m: usize,
}

impl StateFp {
    fn memo_compatible(&self, other: &StateFp) -> bool {
        StateFp { b: 0, m: 0, ..*self } == StateFp { b: 0, m: 0, ..*other }
    }
}

fn fnv1a(h: &mut u64, x: u64) {
    *h ^= x;
    *h = h.wrapping_mul(0x0100_0000_01b3);
}

fn cluster_hash(cluster: &ClusterSpec) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325_u64;
    fnv1a(&mut h, cluster.n() as u64);
    for d in &cluster.devices {
        fnv1a(&mut h, d.mem_bytes);
        fnv1a(&mut h, d.peak_flops.to_bits());
        fnv1a(&mut h, d.work_half.to_bits());
        fnv1a(&mut h, d.overhead_s.to_bits());
    }
    for row in &cluster.bandwidth {
        for &x in row {
            fnv1a(&mut h, x.to_bits());
        }
    }
    fnv1a(&mut h, cluster.latency_s.to_bits());
    h
}

fn model_hash(model: &ModelDesc) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325_u64;
    for c in model.name.bytes() {
        fnv1a(&mut h, c as u64);
    }
    fnv1a(&mut h, model.num_layers() as u64);
    for l in &model.layers {
        fnv1a(&mut h, l.flops_fwd.to_bits());
        fnv1a(&mut h, l.flops_bwd.to_bits());
        fnv1a(&mut h, l.weight_bytes);
        fnv1a(&mut h, l.out_bytes);
    }
    h
}

fn state_fp(
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
) -> StateFp {
    StateFp {
        model_hash: model_hash(model),
        cluster_hash: cluster_hash(cluster),
        policy: pc.policy.name(),
        comm_aware: pc.comm_aware,
        max_stages: pc.max_stages,
        kp_policy: pc.kp_policy,
        memory_aware: pc.alloc.memory_aware,
        heterogeneity_aware: pc.alloc.heterogeneity_aware,
        straggler_offload: pc.alloc.straggler_offload,
        exact_below: pc.exact_device_split_below,
        opt_mem_bits: cfg.optimizer_mem_factor.to_bits(),
        codec_fp: pc.codec.fingerprint(),
        sync: pc.sync,
        b: cfg.microbatch,
        m: cfg.num_microbatches(),
    }
}

/// Self-contained state of one planning run over a device subset: the
/// sorted order, the rung ladder, the dense DP table, the stage-chain
/// arena, and the stage pricer.  Feed it back through
/// [`plan_hpp_incremental`] after a single device removal: DP cells
/// whose device suffix is untouched are copied instead of recomputed,
/// and surviving stage prices hit the memo.  States chain — the state
/// an incremental replan returns is itself a valid `prev` for the next
/// removal.
#[derive(Debug, Clone)]
pub struct DpState {
    order: Vec<usize>,
    rungs: Vec<usize>,
    cells: Vec<Cell>,
    arena: Vec<Node>,
    pricer: StagePricer,
    fp: StateFp,
    l_total: usize,
    max_p: usize,
}

impl DpState {
    /// Devices in planning order (memory-descending; see
    /// [`sorted_device_order`]).
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    /// The group-size ladder this state was computed over.
    pub fn rungs(&self) -> &[usize] {
        &self.rungs
    }

    /// Distinct stage candidates in the pricer memo.
    pub fn memo_entries(&self) -> usize {
        self.pricer.entries()
    }

    /// Nodes in the stage-chain arena.
    pub fn arena_nodes(&self) -> usize {
        self.arena.len()
    }

    fn cell(&self, l: usize, ri: usize, p: usize) -> Cell {
        self.cells[((p - 1) * (self.l_total + 1) + l) * self.rungs.len() + ri]
    }
}

/// Per-run bandwidth oracle.  `min_bandwidth`/`group_bandwidth` are
/// O(g^2) pairwise scans — ruinous inside the DP's candidate loop at
/// fleet scale — but every synthetic fleet (and most real deployments)
/// has a uniform link bandwidth, detected here once with one O(n^2)
/// scan and answered in O(1) thereafter: the min over any set of equal
/// off-diagonal entries is that entry, bit-for-bit.  Non-uniform
/// clusters fall back to the exact pairwise scan, memoized per
/// contiguous run of the sorted order.
struct BwOracle<'a> {
    cluster: &'a ClusterSpec,
    order: &'a [usize],
    uniform: Option<f64>,
    run_min: HashMap<(u32, u32), f64>,
    cross: HashMap<(u32, u32, u32), f64>,
}

impl<'a> BwOracle<'a> {
    fn new(cluster: &'a ClusterSpec, order: &'a [usize]) -> Self {
        let n = cluster.n();
        let mut first: Option<f64> = None;
        let mut uniform = true;
        'scan: for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let x = cluster.bandwidth[i][j];
                match first {
                    None => first = Some(x),
                    Some(f) if x == f => {}
                    Some(_) => {
                        uniform = false;
                        break 'scan;
                    }
                }
            }
        }
        BwOracle {
            cluster,
            order,
            uniform: if uniform { first } else { None },
            run_min: HashMap::new(),
            cross: HashMap::new(),
        }
    }

    /// Bottleneck intra-group bandwidth of `order[a..b)`.  Callers
    /// only query groups of >= 2 devices (Eq. 5 early-outs for g <= 1).
    fn run_min(&mut self, a: usize, b: usize) -> f64 {
        if let Some(x) = self.uniform {
            return x;
        }
        let (cluster, order) = (self.cluster, self.order);
        *self
            .run_min
            .entry((a as u32, b as u32))
            .or_insert_with(|| cluster.min_bandwidth(&order[a..b]))
    }

    /// Bottleneck bandwidth between the adjacent runs `order[a..b)`
    /// and `order[b..c)`.
    fn cross(&mut self, a: usize, b: usize, c: usize) -> f64 {
        if let Some(x) = self.uniform {
            return x;
        }
        let (cluster, order) = (self.cluster, self.order);
        *self
            .cross
            .entry((a as u32, b as u32, c as u32))
            .or_insert_with(|| cluster.group_bandwidth(&order[a..b], &order[b..c]))
    }
}

/// If `new` equals `old` with exactly one element removed, return the
/// removed position in `old`.
fn removal_position(old: &[usize], new: &[usize]) -> Option<usize> {
    if old.len() != new.len() + 1 {
        return None;
    }
    let k = old.iter().zip(new.iter()).position(|(a, b)| a != b).unwrap_or(new.len());
    (old[..k] == new[..k] && old[k + 1..] == new[k..]).then_some(k)
}

/// If `new` equals `old` with exactly one element inserted, return the
/// inserted position in `new` — the mirror of [`removal_position`] for
/// the join fast path.
fn insertion_position(old: &[usize], new: &[usize]) -> Option<usize> {
    if new.len() != old.len() + 1 {
        return None;
    }
    let k = old.iter().zip(new.iter()).position(|(a, b)| a != b).unwrap_or(old.len());
    (old[..k] == new[..k] && old[k..] == new[k + 1..]).then_some(k)
}

/// Append a chain's steps `[exec, comm, exec, comm, ...]` to `out`,
/// head to tail — the same step list the recurrence used to assemble
/// as a fresh `Vec` per candidate.
fn push_chain(arena: &[Node], mut node: u32, out: &mut Vec<StepCost>) {
    while node != NO_NODE {
        let nd = &arena[node as usize];
        out.push(nd.exec);
        if nd.parent != NO_NODE {
            out.push(nd.comm);
        }
        node = nd.parent;
    }
}

/// Copy a chain from a previous state's arena into `arena`, shifting
/// every position by `shift`: −1 for a removal (the removed device
/// sorts strictly before every position a reused chain touches), +1
/// for an insertion (the joined device sorts strictly before them).
/// `map` dedups shared sub-chains across cells.
fn copy_chain(
    prev: &DpState,
    root: u32,
    shift: i32,
    arena: &mut Vec<Node>,
    map: &mut HashMap<u32, u32>,
) -> u32 {
    let mut stack = Vec::new();
    let mut cur = root;
    while cur != NO_NODE && !map.contains_key(&cur) {
        stack.push(cur);
        cur = prev.arena[cur as usize].parent;
    }
    while let Some(old) = stack.pop() {
        let nd = prev.arena[old as usize];
        let parent = if nd.parent == NO_NODE { NO_NODE } else { map[&nd.parent] };
        let ds = (nd.ds as i32 + shift) as u32;
        let de = (nd.de as i32 + shift) as u32;
        arena.push(Node { ds, de, parent, ..nd });
        map.insert(old, (arena.len() - 1) as u32);
    }
    map[&root]
}

/// Walk a winning chain head-to-tail and materialise it as `Stage`s,
/// re-running the (deterministic) intra-stage allocation for each —
/// once per final plan, not once per DP candidate.
#[allow(clippy::too_many_arguments)]
fn reconstruct_plan(
    arena: &[Node],
    order: &[usize],
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
    head: u32,
) -> Result<Plan> {
    let m = cfg.num_microbatches();
    let b = cfg.microbatch;
    let mut stages = Vec::new();
    let mut cur = head;
    while cur != NO_NODE {
        let nd = arena[cur as usize];
        let (i, j) = (nd.i as usize, nd.j as usize);
        let devices: Vec<usize> = order[nd.ds as usize..nd.de as usize].to_vec();
        let kp = nd.kp as usize;
        let eff_kp = pc.policy.effective_kp(kp, m);
        let opts = AllocOpts { stash_copies: pc.policy.weight_stash_copies(kp, m), ..pc.alloc };
        let alloc = allocate_microbatch(table, cluster, model, cfg, i, j, &devices, b, eff_kp, opts)
            .map_err(|e| anyhow::anyhow!("reconstructing a priced stage failed: {e}"))?;
        stages.push(Stage { layers: (i, j), devices, alloc, kp });
        cur = nd.parent;
    }
    Ok(Plan { stages, microbatch: b, num_micro: m })
}

/// Conservative slack on the closed-form stage lower bounds: the bound
/// is mathematically <= the true Eq. 8 cost, but its floating-point
/// evaluation differs from the priced path's, so shave a relative
/// epsilon to make "lb >= incumbent ⇒ candidate loses" robust to
/// rounding.  Costs a handful of extra allocations, never a changed
/// plan.
const LB_SLACK: f64 = 1.0 - 1e-9;

/// The shared core behind [`plan_hpp`], [`plan_hpp_with_state`],
/// [`plan_hpp_subset`] and [`plan_hpp_incremental`]: Algorithm 2 over
/// `subset` (default: the whole cluster) in *original device-id
/// space*, optionally reusing a previous run's DP cells and stage
/// prices.
#[allow(clippy::too_many_arguments)]
fn plan_hpp_core(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
    subset: Option<&[usize]>,
    prev: Option<&DpState>,
) -> Result<(PlanOutcome, DpState)> {
    let t0 = Instant::now();
    let l_total = model.num_layers();
    let m = cfg.num_microbatches();
    let b = cfg.microbatch;

    let devices: Vec<usize> = match subset {
        Some(s) => s.to_vec(),
        None => (0..cluster.n()).collect(),
    };
    if devices.is_empty() {
        bail!("no devices to plan over");
    }
    let order = sorted_device_order(cluster, &devices);
    let n_total = order.len();
    let max_p = pc.max_stages.min(n_total).max(1);
    let rungs = device_rungs(n_total, pc.exact_device_split_below);
    let n_rungs = rungs.len();

    let fp = state_fp(cluster, model, cfg, pc);
    // Memo reuse needs everything but (b, m) to match — those are in
    // the memo keys.  Cell reuse needs exact config equality AND the
    // new order to differ from the previous order by exactly one
    // device: a removal shifts surviving suffix positions down by one,
    // an insertion shifts them up by one.
    let prev_memo = prev.filter(|p| p.fp.memo_compatible(&fp)).map(|p| &p.pricer);
    let delta = prev.filter(|p| p.fp == fp).and_then(|p| {
        removal_position(&p.order, &order)
            .map(|k| (p, k, -1i32))
            .or_else(|| insertion_position(&p.order, &order).map(|k| (p, k, 1i32)))
    });

    let mut pricer = StagePricer::new();
    let mut arena: Vec<Node> = Vec::new();
    let mut cells = vec![EMPTY_CELL; (l_total + 1) * n_rungs * max_p];
    let cell_idx =
        move |l: usize, ri: usize, p: usize| ((p - 1) * (l_total + 1) + l) * n_rungs + ri;
    let mut reused = vec![false; n_rungs];

    // ---- incremental fast path: copy unaffected cells -----------------
    // A rung n' is reusable iff its device suffix (the last n' of the
    // new order) predates the delta — for a removal at old-order
    // position k that means every suffix position sorted strictly
    // after the removed one (`n' <= n_total - k`); for an insertion at
    // new-order position k the suffix must exclude position k
    // (`n' <= n_total - k - 1`) — and the rung ladder below n' is
    // unchanged, so the fresh run would evaluate exactly the same
    // candidate set in exactly the same sequence.  Copied cells are
    // then bit-identical to recomputation (`tests/fleet_planning.rs`
    // proves it per plan, in both directions).  An insertion can grow
    // `max_p` past the previous state's; the cells that go uncopied
    // there need `p > old n_total >= n'`, i.e. more stages than the
    // rung has devices — infeasible for the fresh run too, so no hole.
    if let Some((pstate, k, shift)) = delta {
        let limit = if shift < 0 { n_total - k } else { (n_total - k).saturating_sub(1) };
        let mut node_map: HashMap<u32, u32> = HashMap::new();
        for (ri, &n) in rungs.iter().enumerate() {
            if n > limit {
                continue;
            }
            let Ok(pri) = pstate.rungs.binary_search(&n) else { continue };
            if pstate.rungs[..pri] != rungs[..ri] {
                continue;
            }
            for p in 1..=max_p.min(pstate.max_p) {
                for l in 0..=l_total {
                    let c = pstate.cell(l, pri, p);
                    if c.node == NO_NODE {
                        continue;
                    }
                    let node = copy_chain(pstate, c.node, shift, &mut arena, &mut node_map);
                    cells[cell_idx(l, ri, p)] = Cell { latency: c.latency, node };
                }
            }
            reused[ri] = true;
        }
    }

    // ---- per-run precomputation ---------------------------------------
    let mut bw = BwOracle::new(cluster, &order);
    // Stage-weight prefix sums: w(i, j) in O(1) for Eq. 5.
    let mut wts = vec![0u64; l_total + 1];
    for l in 0..l_total {
        wts[l + 1] = wts[l] + model.weight_bytes_range(l, l + 1);
    }
    // Per-position device constants for the stage lower bounds: with
    // u_d = overhead + work_half/peak, the profiler's affine model is
    //   ef = layers*u_d + Ff*y_d/peak_d      (y_d >= 1)
    //   eb = 2*layers*u_d + Fb*y_d/peak_d,
    // so over any allocation summing to b on group [ds, de):
    //   ef >= Ff*b / sum(peak)                      (throughput bound)
    //   ef >= layers*min(u) + Ff*ceil(b/g)/max(peak) (pigeonhole bound)
    // and both with eb's factor-2 constant term.  `round_latency` is
    // monotone in the head step's (ef, eb), so a head lower bound gives
    // a round-latency lower bound.
    let u: Vec<f64> = order
        .iter()
        .map(|&d| {
            let dev = &cluster.devices[d];
            dev.overhead_s + dev.work_half / dev.peak_flops
        })
        .collect();
    let peak: Vec<f64> = order.iter().map(|&d| cluster.devices[d].peak_flops).collect();
    let mut peak_prefix = vec![0.0f64; n_total + 1];
    for k in 0..n_total {
        peak_prefix[k + 1] = peak_prefix[k] + peak[k];
    }
    // run_aux[ri][g-1] = (min u, max peak) over order[ds, ds+g) where
    // ds = n_total - rungs[ri].
    let run_aux: Vec<Vec<(f64, f64)>> = rungs
        .iter()
        .map(|&n| {
            let ds = n_total - n;
            let mut v = Vec::with_capacity(n_total - ds);
            let (mut mu, mut mp) = (f64::INFINITY, 0.0f64);
            for k in ds..n_total {
                mu = mu.min(u[k]);
                mp = mp.max(peak[k]);
                v.push((mu, mp));
            }
            v
        })
        .collect();

    // ---- base case p = 1 ----------------------------------------------
    // The last l layers as a single (final) stage on the last n devices.
    let kp1 = kp_from_end(pc.kp_policy, 1, m);
    for (ri, &n) in rungs.iter().enumerate() {
        if reused[ri] {
            continue;
        }
        let ds = n_total - n;
        for l in 1..=l_total {
            let i = l_total - l;
            let ta_raw = if n > 1 {
                pc.sync.allreduce_time(
                    pc.codec.wire_sync_bytes(wts[l_total] - wts[i]),
                    n,
                    bw.run_min(ds, n_total),
                )
            } else {
                0.0
            };
            let Some(pr) = pricer.price(
                table,
                cluster,
                model,
                cfg,
                pc,
                i,
                l_total,
                &order[ds..n_total],
                kp1,
                ta_raw,
                prev_memo,
            ) else {
                continue;
            };
            arena.push(Node {
                i: i as u32,
                j: l_total as u32,
                ds: ds as u32,
                de: n_total as u32,
                kp: kp1 as u32,
                parent: NO_NODE,
                exec: pr.cost,
                comm: ZERO_COMM,
            });
            let latency = round_latency(&[pr.cost], m);
            cells[cell_idx(l, ri, 1)] = Cell { latency, node: (arena.len() - 1) as u32 };
        }
    }

    // ---- recurrence (Eq. 10) ------------------------------------------
    // Extend sub-pipelines with a new head stage: layers [L-l, L-lp) on
    // positions [N-n, N-np).  Candidates are screened with the
    // closed-form head lower bound before allocation; the incumbent
    // comparison stays strict-`<` keep-first, so the pruned DP selects
    // exactly the plans the exhaustive one did.
    let mut scratch: Vec<StepCost> = Vec::with_capacity(2 * max_p);
    for p in 2..=max_p {
        let kp = kp_from_end(pc.kp_policy, p, m);
        for l in p..=l_total {
            for (ri, &n) in rungs.iter().enumerate() {
                if n < p || reused[ri] {
                    continue;
                }
                let ds = n_total - n;
                let mut best_lat = f64::INFINITY;
                let mut best: Option<(u32, u32, u32, StepCost, StepCost, u32)> = None;
                for lp in (p - 1)..l {
                    let i = l_total - l;
                    let j = l_total - lp;
                    let ff = table.flops_fwd_range(i, j);
                    let fbk = table.flops_bwd_range(i, j);
                    let w = pc.codec.wire_sync_bytes(wts[j] - wts[i]);
                    let boundary =
                        pc.codec.wire_activation_bytes(j, model.boundary_bytes(j) * b as u64);
                    let lc = (j - i) as f64;
                    for (rpi, &np) in rungs.iter().enumerate() {
                        if np >= n {
                            break;
                        }
                        if np < p - 1 {
                            continue;
                        }
                        let sub = cells[cell_idx(lp, rpi, p - 1)];
                        if sub.node == NO_NODE {
                            continue;
                        }
                        let de = n_total - np;
                        let g = n - np;
                        let ta_raw = if g > 1 {
                            pc.sync.allreduce_time(w, g, bw.run_min(ds, de))
                        } else {
                            0.0
                        };
                        let ta = if pc.comm_aware { ta_raw } else { 0.0 };
                        let comm = if pc.comm_aware {
                            let sub_head_de = arena[sub.node as usize].de as usize;
                            comm_step_cost_parts(
                                boundary,
                                bw.cross(ds, de, sub_head_de),
                                cluster.latency_s,
                            )
                        } else {
                            ZERO_COMM
                        };
                        // O(1) head lower bound; skip allocation when
                        // even the bound cannot beat the incumbent.
                        if best.is_some() {
                            let (min_u, max_peak) = run_aux[ri][g - 1];
                            let sum_peak = peak_prefix[de] - peak_prefix[ds];
                            let q = ((b + g - 1) / g) as f64;
                            let bf = b as f64;
                            let lb_ef =
                                (ff * bf / sum_peak).max(lc * min_u + ff * q / max_peak) * LB_SLACK;
                            let lb_eb = (fbk * bf / sum_peak)
                                .max(2.0 * lc * min_u + fbk * q / max_peak)
                                * LB_SLACK;
                            scratch.clear();
                            scratch.push(StepCost { ef: lb_ef, eb: lb_eb, ta, exec: true });
                            scratch.push(comm);
                            push_chain(&arena, sub.node, &mut scratch);
                            if round_latency(&scratch, m) >= best_lat {
                                continue;
                            }
                        }
                        let Some(pr) = pricer.price(
                            table,
                            cluster,
                            model,
                            cfg,
                            pc,
                            i,
                            j,
                            &order[ds..de],
                            kp,
                            ta_raw,
                            prev_memo,
                        ) else {
                            continue;
                        };
                        scratch.clear();
                        scratch.push(pr.cost);
                        scratch.push(comm);
                        push_chain(&arena, sub.node, &mut scratch);
                        let latency = round_latency(&scratch, m);
                        if latency < best_lat {
                            best_lat = latency;
                            best = Some((i as u32, j as u32, de as u32, pr.cost, comm, sub.node));
                        }
                    }
                }
                if let Some((i, j, de, exec, comm, sub_node)) = best {
                    arena.push(Node {
                        i,
                        j,
                        ds: ds as u32,
                        de,
                        kp: kp as u32,
                        parent: sub_node,
                        exec,
                        comm,
                    });
                    cells[cell_idx(l, ri, p)] =
                        Cell { latency: best_lat, node: (arena.len() - 1) as u32 };
                }
            }
        }
    }

    // ---- finalists + selection ----------------------------------------
    let top_ri = n_rungs - 1;
    debug_assert_eq!(rungs[top_ri], n_total);
    let mut finalists: Vec<(f64, u32)> = Vec::new();
    for p in 1..=max_p {
        let c = cells[cell_idx(l_total, top_ri, p)];
        if c.node != NO_NODE {
            finalists.push((c.latency, c.node));
        }
    }
    if finalists.is_empty() {
        bail!(
            "no feasible HPP plan: model {} does not fit on cluster {} \
             with micro-batch {b}",
            model.name,
            cluster.describe()
        );
    }
    let mut scored: Vec<(f64, Plan)> = Vec::with_capacity(finalists.len());
    for &(lat, node) in &finalists {
        scored
            .push((lat, reconstruct_plan(&arena, &order, table, cluster, model, cfg, pc, node)?));
    }
    // Price each finalist under the run's policy with the
    // event-accurate executor: sim_select ranks (plan, policy) pairs,
    // so a zero-bubble or fill-drain run picks the stage split that is
    // best *under that ordering*, not under an assumed 1F1B.  Prices
    // go through the pricer's sim cache, so replans re-pricing an
    // unchanged finalist hit instead of re-simulating.  Both branches
    // keep the *last* of equal minima, like `Iterator::min_by` did.
    let best_idx = if pc.sim_select && scored.len() > 1 {
        let mut bi = 0usize;
        let mut bl = f64::INFINITY;
        for (idx, (_, plan)) in scored.iter().enumerate() {
            let req = crate::sim::PriceRequest::new(table, cluster, model, plan)
                .policy(pc.policy)
                .codec(pc.codec)
                .sync(pc.sync);
            let lat = pricer.sim.price(&req).round_latency;
            if lat <= bl {
                bl = lat;
                bi = idx;
            }
        }
        bi
    } else {
        let mut bi = 0usize;
        for idx in 0..scored.len() {
            if scored[idx].0 <= scored[bi].0 {
                bi = idx;
            }
        }
        bi
    };
    let (latency, plan) = scored.swap_remove(best_idx);
    plan.validate(model, cluster)?;
    let schedule = Schedule::for_sim(&plan, model, pc.policy);
    let outcome = PlanOutcome {
        predicted_throughput: plan.samples_per_round() as f64 / latency,
        predicted_latency: latency,
        planning_time_s: t0.elapsed().as_secs_f64(),
        schedule,
        policy: pc.policy,
        plan,
    };
    let state = DpState { order, rungs, cells, arena, pricer, fp, l_total, max_p };
    Ok((outcome, state))
}

/// Run Algorithm 2 and return the best plan over all stage counts.
pub fn plan_hpp(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
) -> Result<PlanOutcome> {
    plan_hpp_core(table, cluster, model, cfg, pc, None, None).map(|(o, _)| o)
}

/// [`plan_hpp`], additionally returning the [`DpState`] for later
/// incremental replans.
pub fn plan_hpp_with_state(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
) -> Result<(PlanOutcome, DpState)> {
    plan_hpp_core(table, cluster, model, cfg, pc, None, None)
}

/// Plan over a subset of the cluster's devices, in original device-id
/// space (the emitted plan's device ids index `cluster` directly — no
/// sub-cluster remapping).  `devices` must be distinct ids; order does
/// not matter.
pub fn plan_hpp_subset(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
    devices: &[usize],
) -> Result<(PlanOutcome, DpState)> {
    plan_hpp_core(table, cluster, model, cfg, pc, Some(devices), None)
}

/// Replan after removing one device from a previous run's device set,
/// reusing that run's DP cells and stage prices where valid.  The
/// result is **bit-for-bit identical** to a full
/// [`plan_hpp_subset`] rebuild over the survivors (the property test
/// in `tests/fleet_planning.rs` asserts it): reused cells cover device
/// suffixes the removal cannot have touched, and both paths walk the
/// same candidate sets in the same order with the same arithmetic.
/// When `prev` is incompatible — different model, cluster, config, or
/// not a single-device removal — the fast path silently degrades to a
/// full rebuild (still reusing memoized prices when only the device
/// set changed).
pub fn plan_hpp_incremental(
    prev: &DpState,
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
    removed: usize,
) -> Result<(PlanOutcome, DpState)> {
    let keep: Vec<usize> = prev.order.iter().copied().filter(|&d| d != removed).collect();
    plan_hpp_core(table, cluster, model, cfg, pc, Some(&keep), Some(prev))
}

/// Replan after *adding* one device to a previous run's device set —
/// the join-side mirror of [`plan_hpp_incremental`].  The plan
/// re-expands by extending the sorted device order and reusing every
/// `DpState` cell whose device suffix the insertion left untouched
/// (suffixes that exclude the joined device's sorted position);
/// everything else is recomputed.  The result is **bit-for-bit
/// identical** to a full [`plan_hpp_subset`] rebuild over the union
/// (the join property test in `tests/fleet_planning.rs` asserts it).
/// With an incompatible `prev` — different model, cluster, config, or
/// `added` already present — the fast path silently degrades to a
/// full rebuild, still reusing memoized stage prices where valid.
pub fn plan_hpp_incremental_join(
    prev: &DpState,
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
    added: usize,
) -> Result<(PlanOutcome, DpState)> {
    anyhow::ensure!(
        added < cluster.n(),
        "joined device {added} is not a cluster device (cluster has {})",
        cluster.n()
    );
    let mut union: Vec<usize> = prev.order.clone();
    if !union.contains(&added) {
        union.push(added);
    }
    plan_hpp_core(table, cluster, model, cfg, pc, Some(&union), Some(prev))
}

/// Sweep candidate micro-batch sizes and return the best plan overall.
/// The paper's profiler measures every batch size precisely because
/// execution time is non-linear in B (Fig. 6) — which micro-batch wins
/// depends on the cluster; this makes B a planned quantity rather than
/// a hyper-parameter.  Candidates share one stage pricer (B and M are
/// part of the memo key), so batch-independent infeasibilities and the
/// sim cache carry across the sweep instead of re-profiling from
/// scratch per candidate.
pub fn plan_hpp_sweep_microbatch(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    minibatch: usize,
    candidates: &[usize],
    pc: &PlannerConfig,
) -> Result<PlanOutcome> {
    let t0 = Instant::now();
    let mut best: Option<PlanOutcome> = None;
    let mut carry: Option<DpState> = None;
    for &b in candidates {
        if b == 0 || b > minibatch {
            continue;
        }
        let cfg = TrainConfig::new(minibatch, b);
        if let Ok((out, state)) =
            plan_hpp_core(table, cluster, model, &cfg, pc, None, carry.as_ref())
        {
            if best
                .as_ref()
                .map_or(true, |bst| out.predicted_throughput > bst.predicted_throughput)
            {
                best = Some(out);
            }
            carry = Some(state);
        }
    }
    let mut best = best.ok_or_else(|| {
        anyhow::anyhow!("no feasible plan for any candidate micro-batch size")
    })?;
    best.planning_time_s = t0.elapsed().as_secs_f64();
    Ok(best)
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::planner::cost::plan_peak_memory;

    fn plan_model(
        model: &ModelDesc,
        env: &str,
        mbps: f64,
        minibatch: usize,
        micro: usize,
    ) -> (PlanOutcome, ClusterSpec) {
        let cluster = ClusterSpec::env(env, mbps).unwrap();
        let table = ProfileTable::new(&cluster, model);
        let cfg = TrainConfig::new(minibatch, micro);
        let out = plan_hpp(&table, &cluster, model, &cfg, &PlannerConfig::default()).unwrap();
        (out, cluster)
    }

    #[test]
    fn plans_mobilenet_on_env_a() {
        let model = zoo::mobilenet_v2();
        let (out, cluster) = plan_model(&model, "A", 100.0, 256, 16);
        out.plan.validate(&model, &cluster).unwrap();
        assert!(out.predicted_throughput > 0.0);
        assert!(out.plan.num_stages() >= 1 && out.plan.num_stages() <= 5);
    }

    #[test]
    fn outcome_carries_valid_schedule() {
        let model = zoo::mobilenet_v2();
        let (out, _) = plan_model(&model, "B", 100.0, 256, 16);
        out.schedule.validate().unwrap();
        assert_eq!(out.schedule.num_stages, out.plan.num_stages());
        assert_eq!(out.schedule.num_micro, out.plan.num_micro);
        assert_eq!(out.schedule.timelines.len(), out.plan.devices().len());
    }

    #[test]
    fn plan_uses_every_device() {
        let model = zoo::mobilenet_v2();
        let (out, cluster) = plan_model(&model, "B", 100.0, 256, 16);
        assert_eq!(out.plan.devices().len(), cluster.n());
    }

    #[test]
    fn bert_prefers_straight_pipeline() {
        // Paper §5.2: transformers (huge params vs small activations)
        // plan into a deep pipeline — full-model AllReduce would be
        // ruinous.  Evaluated at 1000 Mbps (the paper's Config 7): with
        // seq-512 activations over a 100 Mbps link our calibrated model
        // makes inter-stage transfer the bottleneck and the planner
        // (correctly, per the cost model) falls back to a single DP
        // group; see EXPERIMENTS.md for the deviation note.
        let model = zoo::bert_small();
        let (out, _) = plan_model(&model, "B", 1000.0, 2048, 8);
        let max_group = out.plan.stages.iter().map(|s| s.replicas()).max().unwrap();
        assert!(
            out.plan.num_stages() >= 3,
            "bert stages = {} (want deep pipeline)",
            out.plan.num_stages()
        );
        assert!(max_group <= 2, "bert max group = {max_group}");

        // ... and it clearly beats DP there (Table 4's Bert row).
        let cluster = ClusterSpec::env("B", 1000.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(2048, 8);
        let dp = crate::planner::baselines::plan_dp(
            &table, &cluster, &model, &cfg,
            crate::planner::alloc::AllocOpts::default(),
            crate::schedule::DEFAULT_POLICY,
        )
        .unwrap();
        assert!(out.predicted_throughput > 1.5 * dp.predicted_throughput);
    }

    #[test]
    fn cnn_replicates_early_layers() {
        // Paper §5.2: CNNs (big early activations, param-dense tail) get
        // DP in early layers rather than a cut through huge feature maps.
        let model = zoo::efficientnet_b1();
        let (out, _) = plan_model(&model, "B", 100.0, 256, 16);
        if out.plan.num_stages() > 1 {
            let first = &out.plan.stages[0];
            let last = out.plan.stages.last().unwrap();
            assert!(
                first.replicas() >= last.replicas(),
                "first stage {} replicas vs last {}",
                first.replicas(),
                last.replicas()
            );
        }
    }

    #[test]
    fn respects_memory_budget() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 32);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        for (d, used) in plan_peak_memory(&model, &cfg, &out.plan, crate::schedule::DEFAULT_POLICY)
        {
            assert!(
                used <= cluster.devices[d].mem_bytes,
                "device {d}: {used} > {}",
                cluster.devices[d].mem_bytes
            );
        }
    }

    #[test]
    fn policy_aware_planning_respects_fill_drain_residency() {
        // With the policy threaded into the memory model, a fill-drain
        // run's plan must fit its O(M) activation residency — the old
        // raw-K_p accounting could emit plans that OOM at execution.
        use crate::schedule::GpipeFillDrain;
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(128, 16);
        let pc = PlannerConfig { policy: &GpipeFillDrain, ..PlannerConfig::default() };
        let out = plan_hpp(&table, &cluster, &model, &cfg, &pc).unwrap();
        assert_eq!(out.schedule.policy, "gpipe-fill-drain");
        assert_eq!(out.policy.name(), "gpipe-fill-drain");
        for (d, used) in plan_peak_memory(&model, &cfg, &out.plan, &GpipeFillDrain) {
            assert!(
                used <= cluster.devices[d].mem_bytes,
                "device {d}: gpipe-priced {used} > {}",
                cluster.devices[d].mem_bytes
            );
        }
    }

    #[test]
    fn async_planning_respects_stash_augmented_budget() {
        // Bounded staleness widens the activation window (K_p + sigma)
        // and pins weight-stash copies: the planner must charge both,
        // and the chosen plan must fit them on every device.
        use crate::schedule::AsyncPipe;
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(128, 16);
        static ASYNC2: AsyncPipe = AsyncPipe { max_staleness: 2 };
        let pc = PlannerConfig { policy: &ASYNC2, ..PlannerConfig::default() };
        let out = plan_hpp(&table, &cluster, &model, &cfg, &pc).unwrap();
        assert_eq!(out.policy.name(), "async:2");
        assert_eq!(out.schedule.policy, "async:2");
        assert_eq!(out.schedule.max_staleness, 2);
        out.schedule.validate().unwrap();
        for (d, used) in plan_peak_memory(&model, &cfg, &out.plan, &ASYNC2) {
            assert!(
                used <= cluster.devices[d].mem_bytes,
                "device {d}: async-priced {used} > {}",
                cluster.devices[d].mem_bytes
            );
        }
    }

    #[test]
    fn int8_codec_repartitions_bandwidth_constrained_cluster() {
        // The acceptance test for compressed-byte planning: on a
        // bandwidth-starved env-C mix the comm terms dominate, so
        // pricing the wire at int8 (~4x smaller) must either move the
        // DP's cut points or — same structure — strictly lower the
        // analytic round latency.  sim_select is off so
        // `predicted_latency` is exactly the DP objective being
        // compared.
        use crate::codec::{Codec, CodecSpec};
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 20.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let pc_fp = PlannerConfig { sim_select: false, ..PlannerConfig::default() };
        let pc_q8 = PlannerConfig {
            sim_select: false,
            codec: CodecSpec::uniform(Codec::Int8),
            ..PlannerConfig::default()
        };
        let fp = plan_hpp(&table, &cluster, &model, &cfg, &pc_fp).unwrap();
        let q8 = plan_hpp(&table, &cluster, &model, &cfg, &pc_q8).unwrap();
        // The fixture must actually exercise the network (otherwise the
        // codec cannot matter): the fp32 winner pays comm or AllReduce.
        assert!(
            fp.plan.num_stages() > 1 || fp.plan.stages[0].devices.len() > 1,
            "fixture degenerated to a single device"
        );
        let cuts = |p: &Plan| p.stages.iter().map(|s| s.layers).collect::<Vec<_>>();
        assert!(
            cuts(&q8.plan) != cuts(&fp.plan) || q8.predicted_latency < fp.predicted_latency,
            "int8 planning changed nothing: cuts {:?} latency {} vs fp32 {}",
            cuts(&q8.plan),
            q8.predicted_latency,
            fp.predicted_latency
        );
        // The optimum under a strictly cheaper wire can never price
        // above the fp32 optimum.
        assert!(q8.predicted_latency <= fp.predicted_latency);
    }

    #[test]
    fn sync_mode_threads_into_allreduce_pricing() {
        // Every candidate's Eq. 5 term satisfies ring <= star
        // (2(g-1)/g*W vs 2g*W over the same bottleneck link) and the
        // round latency is monotone in T_a, so with sim_select off the
        // star-priced analytic optimum can never beat the ring-priced
        // one over the same candidate set.
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 20.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let ring_pc = PlannerConfig { sim_select: false, ..PlannerConfig::default() };
        assert_eq!(ring_pc.sync, SyncMode::Ring, "ring is the planning default");
        let star_pc = PlannerConfig { sync: SyncMode::DriverStar, ..ring_pc };
        let ring = plan_hpp(&table, &cluster, &model, &cfg, &ring_pc).unwrap();
        let star = plan_hpp(&table, &cluster, &model, &cfg, &star_pc).unwrap();
        assert!(
            star.predicted_latency >= ring.predicted_latency,
            "star {} < ring {}",
            star.predicted_latency,
            ring.predicted_latency
        );
    }

    #[test]
    fn stage_pricer_sync_modes_do_not_alias() {
        // The sync tag is part of the stage-price memo key: pricing the
        // same stage under ring then star must yield each mode's own
        // Eq. 5 term, not a stale memo hit.
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(128, 16);
        let mut pricer = StagePricer::new();
        let ring_pc = PlannerConfig::default();
        let star_pc = PlannerConfig { sync: SyncMode::DriverStar, ..PlannerConfig::default() };
        let devs = [0usize, 1, 2];
        let ring = pricer
            .stage_cost(&table, &cluster, &model, &cfg, &ring_pc, 0, 10, &devs, 1)
            .unwrap();
        let star = pricer
            .stage_cost(&table, &cluster, &model, &cfg, &star_pc, 0, 10, &devs, 1)
            .unwrap();
        let w = ring_pc.codec.wire_sync_bytes(model.weight_bytes_range(0, 10));
        let bw = cluster.min_bandwidth(&devs);
        assert!((ring.ta - SyncMode::Ring.allreduce_time(w, 3, bw)).abs() < 1e-12);
        assert!((star.ta - SyncMode::DriverStar.allreduce_time(w, 3, bw)).abs() < 1e-12);
        assert!(star.ta > ring.ta, "star {} !> ring {}", star.ta, ring.ta);
        assert_eq!(ring.ef, star.ef, "compute is topology-independent");
    }

    #[test]
    fn infeasible_when_memory_tiny() {
        let model = zoo::bert_small();
        let mut cluster = ClusterSpec::env("D", 100.0).unwrap();
        for d in &mut cluster.devices {
            d.mem_bytes = 1024 * 1024; // 1 MiB
        }
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(64, 8);
        assert!(plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).is_err());
    }

    #[test]
    fn single_device_cluster_gives_single_stage() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A100", 0.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 32);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        assert_eq!(out.plan.num_stages(), 1);
        assert_eq!(out.plan.stages[0].devices, vec![0]);
    }

    #[test]
    fn kp_matches_policy_from_end() {
        let model = zoo::mobilenet_v2();
        let (out, _) = plan_model(&model, "C", 100.0, 256, 16);
        let p_total = out.plan.num_stages();
        for (p, s) in out.plan.stages.iter().enumerate() {
            let q = p_total - p;
            assert_eq!(s.kp, (2 * q - 1).min(16), "stage {p}");
        }
    }

    #[test]
    fn microbatch_sweep_at_least_as_good_as_any_candidate() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let pc = PlannerConfig::default();
        let swept =
            plan_hpp_sweep_microbatch(&table, &cluster, &model, 512, &[8, 16, 32, 64], &pc)
                .unwrap();
        for b in [8usize, 16, 32, 64] {
            let cfg = TrainConfig::new(512, b);
            if let Ok(o) = plan_hpp(&table, &cluster, &model, &cfg, &pc) {
                assert!(
                    swept.predicted_throughput >= o.predicted_throughput * 0.999,
                    "sweep {} < B={b} candidate {}",
                    swept.predicted_throughput,
                    o.predicted_throughput
                );
            }
        }
        assert!([8usize, 16, 32, 64].contains(&swept.plan.microbatch));
    }

    #[test]
    fn sweep_rejects_empty_candidates() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        assert!(plan_hpp_sweep_microbatch(
            &table, &cluster, &model, 64, &[], &PlannerConfig::default()
        )
        .is_err());
    }

    #[test]
    fn better_bandwidth_never_hurts() {
        let model = zoo::efficientnet_b1();
        let (slow, _) = plan_model(&model, "B", 100.0, 256, 16);
        let (fast, _) = plan_model(&model, "B", 1000.0, 256, 16);
        assert!(
            fast.predicted_throughput >= slow.predicted_throughput * 0.999,
            "fast {} < slow {}",
            fast.predicted_throughput,
            slow.predicted_throughput
        );
    }

    #[test]
    fn device_rungs_exact_below_threshold() {
        // At or below the threshold: every count (the exact regime).
        assert_eq!(device_rungs(6, 32), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(device_rungs(32, 32), (1..=32).collect::<Vec<_>>());
        // Above: dense 1..=16, then geometric, always ending at n.
        let r = device_rungs(128, 32);
        assert_eq!(&r[..16], &(1..=16).collect::<Vec<_>>()[..]);
        assert_eq!(*r.last().unwrap(), 128);
        assert!(r.windows(2).all(|w| w[0] < w[1]), "sorted, deduped: {r:?}");
        // Ladder values below n are fleet-size independent: the 512
        // ladder restricted to <=128 equals the 128 ladder minus its
        // own terminal rung (cell-reuse relies on this).
        let r512: Vec<usize> = device_rungs(512, 32).into_iter().filter(|&x| x < 128).collect();
        assert_eq!(r512, r[..r.len() - 1].to_vec());
    }

    #[test]
    fn order_tie_break_is_total_and_stable_under_removal() {
        // Env A is all-Nano: every device ties on memory and FLOPS, so
        // the id tie-break must produce ascending ids — and removing
        // any one device must not reorder the survivors.
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let all: Vec<usize> = (0..cluster.n()).collect();
        let order = sorted_device_order(&cluster, &all);
        assert_eq!(order, all, "equal devices sort by ascending id");
        for &gone in &all {
            let keep: Vec<usize> = all.iter().copied().filter(|&d| d != gone).collect();
            let sub = sorted_device_order(&cluster, &keep);
            let expect: Vec<usize> = order.iter().copied().filter(|&d| d != gone).collect();
            assert_eq!(sub, expect, "removal of {gone} must not reorder survivors");
        }
    }

    #[test]
    fn with_state_matches_plain_plan() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let pc = PlannerConfig::default();
        let plain = plan_hpp(&table, &cluster, &model, &cfg, &pc).unwrap();
        let (stateful, state) =
            plan_hpp_with_state(&table, &cluster, &model, &cfg, &pc).unwrap();
        assert_eq!(plain.plan, stateful.plan);
        assert_eq!(
            plain.predicted_latency.to_bits(),
            stateful.predicted_latency.to_bits()
        );
        assert_eq!(state.order().len(), cluster.n());
        assert!(state.memo_entries() > 0 && state.arena_nodes() > 0);
    }

    #[test]
    fn subset_plan_uses_only_subset_devices() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let pc = PlannerConfig::default();
        let subset = [0usize, 2, 3, 5];
        let (out, state) =
            plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &subset).unwrap();
        out.plan.validate(&model, &cluster).unwrap();
        for d in out.plan.devices() {
            assert!(subset.contains(&d), "plan uses non-subset device {d}");
        }
        assert_eq!(state.order().len(), subset.len());
    }

    #[test]
    fn incremental_replan_matches_full_rebuild_env_c() {
        // The delta-update-equals-rebuild contract, exhaustively over
        // every single-device removal from env C: the incremental
        // replan must emit the *identical* plan and analytic latency
        // (to the bit) as a from-scratch subset rebuild.
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let pc = PlannerConfig::default();
        let (_, state) = plan_hpp_with_state(&table, &cluster, &model, &cfg, &pc).unwrap();
        for gone in 0..cluster.n() {
            let keep: Vec<usize> = (0..cluster.n()).filter(|&d| d != gone).collect();
            let full = plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &keep);
            let fast = plan_hpp_incremental(&state, &table, &cluster, &model, &cfg, &pc, gone);
            match (full, fast) {
                (Ok((f, _)), Ok((i, inc_state))) => {
                    assert_eq!(f.plan, i.plan, "removal of {gone}: plans diverge");
                    assert_eq!(
                        f.predicted_latency.to_bits(),
                        i.predicted_latency.to_bits(),
                        "removal of {gone}: latency diverges"
                    );
                    assert_eq!(inc_state.order().len(), keep.len());
                }
                (Err(_), Err(_)) => {} // both infeasible is also agreement
                (full, fast) => panic!(
                    "removal of {gone}: feasibility diverges (full ok={}, incremental ok={})",
                    full.is_ok(),
                    fast.is_ok()
                ),
            }
        }
    }

    #[test]
    fn join_incremental_matches_full_rebuild_env_c() {
        // The join-side mirror of the removal contract, exhaustively
        // over env C: plan every (n-1)-device subset, re-add the
        // missing device through the join fast path, and demand the
        // identical plan and bit-identical latency a from-scratch
        // rebuild over all n devices emits.
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let pc = PlannerConfig::default();
        let all: Vec<usize> = (0..cluster.n()).collect();
        for joined in 0..cluster.n() {
            let without: Vec<usize> = all.iter().copied().filter(|&d| d != joined).collect();
            let Ok((_, small)) = plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &without)
            else {
                continue; // subset infeasible: nothing to re-expand
            };
            let full = plan_hpp_subset(&table, &cluster, &model, &cfg, &pc, &all);
            let fast =
                plan_hpp_incremental_join(&small, &table, &cluster, &model, &cfg, &pc, joined);
            match (full, fast) {
                (Ok((f, _)), Ok((i, state))) => {
                    assert_eq!(f.plan, i.plan, "join of {joined}: plans diverge");
                    assert_eq!(
                        f.predicted_latency.to_bits(),
                        i.predicted_latency.to_bits(),
                        "join of {joined}: latency diverges"
                    );
                    assert_eq!(state.order().len(), cluster.n());
                }
                (Err(_), Err(_)) => {}
                (full, fast) => panic!(
                    "join of {joined}: feasibility diverges (full ok={}, join ok={})",
                    full.is_ok(),
                    fast.is_ok()
                ),
            }
        }
    }

    #[test]
    fn remove_then_rejoin_round_trips_env_c() {
        // Exit → rejoin of the same device must re-expand the plan to
        // exactly the original, chaining the two incremental paths:
        // the removal's state seeds the join, and the re-expanded
        // outcome is bit-identical to the initial full plan.
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let pc = PlannerConfig::default();
        let (orig, state) = plan_hpp_with_state(&table, &cluster, &model, &cfg, &pc).unwrap();
        for dev in 0..cluster.n() {
            let Ok((_, shrunk)) =
                plan_hpp_incremental(&state, &table, &cluster, &model, &cfg, &pc, dev)
            else {
                continue; // removal infeasible: no round trip to check
            };
            let (back, grown) =
                plan_hpp_incremental_join(&shrunk, &table, &cluster, &model, &cfg, &pc, dev)
                    .unwrap();
            assert_eq!(back.plan, orig.plan, "rejoin of {dev}: plan did not round-trip");
            assert_eq!(
                back.predicted_latency.to_bits(),
                orig.predicted_latency.to_bits(),
                "rejoin of {dev}: latency did not round-trip"
            );
            assert_eq!(grown.order(), state.order(), "rejoin of {dev}: order diverged");
        }
    }

    #[test]
    fn insertion_position_mirrors_removal() {
        assert_eq!(insertion_position(&[1, 3], &[1, 2, 3]), Some(1));
        assert_eq!(insertion_position(&[2, 3], &[1, 2, 3]), Some(0));
        assert_eq!(insertion_position(&[1, 2], &[1, 2, 3]), Some(2));
        assert_eq!(insertion_position(&[1, 2, 3], &[1, 2, 3]), None);
        assert_eq!(insertion_position(&[1, 4], &[1, 2, 3]), None);
        // The two are inverses over the same pair of orders.
        assert_eq!(removal_position(&[1, 2, 3], &[1, 3]), Some(1));
        assert_eq!(insertion_position(&[1, 3], &[1, 2, 3]), Some(1));
    }
}
