//! Algorithm 2: dynamic-programming HPP planning (Eqs. 10-11).
//!
//! Q(l, n, p) is the optimal HPP-Round latency when slicing the *last*
//! `l` layers into `p` stages across the *last* `n` devices, devices
//! pre-sorted by memory capacity in descending order (the paper's
//! observation: earlier stages hold more activations, so they get the
//! larger-memory devices).  The recurrence extends an optimal
//! sub-pipeline with one new head stage replicated over the next
//! `n - n'` devices, re-evaluating the dominant step per Eq. (11).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::alloc::{allocate_microbatch, AllocOpts};
use crate::planner::cost::{comm_step_cost, exec_step_cost, round_latency, StepCost};
use crate::planner::plan::{KpPolicy, Plan, Stage};
use crate::profiler::ProfileTable;
use crate::schedule::{Schedule, SchedulePolicy, DEFAULT_POLICY};

/// Planner behaviour configuration (ablations of Fig. 15(a)).
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    pub alloc: AllocOpts,
    /// Model inter-stage communication and AllReduce in the DP objective
    /// (off = naive planner that only balances compute).
    pub comm_aware: bool,
    pub max_stages: usize,
    pub kp_policy: KpPolicy,
    /// Validate the per-stage-count finalists with the event-accurate
    /// simulator and pick the best observed round latency.  The
    /// dominant-step model (Eq. 4-6) is an approximation ("practically
    /// effective", §3.3) — this final check removes its residual
    /// ranking errors at the cost of <= max_stages simulations.
    pub sim_select: bool,
    /// The round schedule policy this run plans *for*: memory budgets
    /// charge the policy's `effective_kp`, `sim_select` prices each
    /// finalist under it (picking the best (plan, policy) pair rather
    /// than assuming 1F1B), and the outcome's schedule is built with
    /// it.  `Planner::plan` overrides this field with the session's
    /// threaded policy, so `.schedule(..)` is authoritative; set it
    /// directly only when calling `plan_hpp` by hand.
    pub policy: &'static dyn SchedulePolicy,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            alloc: AllocOpts::default(),
            comm_aware: true,
            max_stages: 8,
            kp_policy: KpPolicy::Ours,
            sim_select: true,
            policy: DEFAULT_POLICY,
        }
    }
}

/// Result of a planning run.
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    pub plan: Plan,
    /// The chosen plan's explicit HPP-Round schedule (the run's policy,
    /// sample-sharded) — downstream layers consume this instead of
    /// re-deriving the op ordering from the plan.
    pub schedule: Schedule,
    /// The schedule policy the run planned for (carried so downstream
    /// layers never fall back to a hardcoded default).
    pub policy: &'static dyn SchedulePolicy,
    /// Predicted HPP-Round latency (seconds) from the *analytic*
    /// Eq. 4-6 dominant-step model.  Deliberately policy-blind: the
    /// paper's cost model assumes 1F1B-style overlap, and this field
    /// is kept as the analytic cross-check it always was.  The
    /// authoritative per-policy number is the event-accurate sim price
    /// (`schedule` through `sim::price_schedule`, what `sim_select`
    /// ranks and `RunReport::throughput` reports).
    pub predicted_latency: f64,
    /// Predicted throughput (samples/s) from the same analytic model
    /// (see `predicted_latency` for the policy-blindness caveat).
    pub predicted_throughput: f64,
    /// Wall-clock planning time (Table 7).
    pub planning_time_s: f64,
}

#[derive(Clone)]
struct QEntry {
    stages: Vec<Stage>,
    steps: Vec<StepCost>,
    latency: f64,
}

/// K_p as a function of the stage's distance-from-end q (q = 1 for the
/// last stage).  Within the DP only the suffix position is known; for
/// the paper's policy K_p = 2(P-p)-1 = 2q-1.
fn kp_from_end(policy: KpPolicy, q: usize, m: usize) -> usize {
    let v = match policy {
        KpPolicy::TwoGapsPlusOne => 2 * q,
        KpPolicy::Linear => q,
        KpPolicy::TwoGapsPlusTwo => 2 * q + 1,
        KpPolicy::Ours => 2 * q - 1,
        KpPolicy::AllForward => m,
    };
    v.clamp(1, m.max(1))
}

/// Run Algorithm 2 and return the best plan over all stage counts.
pub fn plan_hpp(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    pc: &PlannerConfig,
) -> Result<PlanOutcome> {
    let t0 = Instant::now();
    let l_total = model.num_layers();
    let n_total = cluster.n();
    let m = cfg.num_microbatches();
    let b = cfg.microbatch;
    let max_p = pc.max_stages.min(n_total).max(1);

    // Devices sorted by memory desc (ties: capacity desc).
    let mut order: Vec<usize> = (0..n_total).collect();
    order.sort_by(|&a, &b| {
        let da = &cluster.devices[a];
        let db = &cluster.devices[b];
        db.mem_bytes
            .cmp(&da.mem_bytes)
            .then(db.peak_flops.partial_cmp(&da.peak_flops).unwrap())
            .then(a.cmp(&b))
    });

    // Stage-cost cache: (layer i, layer j, dev start, dev end, kp) ->
    // allocation + step cost, or None when the group OOMs.
    #[allow(clippy::type_complexity)]
    let mut cache: HashMap<(usize, usize, usize, usize, usize), Option<(Vec<usize>, StepCost)>> =
        HashMap::new();
    let stage_cost = |i: usize,
                          j: usize,
                          ds: usize,
                          de: usize,
                          kp: usize,
                          cache: &mut HashMap<
        (usize, usize, usize, usize, usize),
        Option<(Vec<usize>, StepCost)>,
    >|
     -> Option<(Vec<usize>, StepCost)> {
        let key = (i, j, ds, de, kp);
        if let Some(hit) = cache.get(&key) {
            return hit.clone();
        }
        let devices: Vec<usize> = order[ds..de].to_vec();
        // Memory budgets charge the policy's true in-flight residency
        // (e.g. the whole round for fill-drain), not the raw warm-up —
        // plus the weight-version stash copies of a bounded-staleness
        // policy (Eq. 3's fourth term).
        let eff_kp = pc.policy.effective_kp(kp, m);
        let alloc_opts = AllocOpts {
            stash_copies: pc.policy.weight_stash_copies(kp, m),
            ..pc.alloc
        };
        let result = allocate_microbatch(
            table, cluster, model, cfg, i, j, &devices, b, eff_kp, alloc_opts,
        )
        .ok()
        .map(|alloc| {
            let stage = Stage { layers: (i, j), devices: devices.clone(), alloc, kp };
            let mut cost = exec_step_cost(table, cluster, model, &stage);
            if !pc.comm_aware {
                cost.ta = 0.0;
            }
            (stage.alloc, cost)
        });
        cache.insert(key, result.clone());
        result
    };

    // Q[l][n][p]; indices 1-based on l, n, p.
    let mut q: Vec<Vec<Vec<Option<QEntry>>>> =
        vec![vec![vec![None; max_p + 1]; n_total + 1]; l_total + 1];

    // Base case p = 1: the last l layers as a single (final) stage on
    // the last n devices.
    for l in 1..=l_total {
        for n in 1..=n_total {
            let i = l_total - l;
            let kp = kp_from_end(pc.kp_policy, 1, m);
            let ds = n_total - n;
            if let Some((alloc, cost)) = stage_cost(i, l_total, ds, n_total, kp, &mut cache) {
                let stage = Stage {
                    layers: (i, l_total),
                    devices: order[ds..n_total].to_vec(),
                    alloc,
                    kp,
                };
                let steps = vec![cost];
                let latency = round_latency(&steps, m);
                q[l][n][1] = Some(QEntry { stages: vec![stage], steps, latency });
            }
        }
    }

    // Recurrence (Eq. 10): extend sub-pipelines with a new head stage.
    for p in 2..=max_p {
        for l in p..=l_total {
            for n in p..=n_total {
                let mut best: Option<QEntry> = None;
                for lp in (p - 1)..l {
                    for np in (p - 1)..n {
                        let Some(sub) = q[lp][np][p - 1].as_ref() else { continue };
                        // New head stage: layers [L-l, L-lp) on devices
                        // order[N-n .. N-np).
                        let i = l_total - l;
                        let j = l_total - lp;
                        let ds = n_total - n;
                        let de = n_total - np;
                        let kp = kp_from_end(pc.kp_policy, p, m);
                        let Some((alloc, exec_cost)) = stage_cost(i, j, ds, de, kp, &mut cache)
                        else {
                            continue;
                        };
                        let new_stage = Stage {
                            layers: (i, j),
                            devices: order[ds..de].to_vec(),
                            alloc,
                            kp,
                        };
                        // Communication step to the sub-pipeline's head.
                        let sub_head = &sub.stages[0];
                        let mut comm =
                            comm_step_cost(cluster, model, &new_stage, sub_head, b);
                        if !pc.comm_aware {
                            comm = StepCost { ef: 0.0, eb: 0.0, ta: 0.0, exec: false };
                        }
                        // Assemble steps; dominant step re-derived inside
                        // round_latency per Eq. (11).
                        let mut steps = Vec::with_capacity(sub.steps.len() + 2);
                        steps.push(exec_cost);
                        steps.push(comm);
                        steps.extend_from_slice(&sub.steps);
                        let latency = round_latency(&steps, m);
                        if best.as_ref().map_or(true, |e| latency < e.latency) {
                            let mut stages = Vec::with_capacity(sub.stages.len() + 1);
                            stages.push(new_stage);
                            stages.extend_from_slice(&sub.stages);
                            best = Some(QEntry { stages, steps, latency });
                        }
                    }
                }
                q[l][n][p] = best;
            }
        }
    }

    // min_p Q(L, N, p): analytic ranking, optionally re-ranked by the
    // event-accurate simulator over the per-p finalists.
    let finalists: Vec<&QEntry> = (1..=max_p)
        .filter_map(|p| q[l_total][n_total][p].as_ref())
        .collect();
    if finalists.is_empty() {
        bail!(
            "no feasible HPP plan: model {} does not fit on cluster {} \
             with micro-batch {b}",
            model.name,
            cluster.describe()
        );
    }
    // Price each finalist under the run's policy with the
    // event-accurate executor: sim_select ranks (plan, policy) pairs,
    // so a zero-bubble or fill-drain run picks the stage split that is
    // best *under that ordering*, not under an assumed 1F1B.
    // `sim::price_policy` prices bounded-staleness policies in steady
    // state (multi-round, barrier-free), so an async run's finalists
    // are ranked by the throughput it will actually sustain.
    let best: &QEntry = if pc.sim_select && finalists.len() > 1 {
        let scored = finalists.iter().map(|e| {
            let plan = Plan { stages: e.stages.clone(), microbatch: b, num_micro: m };
            let lat =
                crate::sim::price_policy(table, cluster, model, &plan, pc.policy).round_latency;
            (lat, *e)
        });
        scored
            .min_by(|x, y| x.0.partial_cmp(&y.0).unwrap())
            .unwrap()
            .1
    } else {
        *finalists
            .iter()
            .min_by(|x, y| x.latency.partial_cmp(&y.latency).unwrap())
            .unwrap()
    };

    let plan = Plan {
        stages: best.stages.clone(),
        microbatch: b,
        num_micro: m,
    };
    plan.validate(model, cluster)?;
    let schedule = Schedule::for_sim(&plan, model, pc.policy);
    let latency = best.latency;
    Ok(PlanOutcome {
        predicted_throughput: plan.samples_per_round() as f64 / latency,
        predicted_latency: latency,
        planning_time_s: t0.elapsed().as_secs_f64(),
        schedule,
        policy: pc.policy,
        plan,
    })
}

/// Sweep candidate micro-batch sizes and return the best plan overall.
/// The paper's profiler measures every batch size precisely because
/// execution time is non-linear in B (Fig. 6) — which micro-batch wins
/// depends on the cluster; this makes B a planned quantity rather than
/// a hyper-parameter.
pub fn plan_hpp_sweep_microbatch(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    minibatch: usize,
    candidates: &[usize],
    pc: &PlannerConfig,
) -> Result<PlanOutcome> {
    let t0 = Instant::now();
    let mut best: Option<PlanOutcome> = None;
    for &b in candidates {
        if b == 0 || b > minibatch {
            continue;
        }
        let cfg = TrainConfig::new(minibatch, b);
        if let Ok(out) = plan_hpp(table, cluster, model, &cfg, pc) {
            if best
                .as_ref()
                .map_or(true, |bst| out.predicted_throughput > bst.predicted_throughput)
            {
                best = Some(out);
            }
        }
    }
    let mut best = best.ok_or_else(|| {
        anyhow::anyhow!("no feasible plan for any candidate micro-batch size")
    })?;
    best.planning_time_s = t0.elapsed().as_secs_f64();
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::planner::cost::plan_peak_memory;

    fn plan_model(
        model: &ModelDesc,
        env: &str,
        mbps: f64,
        minibatch: usize,
        micro: usize,
    ) -> (PlanOutcome, ClusterSpec) {
        let cluster = ClusterSpec::env(env, mbps).unwrap();
        let table = ProfileTable::new(&cluster, model);
        let cfg = TrainConfig::new(minibatch, micro);
        let out = plan_hpp(&table, &cluster, model, &cfg, &PlannerConfig::default()).unwrap();
        (out, cluster)
    }

    #[test]
    fn plans_mobilenet_on_env_a() {
        let model = zoo::mobilenet_v2();
        let (out, cluster) = plan_model(&model, "A", 100.0, 256, 16);
        out.plan.validate(&model, &cluster).unwrap();
        assert!(out.predicted_throughput > 0.0);
        assert!(out.plan.num_stages() >= 1 && out.plan.num_stages() <= 5);
    }

    #[test]
    fn outcome_carries_valid_schedule() {
        let model = zoo::mobilenet_v2();
        let (out, _) = plan_model(&model, "B", 100.0, 256, 16);
        out.schedule.validate().unwrap();
        assert_eq!(out.schedule.num_stages, out.plan.num_stages());
        assert_eq!(out.schedule.num_micro, out.plan.num_micro);
        assert_eq!(out.schedule.timelines.len(), out.plan.devices().len());
    }

    #[test]
    fn plan_uses_every_device() {
        let model = zoo::mobilenet_v2();
        let (out, cluster) = plan_model(&model, "B", 100.0, 256, 16);
        assert_eq!(out.plan.devices().len(), cluster.n());
    }

    #[test]
    fn bert_prefers_straight_pipeline() {
        // Paper §5.2: transformers (huge params vs small activations)
        // plan into a deep pipeline — full-model AllReduce would be
        // ruinous.  Evaluated at 1000 Mbps (the paper's Config 7): with
        // seq-512 activations over a 100 Mbps link our calibrated model
        // makes inter-stage transfer the bottleneck and the planner
        // (correctly, per the cost model) falls back to a single DP
        // group; see EXPERIMENTS.md for the deviation note.
        let model = zoo::bert_small();
        let (out, _) = plan_model(&model, "B", 1000.0, 2048, 8);
        let max_group = out.plan.stages.iter().map(|s| s.replicas()).max().unwrap();
        assert!(
            out.plan.num_stages() >= 3,
            "bert stages = {} (want deep pipeline)",
            out.plan.num_stages()
        );
        assert!(max_group <= 2, "bert max group = {max_group}");

        // ... and it clearly beats DP there (Table 4's Bert row).
        let cluster = ClusterSpec::env("B", 1000.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(2048, 8);
        let dp = crate::planner::baselines::plan_dp(
            &table, &cluster, &model, &cfg,
            crate::planner::alloc::AllocOpts::default(),
            crate::schedule::DEFAULT_POLICY,
        )
        .unwrap();
        assert!(out.predicted_throughput > 1.5 * dp.predicted_throughput);
    }

    #[test]
    fn cnn_replicates_early_layers() {
        // Paper §5.2: CNNs (big early activations, param-dense tail) get
        // DP in early layers rather than a cut through huge feature maps.
        let model = zoo::efficientnet_b1();
        let (out, _) = plan_model(&model, "B", 100.0, 256, 16);
        if out.plan.num_stages() > 1 {
            let first = &out.plan.stages[0];
            let last = out.plan.stages.last().unwrap();
            assert!(
                first.replicas() >= last.replicas(),
                "first stage {} replicas vs last {}",
                first.replicas(),
                last.replicas()
            );
        }
    }

    #[test]
    fn respects_memory_budget() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 32);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        for (d, used) in plan_peak_memory(&model, &cfg, &out.plan, crate::schedule::DEFAULT_POLICY)
        {
            assert!(
                used <= cluster.devices[d].mem_bytes,
                "device {d}: {used} > {}",
                cluster.devices[d].mem_bytes
            );
        }
    }

    #[test]
    fn policy_aware_planning_respects_fill_drain_residency() {
        // With the policy threaded into the memory model, a fill-drain
        // run's plan must fit its O(M) activation residency — the old
        // raw-K_p accounting could emit plans that OOM at execution.
        use crate::schedule::GpipeFillDrain;
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(128, 16);
        let pc = PlannerConfig { policy: &GpipeFillDrain, ..PlannerConfig::default() };
        let out = plan_hpp(&table, &cluster, &model, &cfg, &pc).unwrap();
        assert_eq!(out.schedule.policy, "gpipe-fill-drain");
        assert_eq!(out.policy.name(), "gpipe-fill-drain");
        for (d, used) in plan_peak_memory(&model, &cfg, &out.plan, &GpipeFillDrain) {
            assert!(
                used <= cluster.devices[d].mem_bytes,
                "device {d}: gpipe-priced {used} > {}",
                cluster.devices[d].mem_bytes
            );
        }
    }

    #[test]
    fn async_planning_respects_stash_augmented_budget() {
        // Bounded staleness widens the activation window (K_p + sigma)
        // and pins weight-stash copies: the planner must charge both,
        // and the chosen plan must fit them on every device.
        use crate::schedule::AsyncPipe;
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(128, 16);
        static ASYNC2: AsyncPipe = AsyncPipe { max_staleness: 2 };
        let pc = PlannerConfig { policy: &ASYNC2, ..PlannerConfig::default() };
        let out = plan_hpp(&table, &cluster, &model, &cfg, &pc).unwrap();
        assert_eq!(out.policy.name(), "async:2");
        assert_eq!(out.schedule.policy, "async:2");
        assert_eq!(out.schedule.max_staleness, 2);
        out.schedule.validate().unwrap();
        for (d, used) in plan_peak_memory(&model, &cfg, &out.plan, &ASYNC2) {
            assert!(
                used <= cluster.devices[d].mem_bytes,
                "device {d}: async-priced {used} > {}",
                cluster.devices[d].mem_bytes
            );
        }
    }

    #[test]
    fn infeasible_when_memory_tiny() {
        let model = zoo::bert_small();
        let mut cluster = ClusterSpec::env("D", 100.0).unwrap();
        for d in &mut cluster.devices {
            d.mem_bytes = 1024 * 1024; // 1 MiB
        }
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(64, 8);
        assert!(plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).is_err());
    }

    #[test]
    fn single_device_cluster_gives_single_stage() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A100", 0.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 32);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        assert_eq!(out.plan.num_stages(), 1);
        assert_eq!(out.plan.stages[0].devices, vec![0]);
    }

    #[test]
    fn kp_matches_policy_from_end() {
        let model = zoo::mobilenet_v2();
        let (out, _) = plan_model(&model, "C", 100.0, 256, 16);
        let p_total = out.plan.num_stages();
        for (p, s) in out.plan.stages.iter().enumerate() {
            let q = p_total - p;
            assert_eq!(s.kp, (2 * q - 1).min(16), "stage {p}");
        }
    }

    #[test]
    fn microbatch_sweep_at_least_as_good_as_any_candidate() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        let pc = PlannerConfig::default();
        let swept =
            plan_hpp_sweep_microbatch(&table, &cluster, &model, 512, &[8, 16, 32, 64], &pc)
                .unwrap();
        for b in [8usize, 16, 32, 64] {
            let cfg = TrainConfig::new(512, b);
            if let Ok(o) = plan_hpp(&table, &cluster, &model, &cfg, &pc) {
                assert!(
                    swept.predicted_throughput >= o.predicted_throughput * 0.999,
                    "sweep {} < B={b} candidate {}",
                    swept.predicted_throughput,
                    o.predicted_throughput
                );
            }
        }
        assert!([8usize, 16, 32, 64].contains(&swept.plan.microbatch));
    }

    #[test]
    fn sweep_rejects_empty_candidates() {
        let model = zoo::mobilenet_v2();
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let table = ProfileTable::new(&cluster, &model);
        assert!(plan_hpp_sweep_microbatch(
            &table, &cluster, &model, 64, &[], &PlannerConfig::default()
        )
        .is_err());
    }

    #[test]
    fn better_bandwidth_never_hurts() {
        let model = zoo::efficientnet_b1();
        let (slow, _) = plan_model(&model, "B", 100.0, 256, 16);
        let (fast, _) = plan_model(&model, "B", 1000.0, 256, 16);
        assert!(
            fast.predicted_throughput >= slow.predicted_throughput * 0.999,
            "fast {} < slow {}",
            fast.predicted_throughput,
            slow.predicted_throughput
        );
    }
}
