//! Asteroid's parallelism planning (paper §3.3).
//!
//! * `plan`     — HPP plan representation + K_p policies (Fig. 4);
//! * `memory`   — Eq. (3) per-stage memory model;
//! * `alloc`    — Algorithm 1: micro-batch allocation within a group;
//! * `cost`     — Eqs. (4)-(6), (8), (11): dominant-step latency model;
//! * `dp`       — Algorithm 2: dynamic-programming stage/group search;
//! * `baselines`— DP, EDDL, GPipe-PP, PipeDream, Dapple, HetPipe.
//!
//! [`Planner`] is the single dispatch point over all of the above: the
//! session layer (and anything else that wants a plan) names a planner
//! declaratively and calls [`Planner::plan`] — there is no per-method
//! entry-point family to wire by hand.

pub mod alloc;
pub mod baselines;
pub mod cost;
pub mod dp;
pub mod memory;
pub mod plan;

pub use alloc::{allocate_microbatch, AllocOpts};
pub use cost::{plan_steps, predicted_throughput, round_latency, StepCost};
pub use dp::{
    device_rungs, plan_hpp, plan_hpp_incremental, plan_hpp_incremental_join, plan_hpp_subset,
    plan_hpp_sweep_microbatch, plan_hpp_with_state, sorted_device_order, DpState, PlanOutcome,
    PlannerConfig, StagePricer,
};
pub use plan::{KpPolicy, Plan, Stage};

use anyhow::{Context, Result};

use crate::codec::CodecSpec;
use crate::comm::SyncMode;
use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::profiler::ProfileTable;
use crate::schedule::SchedulePolicy;

use self::baselines::Method;

/// Every way to produce an HPP plan, dispatched through one
/// [`Planner::plan`] path (the paper's Fig. 3 "parallelism planning"
/// phase).
///
/// * `Asteroid` — Algorithm 2 with the default configuration;
/// * `Baseline(method)` — one of the paper's comparison planners
///   (§5.1), including the single-device on-device baseline;
/// * `Custom(config)` — Algorithm 2 under an explicit
///   [`PlannerConfig`] (the Fig. 15(a) ablations).
#[derive(Debug, Clone, Copy, Default)]
pub enum Planner {
    #[default]
    Asteroid,
    Baseline(Method),
    Custom(PlannerConfig),
}

impl Planner {
    /// Short human-readable name for reports and CLI output.
    pub fn describe(&self) -> String {
        match self {
            Planner::Asteroid => "Asteroid".to_string(),
            Planner::Baseline(m) => m.name().to_string(),
            Planner::Custom(_) => "Asteroid (custom config)".to_string(),
        }
    }

    /// The one planning entry point: every method — ours and the
    /// baselines — routes through here, planning *for* the given round
    /// schedule policy (memory budgets, sim_select pricing and the
    /// outcome schedule all honour it; the session threads its
    /// `.schedule(..)` choice into this argument).  For a
    /// `Planner::Custom` config the threaded policy overrides the
    /// config's own `policy` field, so the session stays authoritative.
    ///
    /// `Baseline(HetPipe)` errors: HetPipe is hybrid *data*
    /// parallelism (HDP), whose plan is not an HPP [`Plan`]; its
    /// analytic result lives at [`baselines::plan_hetpipe`].
    pub fn plan(
        &self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        policy: &'static dyn SchedulePolicy,
    ) -> Result<PlanOutcome> {
        self.plan_codec(table, cluster, model, cfg, policy, &CodecSpec::default(), SyncMode::default())
    }

    /// [`Planner::plan`] pricing the wire under `codec` and the Eq. 5
    /// AllReduce term under `sync`.  Like the threaded policy, the
    /// threaded codec and sync mode override a `Custom` config's own
    /// `codec`/`sync` fields — the session's `.codec(..)`/`.sync(..)`
    /// knobs are authoritative.  Only Algorithm 2 (`Asteroid`/`Custom`)
    /// consumes compressed-byte and topology pricing; the comparison
    /// baselines keep their published fp32 cost models (the codec still
    /// compresses their traffic at execution, it just doesn't move
    /// their plan).
    #[allow(clippy::too_many_arguments)]
    pub fn plan_codec(
        &self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        policy: &'static dyn SchedulePolicy,
        codec: &CodecSpec,
        sync: SyncMode,
    ) -> Result<PlanOutcome> {
        match *self {
            Planner::Asteroid | Planner::Baseline(Method::Asteroid) => plan_hpp(
                table,
                cluster,
                model,
                cfg,
                &PlannerConfig { policy, codec: *codec, sync, ..PlannerConfig::default() },
            ),
            Planner::Custom(pc) => plan_hpp(
                table,
                cluster,
                model,
                cfg,
                &PlannerConfig { policy, codec: *codec, sync, ..pc },
            ),
            Planner::Baseline(Method::DataParallel) | Planner::Baseline(Method::Eddl) => {
                baselines::plan_dp(table, cluster, model, cfg, AllocOpts::default(), policy)
            }
            Planner::Baseline(Method::GpipePP) => {
                baselines::plan_gpipe_pp(table, cluster, model, cfg, policy)
            }
            Planner::Baseline(Method::PipeDream) => {
                baselines::plan_pipedream(table, cluster, model, cfg, policy)
            }
            Planner::Baseline(Method::Dapple) => {
                baselines::plan_dapple(table, cluster, model, cfg, policy)
            }
            Planner::Baseline(Method::OnDevice) => plan_on_device(cluster, model, cfg, policy),
            Planner::Baseline(Method::HetPipe) => anyhow::bail!(
                "HetPipe is hybrid data parallelism (HDP), not an HPP plan; \
                 use planner::baselines::plan_hetpipe for its analytic result"
            ),
        }
    }

    /// [`Planner::plan`], additionally returning the planner's
    /// [`DpState`] when the method runs Algorithm 2 (`Asteroid` /
    /// `Custom`) — the state the session keeps so a later device
    /// failure can take [`plan_hpp_incremental`]'s fast path.  Baseline
    /// planners have no reusable DP state and return `None`.
    pub fn plan_with_state(
        &self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        policy: &'static dyn SchedulePolicy,
    ) -> Result<(PlanOutcome, Option<DpState>)> {
        self.plan_with_state_codec(
            table, cluster, model, cfg, policy, &CodecSpec::default(), SyncMode::default(),
        )
    }

    /// [`Planner::plan_with_state`] pricing the wire under `codec` and
    /// the AllReduce topology under `sync` (see [`Planner::plan_codec`]
    /// for the override semantics).
    #[allow(clippy::too_many_arguments)]
    pub fn plan_with_state_codec(
        &self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        cfg: &TrainConfig,
        policy: &'static dyn SchedulePolicy,
        codec: &CodecSpec,
        sync: SyncMode,
    ) -> Result<(PlanOutcome, Option<DpState>)> {
        match *self {
            Planner::Asteroid | Planner::Baseline(Method::Asteroid) => plan_hpp_with_state(
                table,
                cluster,
                model,
                cfg,
                &PlannerConfig { policy, codec: *codec, sync, ..PlannerConfig::default() },
            )
            .map(|(o, s)| (o, Some(s))),
            Planner::Custom(pc) => plan_hpp_with_state(
                table,
                cluster,
                model,
                cfg,
                &PlannerConfig { policy, codec: *codec, sync, ..pc },
            )
            .map(|(o, s)| (o, Some(s))),
            _ => self
                .plan_codec(table, cluster, model, cfg, policy, codec, sync)
                .map(|o| (o, None)),
        }
    }
}

/// On-device baseline: the single strongest device, single stage.
fn plan_on_device(
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    policy: &'static dyn SchedulePolicy,
) -> Result<PlanOutcome> {
    let best = cluster
        .devices
        .iter()
        .max_by(|a, b| a.peak_flops.partial_cmp(&b.peak_flops).unwrap())
        .context("cluster has no devices")?
        .id;
    let mut single = cluster.clone();
    single.devices = vec![cluster.devices[best].clone()];
    single.devices[0].id = 0;
    single.bandwidth = vec![vec![0.0]];
    let table = ProfileTable::new(&single, model);
    let mut out = plan_hpp(
        &table,
        &single,
        model,
        cfg,
        &PlannerConfig { policy, ..PlannerConfig::default() },
    )?;
    // Map back to the original device id and rebuild the schedule so
    // its timelines name the real device (the session consumes the
    // outcome's schedule as-is).
    for s in &mut out.plan.stages {
        for d in &mut s.devices {
            *d = best;
        }
    }
    out.schedule = crate::schedule::Schedule::for_sim(&out.plan, model, policy);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::schedule::{ZeroBubbleH1, DEFAULT_POLICY};

    fn fixture(env: &str) -> (ClusterSpec, ModelDesc, ProfileTable, TrainConfig) {
        let cluster = ClusterSpec::env(env, 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(128, 16);
        (cluster, model, table, cfg)
    }

    #[test]
    fn every_hpp_method_plans_through_one_path() {
        let (cluster, model, table, cfg) = fixture("A");
        for m in [
            Method::Asteroid,
            Method::OnDevice,
            Method::DataParallel,
            Method::Eddl,
            Method::GpipePP,
            Method::PipeDream,
            Method::Dapple,
        ] {
            let out = Planner::Baseline(m)
                .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
                .unwrap();
            assert!(out.predicted_throughput > 0.0, "{m:?}");
            assert_eq!(out.policy.name(), DEFAULT_POLICY.name(), "{m:?}");
        }
        assert!(Planner::Baseline(Method::HetPipe)
            .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
            .is_err());
    }

    #[test]
    fn asteroid_and_default_custom_agree() {
        let (cluster, model, table, cfg) = fixture("B");
        let a = Planner::Asteroid
            .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
            .unwrap();
        let c = Planner::Custom(PlannerConfig::default())
            .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
            .unwrap();
        assert_eq!(a.plan, c.plan);
    }

    #[test]
    fn threaded_policy_overrides_custom_config_policy() {
        // `.schedule(..)` must win over a stale PlannerConfig::policy:
        // the outcome carries the threaded policy, on every method.
        let (cluster, model, table, cfg) = fixture("B");
        let out = Planner::Custom(PlannerConfig::default())
            .plan(&table, &cluster, &model, &cfg, &ZeroBubbleH1)
            .unwrap();
        assert_eq!(out.policy.name(), "zb-h1");
        assert_eq!(out.schedule.policy, "zb-h1");
        for m in [Method::DataParallel, Method::GpipePP, Method::OnDevice] {
            let out = Planner::Baseline(m)
                .plan(&table, &cluster, &model, &cfg, &ZeroBubbleH1)
                .unwrap();
            assert_eq!(out.schedule.policy, "zb-h1", "{m:?}");
        }
    }

    #[test]
    fn on_device_uses_strongest() {
        // Env C: NX is device 0.
        let (cluster, model, table, cfg) = fixture("C");
        let out = Planner::Baseline(Method::OnDevice)
            .plan(&table, &cluster, &model, &cfg, DEFAULT_POLICY)
            .unwrap();
        assert_eq!(out.plan.num_stages(), 1);
        assert_eq!(out.plan.stages[0].devices, vec![0]);
    }
}
