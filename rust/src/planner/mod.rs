//! Asteroid's parallelism planning (paper §3.3).
//!
//! * `plan`     — HPP plan representation + K_p policies (Fig. 4);
//! * `memory`   — Eq. (3) per-stage memory model;
//! * `alloc`    — Algorithm 1: micro-batch allocation within a group;
//! * `cost`     — Eqs. (4)-(6), (8), (11): dominant-step latency model;
//! * `dp`       — Algorithm 2: dynamic-programming stage/group search;
//! * `baselines`— DP, EDDL, GPipe-PP, PipeDream, Dapple, HetPipe.

pub mod alloc;
pub mod baselines;
pub mod cost;
pub mod dp;
pub mod memory;
pub mod plan;

pub use alloc::{allocate_microbatch, AllocOpts};
pub use cost::{plan_steps, predicted_throughput, round_latency, StepCost};
pub use dp::{plan_hpp, plan_hpp_sweep_microbatch, PlanOutcome, PlannerConfig};
pub use plan::{KpPolicy, Plan, Stage};
