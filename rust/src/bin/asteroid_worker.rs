//! `asteroid-worker` — one pipeline stage slot as a standalone edge
//! process.
//!
//! ```text
//! asteroid-worker --listen 127.0.0.1:7101 [--quiet]
//! ```
//!
//! The worker binds its listen address, prints `listening on <addr>`
//! (launch scripts and tests parse this — with `--listen host:0` the
//! kernel picks the port), and then serves the
//! [`asteroid::comm::rpc`] protocol until the driver says `Exit`, the
//! control connection dies, or a `Die` fault injection terminates the
//! process unclean (exit code 86).
//!
//! Everything else — which stage it plays, the schedule script, peer
//! addresses, optimizer, heartbeat period — arrives over the wire from
//! the `asteroid train --backend rpc` driver; restarting a run never
//! needs worker-side flags.

use std::net::TcpListener;

use anyhow::{Context, Result};

use asteroid::pipeline::rpc_worker::{serve, ServeOpts, ServeOutcome};
use asteroid::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["quiet"])?;
    if args.positional.first().map(String::as_str) == Some("help") {
        eprintln!("usage: asteroid-worker --listen <host:port> [--quiet]");
        return Ok(());
    }
    let listen = args.str_or("listen", "127.0.0.1:0");
    // Retry the bind: a restarted worker (churn rejoin) reuses its
    // predecessor's port, which can sit in TIME_WAIT for a few seconds
    // after the old process died mid-connection.
    let listener = bind_with_retry(&listen)
        .with_context(|| format!("binding worker listener on {listen}"))?;
    // Parsed by launchers: the actual bound address (port 0 resolved).
    // Explicit flush — stdout is block-buffered when piped, and the
    // launcher blocks on this line.
    println!("listening on {}", listener.local_addr()?);
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    let opts = ServeOpts { die_for_real: true, verbose: !args.has_flag("quiet") };
    match serve(listener, opts)? {
        ServeOutcome::Clean => Ok(()),
        // Unreachable with die_for_real (the process exits instead),
        // but keep the mapping total.
        ServeOutcome::Died => std::process::exit(86),
    }
}

/// Bind, retrying `EADDRINUSE`-style failures for ~10 s (40 x 250 ms).
fn bind_with_retry(listen: &str) -> Result<TcpListener> {
    let mut last_err = None;
    for _ in 0..40 {
        match TcpListener::bind(listen) {
            Ok(l) => return Ok(l),
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(std::time::Duration::from_millis(250));
            }
        }
    }
    Err(last_err.expect("bind never attempted").into())
}
