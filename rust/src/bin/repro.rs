//! Paper-reproduction harness: regenerate every table and figure of
//! the Asteroid paper's evaluation.
//!
//! ```text
//! repro <experiment> [--out results]
//! repro all --out results
//! repro list
//! ```

use std::path::PathBuf;

use anyhow::{bail, Result};
use asteroid::metrics::Table;
use asteroid::repro;
use asteroid::util::cli::Args;

fn one(name: &str) -> Result<Vec<(String, Table)>> {
    Ok(match name {
        "table1" => vec![("table1".into(), repro::table1())],
        "fig1" => {
            let (l, r) = repro::fig1();
            vec![("fig1_left".into(), l), ("fig1_right".into(), r)]
        }
        "table2" => vec![("table2".into(), repro::table2())],
        "fig5" => vec![("fig5".into(), repro::fig5())],
        "fig6" => vec![("fig6".into(), repro::fig6())],
        "table4" | "fig12" => vec![("table4".into(), repro::table4())],
        "fig13" => vec![("fig13".into(), repro::fig13())],
        "fig14" => vec![("fig14".into(), repro::fig14())],
        "fig15a" => vec![("fig15a".into(), repro::fig15a())],
        "fig15b" => vec![("fig15b".into(), repro::fig15b())],
        "fig16" => vec![("fig16".into(), repro::fig16())],
        "fig17" => vec![("fig17".into(), repro::fig17())],
        "fig18" => vec![("fig18".into(), repro::fig18())],
        "table7" => vec![("table7".into(), repro::table7())],
        "table8" => vec![("table8".into(), repro::table8())],
        "energy" => vec![("energy".into(), repro::energy())],
        "recovery" => vec![("recovery_headline".into(), repro::recovery_headline())],
        "all" => repro::all_experiments(),
        other => bail!("unknown experiment {other:?} (try `repro list`)"),
    })
}

const EXPERIMENTS: &[(&str, &str)] = &[
    ("table1", "epoch time: A100 vs TX2 vs Nano"),
    ("fig1", "DP latency breakdown + bytes/sample DP vs PP"),
    ("table2", "communication volume: HDP vs HPP"),
    ("fig5", "memory footprint breakdown"),
    ("fig6", "non-linear batch->time curves"),
    ("table4", "Asteroid vs on-device/DP/PP (+ Fig 12 configs)"),
    ("fig13", "vs EDDL/PipeDream/Dapple/HetPipe"),
    ("fig14", "time to target accuracy"),
    ("fig15a", "planning ablation"),
    ("fig15b", "1F1B K_p policy ablation"),
    ("fig16", "fault-tolerance recovery per dropout scenario"),
    ("fig17", "throughput timeline around a failure"),
    ("fig18", "scalability on 1..8 Nanos"),
    ("table7", "planning overhead"),
    ("table8", "profiling overhead"),
    ("energy", "energy per sample (§5.7)"),
    ("recovery", "recovery speedup headline (§5.5)"),
    ("all", "everything above"),
];

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let Some(name) = args.positional.first().map(String::as_str) else {
        eprintln!("usage: repro <experiment> [--out results]; `repro list` to enumerate");
        std::process::exit(2);
    };
    if name == "list" {
        for (n, d) in EXPERIMENTS {
            println!("{n:<10} {d}");
        }
        return Ok(());
    }
    let out: Option<PathBuf> = args.get("out").map(PathBuf::from);
    let t0 = std::time::Instant::now();
    for (csv_name, table) in one(name)? {
        table.print();
        if let Some(dir) = &out {
            table.write_csv(dir, &csv_name)?;
            println!("  -> {}/{}.csv\n", dir.display(), csv_name);
        }
    }
    eprintln!("[{} done in {:.1}s]", name, t0.elapsed().as_secs_f64());
    Ok(())
}
