//! # Asteroid
//!
//! Reproduction of "Asteroid: Resource-Efficient Hybrid Pipeline
//! Parallelism for Collaborative DNN Training on Heterogeneous Edge
//! Devices" (MobiCom 2024).  See DESIGN.md for the architecture and
//! EXPERIMENTS.md for the paper-vs-measured results.
//!
//! Three layers: Pallas kernels (python, build-time) -> JAX stage
//! models (python, build-time, AOT-lowered to HLO text) -> this Rust
//! coordinator (planner + simulator + real PJRT pipeline runtime).

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod pipeline;
pub mod planner;
pub mod profiler;
pub mod repro;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;
