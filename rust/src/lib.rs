//! # Asteroid
//!
//! Reproduction of "Asteroid: Resource-Efficient Hybrid Pipeline
//! Parallelism for Collaborative DNN Training on Heterogeneous Edge
//! Devices" (MobiCom 2024).  See DESIGN.md for the architecture and
//! EXPERIMENTS.md for the paper-vs-measured results.
//!
//! Three layers: Pallas kernels (python, build-time) -> JAX stage
//! models (python, build-time, AOT-lowered to HLO text) -> this Rust
//! crate (planner + simulator + real PJRT pipeline runtime).
//!
//! The user-facing surface is [`session`]: a typed
//! [`session::SessionBuilder`] covers preprocessing + planning (every
//! planner through one [`planner::Planner`] dispatch), and an
//! [`session::ExecutionBackend`] — [`session::SimBackend`],
//! [`session::PjrtBackend`] or the multi-process
//! [`session::RpcBackend`] — turns the planned session into one
//! unified [`session::RunReport`].  Device-exit fault tolerance is a
//! declarative [`session::FaultSpec`] on the session.
//!
//! The default build carries the full planner/simulator/fault stack
//! plus the multi-process RPC backend (`asteroid-worker` processes
//! over TCP, reference-kernel numerics); in-process PJRT execution of
//! AOT artifacts needs the `pjrt` cargo feature (see rust/xla/).

// The whole crate is safe Rust.  The one historical exception — a
// zero-copy f32 -> byte reinterpretation at the XLA literal boundary
// (`runtime::tensor`) — was replaced with a safe staging copy so the
// guarantee holds under every feature combination.
#![forbid(unsafe_code)]

pub mod codec;
pub mod comm;
pub mod config;
pub mod data;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod pipeline;
pub mod planner;
pub mod profiler;
pub mod repro;
pub mod runtime;
pub mod schedule;
pub mod session;
pub mod sim;
pub mod util;
pub mod verify;
