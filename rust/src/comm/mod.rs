//! Communication: closed-form volume analytics (paper §2.2-2.3,
//! Eqs. 1-2, Fig. 1, Table 2) and the live [`rpc`] transport of the
//! multi-process edge backend.
//!
//! The closed forms quantify why HPP beats both plain DP and HDP on
//! edge networks: HPP confines AllReduce to the parameter-light layers
//! it replicates and avoids cutting through huge feature maps.

pub mod collective;
pub mod rpc;

pub use collective::{ring_all_reduce, Collective, SyncMode};

use crate::model::ModelDesc;
use crate::planner::plan::Plan;

/// Eq. (2): V_HPP for a concrete plan, bytes per mini-batch.
///
///   G > 1: sum_i [2(|g_i|-1) P_i] + 2 beta sum_j a_j
///   G = 1: 2(|g_1|-1) P
pub fn hpp_volume(model: &ModelDesc, plan: &Plan) -> u64 {
    let beta = (plan.microbatch * plan.num_micro) as u64; // global mini-batch
    let mut allreduce: u64 = 0;
    for s in &plan.stages {
        let g = s.devices.len() as u64;
        if g > 1 {
            let p_i = model.weight_bytes_range(s.layers.0, s.layers.1);
            allreduce += 2 * (g - 1) * p_i;
        }
    }
    let mut pipelined: u64 = 0;
    for w in plan.stages.windows(2) {
        pipelined += 2 * beta * model.boundary_bytes(w[0].layers.1);
    }
    allreduce + pipelined
}

/// Plain-DP volume: every device ring-AllReduces the full model once
/// per mini-batch; per-device volume is 2(n-1)/n * P, total 2(n-1) P.
pub fn dp_volume(model: &ModelDesc, n_devices: usize) -> u64 {
    if n_devices <= 1 {
        return 0;
    }
    2 * (n_devices as u64 - 1) * model.total_weight_bytes()
}

/// Fig. 1(right): bytes communicated **per sample**.
pub fn dp_bytes_per_sample(model: &ModelDesc, n_devices: usize, minibatch: usize) -> f64 {
    dp_volume(model, n_devices) as f64 / minibatch as f64
}

/// Per-sample bytes for a straight pipeline cut at `bounds` (GPipe-style
/// PP): each boundary tensor crosses twice (activation fwd + grad bwd).
pub fn pp_bytes_per_sample(model: &ModelDesc, bounds: &[usize]) -> f64 {
    // bounds: interior cut points, e.g. [10, 20] for 3 stages.
    bounds
        .iter()
        .map(|&j| 2 * model.boundary_bytes(j))
        .sum::<u64>() as f64
}

/// Table 2 support: the *communication-volume-optimal* HPP
/// configuration of Eq. (2) — replicate the (parameter-light) head
/// group and cut the pipeline at the smallest activation boundaries.
///
/// Note the distinction from the throughput planner: Algorithm 2
/// minimises HPP-Round *latency* (pipelined transfers overlap with
/// compute, so volume is nearly free in latency terms); the paper's
/// §2.3 analysis instead asks what the HPP *architecture* can confine
/// communication to, which is this configuration.  DESIGN.md
/// documents the interpretation.
pub fn volume_optimal_hpp(
    model: &ModelDesc,
    n_devices: usize,
    minibatch: usize,
    max_stages: usize,
) -> (Plan, u64) {
    use crate::planner::plan::Stage;
    let nl = model.num_layers();
    let beta = minibatch as u64;
    let mut best: Option<(Plan, u64)> = None;

    // Candidate cut points: the boundaries with the smallest activation
    // tensors (a cut anywhere else is strictly worse for Eq. 2).
    let mut cand: Vec<usize> = (1..nl).collect();
    cand.sort_by_key(|&j| model.boundary_bytes(j));
    cand.truncate(14);
    cand.sort_unstable();

    let max_p = max_stages.min(n_devices).max(1);
    // Enumerate stage counts and cut subsets (small search space).
    for p in 1..=max_p {
        let cuts_needed = p - 1;
        let mut choose = vec![0usize; cuts_needed];
        enumerate_combinations(&cand, cuts_needed, &mut choose, 0, 0, &mut |cuts| {
            // First group takes the spare devices, later stages one each.
            let g1 = n_devices - (p - 1);
            let mut bounds = vec![0usize];
            bounds.extend_from_slice(cuts);
            bounds.push(nl);
            let mut stages = Vec::with_capacity(p);
            let mut dev = 0usize;
            for s in 0..p {
                let g = if s == 0 { g1 } else { 1 };
                let devices: Vec<usize> = (dev..dev + g).collect();
                dev += g;
                let alloc = split_evenly(minibatch.min(64), g);
                stages.push(Stage {
                    layers: (bounds[s], bounds[s + 1]),
                    devices,
                    alloc,
                    kp: 1,
                });
            }
            let plan = Plan {
                stages,
                microbatch: minibatch.min(64),
                num_micro: (minibatch + 63) / 64,
            };
            let _ = beta;
            let v = hpp_volume_minibatch(model, &plan, minibatch);
            if best.as_ref().map_or(true, |(_, bv)| v < *bv) {
                best = Some((plan, v));
            }
        });
    }
    best.expect("at least the single-stage plan exists")
}

fn split_evenly(total: usize, g: usize) -> Vec<usize> {
    let base = total / g;
    let rem = total % g;
    (0..g).map(|i| base + usize::from(i < rem)).collect()
}

fn enumerate_combinations(
    cand: &[usize],
    k: usize,
    buf: &mut [usize],
    depth: usize,
    start: usize,
    f: &mut impl FnMut(&[usize]),
) {
    if depth == k {
        f(&buf[..k]);
        return;
    }
    for i in start..cand.len() {
        buf[depth] = cand[i];
        enumerate_combinations(cand, k, buf, depth + 1, i + 1, f);
    }
}

/// Eq. (2) with an explicit global mini-batch (the plan's
/// microbatch*num_micro may round up).
pub fn hpp_volume_minibatch(model: &ModelDesc, plan: &Plan, minibatch: usize) -> u64 {
    let beta = minibatch as u64;
    let mut allreduce: u64 = 0;
    for s in &plan.stages {
        let g = s.devices.len() as u64;
        if g > 1 {
            allreduce += 2 * (g - 1) * model.weight_bytes_range(s.layers.0, s.layers.1);
        }
    }
    let mut pipelined: u64 = 0;
    for w in plan.stages.windows(2) {
        pipelined += 2 * beta * model.boundary_bytes(w[0].layers.1);
    }
    allreduce + pipelined
}

/// Fig. 1(left): DP mini-batch latency split into computation vs
/// synchronisation, for a homogeneous group.
pub fn dp_latency_breakdown(
    table: &crate::profiler::ProfileTable,
    cluster: &crate::config::ClusterSpec,
    model: &ModelDesc,
    minibatch: usize,
) -> (f64, f64) {
    let n = cluster.n();
    let per_dev = (minibatch + n - 1) / n;
    let nl = model.num_layers();
    let compute = (0..n)
        .map(|d| table.time_fwd_bwd(d, 0, nl, per_dev))
        .fold(0.0, f64::max);
    let group: Vec<usize> = (0..n).collect();
    let sync = 2.0 * (n as f64 - 1.0) * model.total_weight_bytes() as f64
        / (n as f64 * cluster.min_bandwidth(&group));
    (compute, sync)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::planner::plan::{Plan, Stage};
    use crate::profiler::ProfileTable;

    fn two_stage_plan(model: &ModelDesc) -> Plan {
        let nl = model.num_layers();
        Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0, 1], alloc: vec![4, 4], kp: 3 },
                Stage { layers: (nl / 2, nl), devices: vec![2], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 4,
        }
    }

    #[test]
    fn hpp_volume_terms() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let nl = model.num_layers();
        let p1 = model.weight_bytes_range(0, nl / 2);
        let a = model.boundary_bytes(nl / 2);
        let beta = 32u64; // 8 * 4
        let expect = 2 * p1 + 2 * beta * a;
        assert_eq!(hpp_volume(&model, &plan), expect);
    }

    #[test]
    fn single_group_hpp_is_pure_allreduce() {
        let model = zoo::mobilenet_v2();
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 1, 2],
                alloc: vec![3, 3, 2],
                kp: 1,
            }],
            microbatch: 8,
            num_micro: 4,
        };
        assert_eq!(
            hpp_volume(&model, &plan),
            2 * 2 * model.total_weight_bytes()
        );
    }

    #[test]
    fn dp_volume_scales_with_devices() {
        let model = zoo::mobilenet_v2();
        assert_eq!(dp_volume(&model, 1), 0);
        assert!(dp_volume(&model, 5) > dp_volume(&model, 3));
    }

    #[test]
    fn cnn_pp_per_sample_exceeds_dp_at_large_minibatch() {
        // Fig. 1(right): for CNNs, PP's per-sample bytes can exceed DP's.
        let model = zoo::mobilenet_v2();
        let n = 3;
        let minibatch = 2048;
        let dp = dp_bytes_per_sample(&model, n, minibatch);
        // cut early, where feature maps are big
        let early = model.num_layers() / 4;
        let pp = pp_bytes_per_sample(&model, &[early, early * 2]);
        assert!(pp > dp, "pp {pp} dp {dp}");
    }

    #[test]
    fn bert_pp_cheaper_than_dp() {
        // For transformers (huge params, small activations) PP wins —
        // cutting at encoder-block boundaries (9 modules per block, LN
        // output = seq*hidden activations).
        let model = zoo::bert_small();
        let dp = dp_bytes_per_sample(&model, 3, 64);
        let pp = pp_bytes_per_sample(&model, &[1 + 9, 1 + 18]);
        assert!(pp < dp, "pp {pp} dp {dp}");
    }

    #[test]
    fn dp_breakdown_sync_dominates_on_slow_net() {
        // Fig. 1(left): at 100 Mbps, synchronisation dominates the DP
        // mini-batch latency for parameter-heavy models.
        let cluster = ClusterSpec::nanos(3, 100.0);
        let model = zoo::resnet50();
        let table = ProfileTable::new(&cluster, &model);
        let (compute, sync) = dp_latency_breakdown(&table, &cluster, &model, 48);
        assert!(sync > compute, "sync {sync} compute {compute}");
    }
}
