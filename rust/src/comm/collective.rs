//! Pluggable round-sync collectives: *how* a replicated stage's group
//! reconciles parameters each HPP-Round.
//!
//! Two topologies exist, selected per session by [`SyncMode`]
//! (`SessionBuilder::sync`, `--sync ring|driver`):
//!
//! * [`SyncMode::Ring`] (default) — worker-to-worker ring AllReduce on
//!   the data plane.  Each member sends only to its ring successor;
//!   reduce-scatter then all-gather moves `2(g-1)/g * W` wire bytes
//!   per member in `2(g-1)` steps, and the driver's per-round
//!   involvement stays O(1) control messages per member (StartRound /
//!   RoundDone) regardless of group width.  This is Eq. 5's volume —
//!   the paper's AllReduce term *is* the ring formula.
//! * [`SyncMode::DriverStar`] — the degraded fallback: every member
//!   ships its full flat to the driver, which reduces and fans the
//!   result back out.  `2 g W` bytes serialise through the driver's
//!   link, so the star only wins when `g` is tiny (2 members cost the
//!   same wire volume as a ring but half the round trips) or when the
//!   mesh between workers is broken.
//!
//! The same seam prices both sides: the planner's Eq. 6 AllReduce term
//! and `sim::price` consume [`Collective`] (via
//! [`SyncMode::collective`]), and the RPC worker executes the ring
//! schedule through [`ring_all_reduce`] — one formula, one executor,
//! no second copy of the topology.

use anyhow::{bail, Result};

/// Round-sync topology of every replicated stage in a session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SyncMode {
    /// Worker-to-worker ring AllReduce on the data plane (default).
    #[default]
    Ring,
    /// Driver-mediated star: members upload flats, the driver reduces
    /// and fans back out.  Kept as the degraded / 2-member fallback.
    DriverStar,
}

impl SyncMode {
    pub const ALL: [SyncMode; 2] = [SyncMode::Ring, SyncMode::DriverStar];

    pub fn name(self) -> &'static str {
        match self {
            SyncMode::Ring => "ring",
            SyncMode::DriverStar => "driver",
        }
    }

    /// Wire tag (carried in `AssignSpec`).
    pub fn tag(self) -> u8 {
        match self {
            SyncMode::Ring => 0,
            SyncMode::DriverStar => 1,
        }
    }

    pub fn from_tag(tag: u8) -> Result<SyncMode> {
        Ok(match tag {
            0 => SyncMode::Ring,
            1 => SyncMode::DriverStar,
            other => bail!("unknown sync-mode tag {other}"),
        })
    }

    /// `--sync ring|driver`.
    pub fn parse(s: &str) -> Result<SyncMode> {
        Ok(match s {
            "ring" => SyncMode::Ring,
            "driver" | "star" | "driver-star" => SyncMode::DriverStar,
            other => bail!("unknown sync mode {other:?} (expected ring|driver)"),
        })
    }

    /// The pricing half of the seam.
    pub fn collective(self) -> &'static dyn Collective {
        match self {
            SyncMode::Ring => &RingCollective,
            SyncMode::DriverStar => &DriverStarCollective,
        }
    }

    /// Eq. 5/6 AllReduce wall-clock for `wire_bytes` of already-encoded
    /// parameters over a `group`-member stage whose bottleneck link
    /// runs at `min_bw` bytes/s.  Convenience over
    /// [`Collective::allreduce_time`].
    pub fn allreduce_time(self, wire_bytes: u64, group: usize, min_bw: f64) -> f64 {
        self.collective().allreduce_time(wire_bytes, group, min_bw)
    }

    /// Total wire bytes the topology moves per round for one stage.
    pub fn total_wire_bytes(self, wire_bytes: u64, group: usize) -> u64 {
        self.collective().total_wire_bytes(wire_bytes, group)
    }
}

impl std::fmt::Display for SyncMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What the planner's Eq. 6 term and `sim::price` need from a sync
/// topology: predicted wall-clock and wire volume.  The RPC worker
/// consumes the execution half ([`ring_all_reduce`] / the driver-star
/// frames); both halves live in this module so they cannot drift.
pub trait Collective: Sync {
    fn mode(&self) -> SyncMode;

    /// Wall-clock seconds to AllReduce `wire_bytes` over `group`
    /// members whose slowest involved link moves `min_bw` bytes/s.
    /// `group <= 1` is a no-op (0.0).
    fn allreduce_time(&self, wire_bytes: u64, group: usize, min_bw: f64) -> f64;

    /// Total bytes the topology puts on the network per round for one
    /// replicated stage (`group <= 1` -> 0).
    fn total_wire_bytes(&self, wire_bytes: u64, group: usize) -> u64;
}

/// Ring AllReduce: `2(g-1)` steps, each member moving `W/g` per step
/// over its successor link — `2(g-1)/g * W` per member, bandwidth-
/// optimal (paper Eq. 5).
pub struct RingCollective;

impl Collective for RingCollective {
    fn mode(&self) -> SyncMode {
        SyncMode::Ring
    }

    fn allreduce_time(&self, wire_bytes: u64, group: usize, min_bw: f64) -> f64 {
        if group <= 1 {
            return 0.0;
        }
        (2 * (group - 1)) as f64 * wire_bytes as f64 / (group as f64 * min_bw)
    }

    fn total_wire_bytes(&self, wire_bytes: u64, group: usize) -> u64 {
        if group <= 1 {
            return 0;
        }
        2 * (group as u64 - 1) * wire_bytes
    }
}

/// Driver-mediated star: every member uploads its full flat and
/// downloads the reduced one, and all `2 g W` bytes serialise through
/// the driver's link (the driver is one endpoint of every transfer).
pub struct DriverStarCollective;

impl Collective for DriverStarCollective {
    fn mode(&self) -> SyncMode {
        SyncMode::DriverStar
    }

    fn allreduce_time(&self, wire_bytes: u64, group: usize, min_bw: f64) -> f64 {
        if group <= 1 {
            return 0.0;
        }
        (2 * group) as f64 * wire_bytes as f64 / min_bw
    }

    fn total_wire_bytes(&self, wire_bytes: u64, group: usize) -> u64 {
        if group <= 1 {
            return 0;
        }
        2 * group as u64 * wire_bytes
    }
}

/// Segment bounds of a flat of `len` elements split across `group`
/// ring members: segment `s` is `[seg_range.0, seg_range.1)`.  The
/// first `len % group` segments absorb the remainder, so segments
/// differ by at most one element and cover `0..len` exactly.
pub fn seg_range(len: usize, group: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < group);
    let base = len / group;
    let rem = len % group;
    let start = s * base + s.min(rem);
    let end = start + base + usize::from(s < rem);
    (start, end)
}

/// The ring AllReduce schedule, abstracted over the transport: the RPC
/// worker wires `send`/`recv` to framed TCP toward its ring successor
/// / from its predecessor; the loopback tests wire them to in-process
/// channels.  On return `flat` holds the element-wise **sum** over all
/// `group` members (callers divide for an average).
///
/// Step `t` of the reduce-scatter (t in `0..group-1`): member `index`
/// sends segment `(index - t) mod g` and receives-and-adds segment
/// `(index - t - 1) mod g`.  Step `t` of the all-gather (t in
/// `group-1..2(group-1)`): the same rotation, but the received segment
/// *replaces* local data (it is already fully reduced).  Connections
/// are FIFO, so `recv` must yield the peer's step-`t` segment in step
/// order; the executor verifies the segment length.
pub fn ring_all_reduce<S, R>(
    flat: &mut [f32],
    index: usize,
    group: usize,
    mut send: S,
    mut recv: R,
) -> Result<()>
where
    S: FnMut(usize, usize, &[f32]) -> Result<()>,
    R: FnMut(usize, usize) -> Result<Vec<f32>>,
{
    if group <= 1 {
        return Ok(());
    }
    assert!(index < group, "ring index {index} out of group {group}");
    let len = flat.len();
    // Reduce-scatter: after step t every member holds the partial sum
    // of t+2 contributions in the segment it just received.
    for t in 0..group - 1 {
        let send_seg = (index + group - t % group) % group;
        let recv_seg = (index + group - t % group - 1) % group;
        let (ss, se) = seg_range(len, group, send_seg);
        send(t, send_seg, &flat[ss..se])?;
        let chunk = recv(t, recv_seg)?;
        let (rs, re) = seg_range(len, group, recv_seg);
        if chunk.len() != re - rs {
            bail!(
                "ring step {t}: segment {recv_seg} carries {} elems, expected {}",
                chunk.len(),
                re - rs
            );
        }
        for (dst, src) in flat[rs..re].iter_mut().zip(&chunk) {
            *dst += *src;
        }
    }
    // All-gather: rotate the fully-reduced segments around the ring.
    for t in group - 1..2 * (group - 1) {
        let rot = t - (group - 1);
        let send_seg = (index + 1 + group - rot % group) % group;
        let recv_seg = (index + group - rot % group) % group;
        let (ss, se) = seg_range(len, group, send_seg);
        send(t, send_seg, &flat[ss..se])?;
        let chunk = recv(t, recv_seg)?;
        let (rs, re) = seg_range(len, group, recv_seg);
        if chunk.len() != re - rs {
            bail!(
                "ring step {t}: segment {recv_seg} carries {} elems, expected {}",
                chunk.len(),
                re - rs
            );
        }
        flat[rs..re].copy_from_slice(&chunk);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// Run a `group`-wide ring over in-process channels and return
    /// every member's final flat.
    fn run_ring(inputs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let group = inputs.len();
        // tx[i] feeds member i's inbox; member i sends to (i+1) % g.
        let (txs, rxs): (Vec<_>, Vec<_>) =
            (0..group).map(|_| mpsc::channel::<Vec<f32>>()).unzip();
        let mut handles = Vec::new();
        let mut rxs = rxs.into_iter();
        for (i, mut flat) in inputs.into_iter().enumerate() {
            let tx_next = txs[(i + 1) % group].clone();
            let rx = rxs.next().unwrap();
            handles.push(std::thread::spawn(move || {
                ring_all_reduce(
                    &mut flat,
                    i,
                    group,
                    |_t, _seg, chunk| {
                        tx_next.send(chunk.to_vec()).map_err(|e| anyhow::anyhow!("{e}"))
                    },
                    |_t, _seg| rx.recv().map_err(|e| anyhow::anyhow!("{e}")),
                )
                .unwrap();
                flat
            }));
        }
        drop(txs);
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Widths 2/4/8: the ring result equals the star reference (a
    /// plain elementwise sum) to fp tolerance, on every member, with a
    /// length that does not divide evenly.
    #[test]
    fn ring_matches_star_reference_at_widths_2_4_8() {
        for group in [2usize, 4, 8] {
            let len = 1031; // prime: exercises uneven segments
            let inputs: Vec<Vec<f32>> = (0..group)
                .map(|i| {
                    (0..len)
                        .map(|k| ((i * len + k) % 97) as f32 * 0.25 - 3.0)
                        .collect()
                })
                .collect();
            let mut reference = vec![0.0f32; len];
            for input in &inputs {
                for (r, v) in reference.iter_mut().zip(input) {
                    *r += *v;
                }
            }
            let outs = run_ring(inputs);
            for (i, out) in outs.iter().enumerate() {
                for (k, (got, want)) in out.iter().zip(&reference).enumerate() {
                    assert!(
                        (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                        "group {group} member {i} elem {k}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn segments_partition_exactly() {
        for (len, group) in [(10usize, 3usize), (7, 7), (5, 8), (1031, 4), (0, 2)] {
            let mut cursor = 0;
            for s in 0..group {
                let (a, b) = seg_range(len, group, s);
                assert_eq!(a, cursor, "len {len} group {group} seg {s}");
                assert!(b >= a);
                cursor = b;
            }
            assert_eq!(cursor, len);
        }
    }

    #[test]
    fn pricing_formulas_match_topology_volume() {
        let w = 1_000_000u64;
        let bw = 10e6;
        // Ring is Eq. 5: 2(g-1)/g * W / bw.
        let ring = SyncMode::Ring.allreduce_time(w, 4, bw);
        assert!((ring - 2.0 * 3.0 * 1_000_000.0 / (4.0 * 10e6)).abs() < 1e-12);
        // Star serialises 2gW through the driver link.
        let star = SyncMode::DriverStar.allreduce_time(w, 4, bw);
        assert!((star - 8.0 * 1_000_000.0 / 10e6).abs() < 1e-12);
        assert!(star > ring, "the star must price worse at width 4");
        // Degenerate group: free in both modes.
        for m in SyncMode::ALL {
            assert_eq!(m.allreduce_time(w, 1, bw), 0.0);
            assert_eq!(m.total_wire_bytes(w, 1), 0);
        }
        assert_eq!(SyncMode::Ring.total_wire_bytes(w, 4), 6 * w);
        assert_eq!(SyncMode::DriverStar.total_wire_bytes(w, 4), 8 * w);
    }

    #[test]
    fn sync_mode_round_trips_tags_and_names() {
        for m in SyncMode::ALL {
            assert_eq!(SyncMode::from_tag(m.tag()).unwrap(), m);
            assert_eq!(SyncMode::parse(m.name()).unwrap(), m);
        }
        assert!(SyncMode::from_tag(9).is_err());
        assert!(SyncMode::parse("mesh").is_err());
        assert_eq!(SyncMode::default(), SyncMode::Ring);
    }
}
