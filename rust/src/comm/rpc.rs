//! Length-prefixed TCP framing + the RPC message set of the
//! multi-process edge backend (`session::RpcBackend` +
//! `asteroid-worker`).
//!
//! Two planes share one wire format:
//!
//! * **control plane** (driver <-> worker): worker assignment
//!   ([`AssignSpec`]: plan slice + compute script + peer addresses),
//!   round control, heartbeats, round reports, parameter
//!   fetch/restore, group round-sync fallback mediation, and fault
//!   injection;
//! * **data plane** (worker <-> worker): boundary activation and
//!   gradient tensors between adjacent pipeline stages, plus the ring
//!   AllReduce segments ([`RpcMsg::RingChunk`]) replicated-stage
//!   groups exchange under [`SyncMode::Ring`].
//!
//! The codec is a hand-rolled binary format (the build is offline:
//! no serde/bincode), little-endian for payload scalars, with a
//! 9-byte frame header:
//!
//! ```text
//!   magic "ASTR" (4) | version (1) | payload length, big-endian u32 (4)
//! ```
//!
//! Readers use `read_exact`, so partial reads (TCP segmentation) are
//! handled by construction; frames above [`MAX_FRAME`] are rejected
//! *before* any allocation, so a corrupt or hostile peer cannot make a
//! worker allocate gigabytes from four bytes of length.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::collective::SyncMode;
use crate::codec::Codec;
use crate::pipeline::optimizer::OptimizerCfg;
use crate::pipeline::step::RefLayerSpec;
use crate::runtime::{Tensor, TensorData};
use crate::schedule::ComputeOp;

/// Frame magic: an `asteroid-worker` port answers nothing else.
pub const MAGIC: [u8; 4] = *b"ASTR";
/// Wire-format version; bumped on any incompatible codec change.
/// v2: f32 tensor payloads and Sync flats carry a wire-codec tag
/// (fp32/fp16/bf16/int8 compressed data plane); `AssignSpec` carries
/// the worker's per-boundary codecs; `RoundDone` carries data-plane
/// byte meters.
/// v3: `AssignSpec` carries the sync topology (mode tag, ring index,
/// ring member addresses); `RingChunk` frames and the `Ring`
/// connection role exist; `RoundDone` carries round-sync meters.
pub const VERSION: u8 = 3;
/// Hard ceiling on one frame's payload (activations of deep stages
/// stay far below this; anything larger is a framing error).
pub const MAX_FRAME: usize = 256 << 20;
/// Frame header length: magic + version + payload length.
pub const HEADER_LEN: usize = 9;

// ------------------------------------------------------------ framing

/// Write one frame (header + payload) and flush.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame payload {} exceeds MAX_FRAME {}", payload.len(), MAX_FRAME);
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5..9].copy_from_slice(&(payload.len() as u32).to_be_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload.  Blocks until a whole frame arrived
/// (partial reads are reassembled by `read_exact`); rejects bad magic,
/// version mismatches and oversized lengths before allocating.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("reading frame header")?;
    if header[..4] != MAGIC {
        bail!("bad frame magic {:02x?} (not an asteroid peer?)", &header[..4]);
    }
    if header[4] != VERSION {
        bail!("wire version {} != {}", header[4], VERSION);
    }
    let len = u32::from_be_bytes([header[5], header[6], header[7], header[8]]) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("reading frame payload")?;
    Ok(payload)
}

/// Encode + frame + send one message.
pub fn send_msg(w: &mut impl Write, msg: &RpcMsg) -> Result<()> {
    write_frame(w, &msg.encode())
}

/// [`send_msg`] compressing f32 tensor payloads / Sync flats with
/// `codec` (the data-plane hot path; control messages are unaffected).
pub fn send_msg_codec(w: &mut impl Write, msg: &RpcMsg, codec: Codec) -> Result<()> {
    write_frame(w, &msg.encode_with(codec))
}

/// Receive + decode one message.
pub fn recv_msg(r: &mut impl Read) -> Result<RpcMsg> {
    RpcMsg::decode(&read_frame(r)?)
}

/// Zero-copy tensor framing: send a data-plane message without first
/// materialising the whole frame as one contiguous payload `Vec`.
///
/// [`send_msg_codec`] copies every f32 element into a payload buffer
/// before `write_frame` hands it to the socket; on the Act/Grad and
/// ring-chunk hot paths the payload *is* the tensor, so that doubles
/// the memory traffic of every transfer.  Here only the frame header
/// and the small message prefix (tag, generation, shape metadata,
/// element count, codec tag) are encoded up front — their lengths fix
/// the frame length exactly — and the f32 payload is then streamed
/// straight from the borrowed slice through a fixed stack chunk.  The
/// bytes on the wire are identical to `send_msg_codec` (asserted by
/// `streamed_framing_matches_encode_with`); only the copies differ.
///
/// Lossy codecs must transform every element anyway, so their payload
/// is staged through one exactly-sized scratch `Vec` (still never a
/// whole-frame buffer).  Messages without a large f32 payload fall
/// back to the buffered path.
///
/// Returns total bytes written (header + payload) for the wire meters.
pub fn send_msg_streamed(w: &mut impl Write, msg: &RpcMsg, codec: Codec) -> Result<u64> {
    let streamable = matches!(msg, RpcMsg::RingChunk { .. })
        || matches!(
            msg,
            RpcMsg::Act { t, .. } | RpcMsg::Targets { t, .. } | RpcMsg::Grad { t, .. }
                if matches!(t.data, TensorData::F32(_))
        );
    if !streamable {
        let payload = msg.encode_with(codec);
        write_frame(w, &payload)?;
        return Ok((HEADER_LEN + payload.len()) as u64);
    }

    let mut e = Enc::default();
    let flat: &[f32] = match msg {
        RpcMsg::Act { gen, micro, t }
        | RpcMsg::Targets { gen, micro, t }
        | RpcMsg::Grad { gen, micro, t } => {
            let TensorData::F32(v) = &t.data else { unreachable!("checked streamable") };
            e.u8(match msg {
                RpcMsg::Act { .. } => T_ACT,
                RpcMsg::Targets { .. } => T_TARGETS,
                _ => T_GRAD,
            });
            e.u64(*gen);
            e.u64(*micro as u64);
            e.u8(t.shape.len() as u8);
            for &d in &t.shape {
                e.u32(d as u32);
            }
            e.u8(0); // dtype tag: f32
            e.u32(v.len() as u32);
            e.u8(codec.tag());
            v
        }
        RpcMsg::RingChunk { gen, step, seg, flat } => {
            e.u8(T_RING_CHUNK);
            e.u64(*gen);
            e.u32(*step as u32);
            e.u32(*seg as u32);
            e.u32(flat.len() as u32);
            e.u8(codec.tag());
            flat
        }
        _ => unreachable!("checked streamable"),
    };
    stream_frame_f32(w, &e.into_bytes(), flat, codec)
}

/// Frame-and-send one ring AllReduce segment straight from a borrowed
/// slice — the ring executor's send path.  Equivalent on the wire to
/// `send_msg_streamed(&RpcMsg::RingChunk {..})`, without constructing
/// the message (which would copy the segment into an owned `Vec`).
pub fn send_ring_chunk(
    w: &mut impl Write,
    gen: u64,
    step: usize,
    seg: usize,
    flat: &[f32],
    codec: Codec,
) -> Result<u64> {
    let mut e = Enc::default();
    e.u8(T_RING_CHUNK);
    e.u64(gen);
    e.u32(step as u32);
    e.u32(seg as u32);
    e.u32(flat.len() as u32);
    e.u8(codec.tag());
    stream_frame_f32(w, &e.into_bytes(), flat, codec)
}

/// The streaming core: header + `prefix`, then the f32 payload encoded
/// by `codec` without a whole-frame buffer.
fn stream_frame_f32(
    w: &mut impl Write,
    prefix: &[u8],
    flat: &[f32],
    codec: Codec,
) -> Result<u64> {
    let payload_len = prefix.len() + codec.payload_bytes(flat.len());
    if payload_len > MAX_FRAME {
        bail!("frame payload {payload_len} exceeds MAX_FRAME {MAX_FRAME}");
    }
    let mut header = [0u8; HEADER_LEN];
    header[..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5..9].copy_from_slice(&(payload_len as u32).to_be_bytes());
    w.write_all(&header)?;
    w.write_all(prefix)?;
    match codec {
        Codec::Fp32 => {
            // Stream the slice itself: LE conversion happens in a fixed
            // stack chunk, so peak extra memory is 4 KiB however large
            // the tensor.
            let mut tmp = [0u8; 4 * LE_CHUNK];
            for chunk in flat.chunks(LE_CHUNK) {
                for (i, x) in chunk.iter().enumerate() {
                    tmp[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
                }
                w.write_all(&tmp[..4 * chunk.len()])?;
            }
        }
        _ => {
            let mut scratch = Vec::with_capacity(codec.payload_bytes(flat.len()));
            codec.encode_f32s(flat, &mut scratch);
            w.write_all(&scratch)?;
        }
    }
    w.flush()?;
    Ok((HEADER_LEN + payload_len) as u64)
}

// ------------------------------------------------------------- codec

/// Append-only binary encoder (little-endian scalars).
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f32s(&mut self, v: &[f32]) {
        // One reservation up front: these carry whole boundary tensors
        // on the data-plane hot path, and growth-reallocating per
        // element would copy the buffer O(log n) times.  Elements are
        // staged through a fixed chunk so the buffer grows by bulk
        // `extend_from_slice` calls, not 4-byte appends.
        self.buf.reserve(4 + 4 * v.len());
        self.u32(v.len() as u32);
        let mut tmp = [0u8; 4 * LE_CHUNK];
        for chunk in v.chunks(LE_CHUNK) {
            for (i, x) in chunk.iter().enumerate() {
                tmp[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
            }
            self.buf.extend_from_slice(&tmp[..4 * chunk.len()]);
        }
    }

    pub fn i32s(&mut self, v: &[i32]) {
        self.buf.reserve(4 + 4 * v.len());
        self.u32(v.len() as u32);
        let mut tmp = [0u8; 4 * LE_CHUNK];
        for chunk in v.chunks(LE_CHUNK) {
            for (i, x) in chunk.iter().enumerate() {
                tmp[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
            }
            self.buf.extend_from_slice(&tmp[..4 * chunk.len()]);
        }
    }

    /// An f32 vector compressed with `codec` — self-describing on the
    /// wire (element count, codec tag, codec payload), so the decoder
    /// needs no side channel.
    pub fn f32s_codec(&mut self, v: &[f32], codec: Codec) {
        self.buf.reserve(5 + codec.payload_bytes(v.len()));
        self.u32(v.len() as u32);
        self.u8(codec.tag());
        codec.encode_f32s(v, &mut self.buf);
    }

    pub fn tensor(&mut self, t: &Tensor) {
        self.tensor_codec(t, Codec::Fp32);
    }

    /// A tensor whose f32 payload is compressed with `codec` (i32
    /// payloads pass through: lossy codecs are defined over f32 only).
    pub fn tensor_codec(&mut self, t: &Tensor, codec: Codec) {
        self.u8(t.shape.len() as u8);
        for &d in &t.shape {
            self.u32(d as u32);
        }
        match &t.data {
            TensorData::F32(v) => {
                self.u8(0);
                self.f32s_codec(v, codec);
            }
            TensorData::I32(v) => {
                self.u8(1);
                self.i32s(v);
            }
        }
    }
}

/// Staging-chunk length (elements) for the bulk LE scalar copies.
const LE_CHUNK: usize = 1024;

/// Bounds-checked binary decoder over one frame payload.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated message: wanted {n} bytes at offset {}, frame has {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Bytes left in the frame.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Read an element count and validate it against the bytes that
    /// are actually left (each element occupies at least
    /// `min_elem_bytes`), so a corrupt count can never drive a huge
    /// `Vec::with_capacity` — the frame-level `MAX_FRAME` cap bounds
    /// the payload, this bounds what the payload may claim to contain.
    pub fn count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let cap = self.remaining() / min_elem_bytes.max(1);
        if n > cap {
            bail!(
                "corrupt count: {n} elements claimed, at most {cap} fit in the \
                 {} remaining frame bytes",
                self.remaining()
            );
        }
        Ok(n)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec()).context("non-utf8 string")?)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).context("f32 vec overflow")?)?;
        // Bulk decode into a pre-sized buffer (data-plane hot path).
        let mut out = vec![0f32; n];
        for (x, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *x = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(out)
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).context("i32 vec overflow")?)?;
        let mut out = vec![0i32; n];
        for (x, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *x = i32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(out)
    }

    /// Decode a codec-compressed f32 vector ([`Enc::f32s_codec`]) back
    /// to f32 — every receiver computes on decoded values, whatever
    /// the wire carried.
    pub fn f32s_codec(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let codec = Codec::from_tag(self.u8()?)?;
        // Same overflow guard as `f32s`: the logical size must fit
        // before any codec payload arithmetic.
        n.checked_mul(4).context("f32 vec overflow")?;
        let raw = self.take(codec.payload_bytes(n))?;
        codec.decode_f32s(n, raw)
    }

    pub fn tensor(&mut self) -> Result<Tensor> {
        let ndim = self.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let elems: usize = shape.iter().product();
        let tag = self.u8()?;
        let t = match tag {
            // f32 payloads are self-describing (codec tag in-stream)
            // and always decode to f32: receivers compute on decoded
            // values, whatever the wire carried.
            0 => Tensor::from_f32(&shape, self.f32s_codec()?),
            1 => Tensor::from_i32(&shape, self.i32s()?),
            other => bail!("unknown tensor dtype tag {other}"),
        };
        if t.elements() != elems {
            bail!("tensor data length does not match shape {shape:?}");
        }
        Ok(t)
    }
}

// ----------------------------------------------------------- messages

/// What a freshly-accepted connection is for — the first frame on
/// every connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnRole {
    /// The driver's control connection.
    Control,
    /// A peer worker's data connection (identified by its position).
    Data { stage: usize, slot: usize },
    /// A ring-AllReduce predecessor's connection: the sender is ring
    /// member `index` of the replicated stage `stage`, and this
    /// connection carries its `RingChunk` segments every round.
    Ring { stage: usize, index: usize },
}

/// Saved parameter state of one reference layer (checkpoint /
/// warm-start unit).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerState {
    /// Global model layer index.
    pub layer: usize,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
}

/// Everything one worker needs to run its pipeline slice: the plan
/// slice, the schedule script, the reference-layer dimensions, its
/// peers' data addresses and the round-sync/heartbeat configuration.
/// Re-sent in full after a fault (retasked workers get new scripts and
/// layer ranges; `warm_start` restores the checkpointed weights).
#[derive(Debug, Clone)]
pub struct AssignSpec {
    /// Monotone assignment generation (driver-wide).  Every data-plane
    /// tensor frame carries its sender's generation, and receivers
    /// drop frames from other generations — so a stale activation
    /// still in flight from an aborted round can never be consumed as
    /// fresh input by the replayed round after a recovery re-task.
    pub generation: u64,
    /// Global cluster device id this worker plays.
    pub device: usize,
    pub stage: usize,
    pub slot: usize,
    pub num_stages: usize,
    /// Replicas in this stage's group (round sync is only engaged
    /// when > 1, over the topology `sync` selects).
    pub group_size: usize,
    /// Round-sync topology of this stage's group.
    pub sync: SyncMode,
    /// This worker's position on the stage's ring (`0..group_size`;
    /// slot order).  Meaningful only under [`SyncMode::Ring`].
    pub ring_index: usize,
    /// Data addresses of every group member in ring order — member
    /// `ring_index` dials `ring[(ring_index + 1) % group_size]` as its
    /// ring successor.  Empty under [`SyncMode::DriverStar`] or for
    /// unreplicated stages.
    pub ring: Vec<String>,
    /// This device's ordered compute script for one HPP-Round
    /// (`Schedule::compute_script`).
    pub script: Vec<ComputeOp>,
    /// Bounded-staleness stash ring depth (0 = synchronous).
    pub stash_slots: usize,
    pub num_micro: usize,
    pub microbatch: usize,
    pub seed: u64,
    pub opt: OptimizerCfg,
    /// Worker -> driver heartbeat period, milliseconds.
    pub heartbeat_ms: u64,
    /// Wire codec for outbound activations (this stage's output
    /// boundary; the driver resolves it from the session's `CodecSpec`
    /// and the plan's layer cuts).
    pub codec_act: Codec,
    /// Wire codec for outbound gradients (this stage's input boundary).
    pub codec_grad: Codec,
    /// Wire codec for SyncRequest/SyncResult flat buffers.
    pub codec_sync: Codec,
    /// Reference-layer dimensions of this stage's layer range.
    pub layers: Vec<RefLayerSpec>,
    /// Data addresses of the next stage's slots (activation fan-out).
    pub next: Vec<String>,
    /// Data addresses of the previous stage's slots (gradient fan-out).
    pub prev: Vec<String>,
    /// Warm-start parameters by global layer index (fault restore);
    /// empty = fresh seeded init.
    pub warm_start: Vec<LayerState>,
}

/// One message of either plane.  The `u8` tags below are the wire
/// format — append-only (never renumber a released tag).
#[derive(Debug, Clone)]
pub enum RpcMsg {
    /// First frame on every connection.
    Hello { role: ConnRole },
    /// Driver -> worker: full (re)assignment.
    Assign(Box<AssignSpec>),
    /// Worker -> driver: assignment applied, data links up.
    Ready { device: usize },
    /// Driver -> worker: begin HPP-Round `round`.
    StartRound { round: usize },
    /// Stage input for a micro-batch (driver -> stage 0, or
    /// prev-stage worker -> this worker).  `gen` is the sender's
    /// assignment generation; receivers drop other generations.
    Act { gen: u64, micro: usize, t: Tensor },
    /// Head-stage targets for a micro-batch (driver -> last stage).
    Targets { gen: u64, micro: usize, t: Tensor },
    /// Gradient w.r.t. a stage's output (next-stage worker -> this).
    Grad { gen: u64, micro: usize, t: Tensor },
    /// Worker -> driver: periodic liveness beacon.
    Heartbeat { device: usize, seq: u64 },
    /// Worker -> driver: round finished on this worker.
    /// `logical_bytes`/`wire_bytes` meter the round's outbound
    /// data-plane tensor payloads before/after the wire codec, so the
    /// driver can report the measured compression ratio.
    /// `sync_bytes`/`sync_wall_s` meter the round's AllReduce: wire
    /// bytes this worker sent for group sync and the wall-clock it
    /// spent inside the collective (0 for unreplicated stages).
    RoundDone {
        device: usize,
        round: usize,
        loss_sum: f64,
        micros: usize,
        compute_s: f64,
        logical_bytes: u64,
        wire_bytes: u64,
        sync_bytes: u64,
        sync_wall_s: f64,
    },
    /// Worker -> driver: replicated-stage round sync contribution
    /// (kind 0 = summed gradients of a synchronous round, kind 1 =
    /// parameters for bounded-staleness averaging).  The
    /// [`SyncMode::DriverStar`] fallback path only.
    SyncRequest { device: usize, kind: u8, flat: Vec<f32> },
    /// Driver -> worker: the group-reduced buffer (star fallback).
    SyncResult { flat: Vec<f32> },
    /// Driver -> worker: abandon the current round (fault recovery);
    /// the worker discards in-flight state and awaits re-assignment.
    AbortRound,
    /// Worker -> driver: the round died under it (peer loss / abort);
    /// the worker is idle again and awaits instructions.
    RoundFailed { device: usize, error: String },
    /// Driver -> worker: send back the current parameters.
    FetchParams,
    /// Worker -> driver: checkpoint of this worker's layer states.
    Params { layers: Vec<LayerState> },
    /// Driver -> worker: clean shutdown (worker answers `Bye`).
    Exit,
    /// Driver -> worker: die *immediately and unclean* — the fault
    /// injection the integration tests use to make a real process
    /// disappear mid-round.
    Die,
    /// Worker -> driver: clean-shutdown acknowledgement.
    Bye,
    /// Worker -> driver: unrecoverable worker error.
    Fatal { device: usize, error: String },
    /// Driver -> worker: degrade this worker's compute by `factor`
    /// (>= 1.0; 1.0 restores full speed).  The straggler injection the
    /// churn tests use: the worker stays alive and heartbeating but
    /// stretches every round's compute, so only the driver's
    /// timing-drift detector can catch it.  Sent between rounds only.
    Throttle { factor: f64 },
    /// Worker -> worker (ring data plane): one ring-AllReduce segment.
    /// `step` is the position in the `2(g-1)`-step schedule, `seg` the
    /// flat segment index being rotated; receivers drop chunks from
    /// other assignment generations, exactly like `Act`/`Grad`.
    RingChunk { gen: u64, step: usize, seg: usize, flat: Vec<f32> },
}

const T_HELLO: u8 = 1;
const T_ASSIGN: u8 = 2;
const T_READY: u8 = 3;
const T_START_ROUND: u8 = 4;
const T_ACT: u8 = 5;
const T_TARGETS: u8 = 6;
const T_GRAD: u8 = 7;
const T_HEARTBEAT: u8 = 8;
const T_ROUND_DONE: u8 = 9;
const T_SYNC_REQUEST: u8 = 10;
const T_SYNC_RESULT: u8 = 11;
const T_ABORT_ROUND: u8 = 12;
const T_ROUND_FAILED: u8 = 13;
const T_FETCH_PARAMS: u8 = 14;
const T_PARAMS: u8 = 15;
const T_EXIT: u8 = 16;
const T_DIE: u8 = 17;
const T_BYE: u8 = 18;
const T_FATAL: u8 = 19;
const T_THROTTLE: u8 = 20;
const T_RING_CHUNK: u8 = 21;

fn enc_op(e: &mut Enc, op: &ComputeOp) {
    match *op {
        ComputeOp::Fwd(m) => {
            e.u8(0);
            e.u32(m as u32);
        }
        ComputeOp::Bwd(m) => {
            e.u8(1);
            e.u32(m as u32);
        }
        ComputeOp::BwdW(m) => {
            e.u8(2);
            e.u32(m as u32);
        }
    }
}

fn dec_op(d: &mut Dec) -> Result<ComputeOp> {
    let tag = d.u8()?;
    let m = d.u32()? as usize;
    Ok(match tag {
        0 => ComputeOp::Fwd(m),
        1 => ComputeOp::Bwd(m),
        2 => ComputeOp::BwdW(m),
        other => bail!("unknown compute-op tag {other}"),
    })
}

fn enc_opt(e: &mut Enc, opt: &OptimizerCfg) {
    match *opt {
        OptimizerCfg::Sgd { lr, momentum } => {
            e.u8(0);
            e.f32s(&[lr, momentum]);
        }
        OptimizerCfg::Adam { lr, beta1, beta2, eps } => {
            e.u8(1);
            e.f32s(&[lr, beta1, beta2, eps]);
        }
    }
}

fn dec_opt(d: &mut Dec) -> Result<OptimizerCfg> {
    let tag = d.u8()?;
    let v = d.f32s()?;
    Ok(match (tag, v.as_slice()) {
        (0, [lr, momentum]) => OptimizerCfg::Sgd { lr: *lr, momentum: *momentum },
        (1, [lr, b1, b2, eps]) => {
            OptimizerCfg::Adam { lr: *lr, beta1: *b1, beta2: *b2, eps: *eps }
        }
        _ => bail!("bad optimizer encoding (tag {tag}, {} params)", v.len()),
    })
}

fn enc_layer_state(e: &mut Enc, s: &LayerState) {
    e.u64(s.layer as u64);
    e.f32s(&s.scale);
    e.f32s(&s.bias);
}

fn dec_layer_state(d: &mut Dec) -> Result<LayerState> {
    Ok(LayerState { layer: d.u64()? as usize, scale: d.f32s()?, bias: d.f32s()? })
}

impl RpcMsg {
    /// Short tag name for logs/errors.
    pub fn kind(&self) -> &'static str {
        match self {
            RpcMsg::Hello { .. } => "Hello",
            RpcMsg::Assign(_) => "Assign",
            RpcMsg::Ready { .. } => "Ready",
            RpcMsg::StartRound { .. } => "StartRound",
            RpcMsg::Act { .. } => "Act",
            RpcMsg::Targets { .. } => "Targets",
            RpcMsg::Grad { .. } => "Grad",
            RpcMsg::Heartbeat { .. } => "Heartbeat",
            RpcMsg::RoundDone { .. } => "RoundDone",
            RpcMsg::SyncRequest { .. } => "SyncRequest",
            RpcMsg::SyncResult { .. } => "SyncResult",
            RpcMsg::AbortRound => "AbortRound",
            RpcMsg::RoundFailed { .. } => "RoundFailed",
            RpcMsg::FetchParams => "FetchParams",
            RpcMsg::Params { .. } => "Params",
            RpcMsg::Exit => "Exit",
            RpcMsg::Die => "Die",
            RpcMsg::Bye => "Bye",
            RpcMsg::Fatal { .. } => "Fatal",
            RpcMsg::Throttle { .. } => "Throttle",
            RpcMsg::RingChunk { .. } => "RingChunk",
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        self.encode_with(Codec::Fp32)
    }

    /// [`RpcMsg::encode`] with `codec` applied to the compressible
    /// payloads: Act/Targets/Grad tensor data and Sync flats.  The wire
    /// stays self-describing (the codec tag rides in the payload), so
    /// `decode` needs no matching argument — receivers always get f32
    /// back ("decode before compute").
    pub fn encode_with(&self, codec: Codec) -> Vec<u8> {
        let mut e = Enc::default();
        match self {
            RpcMsg::Hello { role } => {
                e.u8(T_HELLO);
                match role {
                    ConnRole::Control => e.u8(0),
                    ConnRole::Data { stage, slot } => {
                        e.u8(1);
                        e.u32(*stage as u32);
                        e.u32(*slot as u32);
                    }
                    ConnRole::Ring { stage, index } => {
                        e.u8(2);
                        e.u32(*stage as u32);
                        e.u32(*index as u32);
                    }
                }
            }
            RpcMsg::Assign(a) => {
                e.u8(T_ASSIGN);
                e.u64(a.generation);
                e.u64(a.device as u64);
                e.u32(a.stage as u32);
                e.u32(a.slot as u32);
                e.u32(a.num_stages as u32);
                e.u32(a.group_size as u32);
                e.u32(a.script.len() as u32);
                for op in &a.script {
                    enc_op(&mut e, op);
                }
                e.u32(a.stash_slots as u32);
                e.u32(a.num_micro as u32);
                e.u32(a.microbatch as u32);
                e.u64(a.seed);
                enc_opt(&mut e, &a.opt);
                e.u64(a.heartbeat_ms);
                e.u8(a.codec_act.tag());
                e.u8(a.codec_grad.tag());
                e.u8(a.codec_sync.tag());
                e.u32(a.layers.len() as u32);
                for l in &a.layers {
                    e.u64(l.layer as u64);
                    e.u32(l.in_elems as u32);
                    e.u32(l.out_elems as u32);
                    e.u8(u8::from(l.head));
                }
                e.u32(a.next.len() as u32);
                for s in &a.next {
                    e.str(s);
                }
                e.u32(a.prev.len() as u32);
                for s in &a.prev {
                    e.str(s);
                }
                e.u32(a.warm_start.len() as u32);
                for s in &a.warm_start {
                    enc_layer_state(&mut e, s);
                }
                e.u8(a.sync.tag());
                e.u32(a.ring_index as u32);
                e.u32(a.ring.len() as u32);
                for s in &a.ring {
                    e.str(s);
                }
            }
            RpcMsg::Ready { device } => {
                e.u8(T_READY);
                e.u64(*device as u64);
            }
            RpcMsg::StartRound { round } => {
                e.u8(T_START_ROUND);
                e.u64(*round as u64);
            }
            RpcMsg::Act { gen, micro, t } => {
                e.u8(T_ACT);
                e.u64(*gen);
                e.u64(*micro as u64);
                e.tensor_codec(t, codec);
            }
            RpcMsg::Targets { gen, micro, t } => {
                e.u8(T_TARGETS);
                e.u64(*gen);
                e.u64(*micro as u64);
                e.tensor_codec(t, codec);
            }
            RpcMsg::Grad { gen, micro, t } => {
                e.u8(T_GRAD);
                e.u64(*gen);
                e.u64(*micro as u64);
                e.tensor_codec(t, codec);
            }
            RpcMsg::Heartbeat { device, seq } => {
                e.u8(T_HEARTBEAT);
                e.u64(*device as u64);
                e.u64(*seq);
            }
            RpcMsg::RoundDone {
                device,
                round,
                loss_sum,
                micros,
                compute_s,
                logical_bytes,
                wire_bytes,
                sync_bytes,
                sync_wall_s,
            } => {
                e.u8(T_ROUND_DONE);
                e.u64(*device as u64);
                e.u64(*round as u64);
                e.f64(*loss_sum);
                e.u64(*micros as u64);
                e.f64(*compute_s);
                e.u64(*logical_bytes);
                e.u64(*wire_bytes);
                e.u64(*sync_bytes);
                e.f64(*sync_wall_s);
            }
            RpcMsg::SyncRequest { device, kind, flat } => {
                e.u8(T_SYNC_REQUEST);
                e.u64(*device as u64);
                e.u8(*kind);
                e.f32s_codec(flat, codec);
            }
            RpcMsg::SyncResult { flat } => {
                e.u8(T_SYNC_RESULT);
                e.f32s_codec(flat, codec);
            }
            RpcMsg::AbortRound => e.u8(T_ABORT_ROUND),
            RpcMsg::RoundFailed { device, error } => {
                e.u8(T_ROUND_FAILED);
                e.u64(*device as u64);
                e.str(error);
            }
            RpcMsg::FetchParams => e.u8(T_FETCH_PARAMS),
            RpcMsg::Params { layers } => {
                e.u8(T_PARAMS);
                e.u32(layers.len() as u32);
                for s in layers {
                    enc_layer_state(&mut e, s);
                }
            }
            RpcMsg::Exit => e.u8(T_EXIT),
            RpcMsg::Die => e.u8(T_DIE),
            RpcMsg::Bye => e.u8(T_BYE),
            RpcMsg::Fatal { device, error } => {
                e.u8(T_FATAL);
                e.u64(*device as u64);
                e.str(error);
            }
            RpcMsg::Throttle { factor } => {
                e.u8(T_THROTTLE);
                e.f64(*factor);
            }
            RpcMsg::RingChunk { gen, step, seg, flat } => {
                e.u8(T_RING_CHUNK);
                e.u64(*gen);
                e.u32(*step as u32);
                e.u32(*seg as u32);
                e.f32s_codec(flat, codec);
            }
        }
        e.into_bytes()
    }

    pub fn decode(payload: &[u8]) -> Result<RpcMsg> {
        let mut d = Dec::new(payload);
        let tag = d.u8().context("empty frame")?;
        let msg = match tag {
            T_HELLO => {
                let role = match d.u8()? {
                    0 => ConnRole::Control,
                    1 => ConnRole::Data {
                        stage: d.u32()? as usize,
                        slot: d.u32()? as usize,
                    },
                    2 => ConnRole::Ring {
                        stage: d.u32()? as usize,
                        index: d.u32()? as usize,
                    },
                    other => bail!("unknown connection role {other}"),
                };
                RpcMsg::Hello { role }
            }
            T_ASSIGN => {
                let generation = d.u64()?;
                let device = d.u64()? as usize;
                let stage = d.u32()? as usize;
                let slot = d.u32()? as usize;
                let num_stages = d.u32()? as usize;
                let group_size = d.u32()? as usize;
                let n_ops = d.count(5)?; // op = tag u8 + micro u32
                let mut script = Vec::with_capacity(n_ops);
                for _ in 0..n_ops {
                    script.push(dec_op(&mut d)?);
                }
                let stash_slots = d.u32()? as usize;
                let num_micro = d.u32()? as usize;
                let microbatch = d.u32()? as usize;
                let seed = d.u64()?;
                let opt = dec_opt(&mut d)?;
                let heartbeat_ms = d.u64()?;
                let codec_act = Codec::from_tag(d.u8()?)?;
                let codec_grad = Codec::from_tag(d.u8()?)?;
                let codec_sync = Codec::from_tag(d.u8()?)?;
                let n_layers = d.count(17)?; // u64 + 2 x u32 + u8
                let mut layers = Vec::with_capacity(n_layers);
                for _ in 0..n_layers {
                    layers.push(RefLayerSpec {
                        layer: d.u64()? as usize,
                        in_elems: d.u32()? as usize,
                        out_elems: d.u32()? as usize,
                        head: d.u8()? != 0,
                    });
                }
                let n_next = d.count(4)?; // string length prefix
                let mut next = Vec::with_capacity(n_next);
                for _ in 0..n_next {
                    next.push(d.str()?);
                }
                let n_prev = d.count(4)?;
                let mut prev = Vec::with_capacity(n_prev);
                for _ in 0..n_prev {
                    prev.push(d.str()?);
                }
                let n_warm = d.count(16)?; // u64 + 2 empty-f32s prefixes
                let mut warm_start = Vec::with_capacity(n_warm);
                for _ in 0..n_warm {
                    warm_start.push(dec_layer_state(&mut d)?);
                }
                let sync = SyncMode::from_tag(d.u8()?)?;
                let ring_index = d.u32()? as usize;
                let n_ring = d.count(4)?;
                let mut ring = Vec::with_capacity(n_ring);
                for _ in 0..n_ring {
                    ring.push(d.str()?);
                }
                RpcMsg::Assign(Box::new(AssignSpec {
                    generation,
                    device,
                    stage,
                    slot,
                    num_stages,
                    group_size,
                    script,
                    stash_slots,
                    num_micro,
                    microbatch,
                    seed,
                    opt,
                    heartbeat_ms,
                    codec_act,
                    codec_grad,
                    codec_sync,
                    layers,
                    next,
                    prev,
                    warm_start,
                    sync,
                    ring_index,
                    ring,
                }))
            }
            T_READY => RpcMsg::Ready { device: d.u64()? as usize },
            T_START_ROUND => RpcMsg::StartRound { round: d.u64()? as usize },
            T_ACT => RpcMsg::Act { gen: d.u64()?, micro: d.u64()? as usize, t: d.tensor()? },
            T_TARGETS => {
                RpcMsg::Targets { gen: d.u64()?, micro: d.u64()? as usize, t: d.tensor()? }
            }
            T_GRAD => RpcMsg::Grad { gen: d.u64()?, micro: d.u64()? as usize, t: d.tensor()? },
            T_HEARTBEAT => RpcMsg::Heartbeat { device: d.u64()? as usize, seq: d.u64()? },
            T_ROUND_DONE => RpcMsg::RoundDone {
                device: d.u64()? as usize,
                round: d.u64()? as usize,
                loss_sum: d.f64()?,
                micros: d.u64()? as usize,
                compute_s: d.f64()?,
                logical_bytes: d.u64()?,
                wire_bytes: d.u64()?,
                sync_bytes: d.u64()?,
                sync_wall_s: d.f64()?,
            },
            T_SYNC_REQUEST => RpcMsg::SyncRequest {
                device: d.u64()? as usize,
                kind: d.u8()?,
                flat: d.f32s_codec()?,
            },
            T_SYNC_RESULT => RpcMsg::SyncResult { flat: d.f32s_codec()? },
            T_ABORT_ROUND => RpcMsg::AbortRound,
            T_ROUND_FAILED => RpcMsg::RoundFailed {
                device: d.u64()? as usize,
                error: d.str()?,
            },
            T_FETCH_PARAMS => RpcMsg::FetchParams,
            T_PARAMS => {
                let n = d.count(16)?;
                let mut layers = Vec::with_capacity(n);
                for _ in 0..n {
                    layers.push(dec_layer_state(&mut d)?);
                }
                RpcMsg::Params { layers }
            }
            T_EXIT => RpcMsg::Exit,
            T_DIE => RpcMsg::Die,
            T_BYE => RpcMsg::Bye,
            T_FATAL => RpcMsg::Fatal { device: d.u64()? as usize, error: d.str()? },
            T_THROTTLE => RpcMsg::Throttle { factor: d.f64()? },
            T_RING_CHUNK => RpcMsg::RingChunk {
                gen: d.u64()?,
                step: d.u32()? as usize,
                seg: d.u32()? as usize,
                flat: d.f32s_codec()?,
            },
            other => bail!("unknown message tag {other}"),
        };
        if !d.done() {
            bail!("{} bytes of trailing garbage after {}", payload.len() - d.pos, msg.kind());
        }
        Ok(msg)
    }
}

// ----------------------------------- control-plane state machine
//
// The driver <-> worker control protocol as ONE declarative
// transition table per side.  `pipeline::rpc_worker`'s serve loop
// dispatches every control frame through [`worker_action`] — there is
// no second copy of the worker machine — and `verify::protocol`
// enumerates the product automaton statically: every (phase, message
// kind) pair must have exactly one entry, and every message one side
// can emit must have a defined transition in every peer phase it may
// arrive in.  An unlisted pair is a protocol hole (lint `ASTR013`),
// caught before any worker is spawned.

/// Every wire message kind, in tag order (append-only, like the tags
/// themselves; keep in sync with [`RpcMsg::kind`]).
pub const MSG_KINDS: [&str; 21] = [
    "Hello",
    "Assign",
    "Ready",
    "StartRound",
    "Act",
    "Targets",
    "Grad",
    "Heartbeat",
    "RoundDone",
    "SyncRequest",
    "SyncResult",
    "AbortRound",
    "RoundFailed",
    "FetchParams",
    "Params",
    "Exit",
    "Die",
    "Bye",
    "Fatal",
    "Throttle",
    "RingChunk",
];

/// Control-plane phase of the worker serve loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkerPhase {
    /// Between rounds: waiting for Assign/StartRound/Exit.
    Idle,
    /// Executing a round's compute script (the data plane's recv loop).
    InRound,
    /// Round compute done, waiting for the driver's `SyncResult`.
    Syncing,
}

impl WorkerPhase {
    /// Every worker phase, in lifecycle order.
    pub const ALL: [WorkerPhase; 3] =
        [WorkerPhase::Idle, WorkerPhase::InRound, WorkerPhase::Syncing];

    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            WorkerPhase::Idle => "Idle",
            WorkerPhase::InRound => "InRound",
            WorkerPhase::Syncing => "Syncing",
        }
    }
}

/// What the worker serve loop does with a message in a given phase.
/// The serve loop destructures the message payload itself; the action
/// only names the transition, so the table stays data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerAction {
    /// Idle: apply the `AssignSpec` (build stage, dial peers, `Ready`).
    ApplyAssign,
    /// Idle: run the round to completion (`RoundDone`/`RoundFailed`).
    BeginRound,
    /// Idle: answer `FetchParams` with a `Params` checkpoint.
    SendParams,
    /// Idle: discard stale round state and acknowledge the abort with
    /// one `RoundFailed("aborted while idle")`.
    AckAbort,
    /// Idle: answer `Bye` and end the serve loop cleanly.
    ExitClean,
    /// Terminate now (thread-mode death injection; the process-mode
    /// `Die` is intercepted on the reader thread before dispatch).
    DieNow,
    /// Harmless in this phase: drop (logged when verbose).
    IgnoreIdle,
    /// Tensor frame (`Act`/`Targets`/`Grad`): routed to the data-plane
    /// inbox, buffered while idle/syncing, generation-filtered in
    /// round — never dispatched as a control message.
    DataPlane,
    /// Fail the current round: the driver aborted it.
    FailAbort,
    /// Fail the current round: shutdown was requested mid-round.
    FailExit,
    /// Syncing: the awaited group-reduced buffer arrived.
    DeliverSync,
    /// Idle: record the compute throttle factor (straggler injection);
    /// takes effect from the next round's script.
    ApplyThrottle,
    /// Protocol violation in this phase: fail the round with an
    /// "unexpected message" error (the driver owns the verdict).
    FailUnexpected,
}

/// The worker half of the control-plane machine: one entry per
/// (phase, message kind).  Total by construction — `verify::protocol`
/// rejects holes and duplicates.
pub const WORKER_TRANSITIONS: &[(WorkerPhase, &str, WorkerAction)] = &[
    // ----- Idle: between rounds, the driver may re-task us freely.
    (WorkerPhase::Idle, "Hello", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "Assign", WorkerAction::ApplyAssign),
    (WorkerPhase::Idle, "Ready", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "StartRound", WorkerAction::BeginRound),
    (WorkerPhase::Idle, "Act", WorkerAction::DataPlane),
    (WorkerPhase::Idle, "Targets", WorkerAction::DataPlane),
    (WorkerPhase::Idle, "Grad", WorkerAction::DataPlane),
    (WorkerPhase::Idle, "Heartbeat", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "RoundDone", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "SyncRequest", WorkerAction::IgnoreIdle),
    // A sync result for a round the driver already aborted: stale.
    (WorkerPhase::Idle, "SyncResult", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "AbortRound", WorkerAction::AckAbort),
    (WorkerPhase::Idle, "RoundFailed", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "FetchParams", WorkerAction::SendParams),
    (WorkerPhase::Idle, "Params", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "Exit", WorkerAction::ExitClean),
    (WorkerPhase::Idle, "Die", WorkerAction::DieNow),
    (WorkerPhase::Idle, "Bye", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "Fatal", WorkerAction::IgnoreIdle),
    (WorkerPhase::Idle, "Throttle", WorkerAction::ApplyThrottle),
    // An early ring segment from a faster peer: buffered like Act.
    (WorkerPhase::Idle, "RingChunk", WorkerAction::DataPlane),
    // ----- InRound: only data, abort, and death may interrupt.
    (WorkerPhase::InRound, "Hello", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "Assign", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "Ready", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "StartRound", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "Act", WorkerAction::DataPlane),
    (WorkerPhase::InRound, "Targets", WorkerAction::DataPlane),
    (WorkerPhase::InRound, "Grad", WorkerAction::DataPlane),
    (WorkerPhase::InRound, "Heartbeat", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "RoundDone", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "SyncRequest", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "SyncResult", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "AbortRound", WorkerAction::FailAbort),
    (WorkerPhase::InRound, "RoundFailed", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "FetchParams", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "Params", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "Exit", WorkerAction::FailExit),
    (WorkerPhase::InRound, "Die", WorkerAction::DieNow),
    (WorkerPhase::InRound, "Bye", WorkerAction::FailUnexpected),
    (WorkerPhase::InRound, "Fatal", WorkerAction::FailUnexpected),
    // Throttles land between rounds only; mid-round is a violation.
    (WorkerPhase::InRound, "Throttle", WorkerAction::FailUnexpected),
    // A faster ring peer can reach the collective while we still
    // compute: buffered until this worker enters its own sync phase.
    (WorkerPhase::InRound, "RingChunk", WorkerAction::DataPlane),
    // ----- Syncing: waiting on the driver's reduced buffer.
    (WorkerPhase::Syncing, "Hello", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Assign", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Ready", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "StartRound", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Act", WorkerAction::DataPlane),
    (WorkerPhase::Syncing, "Targets", WorkerAction::DataPlane),
    (WorkerPhase::Syncing, "Grad", WorkerAction::DataPlane),
    (WorkerPhase::Syncing, "Heartbeat", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "RoundDone", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "SyncRequest", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "SyncResult", WorkerAction::DeliverSync),
    (WorkerPhase::Syncing, "AbortRound", WorkerAction::FailAbort),
    (WorkerPhase::Syncing, "RoundFailed", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "FetchParams", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Params", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Exit", WorkerAction::FailUnexpected),
    // Thread-mode death during sync surfaces as a round failure (the
    // process-mode Die never reaches here: the reader thread exits).
    (WorkerPhase::Syncing, "Die", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Bye", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Fatal", WorkerAction::FailUnexpected),
    (WorkerPhase::Syncing, "Throttle", WorkerAction::FailUnexpected),
    // The ring executor's hot path: consumed by the collective.
    (WorkerPhase::Syncing, "RingChunk", WorkerAction::DataPlane),
];

/// Transition of the worker machine for `kind` in `phase` (`None` is
/// a protocol hole — `verify::protocol` reports it as `ASTR013`).
pub fn worker_action(phase: WorkerPhase, kind: &str) -> Option<WorkerAction> {
    WORKER_TRANSITIONS
        .iter()
        .find(|&&(p, k, _)| p == phase && k == kind)
        .map(|&(_, _, a)| a)
}

/// Wait context of the driver's control loop (`session::rpc`).  Two
/// message kinds are absorbed in *every* phase before dispatch:
/// `Heartbeat` feeds the liveness monitor and `SyncRequest` the group
/// reducer — the table records them as [`DriverAction::Background`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DriverPhase {
    /// `Assign` sent to every worker; waiting for each `Ready`.
    Assigning,
    /// `StartRound` + feeds sent; waiting for each `RoundDone`.
    Rounding,
    /// `FetchParams` sent; waiting for each `Params`.
    Checkpointing,
    /// `Die` injected; waiting for the victim's EOF.
    Detecting,
    /// `AbortRound` sent to survivors; waiting for each `RoundFailed`.
    Aborting,
    /// `Exit` sent; draining `Bye`s best-effort.
    Closing,
}

impl DriverPhase {
    /// Every driver phase, in lifecycle order.
    pub const ALL: [DriverPhase; 6] = [
        DriverPhase::Assigning,
        DriverPhase::Rounding,
        DriverPhase::Checkpointing,
        DriverPhase::Detecting,
        DriverPhase::Aborting,
        DriverPhase::Closing,
    ];

    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            DriverPhase::Assigning => "Assigning",
            DriverPhase::Rounding => "Rounding",
            DriverPhase::Checkpointing => "Checkpointing",
            DriverPhase::Detecting => "Detecting",
            DriverPhase::Aborting => "Aborting",
            DriverPhase::Closing => "Closing",
        }
    }
}

/// What the driver does with a worker message in a given phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DriverAction {
    /// The message this phase is waiting for.
    Accept,
    /// Known-harmless leftover (e.g. a settled `RoundDone` of an
    /// aborted round): dropped.
    Ignore,
    /// Absorbed in every phase before dispatch (heartbeats, sync).
    Background,
    /// The designed failure path: abandon the phase and recover
    /// (a worker reported failure or died).
    FailPeer,
    /// Protocol violation: abort the run with an "unexpected message"
    /// error.
    FailUnexpected,
}

/// The driver half of the control-plane machine: one entry per
/// (phase, message kind).  Total by construction — `verify::protocol`
/// rejects holes and duplicates.
pub const DRIVER_TRANSITIONS: &[(DriverPhase, &str, DriverAction)] = &[
    // ----- Assigning: each worker answers Assign with Ready.
    (DriverPhase::Assigning, "Hello", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Assign", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Ready", DriverAction::Accept),
    (DriverPhase::Assigning, "StartRound", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Act", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Targets", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Grad", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Heartbeat", DriverAction::Background),
    (DriverPhase::Assigning, "RoundDone", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "SyncRequest", DriverAction::Background),
    (DriverPhase::Assigning, "SyncResult", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "AbortRound", DriverAction::FailUnexpected),
    // A late RoundFailed from the round we just aborted: settled.
    (DriverPhase::Assigning, "RoundFailed", DriverAction::Ignore),
    (DriverPhase::Assigning, "FetchParams", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Params", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Exit", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Die", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Bye", DriverAction::FailUnexpected),
    (DriverPhase::Assigning, "Fatal", DriverAction::FailPeer),
    (DriverPhase::Assigning, "Throttle", DriverAction::FailUnexpected),
    // Ring segments are worker-to-worker only; one at the driver is a
    // mis-dialed peer.
    (DriverPhase::Assigning, "RingChunk", DriverAction::FailUnexpected),
    // ----- Rounding: waiting for every stage's RoundDone.
    (DriverPhase::Rounding, "Hello", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Assign", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Ready", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "StartRound", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Act", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Targets", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Grad", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Heartbeat", DriverAction::Background),
    (DriverPhase::Rounding, "RoundDone", DriverAction::Accept),
    (DriverPhase::Rounding, "SyncRequest", DriverAction::Background),
    (DriverPhase::Rounding, "SyncResult", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "AbortRound", DriverAction::FailUnexpected),
    // A worker failed mid-round: the designed recovery entry point.
    (DriverPhase::Rounding, "RoundFailed", DriverAction::FailPeer),
    (DriverPhase::Rounding, "FetchParams", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Params", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Exit", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Die", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Bye", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "Fatal", DriverAction::FailPeer),
    (DriverPhase::Rounding, "Throttle", DriverAction::FailUnexpected),
    (DriverPhase::Rounding, "RingChunk", DriverAction::FailUnexpected),
    // ----- Checkpointing: each survivor answers FetchParams.
    (DriverPhase::Checkpointing, "Hello", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Assign", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Ready", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "StartRound", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Act", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Targets", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Grad", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Heartbeat", DriverAction::Background),
    (DriverPhase::Checkpointing, "RoundDone", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "SyncRequest", DriverAction::Background),
    (DriverPhase::Checkpointing, "SyncResult", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "AbortRound", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "RoundFailed", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "FetchParams", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Params", DriverAction::Accept),
    (DriverPhase::Checkpointing, "Exit", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Die", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Bye", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "Fatal", DriverAction::FailPeer),
    (DriverPhase::Checkpointing, "Throttle", DriverAction::FailUnexpected),
    (DriverPhase::Checkpointing, "RingChunk", DriverAction::FailUnexpected),
    // ----- Detecting: fault injection sent, waiting for the victim's
    // silence; stragglers from the doomed round are settled noise.
    (DriverPhase::Detecting, "Hello", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Assign", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Ready", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "StartRound", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Act", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Targets", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Grad", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Heartbeat", DriverAction::Background),
    (DriverPhase::Detecting, "RoundDone", DriverAction::Ignore),
    (DriverPhase::Detecting, "SyncRequest", DriverAction::Background),
    (DriverPhase::Detecting, "SyncResult", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "AbortRound", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "RoundFailed", DriverAction::Ignore),
    (DriverPhase::Detecting, "FetchParams", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Params", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Exit", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Die", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Bye", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "Fatal", DriverAction::FailPeer),
    (DriverPhase::Detecting, "Throttle", DriverAction::FailUnexpected),
    (DriverPhase::Detecting, "RingChunk", DriverAction::FailUnexpected),
    // ----- Aborting: survivors acknowledge with RoundFailed.
    (DriverPhase::Aborting, "Hello", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Assign", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Ready", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "StartRound", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Act", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Targets", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Grad", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Heartbeat", DriverAction::Background),
    // The round raced the abort to completion: settled.
    (DriverPhase::Aborting, "RoundDone", DriverAction::Ignore),
    (DriverPhase::Aborting, "SyncRequest", DriverAction::Background),
    (DriverPhase::Aborting, "SyncResult", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "AbortRound", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "RoundFailed", DriverAction::Accept),
    (DriverPhase::Aborting, "FetchParams", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Params", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Exit", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Die", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Bye", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "Fatal", DriverAction::FailPeer),
    (DriverPhase::Aborting, "Throttle", DriverAction::FailUnexpected),
    (DriverPhase::Aborting, "RingChunk", DriverAction::FailUnexpected),
    // ----- Closing: best-effort drain; nothing can fail the run now.
    (DriverPhase::Closing, "Hello", DriverAction::Ignore),
    (DriverPhase::Closing, "Assign", DriverAction::Ignore),
    (DriverPhase::Closing, "Ready", DriverAction::Ignore),
    (DriverPhase::Closing, "StartRound", DriverAction::Ignore),
    (DriverPhase::Closing, "Act", DriverAction::Ignore),
    (DriverPhase::Closing, "Targets", DriverAction::Ignore),
    (DriverPhase::Closing, "Grad", DriverAction::Ignore),
    (DriverPhase::Closing, "Heartbeat", DriverAction::Background),
    (DriverPhase::Closing, "RoundDone", DriverAction::Ignore),
    (DriverPhase::Closing, "SyncRequest", DriverAction::Background),
    (DriverPhase::Closing, "SyncResult", DriverAction::Ignore),
    (DriverPhase::Closing, "AbortRound", DriverAction::Ignore),
    (DriverPhase::Closing, "RoundFailed", DriverAction::Ignore),
    (DriverPhase::Closing, "FetchParams", DriverAction::Ignore),
    (DriverPhase::Closing, "Params", DriverAction::Ignore),
    (DriverPhase::Closing, "Exit", DriverAction::Ignore),
    (DriverPhase::Closing, "Die", DriverAction::Ignore),
    (DriverPhase::Closing, "Bye", DriverAction::Accept),
    (DriverPhase::Closing, "Fatal", DriverAction::Ignore),
    (DriverPhase::Closing, "Throttle", DriverAction::Ignore),
    (DriverPhase::Closing, "RingChunk", DriverAction::Ignore),
];

/// Transition of the driver machine for `kind` in `phase` (`None` is
/// a protocol hole — `verify::protocol` reports it as `ASTR013`).
pub fn driver_action(phase: DriverPhase, kind: &str) -> Option<DriverAction> {
    DRIVER_TRANSITIONS
        .iter()
        .find(|&&(p, k, _)| p == phase && k == kind)
        .map(|&(_, _, a)| a)
}

/// Messages the driver can emit, with the worker phases each may
/// arrive in (connection FIFO, so emission context bounds arrival
/// context).  `verify::protocol` checks the product automaton: every
/// (emittable kind × possible receiver phase) must have a transition.
///
/// `RingChunk` travels worker→worker only, so it appears in neither
/// emits table: the driver never sends one, and a worker never sends
/// one to the driver (the transition tables still carry RingChunk rows
/// for totality — a mis-dialed peer is `FailUnexpected`, not a panic).
pub const DRIVER_EMITS: &[(&str, &[WorkerPhase])] = &[
    // Assign / FetchParams / StartRound are only sent between rounds,
    // but an abort can leave the worker mid-round when they land.
    ("Assign", &[WorkerPhase::Idle]),
    ("StartRound", &[WorkerPhase::Idle]),
    ("FetchParams", &[WorkerPhase::Idle]),
    // Throttle (straggler injection) is sent strictly between rounds.
    ("Throttle", &[WorkerPhase::Idle]),
    (
        "AbortRound",
        &[WorkerPhase::Idle, WorkerPhase::InRound, WorkerPhase::Syncing],
    ),
    (
        "SyncResult",
        &[WorkerPhase::Idle, WorkerPhase::InRound, WorkerPhase::Syncing],
    ),
    ("Exit", &[WorkerPhase::Idle, WorkerPhase::InRound]),
    (
        "Die",
        &[WorkerPhase::Idle, WorkerPhase::InRound, WorkerPhase::Syncing],
    ),
    ("Act", &[WorkerPhase::Idle, WorkerPhase::InRound, WorkerPhase::Syncing]),
    ("Targets", &[WorkerPhase::Idle, WorkerPhase::InRound, WorkerPhase::Syncing]),
];

/// Messages the worker can emit, with the driver phases each may
/// arrive in.
pub const WORKER_EMITS: &[(&str, &[DriverPhase])] = &[
    ("Ready", &[DriverPhase::Assigning, DriverPhase::Closing]),
    (
        "RoundDone",
        &[
            DriverPhase::Rounding,
            DriverPhase::Detecting,
            DriverPhase::Aborting,
            DriverPhase::Closing,
        ],
    ),
    (
        "RoundFailed",
        &[
            DriverPhase::Rounding,
            DriverPhase::Detecting,
            DriverPhase::Aborting,
            DriverPhase::Assigning,
            DriverPhase::Closing,
        ],
    ),
    ("Params", &[DriverPhase::Checkpointing, DriverPhase::Closing]),
    ("Bye", &[DriverPhase::Closing]),
    (
        "Heartbeat",
        &[
            DriverPhase::Assigning,
            DriverPhase::Rounding,
            DriverPhase::Checkpointing,
            DriverPhase::Detecting,
            DriverPhase::Aborting,
            DriverPhase::Closing,
        ],
    ),
    (
        "SyncRequest",
        &[
            DriverPhase::Assigning,
            DriverPhase::Rounding,
            DriverPhase::Checkpointing,
            DriverPhase::Detecting,
            DriverPhase::Aborting,
            DriverPhase::Closing,
        ],
    ),
    (
        "Fatal",
        &[
            DriverPhase::Assigning,
            DriverPhase::Rounding,
            DriverPhase::Checkpointing,
            DriverPhase::Detecting,
            DriverPhase::Aborting,
            DriverPhase::Closing,
        ],
    ),
];

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip(msg: &RpcMsg) -> RpcMsg {
        RpcMsg::decode(&msg.encode()).unwrap()
    }

    #[test]
    fn codec_roundtrips_control_messages() {
        let spec = AssignSpec {
            generation: 3,
            device: 2,
            stage: 1,
            slot: 0,
            num_stages: 3,
            group_size: 1,
            script: vec![ComputeOp::Fwd(0), ComputeOp::Bwd(0), ComputeOp::BwdW(0)],
            stash_slots: 2,
            num_micro: 4,
            microbatch: 2,
            seed: 42,
            opt: OptimizerCfg::Sgd { lr: 0.05, momentum: 0.9 },
            heartbeat_ms: 100,
            codec_act: Codec::Int8,
            codec_grad: Codec::Fp16,
            codec_sync: Codec::Fp32,
            layers: vec![RefLayerSpec { layer: 3, in_elems: 8, out_elems: 4, head: true }],
            next: vec!["127.0.0.1:7000".into()],
            prev: vec![],
            warm_start: vec![LayerState {
                layer: 3,
                scale: vec![1.0, 2.0],
                bias: vec![0.5],
            }],
            sync: SyncMode::Ring,
            ring_index: 2,
            ring: vec!["127.0.0.1:7100".into(), "127.0.0.1:7101".into(), "127.0.0.1:7102".into()],
        };
        match roundtrip(&RpcMsg::Assign(Box::new(spec.clone()))) {
            RpcMsg::Assign(a) => {
                assert_eq!(a.generation, 3);
                assert_eq!(a.device, 2);
                assert_eq!(a.script, spec.script);
                assert_eq!(a.layers.len(), 1);
                assert!(a.layers[0].head);
                assert_eq!(a.next, spec.next);
                assert_eq!(a.warm_start, spec.warm_start);
                assert_eq!(a.codec_act, Codec::Int8);
                assert_eq!(a.codec_grad, Codec::Fp16);
                assert_eq!(a.codec_sync, Codec::Fp32);
                assert_eq!(a.sync, SyncMode::Ring);
                assert_eq!(a.ring_index, 2);
                assert_eq!(a.ring, spec.ring);
                match a.opt {
                    OptimizerCfg::Sgd { lr, momentum } => {
                        assert_eq!(lr, 0.05);
                        assert_eq!(momentum, 0.9);
                    }
                    other => panic!("wrong optimizer {other:?}"),
                }
            }
            other => panic!("decoded {}", other.kind()),
        }
        match roundtrip(&RpcMsg::RoundDone {
            device: 1,
            round: 7,
            loss_sum: 2.5,
            micros: 4,
            compute_s: 0.25,
            logical_bytes: 4096,
            wire_bytes: 1032,
            sync_bytes: 2048,
            sync_wall_s: 0.125,
        }) {
            RpcMsg::RoundDone {
                device,
                round,
                loss_sum,
                micros,
                compute_s,
                logical_bytes,
                wire_bytes,
                sync_bytes,
                sync_wall_s,
            } => {
                assert_eq!((device, round, micros), (1, 7, 4));
                assert_eq!(loss_sum, 2.5);
                assert_eq!(compute_s, 0.25);
                assert_eq!((logical_bytes, wire_bytes), (4096, 1032));
                assert_eq!(sync_bytes, 2048);
                assert_eq!(sync_wall_s, 0.125);
            }
            other => panic!("decoded {}", other.kind()),
        }
        for msg in [RpcMsg::Exit, RpcMsg::Die, RpcMsg::Bye, RpcMsg::AbortRound, RpcMsg::FetchParams]
        {
            assert_eq!(roundtrip(&msg).kind(), msg.kind());
        }
        match roundtrip(&RpcMsg::Hello { role: ConnRole::Data { stage: 2, slot: 1 } }) {
            RpcMsg::Hello { role } => assert_eq!(role, ConnRole::Data { stage: 2, slot: 1 }),
            other => panic!("decoded {}", other.kind()),
        }
        match roundtrip(&RpcMsg::Hello { role: ConnRole::Ring { stage: 0, index: 3 } }) {
            RpcMsg::Hello { role } => assert_eq!(role, ConnRole::Ring { stage: 0, index: 3 }),
            other => panic!("decoded {}", other.kind()),
        }
        match roundtrip(&RpcMsg::Throttle { factor: 3.5 }) {
            RpcMsg::Throttle { factor } => assert_eq!(factor, 3.5),
            other => panic!("decoded {}", other.kind()),
        }
    }

    #[test]
    fn ring_chunk_roundtrips_plain_and_compressed() {
        let msg = RpcMsg::RingChunk {
            gen: 9,
            step: 3,
            seg: 1,
            flat: (0..37).map(|i| i as f32 * 0.5 - 4.0).collect(),
        };
        match roundtrip(&msg) {
            RpcMsg::RingChunk { gen, step, seg, flat } => {
                assert_eq!((gen, step, seg), (9, 3, 1));
                assert_eq!(flat.len(), 37);
                assert_eq!(flat[8], 0.0);
            }
            other => panic!("decoded {}", other.kind()),
        }
        // Ring segments ride the sync codec like SyncRequest flats do.
        let wire = msg.encode_with(Codec::Fp16);
        assert!(wire.len() < msg.encode().len());
        match RpcMsg::decode(&wire).unwrap() {
            RpcMsg::RingChunk { flat, .. } => assert_eq!(flat.len(), 37),
            other => panic!("decoded {}", other.kind()),
        }
    }

    #[test]
    fn streamed_framing_matches_encode_with() {
        // The zero-copy path must put the exact same bytes on the wire
        // as encode_with + write_frame, for every streamable message
        // shape x codec, plus the buffered fallback.
        let msgs = [
            RpcMsg::Act {
                gen: 5,
                micro: 2,
                t: Tensor::from_f32(&[3, 700], (0..2100).map(|i| (i as f32).sin()).collect()),
            },
            RpcMsg::Grad {
                gen: 5,
                micro: 2,
                t: Tensor::from_f32(&[1031], vec![0.25; 1031]), // non-chunk-aligned
            },
            RpcMsg::RingChunk { gen: 1, step: 0, seg: 2, flat: vec![1.5; 513] },
            RpcMsg::Targets { gen: 0, micro: 0, t: Tensor::from_i32(&[4], vec![1, 2, 3, 4]) },
            RpcMsg::StartRound { round: 4 },
        ];
        for msg in &msgs {
            for codec in [Codec::Fp32, Codec::Fp16, Codec::Int8] {
                let mut reference = Vec::new();
                send_msg_codec(&mut reference, msg, codec).unwrap();
                let mut streamed = Vec::new();
                let n = send_msg_streamed(&mut streamed, msg, codec).unwrap();
                assert_eq!(streamed, reference, "{} under {}", msg.kind(), codec.name());
                assert_eq!(n, streamed.len() as u64);
                assert_eq!(recv_msg(&mut streamed.as_slice()).unwrap().kind(), msg.kind());
            }
        }
        // The ring executor's slice-borrowing send is the same wire.
        let seg = vec![0.75f32; 300];
        let mut direct = Vec::new();
        send_ring_chunk(&mut direct, 1, 0, 2, &seg, Codec::Fp16).unwrap();
        let mut via_msg = Vec::new();
        send_msg_streamed(
            &mut via_msg,
            &RpcMsg::RingChunk { gen: 1, step: 0, seg: 2, flat: seg },
            Codec::Fp16,
        )
        .unwrap();
        assert_eq!(direct, via_msg);
    }

    #[test]
    fn codec_roundtrips_tensor_messages() {
        let f = Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        match roundtrip(&RpcMsg::Act { gen: 7, micro: 3, t: f.clone() }) {
            RpcMsg::Act { gen, micro, t } => {
                assert_eq!((gen, micro), (7, 3));
                assert_eq!(t, f);
            }
            other => panic!("decoded {}", other.kind()),
        }
        let i = Tensor::from_i32(&[4], vec![1, -2, 3, -4]);
        match roundtrip(&RpcMsg::Targets { gen: 0, micro: 0, t: i.clone() }) {
            RpcMsg::Targets { t, .. } => assert_eq!(t, i),
            other => panic!("decoded {}", other.kind()),
        }
    }

    #[test]
    fn compressed_frames_shrink_and_decode_to_f32() {
        // Every lossy codec shrinks the encoded Act frame and still
        // decodes to an f32 tensor of the right shape — with no decode
        // side channel (the codec tag rides in the payload).
        let t = Tensor::from_f32(&[64], (0..64).map(|i| i as f32 / 7.0).collect());
        let msg = RpcMsg::Act { gen: 2, micro: 1, t: t.clone() };
        let plain = msg.encode();
        for codec in [Codec::Fp16, Codec::Bf16, Codec::Int8] {
            let wire = msg.encode_with(codec);
            assert!(wire.len() < plain.len(), "{} did not shrink", codec.name());
            match RpcMsg::decode(&wire).unwrap() {
                RpcMsg::Act { gen, micro, t: got } => {
                    assert_eq!((gen, micro), (2, 1));
                    assert_eq!(got.shape, t.shape);
                    assert_eq!(got.dtype(), crate::model::from_manifest::DType::F32);
                }
                other => panic!("decoded {}", other.kind()),
            }
        }
        // fp32 via encode_with is bit-identical to plain encode.
        assert_eq!(msg.encode_with(Codec::Fp32), plain);
        // i32 payloads pass through lossy codecs untouched.
        let i = RpcMsg::Targets { gen: 0, micro: 0, t: Tensor::from_i32(&[3], vec![7, -8, 9]) };
        assert_eq!(i.encode_with(Codec::Int8), i.encode());
        // Sync flats compress too (the driver-mediated param path).
        let sync = RpcMsg::SyncResult { flat: vec![0.5f32; 256] };
        assert!(sync.encode_with(Codec::Int8).len() < sync.encode().len());
        match RpcMsg::decode(&sync.encode_with(Codec::Fp16)).unwrap() {
            RpcMsg::SyncResult { flat } => assert_eq!(flat.len(), 256),
            other => panic!("decoded {}", other.kind()),
        }
    }

    #[test]
    fn corrupt_codec_payloads_rejected() {
        // n = 4 elements: the int8 payload (8-byte header + 4) and the
        // fp16 payload (2 x 4) have different lengths, so a swapped
        // codec tag must be caught by the length accounting.
        let msg = RpcMsg::Act {
            gen: 1,
            micro: 0,
            t: Tensor::from_f32(&[4], vec![1.0; 4]),
        };
        let wire = msg.encode_with(Codec::Int8);

        // Truncated codec payload: the frame ends mid-tensor.
        assert!(RpcMsg::decode(&wire[..wire.len() - 3]).is_err());

        // Mismatched codec tag: the int8 payload length no longer
        // matches what the claimed codec needs, so the decoder cannot
        // silently misread the bytes.  The codec tag is the byte right
        // after the tensor's dtype tag and element count:
        //   msg tag 1 | gen 8 | micro 8 | ndim 1 | dim 4 | dtype 1 | n 4 | codec 1
        let tag_off = 1 + 8 + 8 + 1 + 4 + 1 + 4;
        assert_eq!(wire[tag_off], Codec::Int8.tag());
        let mut swapped = wire.clone();
        swapped[tag_off] = Codec::Fp16.tag();
        assert!(RpcMsg::decode(&swapped).is_err());

        // Unknown codec tag.
        let mut unknown = wire;
        unknown[tag_off] = 0x7F;
        let err = RpcMsg::decode(&unknown).unwrap_err().to_string();
        assert!(err.contains("codec"), "{err}");
    }

    #[test]
    fn loopback_roundtrip_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            let a = recv_msg(&mut conn).unwrap();
            let b = recv_msg(&mut conn).unwrap();
            (a, b)
        });
        let mut c = TcpStream::connect(addr).unwrap();
        send_msg(&mut c, &RpcMsg::Hello { role: ConnRole::Control }).unwrap();
        let t = Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        send_msg(&mut c, &RpcMsg::Grad { gen: 1, micro: 9, t: t.clone() }).unwrap();
        let (a, b) = h.join().unwrap();
        assert_eq!(a.kind(), "Hello");
        match b {
            RpcMsg::Grad { gen, micro, t: got } => {
                assert_eq!((gen, micro), (1, 9));
                assert_eq!(got, t);
            }
            other => panic!("decoded {}", other.kind()),
        }
    }

    #[test]
    fn partial_reads_reassemble() {
        // A frame delivered byte-dribbled across the socket must decode
        // identically — read_exact reassembles TCP segmentation.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            recv_msg(&mut conn).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let msg = RpcMsg::Act {
            gen: 0,
            micro: 5,
            t: Tensor::from_f32(&[3], vec![0.25, 0.5, 0.75]),
        };
        let payload = msg.encode();
        let mut wire = Vec::new();
        wire.extend_from_slice(&MAGIC);
        wire.push(VERSION);
        wire.extend_from_slice(&(payload.len() as u32).to_be_bytes());
        wire.extend_from_slice(&payload);
        for b in wire {
            c.write_all(&[b]).unwrap();
            c.flush().unwrap();
        }
        match h.join().unwrap() {
            RpcMsg::Act { gen, micro, t } => {
                assert_eq!((gen, micro), (0, 5));
                assert_eq!(t.as_f32().unwrap(), &[0.25, 0.5, 0.75]);
            }
            other => panic!("decoded {}", other.kind()),
        }
    }

    #[test]
    fn oversized_and_corrupt_frames_rejected() {
        // Oversized length is refused before any allocation.
        let mut wire = Vec::new();
        wire.extend_from_slice(&MAGIC);
        wire.push(VERSION);
        wire.extend_from_slice(&((MAX_FRAME as u32) + 1).to_be_bytes());
        let err = read_frame(&mut wire.as_slice()).unwrap_err().to_string();
        assert!(err.contains("MAX_FRAME"), "{err}");

        // Bad magic: not an asteroid peer.
        let mut wire = Vec::new();
        wire.extend_from_slice(b"HTTP");
        wire.extend_from_slice(&[1, 0, 0, 0, 0]);
        let err = read_frame(&mut wire.as_slice()).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        // Wrong version.
        let mut wire = Vec::new();
        wire.extend_from_slice(&MAGIC);
        wire.push(VERSION + 1);
        wire.extend_from_slice(&0u32.to_be_bytes());
        assert!(read_frame(&mut wire.as_slice()).is_err());

        // Truncated payload errors instead of blocking forever.
        let msg = RpcMsg::Exit.encode();
        let mut wire = Vec::new();
        wire.extend_from_slice(&MAGIC);
        wire.push(VERSION);
        wire.extend_from_slice(&((msg.len() as u32) + 4).to_be_bytes());
        wire.extend_from_slice(&msg);
        assert!(read_frame(&mut wire.as_slice()).is_err());

        // Trailing garbage inside a decoded message is rejected.
        let mut payload = RpcMsg::Exit.encode();
        payload.push(0xAB);
        assert!(RpcMsg::decode(&payload).is_err());

        // A corrupt element count cannot drive a huge pre-allocation:
        // a tiny Params frame claiming u32::MAX layer states is
        // refused by the count-vs-remaining-bytes check.
        let mut e = Enc::default();
        e.u8(15); // T_PARAMS
        e.u32(u32::MAX);
        let err = RpcMsg::decode(&e.into_bytes()).unwrap_err().to_string();
        assert!(err.contains("corrupt count"), "{err}");
    }
}
