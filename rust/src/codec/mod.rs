//! The compressed data plane: per-link wire codecs priced end-to-end.
//!
//! Asteroid's HPP-Round latency (Eq. 4-6) is dominated on real edge
//! links by activation/gradient transfer, and AccEPT-style activation
//! quantization attacks exactly that term.  This module owns the codec
//! taxonomy once, for every byte-touching layer:
//!
//! * [`Codec`] — one wire format for a stream of f32 values: `fp32`
//!   passthrough, `fp16` (IEEE half), `bf16` (truncated f32), `int8`
//!   (per-tensor affine quantization with a stored scale/zero-point
//!   header);
//! * [`CodecSpec`] — the per-link assignment: one uniform default
//!   (`--codec <name>`) plus optional per-boundary overrides
//!   (`--codec fp32,12=int8`), `Copy` so it travels inside
//!   `PlannerConfig` and `Planner` unchanged;
//! * exact wire accounting: [`Codec::wire_bytes`] maps logical tensor
//!   bytes (via `DType::size_bytes`) to on-the-wire bytes, and the
//!   planner cost model, `sim::price` and the RPC byte meters
//!   all consume it — so the DP optimizes cut points for the bytes
//!   that actually cross the link.
//!
//! Non-f32 tensors (i32 targets) always pass through uncompressed:
//! lossy codecs are defined over f32 streams only.

use anyhow::{bail, Context, Result};

use crate::model::from_manifest::DType;
use crate::runtime::{Tensor, TensorData};

// ------------------------------------------------------------- Codec

/// One wire format for a stream of f32 values.  The `u8` tags are the
/// wire encoding (frame codec tag) — append-only, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Codec {
    /// Raw little-endian f32 (exact; the only format prior wire
    /// versions spoke).
    #[default]
    Fp32,
    /// IEEE 754 binary16, round-to-nearest-even.  2 bytes/element.
    Fp16,
    /// bfloat16: f32 truncated to its top 16 bits (round-to-nearest-
    /// even).  2 bytes/element, f32's full exponent range.
    Bf16,
    /// Per-tensor affine u8 quantization: an 8-byte header (scale f32,
    /// zero-point f32) + 1 byte/element.  `q = round((x - zero)/scale)`
    /// saturating to [0, 255]; non-finite values clamp (NaN/-inf -> 0,
    /// +inf -> 255).
    Int8,
}

/// Bytes of the int8 per-tensor header (scale f32 + zero-point f32).
pub const INT8_HEADER_BYTES: u64 = 8;

impl Codec {
    pub const ALL: [Codec; 4] = [Codec::Fp32, Codec::Fp16, Codec::Bf16, Codec::Int8];

    pub fn name(self) -> &'static str {
        match self {
            Codec::Fp32 => "fp32",
            Codec::Fp16 => "fp16",
            Codec::Bf16 => "bf16",
            Codec::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Result<Codec> {
        Ok(match s {
            "fp32" => Codec::Fp32,
            "fp16" => Codec::Fp16,
            "bf16" => Codec::Bf16,
            "int8" => Codec::Int8,
            other => bail!("unknown codec {other:?} (fp32|fp16|bf16|int8)"),
        })
    }

    /// Wire tag (frame codec byte).
    pub fn tag(self) -> u8 {
        match self {
            Codec::Fp32 => 0,
            Codec::Fp16 => 1,
            Codec::Bf16 => 2,
            Codec::Int8 => 3,
        }
    }

    pub fn from_tag(tag: u8) -> Result<Codec> {
        Ok(match tag {
            0 => Codec::Fp32,
            1 => Codec::Fp16,
            2 => Codec::Bf16,
            3 => Codec::Int8,
            other => bail!("unknown codec tag {other}"),
        })
    }

    /// Encoded payload bytes for `n` f32 elements (excluding any
    /// element-count prefix the framing adds).
    pub fn payload_bytes(self, n: usize) -> usize {
        match self {
            Codec::Fp32 => 4 * n,
            Codec::Fp16 | Codec::Bf16 => 2 * n,
            Codec::Int8 => INT8_HEADER_BYTES as usize + n,
        }
    }

    /// Exact wire bytes for `logical_bytes` of `dtype` data.  Lossy
    /// codecs are defined over f32 only — any other dtype passes
    /// through unchanged, and `Fp32` is the identity, so fp32 pricing
    /// is bit-compatible with the uncompressed cost model.
    pub fn wire_bytes(self, logical_bytes: u64, dtype: DType) -> u64 {
        if dtype != DType::F32 || self == Codec::Fp32 {
            return logical_bytes;
        }
        let n = logical_bytes / DType::F32.size_bytes() as u64;
        self.payload_bytes(n as usize) as u64
    }

    /// Append the encoded form of `v` to `out`.
    pub fn encode_f32s(self, v: &[f32], out: &mut Vec<u8>) {
        match self {
            Codec::Fp32 => {
                out.reserve(4 * v.len());
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Codec::Fp16 => {
                out.reserve(2 * v.len());
                for &x in v {
                    out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
                }
            }
            Codec::Bf16 => {
                out.reserve(2 * v.len());
                for &x in v {
                    out.extend_from_slice(&f32_to_bf16_bits(x).to_le_bytes());
                }
            }
            Codec::Int8 => encode_int8(v, out),
        }
    }

    /// Decode exactly `n` f32 elements from `bytes`
    /// (`bytes.len() == self.payload_bytes(n)`, checked).
    pub fn decode_f32s(self, n: usize, bytes: &[u8]) -> Result<Vec<f32>> {
        if bytes.len() != self.payload_bytes(n) {
            bail!(
                "codec {}: payload is {} bytes, {n} elements need {}",
                self.name(),
                bytes.len(),
                self.payload_bytes(n)
            );
        }
        Ok(match self {
            Codec::Fp32 => bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            Codec::Fp16 => bytes
                .chunks_exact(2)
                .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
            Codec::Bf16 => bytes
                .chunks_exact(2)
                .map(|c| bf16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
            Codec::Int8 => {
                let scale = f32::from_le_bytes(bytes[0..4].try_into().unwrap());
                let zero = f32::from_le_bytes(bytes[4..8].try_into().unwrap());
                bytes[8..].iter().map(|&q| zero + q as f32 * scale).collect()
            }
        })
    }

    /// What the receiving stage computes on: encode-then-decode.  The
    /// in-process engine uses this at its data-plane send so both live
    /// paths see exactly the wire's numerics; `Fp32` and non-f32
    /// tensors pass through untouched.
    pub fn transcode(self, t: &Tensor) -> Tensor {
        match (&t.data, self) {
            (TensorData::F32(v), c) if c != Codec::Fp32 => {
                let mut buf = Vec::new();
                c.encode_f32s(v, &mut buf);
                let back = c.decode_f32s(v.len(), &buf).expect("self-roundtrip");
                Tensor::from_f32(&t.shape, back)
            }
            _ => t.clone(),
        }
    }
}

// ---------------------------------------------------------- CodecSpec

/// Upper bound on per-boundary overrides (keeps [`CodecSpec`] `Copy`
/// so it rides inside `PlannerConfig`/`Planner` unchanged).
pub const MAX_OVERRIDES: usize = 8;

/// The per-link codec assignment: a uniform default plus optional
/// per-boundary overrides keyed by the model boundary index `j` (the
/// activation cut after layer `j`; a gradient crossing the same cut
/// uses the same codec, as it rides the same link).  Driver-mediated
/// sync traffic (`SyncRequest`/`SyncResult` flats) and the Eq. 5
/// AllReduce term use the default codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CodecSpec {
    default: Codec,
    overrides: [(u32, Codec); MAX_OVERRIDES],
    n_overrides: u8,
}

impl Default for CodecSpec {
    fn default() -> Self {
        CodecSpec::uniform(Codec::Fp32)
    }
}

impl CodecSpec {
    /// One codec on every link.
    pub fn uniform(codec: Codec) -> CodecSpec {
        CodecSpec {
            default: codec,
            overrides: [(0, Codec::Fp32); MAX_OVERRIDES],
            n_overrides: 0,
        }
    }

    /// Parse `"<default>[,<boundary>=<codec>]*"`, e.g. `"int8"` or
    /// `"fp32,12=int8,20=fp16"`.
    pub fn parse(s: &str) -> Result<CodecSpec> {
        let mut parts = s.split(',');
        let mut spec =
            CodecSpec::uniform(Codec::parse(parts.next().context("empty codec spec")?.trim())?);
        for part in parts {
            let (b, c) = part
                .split_once('=')
                .with_context(|| format!("override {part:?} is not <boundary>=<codec>"))?;
            let boundary: usize =
                b.trim().parse().with_context(|| format!("bad boundary index {b:?}"))?;
            spec = spec.with_override(boundary, Codec::parse(c.trim())?)?;
        }
        Ok(spec)
    }

    /// Override the codec at model boundary `j` (builder-style).
    pub fn with_override(mut self, boundary: usize, codec: Codec) -> Result<CodecSpec> {
        for slot in self.overrides.iter_mut().take(self.n_overrides as usize) {
            if slot.0 as usize == boundary {
                slot.1 = codec;
                return Ok(self);
            }
        }
        if (self.n_overrides as usize) >= MAX_OVERRIDES {
            bail!("at most {MAX_OVERRIDES} per-boundary codec overrides");
        }
        self.overrides[self.n_overrides as usize] = (boundary as u32, codec);
        self.n_overrides += 1;
        Ok(self)
    }

    /// The codec on the link crossing model boundary `j`.
    pub fn at_boundary(&self, j: usize) -> Codec {
        self.overrides
            .iter()
            .take(self.n_overrides as usize)
            .find(|(b, _)| *b as usize == j)
            .map(|(_, c)| *c)
            .unwrap_or(self.default)
    }

    /// Uniform default (driver feeds + sync traffic).
    pub fn default_codec(&self) -> Codec {
        self.default
    }

    /// The active per-boundary overrides, as `(boundary, codec)`
    /// pairs in insertion order (what `verify` validates against the
    /// planned stage cuts).
    pub fn overrides(&self) -> impl Iterator<Item = (u32, Codec)> + '_ {
        self.overrides.iter().take(self.n_overrides as usize).copied()
    }

    /// Codec of the driver-mediated group sync / Eq. 5 AllReduce.
    pub fn sync(&self) -> Codec {
        self.default
    }

    /// True when every link is raw fp32 — wire == logical everywhere.
    pub fn is_identity(&self) -> bool {
        self.default == Codec::Fp32
            && self.overrides.iter().take(self.n_overrides as usize).all(|(_, c)| *c == Codec::Fp32)
    }

    /// Wire bytes of an f32 activation/gradient tensor crossing model
    /// boundary `j`.
    pub fn wire_activation_bytes(&self, j: usize, logical_bytes: u64) -> u64 {
        self.at_boundary(j).wire_bytes(logical_bytes, DType::F32)
    }

    /// Wire bytes of an f32 sync/AllReduce buffer.
    pub fn wire_sync_bytes(&self, logical_bytes: u64) -> u64 {
        self.sync().wire_bytes(logical_bytes, DType::F32)
    }

    /// FNV-1a fingerprint over the canonical (sorted) link assignment —
    /// the component planner memo keys (`StagePricer`, DP state
    /// fingerprints, `sim::PriceCache`) mix in so prices computed under
    /// one codec spec can never answer a query under another.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325_u64;
        let mut put = |h: &mut u64, x: u64| {
            *h ^= x;
            *h = h.wrapping_mul(0x0100_0000_01b3);
        };
        put(&mut h, self.default.tag() as u64);
        let mut ovr: Vec<(u32, Codec)> =
            self.overrides.iter().take(self.n_overrides as usize).copied().collect();
        ovr.sort_unstable_by_key(|(b, _)| *b);
        for (b, c) in ovr {
            put(&mut h, b as u64 + 1);
            put(&mut h, c.tag() as u64);
        }
        h
    }

    /// Canonical display form, parseable by [`CodecSpec::parse`].
    pub fn describe(&self) -> String {
        let mut ovr: Vec<(u32, Codec)> =
            self.overrides.iter().take(self.n_overrides as usize).copied().collect();
        ovr.sort_unstable_by_key(|(b, _)| *b);
        let mut s = self.default.name().to_string();
        for (b, c) in ovr {
            s.push_str(&format!(",{}={}", b, c.name()));
        }
        s
    }
}

// ------------------------------------------------ scalar conversions

/// f32 -> IEEE binary16 bits, round-to-nearest-even; NaN stays NaN
/// (quietened), overflow saturates to +/-inf.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = (b >> 23) & 0xff;
    let man = b & 0x007f_ffff;
    if exp == 0xff {
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp as i32 - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00;
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow to signed zero
        }
        // Subnormal: shift the implicit-bit mantissa into 10 bits.
        let m = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let mut v = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && v & 1 == 1) {
            v += 1;
        }
        return sign | v as u16;
    }
    let mut v = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && v & 1 == 1) {
        v += 1; // a carry into the exponent is correct rounding
    }
    sign | v as u16
}

/// IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x3ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (man << 13));
    }
    if exp == 0 {
        // Subnormal (or zero): value = man * 2^-24, exactly
        // representable in f32.
        let v = man as f32 / 16_777_216.0;
        return if sign != 0 { -v } else { v };
    }
    f32::from_bits(sign | ((exp as u32 + 127 - 15) << 23) | (man << 13))
}

/// f32 -> bfloat16 bits: truncate to the top 16 bits with
/// round-to-nearest-even; NaN keeps a mantissa bit set.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let b = x.to_bits();
    if x.is_nan() {
        return ((b >> 16) as u16) | 0x0040;
    }
    let round = ((b >> 16) & 1) + 0x7fff;
    (b.wrapping_add(round) >> 16) as u16
}

/// bfloat16 bits -> f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Per-tensor affine u8 quantization over the finite value range.
/// Header: scale f32 LE, zero-point f32 LE.  A tensor with no finite
/// values (or a constant one) degenerates to scale 1.0 around its
/// zero-point, so decode is still well-defined.
fn encode_int8(v: &[f32], out: &mut Vec<u8>) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in v {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    if !lo.is_finite() {
        lo = 0.0;
        hi = 0.0;
    }
    let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
    out.reserve(INT8_HEADER_BYTES as usize + v.len());
    out.extend_from_slice(&scale.to_le_bytes());
    out.extend_from_slice(&lo.to_le_bytes());
    for &x in v {
        // Saturating float->int cast: NaN and -inf -> 0, +inf -> 255.
        let q = ((x - lo) / scale).round() as u8;
        out.push(q);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn roundtrip(codec: Codec, v: &[f32]) -> Vec<f32> {
        let mut buf = Vec::new();
        codec.encode_f32s(v, &mut buf);
        assert_eq!(buf.len(), codec.payload_bytes(v.len()), "{}", codec.name());
        codec.decode_f32s(v.len(), &buf).unwrap()
    }

    #[test]
    fn fp32_is_exact_passthrough() {
        let v = [0.0f32, -1.5, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, 1e-42];
        let back = roundtrip(Codec::Fp32, &v);
        for (a, b) in v.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(Codec::Fp32.wire_bytes(400, DType::F32), 400);
    }

    #[test]
    fn wire_bytes_accounting() {
        // 100 f32 elements = 400 logical bytes.
        assert_eq!(Codec::Fp16.wire_bytes(400, DType::F32), 200);
        assert_eq!(Codec::Bf16.wire_bytes(400, DType::F32), 200);
        assert_eq!(Codec::Int8.wire_bytes(400, DType::F32), 100 + INT8_HEADER_BYTES);
        // Non-f32 dtypes pass through uncompressed.
        assert_eq!(Codec::Int8.wire_bytes(400, DType::S32), 400);
        // Empty tensors still pay the int8 header.
        assert_eq!(Codec::Int8.wire_bytes(0, DType::F32), INT8_HEADER_BYTES);
        assert_eq!(Codec::Fp16.wire_bytes(0, DType::F32), 0);
    }

    #[test]
    fn half_conversions_match_known_bit_patterns() {
        // (f32, f16 bits): exact cases from the IEEE 754 tables.
        for (x, bits) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (65504.0, 0x7bff),  // largest finite half
            (65536.0, 0x7c00),  // overflow -> inf
            (6.1035156e-5, 0x0400), // smallest normal
            (5.9604645e-8, 0x0001), // smallest subnormal
            (f32::INFINITY, 0x7c00),
        ] {
            assert_eq!(f32_to_f16_bits(x), bits, "f32_to_f16({x})");
        }
        for bits in [0x0000u16, 0x8000, 0x3c00, 0xc000, 0x7bff, 0x0400, 0x0001, 0x03ff] {
            assert_eq!(
                f32_to_f16_bits(f16_bits_to_f32(bits)),
                bits,
                "f16 bits {bits:#06x} must roundtrip exactly"
            );
        }
        assert!(f16_bits_to_f32(0x7e00).is_nan());
        assert!(f32_to_f16_bits(f32::NAN) & 0x7c00 == 0x7c00);
        assert!(f32_to_f16_bits(f32::NAN) & 0x03ff != 0, "NaN must stay NaN");
    }

    #[test]
    fn bf16_truncation_is_faithful() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(bf16_bits_to_f32(0x3f80), 1.0);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        // Round-to-nearest-even at the truncation boundary.
        let x = f32::from_bits(0x3f80_8000); // exactly halfway
        assert_eq!(f32_to_bf16_bits(x), 0x3f80, "ties to even");
        let y = f32::from_bits(0x3f80_8001); // just above halfway
        assert_eq!(f32_to_bf16_bits(y), 0x3f81);
    }

    #[test]
    fn int8_handles_non_finite_and_clamp_boundaries() {
        // Finite range [0, 255] makes scale exactly 1.0, so the clamp
        // boundaries decode bit-exactly.
        let v = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, 255.0, 127.5];
        let back = roundtrip(Codec::Int8, &v);
        assert_eq!(back[0], 0.0, "NaN saturates to q=0 -> lo");
        assert_eq!(back[1], 255.0, "+inf clamps to hi");
        assert_eq!(back[2], 0.0, "-inf clamps to lo");
        assert_eq!(back[3], 0.0);
        assert_eq!(back[4], 255.0);
        // A mid value lands within scale/2 of itself (ties round even).
        assert!((back[5] - 127.5).abs() <= 0.5, "{}", back[5]);
        // General finite values stay within the scale/2 bound.
        let w = [-3.0f32, -1.0, 0.0, 2.5, 5.0];
        let wb = roundtrip(Codec::Int8, &w);
        let scale = 8.0 / 255.0;
        for (&a, &b) in w.iter().zip(&wb) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-5, "{a} -> {b}");
        }
        // All-non-finite and constant tensors stay well-defined.
        assert_eq!(roundtrip(Codec::Int8, &[f32::NAN, f32::INFINITY]), vec![0.0, 255.0]);
        assert_eq!(roundtrip(Codec::Int8, &[7.25; 4]), vec![7.25; 4]);
    }

    #[test]
    fn empty_tensors_roundtrip_under_every_codec() {
        for c in Codec::ALL {
            assert_eq!(roundtrip(c, &[]), Vec::<f32>::new(), "{}", c.name());
        }
    }

    /// Property: for every codec x shape, finite values roundtrip
    /// within the codec's error bound (fp16 relative ~2^-11 within
    /// range, bf16 relative ~2^-8, int8 absolute scale/2).
    #[test]
    fn roundtrip_error_bounded_per_codec() {
        check(
            48,
            |rng| {
                let n = [0usize, 1, 2, 7, 64, 1000][rng.below(6)];
                let seed = rng.below(1 << 30) as u64;
                let codec = Codec::ALL[rng.below(4)];
                (n, seed, codec)
            },
            |&(n, seed, codec)| {
                let mut rng = Rng::new(seed ^ 0xC0DEC);
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 3.0);
                let back = roundtrip(codec, &v);
                if back.len() != v.len() {
                    return Err(format!("{}: length {} != {}", codec.name(), back.len(), n));
                }
                let (lo, hi) = v.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &x| {
                    (l.min(x), h.max(x))
                });
                let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
                for (&a, &b) in v.iter().zip(&back) {
                    let tol = match codec {
                        Codec::Fp32 => 0.0,
                        Codec::Fp16 => a.abs() * 1e-3 + 1e-7,
                        Codec::Bf16 => a.abs() * 8e-3 + 1e-7,
                        // scale/2 quantization error + f32 arithmetic
                        // slack in the decode's zero + q*scale.
                        Codec::Int8 => scale * 0.5 + 1e-4,
                    };
                    if (a - b).abs() > tol {
                        return Err(format!(
                            "{}: {a} -> {b} exceeds tol {tol}",
                            codec.name()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decode_rejects_wrong_payload_length() {
        for c in [Codec::Fp16, Codec::Int8, Codec::Fp32] {
            let mut buf = Vec::new();
            c.encode_f32s(&[1.0, 2.0, 3.0], &mut buf);
            buf.pop(); // truncate
            assert!(c.decode_f32s(3, &buf).is_err(), "{} accepted truncation", c.name());
        }
        // int8 payloads shorter than their header are rejected, not
        // panicked on.
        assert!(Codec::Int8.decode_f32s(0, &[0u8; 4]).is_err());
    }

    #[test]
    fn transcode_matches_roundtrip_and_passes_i32_through() {
        let t = Tensor::from_f32(&[2, 3], vec![0.1, -0.2, 0.3, 1.0, -1.0, 0.0]);
        let tc = Codec::Int8.transcode(&t);
        assert_eq!(tc.shape, t.shape);
        assert_eq!(tc.as_f32().unwrap(), roundtrip(Codec::Int8, t.as_f32().unwrap()));
        let i = Tensor::from_i32(&[3], vec![1, -2, 3]);
        assert_eq!(Codec::Int8.transcode(&i), i);
        assert_eq!(Codec::Fp32.transcode(&t), t);
    }

    #[test]
    fn spec_parse_overrides_and_fingerprint() {
        let spec = CodecSpec::parse("fp32,12=int8,20=fp16").unwrap();
        assert_eq!(spec.at_boundary(12), Codec::Int8);
        assert_eq!(spec.at_boundary(20), Codec::Fp16);
        assert_eq!(spec.at_boundary(5), Codec::Fp32);
        assert_eq!(spec.sync(), Codec::Fp32);
        assert!(!spec.is_identity());
        assert_eq!(spec.describe(), "fp32,12=int8,20=fp16");
        assert_eq!(CodecSpec::parse(&spec.describe()).unwrap(), spec);

        let uni = CodecSpec::parse("int8").unwrap();
        assert_eq!(uni, CodecSpec::uniform(Codec::Int8));
        assert_eq!(uni.at_boundary(3), Codec::Int8);
        assert!(CodecSpec::default().is_identity());

        // Fingerprints separate distinct specs and ignore override order.
        assert_ne!(spec.fingerprint(), uni.fingerprint());
        assert_ne!(uni.fingerprint(), CodecSpec::default().fingerprint());
        let swapped = CodecSpec::parse("fp32,20=fp16,12=int8").unwrap();
        assert_eq!(spec.fingerprint(), swapped.fingerprint());

        assert!(CodecSpec::parse("zstd").is_err());
        assert!(CodecSpec::parse("fp32,x=int8").is_err());
        assert!(CodecSpec::parse("fp32,3:int8").is_err());

        // Override capacity is bounded (Copy-ability), and re-setting
        // the same boundary replaces instead of consuming a slot.
        let mut s = CodecSpec::uniform(Codec::Fp32);
        for b in 0..MAX_OVERRIDES {
            s = s.with_override(b, Codec::Int8).unwrap();
        }
        assert!(s.with_override(99, Codec::Fp16).is_err());
        let r = s.with_override(0, Codec::Fp16).unwrap();
        assert_eq!(r.at_boundary(0), Codec::Fp16);
    }

    #[test]
    fn spec_wire_accounting_follows_links() {
        let spec = CodecSpec::parse("fp32,4=int8").unwrap();
        assert_eq!(spec.wire_activation_bytes(4, 4000), 1000 + INT8_HEADER_BYTES);
        assert_eq!(spec.wire_activation_bytes(5, 4000), 4000);
        assert_eq!(spec.wire_sync_bytes(4000), 4000);
        let uni = CodecSpec::uniform(Codec::Fp16);
        assert_eq!(uni.wire_sync_bytes(4000), 2000);
    }
}
