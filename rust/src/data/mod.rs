//! Synthetic training data with learnable structure.
//!
//! The paper trains on CIFAR-10 / Mini-ImageNet / synthetic BERT data;
//! training-system behaviour depends on tensor shapes, not pixel
//! content, so we generate synthetic datasets of identical shape.  Both
//! tasks are *learnable* (loss demonstrably falls), which is what the
//! end-to-end example verifies.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// A stream of (input, target) micro-batches.
pub trait DataSource {
    /// Next micro-batch: (stage-0 input tensor, head-stage target tensor).
    fn next_microbatch(&mut self) -> (Tensor, Tensor);
}

/// Character-level-style LM task: sequences follow a noisy affine
/// recurrence `x_{t+1} = (a * x_t + b) mod V` with occasional random
/// resets, so next-token prediction is learnable well below ln(V).
pub struct LmTask {
    vocab: usize,
    seq: usize,
    batch: usize,
    noise: f64,
    rng: Rng,
}

impl LmTask {
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64) -> LmTask {
        assert!(vocab >= 4);
        LmTask { vocab, seq, batch, noise: 0.05, rng: Rng::new(seed) }
    }

    fn sequence(&mut self) -> Vec<i32> {
        let v = self.vocab;
        let mut x = self.rng.below(v);
        let mut out = Vec::with_capacity(self.seq + 1);
        out.push(x as i32);
        for _ in 0..self.seq {
            x = if self.rng.f64() < self.noise {
                self.rng.below(v)
            } else {
                (x * 3 + 7) % v
            };
            out.push(x as i32);
        }
        out
    }
}

impl DataSource for LmTask {
    fn next_microbatch(&mut self) -> (Tensor, Tensor) {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for _ in 0..b {
            let seq = self.sequence(); // length s + 1
            tokens.extend_from_slice(&seq[..s]);
            targets.extend_from_slice(&seq[1..s + 1]);
        }
        (
            Tensor::from_i32(&[b, s], tokens),
            Tensor::from_i32(&[b, s], targets),
        )
    }
}

/// CIFAR-shaped classification task: each class has a distinct smooth
/// template; samples are template + noise.
pub struct VisionTask {
    hw: usize,
    channels: usize,
    classes: usize,
    batch: usize,
    noise: f32,
    templates: Vec<Vec<f32>>,
    rng: Rng,
}

impl VisionTask {
    pub fn new(hw: usize, channels: usize, classes: usize, batch: usize, seed: u64) -> VisionTask {
        let mut rng = Rng::new(seed);
        let n = hw * hw * channels;
        // Class identity must survive global average pooling (the CNN
        // head), so each class gets distinct per-channel mean offsets in
        // addition to a smooth spatial pattern.
        let templates = (0..classes)
            .map(|c| {
                (0..n)
                    .map(|i| {
                        let ch = i % channels;
                        let offset = ((c * 7 + ch * 3) % (classes + 1)) as f32 * 0.35;
                        let phase = (i as f32 * 0.07) + c as f32;
                        offset + phase.sin()
                            + 0.5 * ((i / hw) as f32 * 0.13 + 2.0 * c as f32).cos()
                    })
                    .collect()
            })
            .collect();
        let _ = &mut rng;
        VisionTask { hw, channels, classes, batch, noise: 0.3, templates, rng }
    }
}

impl DataSource for VisionTask {
    fn next_microbatch(&mut self) -> (Tensor, Tensor) {
        let n = self.hw * self.hw * self.channels;
        let mut data = Vec::with_capacity(self.batch * n);
        let mut labels = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let c = self.rng.below(self.classes);
            labels.push(c as i32);
            let t = &self.templates[c];
            for i in 0..n {
                data.push(t[i] + self.noise * self.rng.normal_f32());
            }
        }
        (
            Tensor::from_f32(&[self.batch, self.hw, self.hw, self.channels], data),
            Tensor::from_i32(&[self.batch], labels),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_shapes_and_shift() {
        let mut task = LmTask::new(64, 16, 4, 1);
        let (x, y) = task.next_microbatch();
        assert_eq!(x.shape, vec![4, 16]);
        assert_eq!(y.shape, vec![4, 16]);
        // targets are the next-token shift of tokens
        let xs = x.as_i32().unwrap();
        let ys = y.as_i32().unwrap();
        for row in 0..4 {
            for t in 0..15 {
                assert_eq!(ys[row * 16 + t], xs[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let mut task = LmTask::new(32, 8, 8, 2);
        for _ in 0..10 {
            let (x, _) = task.next_microbatch();
            assert!(x.as_i32().unwrap().iter().all(|&t| (0..32).contains(&t)));
        }
    }

    #[test]
    fn lm_is_predictable() {
        // Most transitions follow the affine rule: a bigram oracle gets
        // well above chance accuracy (what the trained model exploits).
        let mut task = LmTask::new(64, 64, 16, 3);
        let (x, y) = task.next_microbatch();
        let xs = x.as_i32().unwrap();
        let ys = y.as_i32().unwrap();
        let correct = xs
            .iter()
            .zip(ys)
            .filter(|&(&a, &b)| (a * 3 + 7) % 64 == b)
            .count();
        let frac = correct as f64 / xs.len() as f64;
        assert!(frac > 0.8, "rule coverage {frac}");
    }

    #[test]
    fn vision_shapes_and_labels() {
        let mut task = VisionTask::new(16, 3, 10, 8, 4);
        let (x, y) = task.next_microbatch();
        assert_eq!(x.shape, vec![8, 16, 16, 3]);
        assert_eq!(y.shape, vec![8]);
        assert!(y.as_i32().unwrap().iter().all(|&c| (0..10).contains(&c)));
    }

    #[test]
    fn vision_classes_are_separable() {
        // Nearest-template classification recovers the label — the task
        // is learnable by construction.
        let mut task = VisionTask::new(8, 3, 4, 32, 5);
        let (x, y) = task.next_microbatch();
        let n = 8 * 8 * 3;
        let xs = x.as_f32().unwrap();
        let ys = y.as_i32().unwrap();
        let mut correct = 0;
        for b in 0..32 {
            let img = &xs[b * n..(b + 1) * n];
            let best = (0..4)
                .min_by(|&a, &c| {
                    let da: f32 = task.templates[a].iter().zip(img).map(|(t, v)| (t - v).powi(2)).sum();
                    let dc: f32 = task.templates[c].iter().zip(img).map(|(t, v)| (t - v).powi(2)).sum();
                    da.partial_cmp(&dc).unwrap()
                })
                .unwrap();
            if best as i32 == ys[b] {
                correct += 1;
            }
        }
        assert!(correct >= 28, "separability {correct}/32");
    }
}
