//! Host-side tensors exchanged between pipeline workers.
//!
//! XLA `Literal`s are not `Send`; workers exchange these plain buffers
//! over channels and convert at the PJRT boundary.

use anyhow::{bail, Result};

use crate::model::from_manifest::{DType, TensorSig};

/// A host tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Size in bytes, dtype-aware: all memory and network accounting
    /// routes through `DType::size_bytes()` so a future f16/bf16 dtype
    /// cannot silently miscount.
    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype().size_bytes()
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::S32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Scalar f32 extraction (loss values).
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    /// Validate against an artifact signature entry.
    pub fn check_sig(&self, sig: &TensorSig) -> Result<()> {
        if self.shape != sig.shape {
            bail!(
                "tensor {:?}: shape {:?} does not match signature {:?}",
                sig.name,
                self.shape,
                sig.shape
            );
        }
        if self.dtype() != sig.dtype {
            bail!("tensor {:?}: dtype mismatch", sig.name);
        }
        Ok(())
    }
}

// ---------------------------------------------------- XLA boundary
//
// Literal conversion is the only place host tensors meet the PJRT
// binding; it only exists under the `pjrt` feature.
#[cfg(feature = "pjrt")]
impl Tensor {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        // One bulk byte-staging pass feeding the untyped constructor
        // (vec1().reshape() builds the literal element-by-element —
        // 10x slower on the 256 KB stage tensors; see EXPERIMENTS.md
        // §Perf).  The staging copy keeps the crate free of unsafe
        // pointer reinterpretation under `#![forbid(unsafe_code)]`.
        let lit = match &self.data {
            TensorData::F32(v) => {
                let bytes = ne_bytes(v, |x: &f32| x.to_ne_bytes());
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &self.shape,
                    &bytes,
                )?
            }
            TensorData::I32(v) => {
                let bytes = ne_bytes(v, |x: &i32| x.to_ne_bytes());
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &self.shape,
                    &bytes,
                )?
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(Tensor { shape: dims, data })
    }
}

/// Stage a 4-byte-element slice into one contiguous native-endian byte
/// buffer (bit-identical to the raw reinterpretation it replaces).
#[cfg(feature = "pjrt")]
fn ne_bytes<T>(v: &[T], f: impl Fn(&T) -> [u8; 4]) -> Vec<u8> {
    let mut out = vec![0u8; 4 * v.len()];
    for (dst, x) in out.chunks_exact_mut(4).zip(v) {
        dst.copy_from_slice(&f(x));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0; 6]);
        assert_eq!(t.elements(), 6);
        assert_eq!(t.byte_len(), 24);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let i = Tensor::from_i32(&[4], vec![1, 2, 3, 4]);
        assert!(i.as_i32().is_ok());
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn byte_len_routes_through_dtype() {
        use crate::model::from_manifest::DType;
        let f = Tensor::zeros_f32(&[3, 5]);
        assert_eq!(f.byte_len(), f.elements() * DType::F32.size_bytes());
        let i = Tensor::from_i32(&[7], vec![0; 7]);
        assert_eq!(i.byte_len(), i.elements() * DType::S32.size_bytes());
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn scalar_extraction() {
        assert_eq!(Tensor::from_f32(&[], vec![2.5]).scalar_f32().unwrap(), 2.5);
        assert!(Tensor::from_f32(&[2], vec![1.0, 2.0]).scalar_f32().is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(&[4], vec![7, -1, 0, 3]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn sig_check() {
        use crate::model::from_manifest::{DType, TensorSig};
        let sig = TensorSig { name: "x".into(), shape: vec![2, 2], dtype: DType::F32 };
        assert!(Tensor::zeros_f32(&[2, 2]).check_sig(&sig).is_ok());
        assert!(Tensor::zeros_f32(&[2, 3]).check_sig(&sig).is_err());
        assert!(Tensor::from_i32(&[2, 2], vec![0; 4]).check_sig(&sig).is_err());
    }
}
