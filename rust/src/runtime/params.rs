//! Stage parameter state: host-side f32 buffers initialised from the
//! manifest's init specs with the coordinator's deterministic RNG.
//!
//! Keeping parameters host-side (rather than as device literals) makes
//! the optimizer a plain f32 stream, AllReduce a buffer average, and
//! fault-tolerant replication (§3.4) a memcpy — the weights *are* the
//! checkpoint.
//!
//! [`ParamStash`] adds the bounded-staleness machinery: a
//! capacity-bounded ring of weight-version snapshots keyed by
//! micro-batch, so an `AsyncPipe` worker's backward can run against
//! exactly the version its forward read (PipeDream-style weight
//! stashing) while the scheduler keeps updating the live weights.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::model::from_manifest::ManifestLayer;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

/// Parameters (and gradient accumulators) of one model layer.
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub layer_name: String,
    /// Parameter tensors, in artifact argument order.
    pub values: Vec<Tensor>,
    /// Gradient accumulators, same shapes.
    pub grads: Vec<Tensor>,
}

impl LayerParams {
    pub fn num_elements(&self) -> usize {
        self.values.iter().map(|t| t.elements()).sum()
    }

    /// Zero all gradient accumulators (start of an HPP-Round).
    pub fn zero_grads(&mut self) {
        for g in &mut self.grads {
            for v in g.as_f32_mut().unwrap() {
                *v = 0.0;
            }
        }
    }

    /// Accumulate `delta` into the gradient buffers.
    pub fn accumulate(&mut self, delta: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(delta.len() == self.grads.len(), "grad arity mismatch");
        for (g, d) in self.grads.iter_mut().zip(delta) {
            let gs = g.as_f32_mut()?;
            let ds = d.as_f32()?;
            anyhow::ensure!(gs.len() == ds.len(), "grad shape mismatch");
            for (a, b) in gs.iter_mut().zip(ds) {
                *a += *b;
            }
        }
        Ok(())
    }

    /// Total bytes of the parameter values (replication cost).
    pub fn byte_len(&self) -> usize {
        self.values.iter().map(|t| t.byte_len()).sum()
    }
}

/// One stashed weight version as host tensors: every parameter tensor
/// of every layer of the stage, in layer order.
pub type ParamSnapshot = Arc<Vec<Vec<Tensor>>>;

/// Bounded ring of weight-version snapshots for a bounded-staleness
/// worker (the live realisation of the Schedule IR's version tags).
/// Generic over the snapshot payload `T`: host tensors
/// ([`ParamSnapshot`]) or — what the live worker actually stashes —
/// the already-converted XLA parameter literals, so a backward never
/// pays a tensor-to-literal conversion (that conversion is the
/// engine's documented top hot-path cost).
///
/// * [`ParamStash::record`] pins the current weights for a micro-batch
///   at its `Fwd` — reusing the previously recorded snapshot when the
///   version is unchanged, calling `snap` otherwise (snapshots are
///   `Arc`-shared, so recording an existing `Arc` is free).
/// * [`ParamStash::take`] releases the snapshot at the micro's `Bwd`,
///   returning the version the gradient must be computed against.
/// * Capacity is the schedule's admission window (K_p + sigma): a
///   `record` beyond it means the worker ran ahead of the staleness
///   bound — a scheduling bug, reported as an error rather than grown
///   past the memory the planner charged (Eq. 3's stash term).
pub struct ParamStash<T> {
    capacity: usize,
    by_micro: BTreeMap<usize, (u64, Arc<T>)>,
    last: Option<(u64, Arc<T>)>,
}

impl<T> ParamStash<T> {
    /// A ring holding at most `capacity` in-flight snapshots (the
    /// policy's effective admission window).
    pub fn new(capacity: usize) -> ParamStash<T> {
        ParamStash { capacity, by_micro: BTreeMap::new(), last: None }
    }

    /// Pin the weights `version` for `micro`; `snap` is only called
    /// when `version` differs from the most recently recorded one.
    pub fn record(
        &mut self,
        micro: usize,
        version: u64,
        snap: impl FnOnce() -> Arc<T>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.by_micro.len() < self.capacity,
            "weight stash ring full ({} in flight): micro {micro} exceeds the \
             staleness window",
            self.by_micro.len()
        );
        anyhow::ensure!(
            !self.by_micro.contains_key(&micro),
            "micro {micro} already stashed"
        );
        let snap = match &self.last {
            Some((v, s)) if *v == version => s.clone(),
            _ => {
                let s = snap();
                self.last = Some((version, s.clone()));
                s
            }
        };
        self.by_micro.insert(micro, (version, snap));
        Ok(())
    }

    /// Release and return the stashed (version, weights) of `micro`.
    pub fn take(&mut self, micro: usize) -> Option<(u64, Arc<T>)> {
        self.by_micro.remove(&micro)
    }

    /// Forget the `record`-dedup anchor (call after any out-of-band
    /// weight write, e.g. the round-end parameter averaging, so a
    /// later `record` at an old version number cannot alias weights
    /// that changed underneath it).
    pub fn invalidate_last(&mut self) {
        self.last = None;
    }

    /// In-flight snapshot count (bounded by the capacity).
    pub fn len(&self) -> usize {
        self.by_micro.len()
    }

    /// True when no snapshot is in flight.
    pub fn is_empty(&self) -> bool {
        self.by_micro.is_empty()
    }

    /// Distinct weight versions currently pinned (shared snapshots
    /// counted once) — bounded by the ring capacity.
    pub fn distinct_versions(&self) -> usize {
        let mut vs: Vec<u64> = self.by_micro.values().map(|(v, _)| *v).collect();
        vs.sort_unstable();
        vs.dedup();
        vs.len()
    }
}

/// Initialise one layer's parameters per the manifest spec.
pub fn init_layer_params(layer: &ManifestLayer, rng: &mut Rng) -> LayerParams {
    let mut values = Vec::with_capacity(layer.params.len());
    let mut grads = Vec::with_capacity(layer.params.len());
    for p in &layer.params {
        let n = p.elements();
        let mut data = vec![0.0f32; n];
        match p.init.as_str() {
            "zeros" => {}
            "ones" => data.iter_mut().for_each(|v| *v = 1.0),
            _ => rng.fill_normal(&mut data, p.scale as f32),
        }
        values.push(Tensor::from_f32(&p.shape, data));
        grads.push(Tensor::zeros_f32(&p.shape));
    }
    LayerParams { layer_name: layer.name.clone(), values, grads }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::from_manifest::ParamInit;

    fn mk_layer() -> ManifestLayer {
        ManifestLayer {
            name: "test".into(),
            kind: "block".into(),
            params: vec![
                ParamInit { name: "w".into(), shape: vec![4, 4], init: "normal".into(), scale: 0.5 },
                ParamInit { name: "b".into(), shape: vec![4], init: "zeros".into(), scale: 0.0 },
                ParamInit { name: "s".into(), shape: vec![4], init: "ones".into(), scale: 0.0 },
            ],
            weight_bytes: 96,
            out_bytes: 0,
            flops_fwd: 0.0,
            flops_bwd: 0.0,
            artifact_fwd: "f".into(),
            artifact_bwd: "b".into(),
        }
    }

    #[test]
    fn init_respects_spec() {
        let mut rng = Rng::new(1);
        let p = init_layer_params(&mk_layer(), &mut rng);
        assert_eq!(p.values.len(), 3);
        assert_eq!(p.num_elements(), 16 + 4 + 4);
        let w = p.values[0].as_f32().unwrap();
        assert!(w.iter().any(|&v| v != 0.0), "normal init all zero");
        assert!(p.values[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(p.values[2].as_f32().unwrap().iter().all(|&v| v == 1.0));
        assert_eq!(p.byte_len(), (16 + 4 + 4) * 4);
    }

    #[test]
    fn init_deterministic_per_seed() {
        let a = init_layer_params(&mk_layer(), &mut Rng::new(7));
        let b = init_layer_params(&mk_layer(), &mut Rng::new(7));
        let c = init_layer_params(&mk_layer(), &mut Rng::new(8));
        assert_eq!(a.values[0], b.values[0]);
        assert_ne!(a.values[0], c.values[0]);
    }

    #[test]
    fn grad_accumulation() {
        let mut rng = Rng::new(1);
        let mut p = init_layer_params(&mk_layer(), &mut rng);
        let delta: Vec<Tensor> = p
            .grads
            .iter()
            .map(|g| Tensor::from_f32(&g.shape, vec![2.0; g.elements()]))
            .collect();
        p.accumulate(&delta).unwrap();
        p.accumulate(&delta).unwrap();
        assert!(p.grads[0].as_f32().unwrap().iter().all(|&v| v == 4.0));
        p.zero_grads();
        assert!(p.grads[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn accumulate_arity_checked() {
        let mut rng = Rng::new(1);
        let mut p = init_layer_params(&mk_layer(), &mut rng);
        assert!(p.accumulate(&[]).is_err());
    }

    fn snap(v: f32) -> ParamSnapshot {
        Arc::new(vec![vec![Tensor::from_f32(&[2], vec![v, v])]])
    }

    #[test]
    fn stash_roundtrips_versions() {
        let mut s: ParamStash<Vec<Vec<Tensor>>> = ParamStash::new(3);
        s.record(0, 0, || snap(0.0)).unwrap();
        s.record(1, 0, || snap(99.0)).unwrap(); // same version: closure skipped
        s.record(2, 1, || snap(1.0)).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s.distinct_versions(), 2);
        let (v0, w0) = s.take(0).unwrap();
        assert_eq!(v0, 0);
        assert_eq!(w0[0][0].as_f32().unwrap(), &[0.0, 0.0]);
        // Micro 1 shares micro 0's snapshot (recorded at the same
        // version), so the 99.0 closure never ran.
        let (v1, w1) = s.take(1).unwrap();
        assert_eq!(v1, 0);
        assert!(Arc::ptr_eq(&w0, &w1));
        let (v2, w2) = s.take(2).unwrap();
        assert_eq!(v2, 1);
        assert_eq!(w2[0][0].as_f32().unwrap(), &[1.0, 1.0]);
        assert!(s.is_empty());
        assert!(s.take(0).is_none());
    }

    #[test]
    fn stash_ring_is_bounded() {
        let mut s: ParamStash<Vec<Vec<Tensor>>> = ParamStash::new(2);
        s.record(0, 0, || snap(0.0)).unwrap();
        s.record(1, 1, || snap(1.0)).unwrap();
        // A third in-flight micro exceeds the staleness window.
        assert!(s.record(2, 2, || snap(2.0)).is_err());
        // Duplicate stash for an in-flight micro is a bug too.
        assert!(s.record(1, 1, || snap(1.0)).is_err());
        // Draining one reader frees a slot.
        s.take(0).unwrap();
        s.record(2, 2, || snap(2.0)).unwrap();
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn stash_invalidate_last_breaks_version_aliasing() {
        // After an out-of-band weight write (round-end parameter
        // averaging), a record at the *same* version number must not
        // alias the pre-write snapshot.
        let mut s: ParamStash<Vec<Vec<Tensor>>> = ParamStash::new(2);
        s.record(0, 7, || snap(0.0)).unwrap();
        let (_, before) = s.take(0).unwrap();
        s.invalidate_last();
        s.record(1, 7, || snap(1.0)).unwrap();
        let (_, after) = s.take(1).unwrap();
        assert!(!Arc::ptr_eq(&before, &after));
        assert_eq!(after[0][0].as_f32().unwrap(), &[1.0, 1.0]);
    }
}
