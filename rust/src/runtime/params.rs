//! Stage parameter state: host-side f32 buffers initialised from the
//! manifest's init specs with the coordinator's deterministic RNG.
//!
//! Keeping parameters host-side (rather than as device literals) makes
//! the optimizer a plain f32 stream, AllReduce a buffer average, and
//! fault-tolerant replication (§3.4) a memcpy — the weights *are* the
//! checkpoint.

use crate::model::from_manifest::ManifestLayer;
use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

/// Parameters (and gradient accumulators) of one model layer.
#[derive(Debug, Clone)]
pub struct LayerParams {
    pub layer_name: String,
    /// Parameter tensors, in artifact argument order.
    pub values: Vec<Tensor>,
    /// Gradient accumulators, same shapes.
    pub grads: Vec<Tensor>,
}

impl LayerParams {
    pub fn num_elements(&self) -> usize {
        self.values.iter().map(|t| t.elements()).sum()
    }

    /// Zero all gradient accumulators (start of an HPP-Round).
    pub fn zero_grads(&mut self) {
        for g in &mut self.grads {
            for v in g.as_f32_mut().unwrap() {
                *v = 0.0;
            }
        }
    }

    /// Accumulate `delta` into the gradient buffers.
    pub fn accumulate(&mut self, delta: &[Tensor]) -> anyhow::Result<()> {
        anyhow::ensure!(delta.len() == self.grads.len(), "grad arity mismatch");
        for (g, d) in self.grads.iter_mut().zip(delta) {
            let gs = g.as_f32_mut()?;
            let ds = d.as_f32()?;
            anyhow::ensure!(gs.len() == ds.len(), "grad shape mismatch");
            for (a, b) in gs.iter_mut().zip(ds) {
                *a += *b;
            }
        }
        Ok(())
    }

    /// Total bytes of the parameter values (replication cost).
    pub fn byte_len(&self) -> usize {
        self.values.iter().map(|t| t.byte_len()).sum()
    }
}

/// Initialise one layer's parameters per the manifest spec.
pub fn init_layer_params(layer: &ManifestLayer, rng: &mut Rng) -> LayerParams {
    let mut values = Vec::with_capacity(layer.params.len());
    let mut grads = Vec::with_capacity(layer.params.len());
    for p in &layer.params {
        let n = p.elements();
        let mut data = vec![0.0f32; n];
        match p.init.as_str() {
            "zeros" => {}
            "ones" => data.iter_mut().for_each(|v| *v = 1.0),
            _ => rng.fill_normal(&mut data, p.scale as f32),
        }
        values.push(Tensor::from_f32(&p.shape, data));
        grads.push(Tensor::zeros_f32(&p.shape));
    }
    LayerParams { layer_name: layer.name.clone(), values, grads }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::from_manifest::ParamInit;

    fn mk_layer() -> ManifestLayer {
        ManifestLayer {
            name: "test".into(),
            kind: "block".into(),
            params: vec![
                ParamInit { name: "w".into(), shape: vec![4, 4], init: "normal".into(), scale: 0.5 },
                ParamInit { name: "b".into(), shape: vec![4], init: "zeros".into(), scale: 0.0 },
                ParamInit { name: "s".into(), shape: vec![4], init: "ones".into(), scale: 0.0 },
            ],
            weight_bytes: 96,
            out_bytes: 0,
            flops_fwd: 0.0,
            flops_bwd: 0.0,
            artifact_fwd: "f".into(),
            artifact_bwd: "b".into(),
        }
    }

    #[test]
    fn init_respects_spec() {
        let mut rng = Rng::new(1);
        let p = init_layer_params(&mk_layer(), &mut rng);
        assert_eq!(p.values.len(), 3);
        assert_eq!(p.num_elements(), 16 + 4 + 4);
        let w = p.values[0].as_f32().unwrap();
        assert!(w.iter().any(|&v| v != 0.0), "normal init all zero");
        assert!(p.values[1].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(p.values[2].as_f32().unwrap().iter().all(|&v| v == 1.0));
        assert_eq!(p.byte_len(), (16 + 4 + 4) * 4);
    }

    #[test]
    fn init_deterministic_per_seed() {
        let a = init_layer_params(&mk_layer(), &mut Rng::new(7));
        let b = init_layer_params(&mk_layer(), &mut Rng::new(7));
        let c = init_layer_params(&mk_layer(), &mut Rng::new(8));
        assert_eq!(a.values[0], b.values[0]);
        assert_ne!(a.values[0], c.values[0]);
    }

    #[test]
    fn grad_accumulation() {
        let mut rng = Rng::new(1);
        let mut p = init_layer_params(&mk_layer(), &mut rng);
        let delta: Vec<Tensor> = p
            .grads
            .iter()
            .map(|g| Tensor::from_f32(&g.shape, vec![2.0; g.elements()]))
            .collect();
        p.accumulate(&delta).unwrap();
        p.accumulate(&delta).unwrap();
        assert!(p.grads[0].as_f32().unwrap().iter().all(|&v| v == 4.0));
        p.zero_grads();
        assert!(p.grads[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn accumulate_arity_checked() {
        let mut rng = Rng::new(1);
        let mut p = init_layer_params(&mk_layer(), &mut rng);
        assert!(p.accumulate(&[]).is_err());
    }
}
