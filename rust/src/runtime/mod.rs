//! PJRT runtime: load AOT artifacts and execute them from the training
//! hot path.  Python never runs here — the HLO text was produced once
//! by `python/compile/aot.py` (see DESIGN.md for the HLO-text-vs-proto
//! rationale) and is compiled by the in-process PJRT CPU client.
//!
//! XLA handles are not `Send`, so each pipeline worker thread builds its
//! own `Runtime` (client + compiled executables) — mirroring the real
//! system where every edge device runs its own Asteroid Worker process.

pub mod params;
pub mod tensor;

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use crate::model::from_manifest::{ArtifactSig, Manifest, ManifestModel};
pub use params::{init_layer_params, LayerParams, ParamSnapshot, ParamStash};
pub use tensor::{Tensor, TensorData};

/// A compiled model runtime: one PJRT client plus the compiled
/// executables this worker's stage needs.  Only exists under the
/// `pjrt` feature — the rest of the crate (planner, simulator, fault
/// machinery, host tensors) never touches XLA.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    exes: BTreeMap<String, (xla::PjRtLoadedExecutable, ArtifactSig)>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Compile the named artifacts of `model` (or all of them when
    /// `names` is empty).
    pub fn load(model: &ManifestModel, names: &[&str]) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = BTreeMap::new();
        let wanted: Vec<String> = if names.is_empty() {
            model.artifacts.keys().cloned().collect()
        } else {
            names.iter().map(|s| s.to_string()).collect()
        };
        for name in wanted {
            let sig = model.artifact(&name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                sig.file.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO {:?}", sig.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.insert(name, (exe, sig));
        }
        Ok(Runtime { client, exes })
    }

    /// Convenience: load from an artifacts dir + model name.
    pub fn load_model(artifacts_dir: &Path, model_name: &str, names: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        Runtime::load(manifest.model(model_name)?, names)
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn signature(&self, name: &str) -> Result<&ArtifactSig> {
        Ok(&self
            .exes
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?
            .1)
    }

    /// Execute an artifact on pre-converted literals (hot path: lets
    /// callers cache parameter literals across micro-batches instead of
    /// re-copying them per execution — see EXPERIMENTS.md §Perf).
    pub fn execute_literals(
        &self,
        name: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<Tensor>> {
        let (exe, sig) = self
            .exes
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        if inputs.len() != sig.inputs.len() {
            anyhow::bail!(
                "{name}: {} inputs given, signature wants {}",
                inputs.len(),
                sig.inputs.len()
            );
        }
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (p, s) in parts.iter().zip(&sig.outputs) {
            out.push(
                Tensor::from_literal(p).with_context(|| format!("{name} output {:?}", s.name))?,
            );
        }
        Ok(out)
    }

    /// Execute an artifact on host tensors; returns the tuple outputs.
    pub fn execute(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let (exe, sig) = self
            .exes
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))?;
        if inputs.len() != sig.inputs.len() {
            anyhow::bail!(
                "{name}: {} inputs given, signature wants {}",
                inputs.len(),
                sig.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&sig.inputs) {
            t.check_sig(s).with_context(|| format!("{name} input"))?;
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (p, s) in parts.iter().zip(&sig.outputs) {
            let t = Tensor::from_literal(p)
                .with_context(|| format!("{name} output {:?}", s.name))?;
            out.push(t);
        }
        Ok(out)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::model::from_manifest::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_and_executes_lm_head_loss() {
        let manifest = Manifest::load(&artifacts_dir()).unwrap();
        let lm = manifest.model("lm").unwrap();
        let rt = Runtime::load(lm, &["head_loss"]).unwrap();
        assert!(rt.has("head_loss"));
        assert!(!rt.has("block_fwd"));

        let sig = rt.signature("head_loss").unwrap().clone();
        // params + x as zeros except LN scale = 1 → uniform logits →
        // loss = ln(vocab).
        let vocab = lm.cfg_usize("vocab").unwrap();
        let inputs: Vec<Tensor> = sig
            .inputs
            .iter()
            .map(|s| {
                if s.name == "lnf_scale" {
                    Tensor::from_f32(&s.shape, vec![1.0; s.shape.iter().product()])
                } else if s.dtype == crate::model::from_manifest::DType::S32 {
                    Tensor::from_i32(&s.shape, vec![0; s.shape.iter().product()])
                } else {
                    Tensor::zeros_f32(&s.shape)
                }
            })
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = rt.execute("head_loss", &refs).unwrap();
        assert_eq!(out.len(), 1);
        let loss = out[0].scalar_f32().unwrap();
        assert!(
            (loss - (vocab as f32).ln()).abs() < 1e-4,
            "loss {loss} vs ln({vocab})"
        );
    }

    #[test]
    fn rejects_wrong_arity_and_shapes() {
        let manifest = Manifest::load(&artifacts_dir()).unwrap();
        let lm = manifest.model("lm").unwrap();
        let rt = Runtime::load(lm, &["head_loss"]).unwrap();
        assert!(rt.execute("head_loss", &[]).is_err());
        assert!(rt.execute("missing", &[]).is_err());
        let bad = Tensor::zeros_f32(&[1]);
        let sig = rt.signature("head_loss").unwrap().clone();
        let mut inputs: Vec<Tensor> =
            sig.inputs.iter().map(|s| Tensor::zeros_f32(&s.shape)).collect();
        inputs[0] = bad;
        let refs: Vec<&Tensor> = inputs.iter().collect();
        assert!(rt.execute("head_loss", &refs).is_err());
    }
}
