//! Reproduction harness: one function per table/figure of the paper's
//! evaluation (§2 measurement studies + §5 evaluation).  Each returns a
//! `metrics::Table` whose rows mirror what the paper reports; the
//! `repro` binary prints them and writes CSVs under `results/`.
//!
//! Absolute numbers come from the calibrated device/network models
//! (DESIGN.md §Hardware-Adaptation); the claims these tables support
//! are the paper's *relative* ones — who wins, by roughly what factor,
//! where crossovers and OOMs appear.
//!
//! Every experiment that plans-and-executes goes through the public
//! [`Session`] surface: one builder per (model, cluster, planner)
//! triple, priced by [`SimBackend`], with device exits injected as
//! [`FaultSpec`]s.  Only sub-planner probes (custom plans, K_p policy
//! sweeps) drop to `sim::simulate_round` directly.

use anyhow::Result;

use crate::comm;
use crate::config::{ClusterSpec, DeviceKind, DeviceSpec, TrainConfig};
use crate::metrics::{fx, Table};
use crate::model::{zoo, ModelDesc};
use crate::planner::baselines::{plan_hetpipe, Method};
use crate::planner::cost::plan_peak_memory;
use crate::planner::dp::PlannerConfig;
use crate::planner::plan::KpPolicy;
use crate::planner::{AllocOpts, Plan, Planner};
use crate::profiler::{self, ProfileTable};
use crate::session::{FaultSpec, RecoveryKind, Session, SimBackend};
use crate::sim::convergence::convergence_point;
use crate::sim::simulate_round;

/// Per-model evaluation configuration (paper §5.1): mini-batch 2048
/// except ResNet50's 256; micro-batch sizes chosen as the paper's
/// profiler sweep suggests.
fn eval_cfg(model_name: &str) -> TrainConfig {
    match model_name {
        "resnet50" => TrainConfig::new(256, 8),
        "bert-small" => TrainConfig::new(2048, 8),
        _ => TrainConfig::new(2048, 32),
    }
}

fn eval_models() -> Vec<ModelDesc> {
    zoo::all()
}

/// Samples per epoch per dataset (CIFAR-10 50k; Mini-ImageNet train
/// split ~48k; Bert synthetic corpus sized like the paper's).
fn epoch_size(model_name: &str) -> usize {
    match model_name {
        "resnet50" => 48_000,
        "bert-small" => 20_000,
        _ => 50_000,
    }
}

/// Plan + profile one (model, cluster, planner) triple.
fn zoo_session(
    model: &str,
    cluster: ClusterSpec,
    cfg: TrainConfig,
    planner: Planner,
) -> Result<Session> {
    Session::builder()
        .model(model)
        .cluster(cluster)
        .train(cfg)
        .planner(planner)
        .build()
}

/// Event-accurate samples/s of a planned session.
fn priced_throughput(s: &Session) -> f64 {
    s.run(&mut SimBackend::default())
        .expect("sim pricing of a planned session")
        .throughput
}

/// Whether the session's plan violates any device's memory budget
/// (the baselines plan memory-blind; the paper marks those runs
/// x/OOM).
fn plan_ooms(s: &Session) -> bool {
    plan_peak_memory(s.model(), s.train_config(), s.plan(), s.policy())
        .iter()
        .any(|&(d, used)| used > s.cluster().devices[d].mem_bytes)
}

// ====================================================================
// Table 1: on-device epoch time across device classes
// ====================================================================

pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1: elapsed time of a training epoch on devices",
        &["model", "A100", "Jetson TX2", "Jetson Nano", "TX2/A100", "Nano/A100"],
    );
    let devices = [
        DeviceSpec::of_kind(DeviceKind::A100, 0),
        DeviceSpec::of_kind(DeviceKind::JetsonTX2, 1),
        DeviceSpec::of_kind(DeviceKind::JetsonNano, 2),
    ];
    for model in [zoo::efficientnet_b1(), zoo::mobilenet_v2(), zoo::resnet50()] {
        let n = epoch_size(&model.name);
        let times: Vec<f64> = devices
            .iter()
            .map(|d| profiler::on_device_sample_time(d, &model, 32) * n as f64)
            .collect();
        t.row(vec![
            model.name.clone(),
            crate::util::stats::human_secs(times[0]),
            crate::util::stats::human_secs(times[1]),
            crate::util::stats::human_secs(times[2]),
            fx(times[1] / times[0], 0) + "x",
            fx(times[2] / times[0], 0) + "x",
        ]);
    }
    t
}

// ====================================================================
// Fig. 1: DP latency breakdown (left) + bytes/sample DP vs PP (right)
// ====================================================================

pub fn fig1() -> (Table, Table) {
    let cluster = ClusterSpec::nanos(3, 100.0);
    let mut left = Table::new(
        "Fig 1 (left): DP mini-batch latency breakdown on 3x Nano @ 100 Mbps",
        &["model", "compute s", "sync s", "sync share"],
    );
    for model in eval_models() {
        let table = ProfileTable::new(&cluster, &model);
        let (compute, sync) = comm::dp_latency_breakdown(&table, &cluster, &model, 96);
        left.row(vec![
            model.name.clone(),
            fx(compute, 2),
            fx(sync, 2),
            fx(100.0 * sync / (sync + compute), 0) + "%",
        ]);
    }

    let mut right = Table::new(
        "Fig 1 (right): bytes communicated per sample, DP vs PP (3 workers)",
        &["model", "DP B/sample", "PP B/sample", "PP/DP"],
    );
    for model in eval_models() {
        let cfg = eval_cfg(&model.name);
        let dp = comm::dp_bytes_per_sample(&model, 3, cfg.minibatch);
        // PP cut into 3 compute-balanced stages (GPipe-style cuts).
        let s = zoo_session(
            &model.name,
            ClusterSpec::nanos(3, 100.0),
            cfg,
            Planner::Baseline(Method::GpipePP),
        )
        .unwrap();
        let bounds: Vec<usize> =
            s.plan().stages.iter().skip(1).map(|st| st.layers.0).collect();
        let ppb = comm::pp_bytes_per_sample(&model, &bounds);
        right.row(vec![
            model.name.clone(),
            fx(dp, 0),
            fx(ppb, 0),
            fx(ppb / dp, 2) + "x",
        ]);
    }
    (left, right)
}

// ====================================================================
// Table 2: communication volume, HDP vs HPP (5x Nano)
// ====================================================================

pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2: comm volume per mini-batch, HDP (HetPipe) vs HPP (Asteroid), 5x Nano",
        &["model", "V_HDP MB", "V_HPP MB", "HDP/HPP"],
    );
    let cluster = ClusterSpec::env("A", 100.0).unwrap();
    for model in [zoo::efficientnet_b1(), zoo::mobilenet_v2(), zoo::resnet50()] {
        let cfg = eval_cfg(&model.name);
        let table = ProfileTable::new(&cluster, &model);
        let hdp = plan_hetpipe(&table, &cluster, &model, &cfg).unwrap();
        // §2.3's architecture analysis: what communication the HPP
        // architecture can *confine itself to* (volume-optimal config;
        // see comm::volume_optimal_hpp docs for the distinction from
        // the latency-optimal throughput planner).
        let (_, v_hpp) =
            comm::volume_optimal_hpp(&model, cluster.n(), cfg.minibatch, 4);
        let mb = 1024.0 * 1024.0;
        t.row(vec![
            model.name.clone(),
            fx(hdp.volume_bytes as f64 / mb, 1),
            fx(v_hpp as f64 / mb, 1),
            fx(hdp.volume_bytes as f64 / v_hpp as f64, 2) + "x",
        ]);
    }
    t
}

// ====================================================================
// Fig. 5: memory-footprint breakdown during training
// ====================================================================

pub fn fig5() -> Table {
    let mut t = Table::new(
        "Fig 5: memory footprint breakdown (whole model, batch 32, Jetson NX)",
        &["model", "weights+grads MB", "optimizer MB", "activations MB", "act share"],
    );
    for model in eval_models() {
        let cfg = TrainConfig::new(256, 32);
        let mem = crate::planner::memory::stage_memory(&model, &cfg, 0, model.num_layers(), 32, 1);
        let mb = 1024.0 * 1024.0;
        let act = mem.activation_bytes_per_mb as f64;
        let total = mem.total() as f64;
        t.row(vec![
            model.name.clone(),
            fx(mem.model_bytes as f64 / mb, 1),
            fx(mem.optimizer_bytes as f64 / mb, 1),
            fx(act / mb, 1),
            fx(100.0 * act / total, 0) + "%",
        ]);
    }
    t
}

// ====================================================================
// Fig. 6: non-linear batch-size -> execution-time curves
// ====================================================================

pub fn fig6() -> Table {
    let mut t = Table::new(
        "Fig 6: MobileNetV2 fwd+bwd time vs batch size (non-linear scaling)",
        &["batch", "TX2 ms", "NX ms", "TX2 ms/sample", "NX ms/sample"],
    );
    let model = zoo::mobilenet_v2();
    let tx2 = DeviceSpec::of_kind(DeviceKind::JetsonTX2, 0);
    let nx = DeviceSpec::of_kind(DeviceKind::JetsonNX, 1);
    for beta in [1usize, 2, 4, 8, 16, 32, 64] {
        let f = |d: &DeviceSpec| {
            model
                .layers
                .iter()
                .map(|l| {
                    profiler::layer_time_fwd(d, l.flops_fwd, beta)
                        + profiler::layer_time_bwd(d, l.flops_bwd, beta)
                })
                .sum::<f64>()
        };
        let (a, b) = (f(&tx2), f(&nx));
        t.row(vec![
            beta.to_string(),
            fx(a * 1e3, 1),
            fx(b * 1e3, 1),
            fx(a * 1e3 / beta as f64, 2),
            fx(b * 1e3 / beta as f64, 2),
        ]);
    }
    t
}

// ====================================================================
// Table 4 (+ Fig. 12): Asteroid vs on-device / DP / PP
// ====================================================================

pub fn table4() -> Table {
    let mut t = Table::new(
        "Table 4: throughput vs on-device, DP, PP (sim; speedups = Asteroid/other)",
        &["model", "env", "asteroid cfg (Fig 12)", "tput s/s", "vs device", "vs DP", "vs PP"],
    );
    let envs: Vec<(&str, f64)> = vec![("A", 100.0), ("B", 100.0), ("B", 1000.0)];
    for model in eval_models() {
        for &(env, mbps) in &envs {
            let cluster = ClusterSpec::env(env, mbps).unwrap();
            let cfg = eval_cfg(&model.name);
            let ours =
                zoo_session(&model.name, cluster.clone(), cfg.clone(), Planner::Asteroid)
                    .unwrap();
            let ours_tput = priced_throughput(&ours);
            let tput = |m: Method| -> Option<f64> {
                let s = zoo_session(
                    &model.name,
                    cluster.clone(),
                    cfg.clone(),
                    Planner::Baseline(m),
                )
                .ok()?;
                Some(priced_throughput(&s))
            };
            let dev = tput(Method::OnDevice);
            let dp = tput(Method::DataParallel);
            let pp = tput(Method::GpipePP);
            let rel = |x: Option<f64>| match x {
                Some(v) if v > 0.0 => fx(ours_tput / v, 1) + "x",
                _ => "OOM".into(),
            };
            t.row(vec![
                model.name.clone(),
                format!("{env}@{mbps:.0}Mbps"),
                ours.plan().describe(&cluster),
                fx(ours_tput, 1),
                rel(dev),
                rel(dp),
                rel(pp),
            ]);
        }
    }
    t
}

// ====================================================================
// Fig. 13: Asteroid vs EDDL / PipeDream / Dapple / HetPipe
// ====================================================================

pub fn fig13() -> Table {
    let mut t = Table::new(
        "Fig 13: throughput (samples/s) vs existing systems on Env B and C",
        &["model", "env", "EDDL", "PipeDream", "Dapple", "HetPipe", "Asteroid"],
    );
    for model in eval_models() {
        for env in ["B", "C"] {
            let cluster = ClusterSpec::env(env, 100.0).unwrap();
            let cfg = eval_cfg(&model.name);
            let cell = |m: Method| -> String {
                match zoo_session(
                    &model.name,
                    cluster.clone(),
                    cfg.clone(),
                    Planner::Baseline(m),
                ) {
                    Ok(s) => {
                        if plan_ooms(&s) {
                            "OOM".into()
                        } else {
                            fx(priced_throughput(&s), 1)
                        }
                    }
                    Err(_) => "OOM".into(),
                }
            };
            let table = ProfileTable::new(&cluster, &model);
            let hetpipe = match plan_hetpipe(&table, &cluster, &model, &cfg) {
                Err(_) => "OOM".into(),
                Ok(h) if h.groups.len() == 1 => {
                    // G = 1 degenerates to a plain pipeline: score it with
                    // the same simulator as every other method.
                    let g = &h.groups[0];
                    let cuts = &h.cuts[0];
                    let plan = Plan {
                        stages: (0..g.len())
                            .map(|s| crate::planner::Stage {
                                layers: (cuts[s], cuts[s + 1]),
                                devices: vec![g[s]],
                                alloc: vec![cfg.microbatch],
                                kp: (2 * (g.len() - s)).saturating_sub(1)
                                    .clamp(1, cfg.num_microbatches()),
                            })
                            .collect(),
                        microbatch: cfg.microbatch,
                        num_micro: cfg.num_microbatches(),
                    };
                    fx(simulate_round(&table, &cluster, &model, &plan).throughput, 1)
                }
                Ok(h) => fx(h.throughput, 1),
            };
            t.row(vec![
                model.name.clone(),
                env.into(),
                cell(Method::Eddl),
                cell(Method::PipeDream),
                cell(Method::Dapple),
                hetpipe,
                cell(Method::Asteroid),
            ]);
        }
    }
    t
}

// ====================================================================
// Fig. 14: convergence (time to 85% accuracy)
// ====================================================================

pub fn fig14() -> Table {
    let mut t = Table::new(
        "Fig 14: time to target accuracy (85%), EffNet-B1 + MobileNetV2, Env B and C",
        &["model", "env", "method", "tput s/s", "epochs", "hours to target"],
    );
    // Epochs-to-85% from reference CIFAR-10 curves.
    let epochs_to_target = 35.0;
    for model in [zoo::efficientnet_b1(), zoo::mobilenet_v2()] {
        for env in ["B", "C"] {
            let cluster = ClusterSpec::env(env, 100.0).unwrap();
            let cfg = eval_cfg(&model.name);
            let ds = epoch_size(&model.name);
            let mut add = |name: &str, tput: f64, asynchronous: bool| {
                let p = convergence_point(name, tput, epochs_to_target, ds, asynchronous);
                t.row(vec![
                    model.name.clone(),
                    env.into(),
                    name.into(),
                    fx(tput, 1),
                    fx(p.epochs, 0),
                    fx(p.hours_to_target, 2),
                ]);
            };
            let session_for = |m: Method| {
                zoo_session(&model.name, cluster.clone(), cfg.clone(), Planner::Baseline(m))
            };
            if let Ok(s) = session_for(Method::Eddl) {
                add("EDDL", priced_throughput(&s), false);
            }
            if let Ok(s) = session_for(Method::Dapple) {
                if !plan_ooms(&s) {
                    add("Dapple", priced_throughput(&s), false);
                }
            }
            let table = ProfileTable::new(&cluster, &model);
            if let Ok(h) = plan_hetpipe(&table, &cluster, &model, &cfg) {
                add("HetPipe", h.throughput, true);
            }
            let ours = session_for(Method::Asteroid).unwrap();
            add("Asteroid", priced_throughput(&ours), false);
        }
    }
    t
}

// ====================================================================
// Fig. 15(a): planning ablation
// ====================================================================

pub fn fig15a() -> Table {
    let mut t = Table::new(
        "Fig 15a: planning ablation on Env C (naive -> +inter-stage -> +intra-stage)",
        &["model", "variant", "tput s/s", "note"],
    );
    // Micro-batch 64 (vs Table 4's 32) raises memory pressure so that
    // memory-blind planning actually hits the OOM wall the paper's
    // ablation shows (x marks in Fig. 15a).
    for model in [zoo::efficientnet_b1(), zoo::mobilenet_v2()] {
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let cfg = TrainConfig::new(2048, 64);

        let variants: Vec<(&str, PlannerConfig)> = vec![
            (
                "naive",
                PlannerConfig {
                    alloc: AllocOpts {
                        memory_aware: false,
                        heterogeneity_aware: false,
                        straggler_offload: false,
                        ..AllocOpts::default()
                    },
                    comm_aware: false,
                    ..PlannerConfig::default()
                },
            ),
            (
                "+inter-stage (A)",
                PlannerConfig {
                    alloc: AllocOpts {
                        memory_aware: false,
                        heterogeneity_aware: false,
                        straggler_offload: false,
                        ..AllocOpts::default()
                    },
                    comm_aware: true,
                    ..PlannerConfig::default()
                },
            ),
            ("+intra-stage (A+B)", PlannerConfig::default()),
        ];
        for (name, pc) in variants {
            match zoo_session(&model.name, cluster.clone(), cfg.clone(), Planner::Custom(pc)) {
                Ok(s) => {
                    let oom = plan_ooms(&s);
                    let tput = priced_throughput(&s);
                    t.row(vec![
                        model.name.clone(),
                        name.into(),
                        if oom { "x".into() } else { fx(tput, 1) },
                        if oom { "OOM (memory-blind)".into() } else { String::new() },
                    ]);
                }
                Err(_) => t.row(vec![
                    model.name.clone(),
                    name.into(),
                    "x".into(),
                    "infeasible".into(),
                ]),
            }
        }
    }
    t
}

// ====================================================================
// Fig. 15(b): 1F1B K_p policy ablation
// ====================================================================

pub fn fig15b() -> Table {
    let mut t = Table::new(
        "Fig 15b: K_p policy ablation (EffNet-B1, 3x TX2 3-stage pipeline)",
        &["policy", "peak mem MB (stage 0)", "tput s/s"],
    );
    let cluster = ClusterSpec::uniform(&[DeviceKind::JetsonTX2; 3], 100.0);
    let model = zoo::efficientnet_b1();
    let cfg = TrainConfig::new(512, 16);
    let table = ProfileTable::new(&cluster, &model);
    for policy in [
        KpPolicy::TwoGapsPlusOne,
        KpPolicy::Linear,
        KpPolicy::TwoGapsPlusTwo,
        KpPolicy::Ours,
        KpPolicy::AllForward,
    ] {
        let pc = PlannerConfig { kp_policy: policy, max_stages: 3, ..PlannerConfig::default() };
        // Force a pipeline comparison by requiring >= 2 stages: fall back
        // to the gpipe partitioner when the DP picks a single stage.
        let plan = match crate::planner::dp::plan_hpp(&table, &cluster, &model, &cfg, &pc) {
            Ok(o) if o.plan.num_stages() >= 2 => o.plan,
            _ => {
                let mut o = crate::planner::baselines::plan_gpipe_pp(
                    &table,
                    &cluster,
                    &model,
                    &cfg,
                    crate::schedule::DEFAULT_POLICY,
                )
                .unwrap()
                .plan;
                let m = o.num_micro;
                let p_total = o.stages.len();
                for (p, s) in o.stages.iter_mut().enumerate() {
                    s.kp = policy.kp(p_total, p, m);
                }
                o
            }
        };
        let sim = simulate_round(&table, &cluster, &model, &plan);
        let peak0 = sim.peak_memory[plan.stages[0].devices[0]] as f64 / (1024.0 * 1024.0);
        t.row(vec![policy.name().into(), fx(peak0, 1), fx(sim.throughput, 1)]);
    }
    t
}

// ====================================================================
// Fig. 16: fault tolerance across dropout scenarios
// ====================================================================

/// The recovery report a session + fault spec produces under sim
/// pricing.
fn recovery_of(base: &Session, spec: FaultSpec) -> crate::fault::RecoveryReport {
    let mut report = base
        .clone()
        .with_fault(spec)
        .run(&mut SimBackend::default())
        .expect("sim-priced recovery");
    report.recoveries.remove(0).report
}

pub fn fig16() -> Table {
    let mut t = Table::new(
        "Fig 16: recovery time + post-recovery throughput per dropped device (EffNet-B1, Env D)",
        &["dropped", "mech", "detect s", "restore s", "replan s", "migrate s", "total s", "tput after"],
    );
    let cluster = ClusterSpec::env("D", 100.0).unwrap();
    let model = zoo::efficientnet_b1();
    let cfg = eval_cfg(&model.name);
    let base = zoo_session(&model.name, cluster.clone(), cfg, Planner::Asteroid).unwrap();
    for &failed in &base.plan().devices() {
        for kind in [RecoveryKind::Lightweight, RecoveryKind::Heavy] {
            let r = recovery_of(&base, FaultSpec::device(failed).with_recovery(kind));
            t.row(vec![
                cluster.devices[failed].name.clone(),
                r.mechanism.into(),
                fx(r.detection_s, 2),
                fx(r.restore_s, 2),
                fx(r.replan_s, 2),
                fx(r.migration_s, 2),
                fx(r.total_s(), 2),
                fx(r.new_throughput, 1),
            ]);
        }
    }
    t
}

// ====================================================================
// Fig. 17: throughput timeline around a failure
// ====================================================================

pub fn fig17() -> Table {
    let mut t = Table::new(
        "Fig 17: throughput timeline, device B exits at t=100 (EffNet-B1, Env D)",
        &["t", "lightweight s/s", "heavy s/s"],
    );
    let cluster = ClusterSpec::env("D", 100.0).unwrap();
    let model = zoo::efficientnet_b1();
    let cfg = eval_cfg(&model.name);
    let base = zoo_session(&model.name, cluster, cfg, Planner::Asteroid).unwrap();
    let before = priced_throughput(&base);
    // "device B": the second device of the orchestration.
    let failed = base.plan().devices()[1];
    let lite = recovery_of(&base, FaultSpec::device(failed));
    let heavy = recovery_of(&base, FaultSpec::device(failed).heavy());
    let horizon = 100.0 + heavy.total_s() * 1.3 + 20.0;
    let dt = (horizon / 60.0).max(1.0);
    let tl_l = crate::fault::throughput_timeline(before, &lite, 100.0, horizon, dt);
    let tl_h = crate::fault::throughput_timeline(before, &heavy, 100.0, horizon, dt);
    for (a, b) in tl_l.iter().zip(&tl_h) {
        t.row(vec![fx(a.0, 0), fx(a.1, 1), fx(b.1, 1)]);
    }
    t
}

// ====================================================================
// Fig. 18: scalability on 1..8 homogeneous Nanos
// ====================================================================

pub fn fig18() -> Table {
    let mut t = Table::new(
        "Fig 18: scalability, n x Nano @ 100 Mbps, micro-batch 32/device",
        &["model", "n", "Asteroid", "DP", "PP (GPipe)"],
    );
    for model in [zoo::efficientnet_b1(), zoo::mobilenet_v2()] {
        for n in [1usize, 2, 4, 6, 8] {
            let cluster = ClusterSpec::nanos(n, 100.0);
            let micro = 32 * n;
            let cfg = TrainConfig::new(micro * 16, micro);
            let cell = |m: Method| -> String {
                match zoo_session(
                    &model.name,
                    cluster.clone(),
                    cfg.clone(),
                    Planner::Baseline(m),
                ) {
                    Ok(s) => {
                        if plan_ooms(&s) {
                            "OOM".into()
                        } else {
                            fx(priced_throughput(&s), 1)
                        }
                    }
                    Err(_) => "OOM".into(),
                }
            };
            t.row(vec![
                model.name.clone(),
                n.to_string(),
                cell(Method::Asteroid),
                cell(Method::DataParallel),
                if n == 1 { "-".into() } else { cell(Method::GpipePP) },
            ]);
        }
    }
    t
}

// ====================================================================
// Table 7: planning overhead
// ====================================================================

pub fn table7() -> Table {
    let mut t = Table::new(
        "Table 7: Asteroid planning time for Env C (host-measured; paper ran Python on a Jetson NX)",
        &["model", "layers", "host s", "est. on-device s (x300)"],
    );
    for model in eval_models() {
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let cfg = eval_cfg(&model.name);
        let s = zoo_session(&model.name, cluster, cfg, Planner::Asteroid).unwrap();
        t.row(vec![
            model.name.clone(),
            model.num_layers().to_string(),
            fx(s.outcome().planning_time_s, 2),
            fx(
                s.outcome().planning_time_s * crate::fault::replay::EDGE_PLANNER_SLOWDOWN,
                0,
            ),
        ]);
    }
    t
}

// ====================================================================
// Table 8: profiling overhead
// ====================================================================

pub fn table8() -> Table {
    let mut t = Table::new(
        "Table 8: total profiling time of the four models per device (batch sweep x3 repeats)",
        &["device", "total min"],
    );
    for kind in [DeviceKind::JetsonNano, DeviceKind::JetsonTX2, DeviceKind::JetsonNX] {
        let dev = DeviceSpec::of_kind(kind, 0);
        let mut total = 0.0;
        for model in eval_models() {
            let max_batch = if model.name == "resnet50" { 32 } else { 256 };
            total += profiler::profiling_cost(&dev, &model, max_batch, 3);
        }
        t.row(vec![dev.kind.name().into(), fx(total / 60.0, 0)]);
    }
    t
}

/// §5.7 energy: J/sample from device power draw x busy time.
pub fn energy() -> Table {
    let mut t = Table::new(
        "Energy (§5.7): J per training sample, EffNet-B1 on Env D",
        &["method", "tput s/s", "cluster W", "J/sample"],
    );
    // Board power draws under load (published module specs): Nano 10 W,
    // TX2 15 W, NX 15 W.
    let power = |k: DeviceKind| match k {
        DeviceKind::JetsonNano => 10.0,
        DeviceKind::JetsonTX2 => 15.0,
        DeviceKind::JetsonNX => 15.0,
        _ => 50.0,
    };
    let cluster = ClusterSpec::env("D", 100.0).unwrap();
    let model = zoo::efficientnet_b1();
    let cfg = eval_cfg(&model.name);
    let watts: f64 = cluster.devices.iter().map(|d| power(d.kind)).sum();
    for m in [Method::Asteroid, Method::DataParallel] {
        if let Ok(s) =
            zoo_session(&model.name, cluster.clone(), cfg.clone(), Planner::Baseline(m))
        {
            let tput = priced_throughput(&s);
            t.row(vec![
                m.name().into(),
                fx(tput, 1),
                fx(watts, 0),
                fx(watts / tput, 3),
            ]);
        }
    }
    t
}

/// Recovery-speedup headline (the 14x claim) as a one-row table.
pub fn recovery_headline() -> Table {
    let mut t = Table::new(
        "§5.5 headline: lightweight vs heavy recovery (device B, EffNet-B1, Env D)",
        &["mech", "total s", "tput after", "speedup"],
    );
    let cluster = ClusterSpec::env("D", 100.0).unwrap();
    let model = zoo::efficientnet_b1();
    let cfg = eval_cfg(&model.name);
    let base = zoo_session(&model.name, cluster, cfg, Planner::Asteroid).unwrap();
    let failed = base.plan().devices()[1];
    let lite = recovery_of(&base, FaultSpec::device(failed));
    let heavy = recovery_of(&base, FaultSpec::device(failed).heavy());
    t.row(vec![
        "lightweight".into(),
        fx(lite.total_s(), 2),
        fx(lite.new_throughput, 1),
        fx(heavy.total_s() / lite.total_s(), 1) + "x faster",
    ]);
    t.row(vec![
        "heavy".into(),
        fx(heavy.total_s(), 2),
        fx(heavy.new_throughput, 1),
        "1.0x".into(),
    ]);
    t
}

/// All experiments in paper order: (csv name, table).
pub fn all_experiments() -> Vec<(String, Table)> {
    let (f1l, f1r) = fig1();
    vec![
        ("table1".into(), table1()),
        ("fig1_left".into(), f1l),
        ("fig1_right".into(), f1r),
        ("table2".into(), table2()),
        ("fig5".into(), fig5()),
        ("fig6".into(), fig6()),
        ("table4".into(), table4()),
        ("fig13".into(), fig13()),
        ("fig14".into(), fig14()),
        ("fig15a".into(), fig15a()),
        ("fig15b".into(), fig15b()),
        ("fig16".into(), fig16()),
        ("fig17".into(), fig17()),
        ("fig18".into(), fig18()),
        ("table7".into(), table7()),
        ("table8".into(), table8()),
        ("energy".into(), energy()),
        ("recovery_headline".into(), recovery_headline()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ratios_match_paper_shape() {
        let t = table1();
        assert_eq!(t.rows.len(), 3);
        for row in &t.rows {
            // Nano slower than TX2 relative to A100.
            let tx2: f64 = row[4].trim_end_matches('x').parse().unwrap();
            let nano: f64 = row[5].trim_end_matches('x').parse().unwrap();
            assert!(nano > tx2, "{row:?}");
            assert!(nano > 30.0, "Nano must be >>1 order slower: {row:?}");
        }
    }

    #[test]
    fn fig1_sync_dominates_for_heavy_models() {
        let (left, _right) = fig1();
        let resnet = left.rows.iter().find(|r| r[0] == "resnet50").unwrap();
        let share: f64 = resnet[3].trim_end_matches('%').parse().unwrap();
        assert!(share > 50.0, "resnet DP sync share {share}%");
    }

    #[test]
    fn table2_hdp_exceeds_hpp() {
        let t = table2();
        for row in &t.rows {
            let ratio: f64 = row[3].trim_end_matches('x').parse().unwrap();
            assert!(ratio > 1.0, "{row:?}");
        }
    }

    #[test]
    fn fig5_activations_dominate_cnns() {
        let t = fig5();
        for row in t.rows.iter().filter(|r| r[0] != "bert-small") {
            let share: f64 = row[4].trim_end_matches('%').parse().unwrap();
            assert!(share > 50.0, "{row:?}");
        }
    }

    #[test]
    fn fig6_time_sublinear_in_batch() {
        let t = fig6();
        let first: f64 = t.rows[0][3].parse().unwrap(); // ms/sample at B=1
        let last: f64 = t.rows.last().unwrap()[3].parse().unwrap(); // at B=64
        assert!(last < first / 2.0, "per-sample time must fall: {first} -> {last}");
    }

    #[test]
    fn fig18_asteroid_scales() {
        let t = fig18();
        let get = |model: &str, n: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == model && r[1] == n)
                .and_then(|r| r[2].parse().ok())
                .unwrap()
        };
        for model in ["efficientnet-b1", "mobilenetv2"] {
            let t1 = get(model, "1");
            let t8 = get(model, "8");
            assert!(t8 > 2.0 * t1, "{model}: {t1} -> {t8} (want >2x at 8 devices)");
        }
    }

    #[test]
    fn table7_planning_time_tracks_layer_count() {
        let t = table7();
        let effnet: f64 = t.rows[0][2].parse().unwrap();
        let bert: f64 = t.rows[3][2].parse().unwrap();
        assert!(
            effnet > bert,
            "EffNet (most layers) must plan slowest: {effnet} vs {bert}"
        );
    }

    #[test]
    fn table8_nano_profiles_slowest() {
        let t = table8();
        let nano: f64 = t.rows[0][1].parse().unwrap();
        let nx: f64 = t.rows[2][1].parse().unwrap();
        assert!(nano > nx);
    }
}
