//! Layer tables for the paper's evaluation models.
//!
//! The Asteroid profiler records, per layer: output activation size a_l,
//! weight size w_l, and FP/BP time per batch size.  We reconstruct the
//! same tables analytically from the published architectures:
//! EfficientNet-B1 and MobileNetV2 at 3x32x32 (CIFAR-10), ResNet50 at
//! 3x224x224 (Mini-ImageNet) and Bert-small at 32x512 tokens — exactly
//! the workloads of Table 4.  Layer granularity is the module level
//! (conv / depthwise / SE / attention projection / FFN), matching how
//! the paper's planner partitions models.

use super::{Layer, ModelDesc};

const F32: u64 = 4;

/// Running builder that tracks spatial dims while appending conv modules.
///
/// `live_extra` accounts for tensors that bypass the current module
/// (residual skips, the main feature map around an SE branch): a
/// pipeline cut at an intra-block boundary must transfer those live
/// tensors too, so they are added to each module's boundary size.
/// Without this the planner would "cut" inside an SE module at its
/// tiny squeeze vector — impossible in the real dataflow graph.
struct Cnn {
    layers: Vec<Layer>,
    h: usize,
    w: usize,
    c: usize,
    live_extra: u64,
}

impl Cnn {
    fn new(h: usize, w: usize, c: usize) -> Cnn {
        Cnn { layers: Vec::new(), h, w, c, live_extra: 0 }
    }

    /// Begin a residual block: the input map stays live until `end_block`.
    fn begin_skip(&mut self) {
        self.live_extra = (self.h * self.w * self.c) as u64 * F32;
    }

    fn end_block(&mut self) {
        self.live_extra = 0;
        // The final module of the block now carries only its own output.
        if let Some(last) = self.layers.last_mut() {
            last.out_bytes = (self.h * self.w * self.c) as u64 * F32;
        }
    }

    /// Standard KxK convolution (+BN params folded in), `stride` >= 1.
    fn conv(&mut self, name: &str, k: usize, cout: usize, stride: usize) {
        let (h, w) = (self.h / stride, self.w / stride);
        let flops = 2.0 * (h * w * k * k * self.c * cout) as f64;
        let weights = (k * k * self.c * cout + 2 * cout) as u64 * F32;
        let out = (h * w * cout) as u64 * F32 + self.live_extra;
        self.layers.push(Layer::new(name, flops, weights, out));
        self.h = h;
        self.w = w;
        self.c = cout;
    }

    /// Depthwise KxK convolution.
    fn dwconv(&mut self, name: &str, k: usize, stride: usize) {
        let (h, w) = (self.h / stride, self.w / stride);
        let flops = 2.0 * (h * w * k * k * self.c) as f64;
        let weights = (k * k * self.c + 2 * self.c) as u64 * F32;
        let out = (h * w * self.c) as u64 * F32 + self.live_extra;
        self.layers.push(Layer::new(name, flops, weights, out));
        self.h = h;
        self.w = w;
    }

    /// Squeeze-and-excitation pair (global pool -> fc reduce -> fc
    /// expand).  The main feature map bypasses the branch and stays
    /// live across both boundaries.
    fn se(&mut self, name: &str, reduced: usize) {
        let c = self.c;
        let main = (self.h * self.w * c) as u64 * F32;
        let flops_r = 2.0 * (c * reduced) as f64 + (self.h * self.w * c) as f64;
        let flops_e = 2.0 * (reduced * c) as f64 + (self.h * self.w * c) as f64;
        self.layers.push(Layer::new(
            &format!("{name}_se_reduce"),
            flops_r,
            (c * reduced + reduced) as u64 * F32,
            reduced as u64 * F32 + main + self.live_extra,
        ));
        self.layers.push(Layer::new(
            &format!("{name}_se_expand"),
            flops_e,
            (reduced * c + c) as u64 * F32,
            main + self.live_extra,
        ));
    }

    /// Global average pool.
    fn gap(&mut self, name: &str) {
        let flops = (self.h * self.w * self.c) as f64;
        self.layers.push(Layer::new(name, flops, 0, self.c as u64 * F32));
        self.h = 1;
        self.w = 1;
    }

    /// Fully-connected classifier.
    fn fc(&mut self, name: &str, classes: usize) {
        let flops = 2.0 * (self.c * classes) as f64;
        self.layers.push(Layer::new(
            name,
            flops,
            (self.c * classes + classes) as u64 * F32,
            classes as u64 * F32,
        ));
        self.c = classes;
    }

    fn finish(self, name: &str, input_bytes: u64) -> ModelDesc {
        ModelDesc::new(name, self.layers, input_bytes)
    }
}

/// MobileNetV2 at 32x32 (CIFAR-10 adaptation: stride-1 stem, first
/// down-sampling removed, as is standard for CIFAR training).
pub fn mobilenet_v2() -> ModelDesc {
    let mut b = Cnn::new(32, 32, 3);
    b.conv("stem", 3, 32, 1);
    // (expansion t, channels c, repeats n, stride s) per inverted stage;
    // strides adapted for 32x32.
    let cfg: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 1),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut idx = 0;
    for &(t, c, n, s) in cfg {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let name = format!("ir{idx}");
            let cin = b.c;
            let has_skip = stride == 1 && cin == c;
            if has_skip {
                b.begin_skip();
            }
            if t != 1 {
                b.conv(&format!("{name}_expand"), 1, cin * t, 1);
            }
            b.dwconv(&format!("{name}_dw"), 3, stride);
            b.conv(&format!("{name}_project"), 1, c, 1);
            b.end_block();
            idx += 1;
        }
    }
    b.conv("head_conv", 1, 1280, 1);
    b.gap("gap");
    b.fc("classifier", 10);
    b.finish("mobilenetv2", (32 * 32 * 3) as u64 * F32)
}

/// EfficientNet-B1 at 32x32 (CIFAR-10).  B1 = B0 widths with depth
/// multiplier 1.1 (repeats rounded up); SE in every MBConv.
pub fn efficientnet_b1() -> ModelDesc {
    let mut b = Cnn::new(32, 32, 3);
    b.conv("stem", 3, 32, 1);
    // (expansion, channels, repeats(B1), kernel, stride) per MBConv stage.
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (1, 16, 2, 3, 1),
        (6, 24, 3, 3, 1),
        (6, 40, 3, 5, 2),
        (6, 80, 4, 3, 2),
        (6, 112, 4, 5, 1),
        (6, 192, 5, 5, 2),
        (6, 320, 2, 3, 1),
    ];
    let mut idx = 0;
    for &(t, c, n, k, s) in cfg {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let name = format!("mb{idx}");
            let cin = b.c;
            let has_skip = stride == 1 && cin == c;
            if has_skip {
                b.begin_skip();
            }
            if t != 1 {
                b.conv(&format!("{name}_expand"), 1, cin * t, 1);
            }
            b.dwconv(&format!("{name}_dw"), k, stride);
            b.se(&name, (cin / 4).max(1));
            b.conv(&format!("{name}_project"), 1, c, 1);
            b.end_block();
            idx += 1;
        }
    }
    b.conv("head_conv", 1, 1280, 1);
    b.gap("gap");
    b.fc("classifier", 10);
    b.finish("efficientnet-b1", (32 * 32 * 3) as u64 * F32)
}

/// ResNet50 at 224x224 (Mini-ImageNet, 100 classes).
pub fn resnet50() -> ModelDesc {
    let mut b = Cnn::new(224, 224, 3);
    b.conv("stem", 7, 64, 2);
    // maxpool /2: model as a zero-weight layer.
    {
        let flops = (b.h * b.w * b.c) as f64;
        b.h /= 2;
        b.w /= 2;
        let out = (b.h * b.w * b.c) as u64 * F32;
        b.layers.push(Layer::new("maxpool", flops, 0, out));
    }
    let stages: &[(usize, usize, usize)] = &[
        // (bottleneck width, repeats, first stride)
        (64, 3, 1),
        (128, 4, 2),
        (256, 6, 2),
        (512, 3, 2),
    ];
    for (si, &(width, n, s)) in stages.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            let name = format!("res{}_{r}", si + 2);
            b.begin_skip(); // every bottleneck has an (identity or
                            // projected) shortcut live across it
            b.conv(&format!("{name}_1x1a"), 1, width, 1);
            b.conv(&format!("{name}_3x3"), 3, width, stride);
            b.conv(&format!("{name}_1x1b"), 1, width * 4, 1);
            b.end_block();
        }
    }
    b.gap("gap");
    b.fc("classifier", 100);
    b.finish("resnet50", (224 * 224 * 3) as u64 * F32)
}

/// Bert-small encoder (4 layers, hidden 512, 8 heads, FFN 2048) with an
/// MLM-style vocabulary head.
///
/// Sequence length: the paper lists the Bert input size as "32 x 512".
/// We read that as per-sample (seq 32 x hidden 512), matching the
/// vision rows where input size is per-sample dims — and matching the
/// paper's *observed* behaviour: only with ~64 KB/sample boundary
/// activations can Bert run a straight pipeline at 100 Mbps and beat
/// DP 6.4x (Table 4).  With seq = 512 (1 MB/sample activations) the
/// inter-stage wall would dominate any plan at 100 Mbps.
pub fn bert_small() -> ModelDesc {
    let (l_cnt, h, ff, seq, vocab) = (4usize, 512usize, 2048usize, 32usize, 30522usize);
    let mut layers = Vec::new();
    let act = (seq * h) as u64 * F32; // per-sample activation a_l

    // Embedding: word + position tables, then LN.  Lookup FLOPs are
    // negligible; weights dominate.
    layers.push(Layer::new(
        "embeddings",
        2.0 * (seq * h) as f64,
        ((vocab + seq + 2) * h) as u64 * F32,
        act,
    ));
    for i in 0..l_cnt {
        let p = |n: &str| format!("enc{i}_{n}");
        let proj_flops = 2.0 * (seq * h * h) as f64;
        let proj_w = (h * h + h) as u64 * F32;
        // Boundary sizes count every tensor live at the cut: the
        // residual stream x bypasses the whole sub-block, and q/k/v
        // accumulate until attention consumes them.
        layers.push(Layer::new(&p("q"), proj_flops, proj_w, 2 * act));
        layers.push(Layer::new(&p("k"), proj_flops, proj_w, 3 * act));
        layers.push(Layer::new(&p("v"), proj_flops, proj_w, 4 * act));
        // attention scores + context (no weights)
        layers.push(Layer::new(
            &p("attn"),
            2.0 * 2.0 * (seq * seq * h) as f64,
            0,
            2 * act,
        ));
        layers.push(Layer::new(&p("attn_out"), proj_flops, proj_w, 2 * act));
        layers.push(Layer::new(&p("ln1"), 5.0 * (seq * h) as f64, (2 * h) as u64 * F32, act));
        layers.push(Layer::new(
            &p("ffn_in"),
            2.0 * (seq * h * ff) as f64,
            (h * ff + ff) as u64 * F32,
            (seq * ff) as u64 * F32 + act, // hidden + residual stream
        ));
        layers.push(Layer::new(
            &p("ffn_out"),
            2.0 * (seq * ff * h) as f64,
            (ff * h + h) as u64 * F32,
            2 * act,
        ));
        layers.push(Layer::new(&p("ln2"), 5.0 * (seq * h) as f64, (2 * h) as u64 * F32, act));
    }
    // MLM head: dense + vocab projection (tied weights counted once in
    // embeddings; decoder bias only).
    layers.push(Layer::new(
        "mlm_dense",
        2.0 * (seq * h * h) as f64,
        (h * h + h) as u64 * F32,
        act,
    ));
    layers.push(Layer::new(
        "mlm_decoder",
        2.0 * (seq * h * vocab) as f64,
        vocab as u64 * F32,
        (seq * vocab) as u64 * F32,
    ));
    ModelDesc::new("bert-small", layers, seq as u64 * F32)
}

/// Look up a zoo model by name.
pub fn by_name(name: &str) -> Option<ModelDesc> {
    match name.to_ascii_lowercase().as_str() {
        "efficientnet-b1" | "effnet" | "efficientnet" => Some(efficientnet_b1()),
        "mobilenetv2" | "mobilenet" => Some(mobilenet_v2()),
        "resnet50" | "resnet" => Some(resnet50()),
        "bert-small" | "bert" => Some(bert_small()),
        _ => None,
    }
}

/// All four evaluation models in the paper's Table 4 order.
pub fn all() -> Vec<ModelDesc> {
    vec![efficientnet_b1(), mobilenet_v2(), resnet50(), bert_small()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_ordered_like_table7() {
        // Planning time in Table 7 scales with layer count:
        // EffNet-B1 (213 layers) > MobileNetV2 > ResNet50 > Bert-small (56).
        let e = efficientnet_b1().num_layers();
        let m = mobilenet_v2().num_layers();
        let r = resnet50().num_layers();
        let b = bert_small().num_layers();
        assert!(e > m, "effnet {e} vs mobilenet {m}");
        assert!(m > r || m > b, "mobilenet {m} vs resnet {r}");
        assert!(r > b, "resnet {r} vs bert {b}");
        assert!(e >= 100, "effnet module count {e}");
        assert!(b >= 30, "bert module count {b}");
    }

    #[test]
    fn parameter_counts_plausible() {
        // Within 2x of the published parameter counts.
        let check = |m: &ModelDesc, params_m: f64| {
            let p = m.total_weight_bytes() as f64 / 4.0 / 1e6;
            assert!(
                p > params_m * 0.5 && p < params_m * 2.0,
                "{}: {p:.1}M params vs expected ~{params_m}M",
                m.name
            );
        };
        check(&mobilenet_v2(), 2.9); // ~2.2M backbone + cifar head
        check(&efficientnet_b1(), 7.8);
        check(&resnet50(), 25.6);
        check(&bert_small(), 28.8);
    }

    #[test]
    fn resnet_has_most_flops() {
        // 224x224 input makes ResNet50 the heaviest per sample (Table 1:
        // its epoch time dominates).
        let r = resnet50().total_flops();
        let m = mobilenet_v2().total_flops();
        let e = efficientnet_b1().total_flops();
        assert!(r > 5.0 * m, "resnet {r:.2e} vs mobilenet {m:.2e}");
        assert!(r > e);
        // ResNet50 fwd at 224 is ~4.1 GFLOPs; fwd+bwd ~12 GFLOPs.
        assert!(r > 6e9 && r < 4e10, "resnet fwd+bwd {r:.2e}");
    }

    #[test]
    fn cnn_activations_shrink_with_depth() {
        // Feature maps shrink as layers deepen (motivation for DP-early /
        // PP-late planning in CNNs, paper §5.2).
        for m in [mobilenet_v2(), efficientnet_b1(), resnet50()] {
            let first = m.layers[0].out_bytes;
            let last_conv = m.layers[m.num_layers() - 3].out_bytes;
            assert!(
                first > last_conv,
                "{}: first {first} last {last_conv}",
                m.name
            );
        }
    }

    #[test]
    fn bert_params_concentrated_in_embedding_and_head() {
        // Transformer param distribution drives the straight-pipeline
        // planning outcome for Bert (paper §5.2).
        let b = bert_small();
        let total = b.total_weight_bytes() as f64;
        let emb = b.layers[0].weight_bytes as f64;
        assert!(emb / total > 0.3, "embedding share {:.2}", emb / total);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("bert").is_some());
        assert!(by_name("resnet50").is_some());
        assert!(by_name("vgg").is_none());
        assert_eq!(all().len(), 4);
    }
}
