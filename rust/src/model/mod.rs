//! DNN models as layer sequences (the planner's view).
//!
//! The paper treats a DNN as a DAG topologically sorted into a layer
//! sequence; each layer l carries its activation size a_l, weight size
//! w_l, and per-sample FP/BP compute (profiled on real hardware; here
//! derived from the layer's FLOPs and the device execution model).
//!
//! Two sources of models:
//!   * `zoo` — layer tables for the paper's evaluation models
//!     (EfficientNet-B1, MobileNetV2, ResNet50, Bert-small), built
//!     programmatically from the architectures.
//!   * `from_manifest` — the real AOT-compiled models (`lm`, `cnn`)
//!     loaded from artifacts/manifest.json, so the planner can plan the
//!     models the Rust pipeline actually executes.

pub mod from_manifest;
pub mod zoo;

/// One profiled model layer (module granularity).
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    /// FP floating-point ops for a single sample.
    pub flops_fwd: f64,
    /// BP floating-point ops for a single sample (~2x FP for dense nets).
    pub flops_bwd: f64,
    /// Weight + bias bytes w_l (f32).
    pub weight_bytes: u64,
    /// Output activation bytes a_l for a single sample (f32).  This is
    /// both the inter-stage transfer unit and the per-micro-batch
    /// activation memory term of Eq. (3).
    pub out_bytes: u64,
}

impl Layer {
    pub fn new(name: &str, flops_fwd: f64, weight_bytes: u64, out_bytes: u64) -> Layer {
        Layer {
            name: name.to_string(),
            flops_fwd,
            flops_bwd: 2.0 * flops_fwd,
            weight_bytes,
            out_bytes,
        }
    }
}

/// A DNN model: ordered layers plus bookkeeping prefix sums.
#[derive(Debug, Clone)]
pub struct ModelDesc {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Per-sample input bytes fed to layer 0 (e.g. image bytes).
    pub input_bytes: u64,
}

impl ModelDesc {
    pub fn new(name: &str, layers: Vec<Layer>, input_bytes: u64) -> ModelDesc {
        assert!(!layers.is_empty());
        ModelDesc { name: name.to_string(), layers, input_bytes }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter bytes P (paper Eq. 1/2).
    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes).sum()
    }

    /// Total per-sample FP+BP FLOPs.
    pub fn total_flops(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_fwd + l.flops_bwd).sum()
    }

    /// Weight bytes of a contiguous layer range [i, j).
    pub fn weight_bytes_range(&self, i: usize, j: usize) -> u64 {
        self.layers[i..j].iter().map(|l| l.weight_bytes).sum()
    }

    /// FP+BP FLOPs (per sample) of a contiguous layer range [i, j).
    pub fn flops_range(&self, i: usize, j: usize) -> f64 {
        self.layers[i..j]
            .iter()
            .map(|l| l.flops_fwd + l.flops_bwd)
            .sum()
    }

    /// Activation bytes crossing the boundary after layer index `j-1`,
    /// per sample; i.e. the inter-stage tensor when cutting at j.
    pub fn boundary_bytes(&self, j: usize) -> u64 {
        assert!(j >= 1 && j <= self.layers.len());
        self.layers[j - 1].out_bytes
    }

    /// Sum of activation bytes produced inside [i, j) per sample —
    /// the ACT term of Eq. (3) for one micro-batch sample.
    pub fn act_bytes_range(&self, i: usize, j: usize) -> u64 {
        self.layers[i..j].iter().map(|l| l.out_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ModelDesc {
        ModelDesc::new(
            "toy",
            vec![
                Layer::new("a", 100.0, 10, 1000),
                Layer::new("b", 200.0, 20, 500),
                Layer::new("c", 300.0, 30, 250),
            ],
            4096,
        )
    }

    #[test]
    fn totals() {
        let m = toy();
        assert_eq!(m.total_weight_bytes(), 60);
        assert_eq!(m.total_flops(), (100.0 + 200.0 + 300.0) * 3.0);
    }

    #[test]
    fn ranges() {
        let m = toy();
        assert_eq!(m.weight_bytes_range(0, 2), 30);
        assert_eq!(m.weight_bytes_range(1, 3), 50);
        assert_eq!(m.flops_range(1, 2), 600.0);
        assert_eq!(m.boundary_bytes(1), 1000);
        assert_eq!(m.boundary_bytes(3), 250);
        assert_eq!(m.act_bytes_range(0, 3), 1750);
    }

    #[test]
    fn bwd_defaults_to_twice_fwd() {
        let l = Layer::new("x", 50.0, 0, 0);
        assert_eq!(l.flops_bwd, 100.0);
    }
}
