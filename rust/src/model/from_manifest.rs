//! Artifact-manifest parsing: the contract between `python/compile/aot.py`
//! and the Rust runtime/planner.
//!
//! The manifest describes every AOT-lowered HLO artifact (flattened
//! input/output signatures) plus the logical layer sequence of each
//! model with parameter init specs and per-layer FLOPs/bytes — enough
//! for the planner to plan the *real* models and for the runtime to
//! initialise and execute them without Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::{Layer, ModelDesc};
use crate::util::json::Json;

/// Element type of a tensor in an artifact signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" => DType::S32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    /// Bytes per element — the single source of truth for every size
    /// computation (memory accounting, network byte counts, literal
    /// conversion).  Future f16/bf16 support only changes this match.
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::S32 => 4,
        }
    }
}

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSig> {
        Ok(TensorSig {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?,
            dtype: DType::parse(j.get("dtype")?.as_str()?)?,
        })
    }
}

/// One AOT artifact: HLO file + flattened input/output signatures.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Parameter init spec for one tensor of a layer.
#[derive(Debug, Clone)]
pub struct ParamInit {
    pub name: String,
    pub shape: Vec<usize>,
    /// "normal" | "zeros" | "ones"
    pub init: String,
    pub scale: f64,
}

impl ParamInit {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One logical model layer (planner + runtime view).
#[derive(Debug, Clone)]
pub struct ManifestLayer {
    pub name: String,
    pub kind: String,
    pub params: Vec<ParamInit>,
    pub weight_bytes: u64,
    /// Output bytes for a full micro-batch.
    pub out_bytes: u64,
    /// FLOPs for a full micro-batch.
    pub flops_fwd: f64,
    pub flops_bwd: f64,
    pub artifact_fwd: String,
    pub artifact_bwd: String,
}

/// One compiled model in the manifest.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub name: String,
    pub kind: String,
    pub microbatch: usize,
    pub config: BTreeMap<String, f64>,
    pub layers: Vec<ManifestLayer>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

impl ManifestModel {
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .with_context(|| format!("model {}: no artifact {name:?}", self.name))
    }

    /// Fallible config lookup.  The `config` map is whatever
    /// `python/compile/aot.py` emitted for this model — a missing key
    /// means a stale or hand-edited manifest, which should surface as
    /// an error naming the key, never as a panicking `unwrap()`.
    pub fn cfg_f64(&self, key: &str) -> Result<f64> {
        self.config.get(key).copied().with_context(|| {
            format!(
                "model {}: manifest config has no key {key:?} (available: {:?}); \
                 re-run `make artifacts`",
                self.name,
                self.config.keys().collect::<Vec<_>>()
            )
        })
    }

    /// [`Self::cfg_f64`] narrowed to a non-negative integer (sizes,
    /// counts: vocab, seq, hw, classes, ...).
    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        let v = self.cfg_f64(key)?;
        anyhow::ensure!(
            v >= 0.0 && v.fract() == 0.0 && v <= usize::MAX as f64,
            "model {}: config {key:?} = {v} is not a valid size",
            self.name
        );
        Ok(v as usize)
    }

    /// Planner view: per-sample ModelDesc (manifest numbers are per
    /// micro-batch; divide by B).
    pub fn to_model_desc(&self) -> ModelDesc {
        let b = self.microbatch as f64;
        let layers = self
            .layers
            .iter()
            .map(|l| Layer {
                name: l.name.clone(),
                flops_fwd: l.flops_fwd / b,
                flops_bwd: l.flops_bwd / b,
                weight_bytes: l.weight_bytes,
                out_bytes: (l.out_bytes as f64 / b) as u64,
            })
            .collect();
        let input_bytes = match self.kind.as_str() {
            "transformer" => {
                // Token ids, s32.
                let seq = *self.config.get("seq").unwrap_or(&128.0) as u64;
                seq * DType::S32.size_bytes() as u64
            }
            _ => {
                // Image tensor, f32.
                let hw = *self.config.get("hw").unwrap_or(&32.0) as u64;
                let c = *self.config.get("in_ch").unwrap_or(&3.0) as u64;
                hw * hw * c * DType::F32.size_bytes() as u64
            }
        };
        ModelDesc::new(&self.name, layers, input_bytes)
    }

    pub fn total_params(&self) -> usize {
        self.layers
            .iter()
            .flat_map(|l| l.params.iter())
            .map(|p| p.elements())
            .sum()
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub backend: String,
    pub models: BTreeMap<String, ManifestModel>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let backend = j
            .opt("backend")?
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "pallas".into());
        let mut models = BTreeMap::new();
        for (name, mj) in j.get("models")?.as_obj()? {
            models.insert(
                name.clone(),
                parse_model(name, mj, dir)
                    .with_context(|| format!("manifest model {name:?}"))?,
            );
        }
        Ok(Manifest { root: dir.to_path_buf(), backend, models })
    }

    pub fn model(&self, name: &str) -> Result<&ManifestModel> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model {name:?}"))
    }
}

fn parse_model(name: &str, j: &Json, root: &Path) -> Result<ManifestModel> {
    let mut config = BTreeMap::new();
    for (k, v) in j.get("config")?.as_obj()? {
        if let Ok(f) = v.as_f64() {
            config.insert(k.clone(), f);
        }
    }
    let layers = j
        .get("layers")?
        .as_arr()?
        .iter()
        .map(parse_layer)
        .collect::<Result<Vec<_>>>()?;
    let mut artifacts = BTreeMap::new();
    for (aname, aj) in j.get("artifacts")?.as_obj()? {
        let inputs = aj
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(TensorSig::from_json)
            .collect::<Result<Vec<_>>>()?;
        let outputs = aj
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(TensorSig::from_json)
            .collect::<Result<Vec<_>>>()?;
        artifacts.insert(
            aname.clone(),
            ArtifactSig {
                name: aname.clone(),
                file: root.join(aj.get("file")?.as_str()?),
                inputs,
                outputs,
            },
        );
    }
    let model = ManifestModel {
        name: name.to_string(),
        kind: j.get("kind")?.as_str()?.to_string(),
        microbatch: j.get("microbatch")?.as_usize()?,
        config,
        layers,
        artifacts,
    };
    // Integrity: every layer's artifacts must exist.
    for l in &model.layers {
        model.artifact(&l.artifact_fwd)?;
        model.artifact(&l.artifact_bwd)?;
    }
    Ok(model)
}

fn parse_layer(j: &Json) -> Result<ManifestLayer> {
    let params = j
        .get("params")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamInit {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_usize())
                    .collect::<Result<Vec<_>>>()?,
                init: p.get("init")?.as_str()?.to_string(),
                scale: p.get("scale")?.as_f64()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ManifestLayer {
        name: j.get("name")?.as_str()?.to_string(),
        kind: j.get("kind")?.as_str()?.to_string(),
        params,
        weight_bytes: j.get("weight_bytes")?.as_u64()?,
        out_bytes: j.get("out_bytes")?.as_u64()?,
        flops_fwd: j.get("flops_fwd")?.as_f64()?,
        flops_bwd: j.get("flops_bwd")?.as_f64()?,
        artifact_fwd: j.get("artifact_fwd")?.as_str()?.to_string(),
        artifact_bwd: j.get("artifact_bwd")?.as_str()?.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; artifacts are built by
        // `make artifacts` before `cargo test` (see Makefile).
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_built_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect("run `make artifacts` first");
        let lm = m.model("lm").unwrap();
        assert_eq!(lm.kind, "transformer");
        assert!(lm.layers.len() >= 3);
        assert_eq!(lm.layers[0].kind, "embed");
        assert_eq!(lm.layers.last().unwrap().kind, "head");
        assert!(lm.total_params() > 100_000);

        let cnn = m.model("cnn").unwrap();
        assert_eq!(cnn.kind, "cnn");
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn artifact_signatures_consistent() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let lm = m.model("lm").unwrap();
        let bf = lm.artifact("block_fwd").unwrap();
        // block_fwd: 12 params + x; one output.
        assert_eq!(bf.inputs.len(), 13);
        assert_eq!(bf.outputs.len(), 1);
        // block_bwd mirrors: 12 params + x + grad; 12 grads + gx out.
        let bb = lm.artifact("block_bwd").unwrap();
        assert_eq!(bb.inputs.len(), 14);
        assert_eq!(bb.outputs.len(), 13);
        // files exist on disk
        assert!(bf.file.exists(), "{:?}", bf.file);
    }

    #[test]
    fn model_desc_is_per_sample() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let lm = m.model("lm").unwrap();
        let desc = lm.to_model_desc();
        let b = lm.microbatch as f64;
        assert_eq!(desc.num_layers(), lm.layers.len());
        let manifest_flops: f64 = lm.layers.iter().map(|l| l.flops_fwd + l.flops_bwd).sum();
        assert!((desc.total_flops() - manifest_flops / b).abs() / manifest_flops < 0.01);
    }

    #[test]
    fn config_accessors_fail_cleanly() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let lm = m.model("lm").unwrap();
        assert!(lm.cfg_usize("vocab").unwrap() > 1);
        assert!(lm.cfg_f64("seq").unwrap() > 0.0);
        let err = lm.cfg_f64("no-such-key").unwrap_err().to_string();
        assert!(err.contains("no-such-key"), "{err}");
        assert!(err.contains("lm"), "{err}");
    }

    #[test]
    fn tensor_sig_sizes() {
        let t = TensorSig {
            name: "x".into(),
            shape: vec![8, 64, 128],
            dtype: DType::F32,
        };
        assert_eq!(t.elements(), 8 * 64 * 128);
        assert_eq!(t.byte_len(), 8 * 64 * 128 * 4);
    }
}
