//! Experiment metrics: tabular results with console + CSV output.
//!
//! Every repro subcommand emits a `Table`, printed in the paper's
//! row/column layout and optionally written under `results/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// A simple results table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    /// Console rendering with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        let _ = writeln!(out, "{}", header.join("  "));
        let _ = writeln!(out, "{}", "-".repeat(header.join("  ").len()));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            let _ = writeln!(out, "{}", line.join("  "));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// CSV rendering (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.columns.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn write_csv(&self, dir: &Path, name: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Format helpers shared by the repro harness.
pub fn fx(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

pub fn speedup(ours: f64, other: f64) -> String {
    if other <= 0.0 {
        "inf".into()
    } else {
        format!("{:.1}x", ours / other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "tput"]);
        t.row(vec!["mobilenet".into(), "12.5".into()]);
        t.row(vec!["bert".into(), "3.1".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("mobilenet"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("q", &["a", "b"]);
        t.row(vec!["x,y".into(), "he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn csv_writes_to_disk() {
        let dir = std::env::temp_dir().join("asteroid_metrics_test");
        let mut t = Table::new("w", &["a"]);
        t.row(vec!["1".into()]);
        t.write_csv(&dir, "test_table").unwrap();
        let content = std::fs::read_to_string(dir.join("test_table.csv")).unwrap();
        assert!(content.starts_with("a\n"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn speedup_formats() {
        assert_eq!(speedup(10.0, 5.0), "2.0x");
        assert_eq!(speedup(1.0, 0.0), "inf");
        assert_eq!(fx(1.23456, 2), "1.23");
    }
}
