//! Convergence-time model (Fig. 14): time to reach a target accuracy.
//!
//! All synchronous methods (Asteroid, DP/EDDL, PipeDream*, Dapple) do
//! identical SGD math — same mini-batch, same updates — so they need
//! the same number of epochs; their time-to-accuracy differs only
//! through per-epoch wall-clock (throughput).  HetPipe's asynchronous
//! PS updates suffer parameter staleness, which the paper observes as
//! extra epochs to reach the target (§5.3, citing [55, 56]).
//!
//! (*PipeDream is asynchronous in its original form, but the paper
//! compares the planners under synchronous training.)

/// Epoch multiplier for asynchronous staleness.  The paper's Fig. 14
/// shows HetPipe needing noticeably more epochs; 1.5 is the midpoint of
/// the 1.3-1.7x range reported in asynchronous-SGD literature.
pub const HETPIPE_STALENESS_FACTOR: f64 = 1.5;

/// Time to reach the accuracy target.
///
/// * `epochs_to_target` — epochs a synchronous run needs (from the
///   reference training curve);
/// * `dataset_size` — samples per epoch;
/// * `throughput` — samples/second of the evaluated system;
/// * `staleness` — epoch multiplier (1.0 for synchronous methods).
pub fn time_to_accuracy(
    epochs_to_target: f64,
    dataset_size: usize,
    throughput: f64,
    staleness: f64,
) -> f64 {
    assert!(throughput > 0.0);
    epochs_to_target * staleness * dataset_size as f64 / throughput
}

/// Convergence summary for one method.
#[derive(Debug, Clone)]
pub struct ConvergencePoint {
    pub method: String,
    pub throughput: f64,
    pub epochs: f64,
    pub hours_to_target: f64,
}

pub fn convergence_point(
    method: &str,
    throughput: f64,
    epochs_to_target: f64,
    dataset_size: usize,
    asynchronous: bool,
) -> ConvergencePoint {
    let staleness = if asynchronous { HETPIPE_STALENESS_FACTOR } else { 1.0 };
    ConvergencePoint {
        method: method.to_string(),
        throughput,
        epochs: epochs_to_target * staleness,
        hours_to_target: time_to_accuracy(epochs_to_target, dataset_size, throughput, staleness)
            / 3600.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faster_system_converges_sooner() {
        let slow = time_to_accuracy(30.0, 50_000, 50.0, 1.0);
        let fast = time_to_accuracy(30.0, 50_000, 100.0, 1.0);
        assert!((slow / fast - 2.0).abs() < 1e-12);
    }

    #[test]
    fn staleness_penalises_async() {
        let sync = convergence_point("asteroid", 100.0, 30.0, 50_000, false);
        let asyn = convergence_point("hetpipe", 100.0, 30.0, 50_000, true);
        assert!(asyn.hours_to_target > sync.hours_to_target);
        assert!((asyn.epochs / sync.epochs - HETPIPE_STALENESS_FACTOR).abs() < 1e-12);
    }

    #[test]
    fn units_sane() {
        // 50k samples/epoch at 100 samples/s = 500 s/epoch; 36 epochs = 5 h.
        let t = time_to_accuracy(36.0, 50_000, 100.0, 1.0);
        assert!((t - 18_000.0).abs() < 1e-9);
    }
}
