//! Discrete-event simulator for HPP training rounds.
//!
//! The planner's cost model (Eqs. 4-6) is an *approximation* built on
//! the dominant-step idea; this simulator executes the full
//! event-accurate schedule — per-device 1F1B with K_p warm-up, sample-
//! sharded inter-stage messages over serialised links, intra-stage
//! AllReduce — and reports observed round latency, per-device busy
//! time, bubble fractions and in-flight activation peaks.  Every paper
//! table/figure that reports throughput is measured here, with the
//! analytic prediction used as a cross-check.
//!
//! Intra-stage data parallelism follows the paper's Fig. 10: each
//! micro-batch is sample-sharded across the group, and each device of
//! stage p sends each device of stage p+1 exactly the activation rows
//! of the samples they share.

pub mod engine;
pub mod convergence;

use crate::config::ClusterSpec;
use crate::model::ModelDesc;
use crate::planner::plan::Plan;
use crate::profiler::ProfileTable;

use engine::{EventQueue, LinkSet};

/// Result of simulating one HPP-Round.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock of the round (first FP start to last AllReduce end).
    pub round_latency: f64,
    /// Samples per second.
    pub throughput: f64,
    /// Per device: total busy compute time within the round.
    pub busy: Vec<f64>,
    /// Per device: 1 - busy/span over the device's active span.
    pub bubble_fraction: Vec<f64>,
    /// Per device: peak in-flight micro-batches (drives Eq. 3 memory).
    pub peak_inflight: Vec<usize>,
    /// Per device: peak memory bytes (Eq. 3 with observed in-flight).
    pub peak_memory: Vec<u64>,
    /// Total bytes moved across links during the round.
    pub bytes_on_network: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TaskKind {
    Fwd,
    Bwd,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Compute finished on device (global id) for (stage, micro, kind).
    Done { dev: usize, stage: usize, micro: usize, kind: TaskKind },
    /// A message (activation or gradient chunk) arrived.
    Msg { to: usize, micro: usize, kind: TaskKind },
}

/// Per-device scheduler state.
struct DevState {
    stage: usize,
    /// index within the stage group
    slot: usize,
    /// samples this device processes per micro-batch
    share: usize,
    busy_until: f64,
    /// received input chunk counts per micro-batch (FP deps).
    fp_deps: Vec<usize>,
    /// received grad chunk counts per micro-batch (BP deps).
    bp_deps: Vec<usize>,
    fp_needed: usize,
    bp_needed: usize,
    fp_issued: usize,
    fp_done: usize,
    bp_issued: usize,
    bp_done: usize,
    busy_total: f64,
    first_start: f64,
    last_end: f64,
    peak_inflight: usize,
}

impl DevState {
    fn inflight(&self) -> usize {
        self.fp_issued - self.bp_done
    }
}

/// Simulate one HPP-Round of `plan` and return observed metrics.
pub fn simulate_round(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
) -> SimResult {
    let m_total = plan.num_micro;
    let n_stages = plan.stages.len();

    // --- static routing tables -----------------------------------------
    // For each adjacent stage pair: bytes[d][d'] of activation rows the
    // devices share (contiguous sample ranges per Fig. 10).
    let mut fwd_bytes: Vec<Vec<Vec<u64>>> = Vec::new(); // [cut][from][to]
    for w in plan.stages.windows(2) {
        let a = model.boundary_bytes(w[0].layers.1); // per sample
        let from_ranges = ranges(&w[0].alloc);
        let to_ranges = ranges(&w[1].alloc);
        let mut mat = vec![vec![0u64; w[1].devices.len()]; w[0].devices.len()];
        for (i, fr) in from_ranges.iter().enumerate() {
            for (j, tr) in to_ranges.iter().enumerate() {
                let overlap = overlap(*fr, *tr);
                mat[i][j] = a * overlap as u64;
            }
        }
        fwd_bytes.push(mat);
    }

    // Device states, indexed by global device id.
    let mut dev_of_stage: Vec<Vec<usize>> = Vec::new();
    let mut states: std::collections::BTreeMap<usize, DevState> = Default::default();
    for (p, stage) in plan.stages.iter().enumerate() {
        dev_of_stage.push(stage.devices.clone());
        for (slot, (&d, &y)) in stage.devices.iter().zip(&stage.alloc).enumerate() {
            // FP needs one chunk from every previous-stage device sharing
            // samples; stage 0 FP deps are free (local data).
            let fp_needed = if p == 0 {
                0
            } else {
                fwd_bytes[p - 1]
                    .iter()
                    .filter(|row| row[slot] > 0)
                    .count()
            };
            let bp_needed = if p + 1 == n_stages {
                0 // BP enabled by own FP completion
            } else {
                fwd_bytes[p][slot].iter().filter(|&&b| b > 0).count()
            };
            states.insert(
                d,
                DevState {
                    stage: p,
                    slot,
                    share: y,
                    busy_until: 0.0,
                    fp_deps: vec![0; m_total],
                    bp_deps: vec![0; m_total],
                    fp_needed,
                    bp_needed,
                    fp_issued: 0,
                    fp_done: 0,
                    bp_issued: 0,
                    bp_done: 0,
                    busy_total: 0.0,
                    first_start: f64::INFINITY,
                    last_end: 0.0,
                    peak_inflight: 0,
                },
            );
        }
    }

    let mut q = EventQueue::new();
    let mut links = LinkSet::new(cluster);
    let mut bytes_on_network: u64 = 0;

    // Kick off: all stage-0 devices may begin FP immediately.
    let mut now = 0.0f64;

    // Dispatch loop helper: choose and start a task per 1F1B.
    // Returns scheduled (end_time, task) if dispatched.
    fn try_dispatch(
        d: usize,
        st: &mut DevState,
        plan: &Plan,
        table: &ProfileTable,
        now: f64,
        q: &mut EventQueue<Ev>,
    ) {
        if st.busy_until > now || st.share == 0 {
            return;
        }
        let stage = &plan.stages[st.stage];
        let (i, j) = stage.layers;
        let m_total = plan.num_micro;
        let last = st.stage + 1 == plan.stages.len();

        // K_p >= M degenerates to GPipe's backward-after-forward: no BP
        // until every FP of the round has been issued (this is what makes
        // GPipe's activation residency O(M), Fig. 15(b)).
        let gpipe_mode = stage.kp >= m_total;
        // BP first (1F1B): next BP micro is bp_issued.
        let bp_ready = st.bp_issued < st.fp_done // BP m requires own FP m done
            && (!gpipe_mode || st.fp_issued == m_total)
            && (if last {
                true
            } else {
                st.bp_deps[st.bp_issued] >= st.bp_needed
            });
        if bp_ready {
            let t = table.time_bwd(d, i, j, st.share);
            let end = now + t;
            st.busy_until = end;
            st.busy_total += t;
            st.first_start = st.first_start.min(now);
            st.bp_issued += 1;
            q.push(end, Ev::Done { dev: d, stage: st.stage, micro: st.bp_issued - 1, kind: TaskKind::Bwd });
            return;
        }
        // FP next, subject to the K_p window.
        let fp_ready = st.fp_issued < m_total
            && st.inflight() < stage.kp
            && (st.fp_needed == 0 || st.fp_deps[st.fp_issued] >= st.fp_needed);
        if fp_ready {
            let t = table.time_fwd(d, i, j, st.share);
            let end = now + t;
            st.busy_until = end;
            st.busy_total += t;
            st.first_start = st.first_start.min(now);
            st.fp_issued += 1;
            st.peak_inflight = st.peak_inflight.max(st.inflight());
            q.push(end, Ev::Done { dev: d, stage: st.stage, micro: st.fp_issued - 1, kind: TaskKind::Fwd });
        }
    }

    // Prime stage-0 (and any zero-share idle devices are skipped).
    let dev_ids: Vec<usize> = states.keys().copied().collect();
    for &d in &dev_ids {
        let st = states.get_mut(&d).unwrap();
        try_dispatch(d, st, plan, table, now, &mut q);
    }

    // --- main event loop -------------------------------------------------
    while let Some((t, ev)) = q.pop() {
        now = t;
        match ev {
            Ev::Done { dev, stage, micro, kind } => {
                {
                    let st = states.get_mut(&dev).unwrap();
                    st.last_end = now;
                    match kind {
                        TaskKind::Fwd => st.fp_done += 1,
                        TaskKind::Bwd => st.bp_done += 1,
                    }
                }
                let slot = states[&dev].slot;
                match kind {
                    TaskKind::Fwd if stage + 1 < n_stages => {
                        // Send activation chunks to next stage.
                        for (to_slot, &to_dev) in dev_of_stage[stage + 1].iter().enumerate() {
                            let bytes = fwd_bytes[stage][slot][to_slot];
                            if bytes == 0 {
                                continue;
                            }
                            bytes_on_network += bytes;
                            let arrive = links.send(dev, to_dev, bytes, now);
                            q.push(
                                arrive,
                                Ev::Msg { to: to_dev, micro, kind: TaskKind::Fwd },
                            );
                        }
                    }
                    TaskKind::Bwd if stage > 0 => {
                        // Send gradient chunks to previous stage.
                        for (to_slot, &to_dev) in dev_of_stage[stage - 1].iter().enumerate() {
                            let bytes = fwd_bytes[stage - 1][to_slot][slot];
                            if bytes == 0 {
                                continue;
                            }
                            bytes_on_network += bytes;
                            let arrive = links.send(dev, to_dev, bytes, now);
                            q.push(
                                arrive,
                                Ev::Msg { to: to_dev, micro, kind: TaskKind::Bwd },
                            );
                        }
                    }
                    _ => {}
                }
                let st = states.get_mut(&dev).unwrap();
                try_dispatch(dev, st, plan, table, now, &mut q);
            }
            Ev::Msg { to, micro, kind } => {
                let st = states.get_mut(&to).unwrap();
                match kind {
                    TaskKind::Fwd => st.fp_deps[micro] += 1,
                    TaskKind::Bwd => st.bp_deps[micro] += 1,
                }
                try_dispatch(to, st, plan, table, now, &mut q);
            }
        }
    }

    // --- AllReduce + result assembly --------------------------------------
    let mut round_end = now;
    for stage in &plan.stages {
        if stage.devices.len() > 1 {
            let last_bp = stage
                .devices
                .iter()
                .map(|d| states[d].last_end)
                .fold(0.0, f64::max);
            let ta = crate::planner::cost::allreduce_time(cluster, model, stage);
            let w = model.weight_bytes_range(stage.layers.0, stage.layers.1);
            bytes_on_network += 2 * (stage.devices.len() as u64 - 1) * w;
            round_end = round_end.max(last_bp + ta);
        }
    }

    let n_dev = cluster.n();
    let mut busy = vec![0.0; n_dev];
    let mut bubble = vec![0.0; n_dev];
    let mut peak_inflight = vec![0usize; n_dev];
    let mut peak_memory = vec![0u64; n_dev];
    for (&d, st) in &states {
        busy[d] = st.busy_total;
        let span = (st.last_end - st.first_start).max(1e-12);
        bubble[d] = (1.0 - st.busy_total / span).max(0.0);
        peak_inflight[d] = st.peak_inflight;
        let stage = &plan.stages[st.stage];
        let mem = crate::planner::memory::stage_memory(
            model,
            &crate::config::TrainConfig::new(
                plan.microbatch * plan.num_micro,
                plan.microbatch,
            ),
            stage.layers.0,
            stage.layers.1,
            st.share,
            st.peak_inflight.max(1),
        );
        peak_memory[d] = mem.total();
    }

    // Sanity: every micro-batch fully processed.
    for st in states.values() {
        debug_assert_eq!(st.fp_done, m_total, "stage {} fp incomplete", st.stage);
        debug_assert_eq!(st.bp_done, m_total, "stage {} bp incomplete", st.stage);
    }

    SimResult {
        round_latency: round_end,
        throughput: plan.samples_per_round() as f64 / round_end,
        busy,
        bubble_fraction: bubble,
        peak_inflight,
        peak_memory,
        bytes_on_network,
    }
}

/// Contiguous sample ranges implied by an allocation, e.g. [3,5] ->
/// [(0,3), (3,8)].
fn ranges(alloc: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(alloc.len());
    let mut start = 0;
    for &y in alloc {
        out.push((start, start + y));
        start += y;
    }
    out
}

fn overlap(a: (usize, usize), b: (usize, usize)) -> usize {
    a.1.min(b.1).saturating_sub(a.0.max(b.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, TrainConfig};
    use crate::model::zoo;
    use crate::planner::cost::{plan_steps, round_latency};
    use crate::planner::dp::{plan_hpp, PlannerConfig};
    use crate::planner::plan::{Plan, Stage};
    use crate::profiler::ProfileTable;

    fn fixture(env: &str) -> (ClusterSpec, crate::model::ModelDesc, ProfileTable) {
        let cluster = ClusterSpec::env(env, 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        (cluster, model, table)
    }

    #[test]
    fn ranges_and_overlap() {
        assert_eq!(ranges(&[3, 5]), vec![(0, 3), (3, 8)]);
        assert_eq!(overlap((0, 3), (2, 8)), 1);
        assert_eq!(overlap((0, 3), (3, 8)), 0);
        assert_eq!(overlap((0, 8), (2, 5)), 3);
    }

    #[test]
    fn simulates_planned_mobilenet() {
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let sim = simulate_round(&table, &cluster, &model, &out.plan);
        assert!(sim.round_latency > 0.0);
        assert!(sim.throughput > 0.0);
        // Every participating device did work.
        for &d in &out.plan.devices() {
            assert!(sim.busy[d] > 0.0, "device {d} idle");
        }
    }

    #[test]
    fn sim_close_to_analytic_prediction() {
        // The dominant-step model approximates the event-accurate
        // schedule; they must agree within a modest factor.
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let steps = plan_steps(&table, &cluster, &model, &out.plan);
        let predicted = round_latency(&steps, out.plan.num_micro);
        let sim = simulate_round(&table, &cluster, &model, &out.plan);
        let ratio = sim.round_latency / predicted;
        assert!(
            (0.6..1.7).contains(&ratio),
            "sim {} vs predicted {predicted} (ratio {ratio})",
            sim.round_latency
        );
    }

    #[test]
    fn single_stage_dp_has_no_network_activations() {
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 1, 2, 3, 4],
                alloc: vec![4, 3, 3, 3, 3],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 4,
        };
        let sim = simulate_round(&table, &cluster, &model, &plan);
        // Only AllReduce bytes, no inter-stage messages.
        assert_eq!(
            sim.bytes_on_network,
            2 * 4 * model.total_weight_bytes()
        );
    }

    #[test]
    fn kp_bounds_inflight_microbatches() {
        // 1F1B with K_p must never hold more than K_p activations.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kp0: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kp0 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let sim_ours = simulate_round(&table, &cluster, &model, &mk(3));
        assert!(sim_ours.peak_inflight[0] <= 3);
        let sim_gpipe = simulate_round(&table, &cluster, &model, &mk(8));
        assert!(sim_gpipe.peak_inflight[0] > 3, "gpipe should buffer more");
        assert!(sim_gpipe.peak_memory[0] > sim_ours.peak_memory[0]);
    }

    #[test]
    fn gpipe_memory_grows_with_m_but_ours_does_not() {
        // Fig. 15(b): O(M) vs O(K_p) activation residency.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |m: usize, kp: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: m,
        };
        let ours_m8 = simulate_round(&table, &cluster, &model, &mk(8, 3));
        let ours_m32 = simulate_round(&table, &cluster, &model, &mk(32, 3));
        assert_eq!(ours_m8.peak_inflight[0], ours_m32.peak_inflight[0]);
        let gpipe_m8 = simulate_round(&table, &cluster, &model, &mk(8, 8));
        let gpipe_m32 = simulate_round(&table, &cluster, &model, &mk(32, 32));
        assert!(gpipe_m32.peak_inflight[0] > gpipe_m8.peak_inflight[0]);
    }

    #[test]
    fn kp_one_serialises_stages() {
        // K_p = 1 for all stages means only one stage active at a time:
        // throughput strictly below the K_p policy pipeline.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kps: [usize; 2]| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kps[0] },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: kps[1] },
            ],
            microbatch: 8,
            num_micro: 16,
        };
        let serial = simulate_round(&table, &cluster, &model, &mk([1, 1]));
        let ours = simulate_round(&table, &cluster, &model, &mk([3, 1]));
        assert!(
            ours.throughput > serial.throughput,
            "ours {} vs serial {}",
            ours.throughput,
            serial.throughput
        );
    }

    #[test]
    fn more_microbatches_amortise_bubbles() {
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |m: usize| {
            let mut p = Plan {
                stages: vec![
                    Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: 1 },
                    Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
                ],
                microbatch: 8,
                num_micro: m,
            };
            p.apply_default_kp();
            p
        };
        let s4 = simulate_round(&table, &cluster, &model, &mk(4));
        let s32 = simulate_round(&table, &cluster, &model, &mk(32));
        assert!(s32.throughput > s4.throughput);
    }

    #[test]
    fn heterogeneous_alloc_beats_equal_split_in_sim() {
        // End-to-end: Alg. 1's allocation must beat a naive equal split
        // when the group mixes NX and Nano.
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let equal = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 3], // NX + Nano
                alloc: vec![8, 8],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 8,
        };
        let mut skewed = equal.clone();
        skewed.stages[0].alloc = vec![13, 3];
        let sim_eq = simulate_round(&table, &cluster, &model, &equal);
        let sim_sk = simulate_round(&table, &cluster, &model, &skewed);
        assert!(
            sim_sk.throughput > sim_eq.throughput,
            "skewed {} vs equal {}",
            sim_sk.throughput,
            sim_eq.throughput
        );
    }
}
