//! Discrete-event pricing of HPP-Round schedules.
//!
//! The planner's cost model (Eqs. 4-6) is an *approximation* built on
//! the dominant-step idea; this module prices the *explicit* schedule:
//! [`price`] walks each device's `schedule::Schedule` timeline
//! task by task against the `ProfileTable` (compute durations) and the
//! `LinkSet` (serialised inter-device transfers), and reports observed
//! round latency, per-device busy time, bubble fractions and in-flight
//! activation peaks.  Every paper table/figure that reports throughput
//! is measured here, with the analytic prediction as a cross-check.
//!
//! The simulator owns **no scheduling logic**: which task runs next on
//! a device — 1F1B order, the K_p warm-up window, GPipe fill-drain —
//! is entirely encoded in the `Schedule` IR by its `SchedulePolicy`.
//! [`simulate_round`] is a thin wrapper that builds the default
//! (1F1B-K_p, sample-sharded) schedule for a plan and prices it.
//! [`price`] is the single full entry point, fed by a [`PriceRequest`]
//! naming the plan plus every pricing knob — schedule policy (or an
//! explicit schedule), wire codec, collective sync topology.
//! Synchronous policies price as one barriered round,
//! bounded-staleness policies as a barrier-free
//! [`ASYNC_STEADY_ROUNDS`]-round chain normalised to per-round figures
//! (their fill/drain amortises away — the async payoff).

pub mod convergence;
pub mod engine;

use std::collections::{BTreeMap, HashSet};

use crate::codec::CodecSpec;
use crate::comm::SyncMode;
use crate::config::ClusterSpec;
use crate::model::ModelDesc;
use crate::planner::plan::Plan;
use crate::profiler::ProfileTable;
use crate::schedule::{
    Payload, Schedule, SchedulePolicy, Sharding, Task, BWD_INPUT_FRAC, DEFAULT_POLICY,
};

use engine::{EventQueue, LinkSet};

/// How many HPP-Rounds [`price`] chains back-to-back when
/// pricing a bounded-staleness policy: without an inter-round barrier
/// the fill/drain of consecutive rounds overlap, and the per-round
/// steady-state latency is the chained makespan divided by the round
/// count.  Large enough to amortise the one fill + one drain that
/// remain at the window edges.
pub const ASYNC_STEADY_ROUNDS: usize = 6;

/// Result of pricing one HPP-Round.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock of the round (first FP start to last AllReduce end).
    /// For a steady-state (multi-round async) pricing this is the
    /// per-round figure: chained makespan / rounds.
    pub round_latency: f64,
    /// Samples per second.
    pub throughput: f64,
    /// Per device: total busy compute time within the round.
    pub busy: Vec<f64>,
    /// Per device: 1 - busy/span over the device's active span.
    pub bubble_fraction: Vec<f64>,
    /// Pipeline bubble ratio of the whole round: 1 - total busy time /
    /// (participating devices x round latency).  The cross-policy
    /// comparison metric — per-device compute is conserved across
    /// policies, so a strictly lower ratio means a strictly shorter
    /// round.
    pub round_bubble_ratio: f64,
    /// Per device: peak in-flight micro-batches (drives Eq. 3 memory).
    pub peak_inflight: Vec<usize>,
    /// Per device: peak memory bytes (Eq. 3 with observed in-flight,
    /// plus the weight-stash copies of a bounded-staleness schedule).
    pub peak_memory: Vec<u64>,
    /// Total bytes moved across links during the round.
    pub bytes_on_network: u64,
    /// Pipeline fill latency: the instant every device has completed
    /// its first compute task.  This is the warm-up cost the fault
    /// machinery charges a freshly replayed pipeline.
    pub fill_latency: f64,
    /// HPP-Rounds the priced timeline encoded (1 for synchronous
    /// policies; [`ASYNC_STEADY_ROUNDS`] for bounded-staleness
    /// steady-state pricing).
    pub rounds_priced: usize,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// The compute task at the device's cursor finished.
    Done { dev: usize },
    /// A transfer arrived at its destination.
    Msg { to: usize, from: usize, micro: usize, payload: Payload },
}

/// Per-device execution cursor over its timeline.
struct ExecDev<'a> {
    tl: &'a crate::schedule::DeviceTimeline,
    /// Index of the next task to start (the task a `Done` refers to
    /// while `running`).
    pos: usize,
    /// Whether this timeline splits backwards (contains BwdW tasks):
    /// its Bwd tasks then price the input-gradient fraction only, and
    /// BwdW tasks carry the weight-gradient remainder, conserving
    /// total backward compute.
    split_bwd: bool,
    running: bool,
    busy_total: f64,
    first_start: f64,
    first_end: f64,
    last_end: f64,
    inflight: usize,
    peak_inflight: usize,
    fwd_done: usize,
    bwd_done: usize,
}

/// Simulate one HPP-Round of `plan` under the default schedule policy.
pub fn simulate_round(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
) -> SimResult {
    price(&PriceRequest::new(table, cluster, model, plan))
}

/// One fully-specified pricing question for [`price`]: the plan plus
/// every knob that changes its price.  Replaces the old
/// `price_policy`/`price_schedule`/`*_codec` wrapper family — the
/// defaults mirror theirs (default 1F1B-K_p policy, identity fp32
/// codec, ring sync, policy-derived schedule), so
/// `price(&PriceRequest::new(..))` is the old `simulate_round`, and
/// each knob is a builder call instead of another function signature.
#[derive(Clone, Copy)]
pub struct PriceRequest<'a> {
    pub table: &'a ProfileTable,
    pub cluster: &'a ClusterSpec,
    pub model: &'a ModelDesc,
    pub plan: &'a Plan,
    /// Price this explicit sample-sharded schedule instead of deriving
    /// one from `policy`.  The schedule already encodes its policy's
    /// ordering and round count, so `policy` staleness handling is
    /// bypassed (no steady-state normalisation is applied).
    pub schedule: Option<&'a Schedule>,
    pub policy: &'a dyn SchedulePolicy,
    /// Wire codec: every boundary transfer and AllReduce is priced at
    /// its *wire* bytes (`bytes_on_network` included), so the simulator
    /// agrees byte-for-byte with the framed-TCP data plane.
    pub codec: CodecSpec,
    /// Collective topology the Eq. 5 sync term assumes: worker-to-worker
    /// `Ring` (default, `2(g-1)/g * W` over the slowest intra-group
    /// link) or `DriverStar` mediation (`2W` per worker).
    pub sync: SyncMode,
}

impl<'a> PriceRequest<'a> {
    /// A request with every knob at its default — prices exactly like
    /// the pre-refactor `simulate_round`.
    pub fn new(
        table: &'a ProfileTable,
        cluster: &'a ClusterSpec,
        model: &'a ModelDesc,
        plan: &'a Plan,
    ) -> Self {
        Self {
            table,
            cluster,
            model,
            plan,
            schedule: None,
            policy: DEFAULT_POLICY,
            codec: CodecSpec::default(),
            sync: SyncMode::default(),
        }
    }

    pub fn policy(mut self, policy: &'a dyn SchedulePolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn schedule(mut self, sched: &'a Schedule) -> Self {
        self.schedule = Some(sched);
        self
    }

    pub fn codec(mut self, codec: CodecSpec) -> Self {
        self.codec = codec;
        self
    }

    pub fn sync(mut self, sync: SyncMode) -> Self {
        self.sync = sync;
        self
    }
}

/// Price a [`PriceRequest`], choosing the pricing form its semantics
/// call for.  An explicit schedule is priced as-is.  Otherwise a
/// synchronous policy is priced as one barriered HPP-Round
/// ([`Schedule::for_sim`]); a bounded-staleness policy
/// ([`SchedulePolicy::max_staleness`] > 0) is priced in **steady
/// state** — [`ASYNC_STEADY_ROUNDS`] rounds chained without a barrier,
/// per-round figures normalised by the round count — because its whole
/// point is that round r+1's warm-up fills round r's drain.  This is
/// the single entry the planner's `sim_select`, the session's
/// `SimBackend`, the fault re-pricing and the benches all use, so every
/// reported throughput compares configurations on their honest
/// semantics.
pub fn price(req: &PriceRequest) -> SimResult {
    if let Some(sched) = req.schedule {
        return price_one(sched, req);
    }
    if req.policy.max_staleness() == 0 {
        let sched = Schedule::for_sim(req.plan, req.model, req.policy);
        return price_one(&sched, req);
    }
    let rounds = ASYNC_STEADY_ROUNDS;
    let sched = Schedule::for_sim_rounds(req.plan, req.model, req.policy, rounds);
    let mut sim = price_one(&sched, req);
    // Normalise the chained run to per-round figures.  Ratios
    // (bubbles, throughput) are already steady-state: numerator and
    // denominator scale together.
    let r = rounds as f64;
    sim.round_latency /= r;
    for b in &mut sim.busy {
        *b /= r;
    }
    sim.bytes_on_network /= rounds as u64;
    sim
}

/// Memo for repeated [`price`] calls over identical
/// (plan, policy, codec, sync) tuples.  `sim_select` prices up to
/// `max_stages` finalists per planning run, and replans — micro-batch
/// sweeps, fault-time incremental replans — re-price mostly-identical
/// finalists.  The cache keys on an FNV fingerprint of the plan,
/// policy name, codec fingerprint and sync tag, with full `Plan`
/// equality verified on hit, so a hit is exact, never heuristic.
/// Prices are only valid for the (table, cluster, model) the cache was
/// populated under — callers thread one cache per planning context
/// (`planner::StagePricer` owns one and `plan_hpp` threads it through
/// replans).
#[derive(Debug, Clone, Default)]
pub struct PriceCache {
    entries: std::collections::HashMap<u64, Vec<(Plan, &'static str, u64, u8, SimResult)>>,
    hits: u64,
}

impl PriceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Exact-hit count so far (observability for bench/test assertions).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    fn fingerprint(plan: &Plan, policy: &str, codec_fp: u64, sync_tag: u8) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325_u64;
        let mut put = |h: &mut u64, x: u64| {
            *h ^= x;
            *h = h.wrapping_mul(0x0100_0000_01b3);
        };
        put(&mut h, plan.microbatch as u64);
        put(&mut h, plan.num_micro as u64);
        for s in &plan.stages {
            put(&mut h, s.layers.0 as u64);
            put(&mut h, s.layers.1 as u64);
            put(&mut h, s.kp as u64);
            for &d in &s.devices {
                put(&mut h, d as u64);
            }
            for &a in &s.alloc {
                put(&mut h, a as u64);
            }
        }
        for c in policy.bytes() {
            put(&mut h, c as u64);
        }
        put(&mut h, codec_fp);
        put(&mut h, sync_tag as u64);
        h
    }

    /// [`price`] through the cache.  The codec fingerprint and sync tag
    /// are part of the memo key (and re-verified on hit), so prices for
    /// different wire formats or collective topologies never alias —
    /// fault-time incremental replans may reuse a cache across codec or
    /// sync changes safely.  Memoizes policy-derived pricing only:
    /// `req.schedule` must be `None` (explicit schedules are one-shot
    /// and have no stable identity to key on).
    pub fn price(&mut self, req: &PriceRequest) -> SimResult {
        debug_assert!(
            req.schedule.is_none(),
            "PriceCache memoizes policy-derived pricing; explicit schedules are uncacheable"
        );
        let name = req.policy.name();
        let cfp = req.codec.fingerprint();
        let tag = req.sync.tag();
        let key = Self::fingerprint(req.plan, name, cfp, tag);
        if let Some(list) = self.entries.get(&key) {
            if let Some((_, _, _, _, r)) = list
                .iter()
                .find(|(p, n, c, t, _)| *n == name && *c == cfp && *t == tag && p == req.plan)
            {
                self.hits += 1;
                return r.clone();
            }
        }
        let r = price(req);
        self.entries
            .entry(key)
            .or_default()
            .push((req.plan.clone(), name, cfp, tag, r.clone()));
        r
    }
}

/// Event-accurate pricing of one explicit sample-sharded `Schedule`
/// under the request's codec and sync topology — the core every
/// [`price`] branch lands on.  Each `Send` is priced at the wire size
/// of its payload — looked up per producing boundary (an `Activation`
/// leaving stage p crosses boundary `layers.1`, a `Gradient` crosses
/// `layers.0`) — and the Eq. 5 AllReduce term uses compressed
/// flat-parameter bytes over the request's collective topology.
/// Compute durations are untouched: encode/decode cost is treated as
/// negligible next to link time, the same assumption the planner's
/// cost model makes.  Panics if the schedule deadlocks (i.e. it would
/// fail `Schedule::validate`) — callers price planner/policy output,
/// which is valid by construction.
fn price_one(sched: &Schedule, req: &PriceRequest) -> SimResult {
    let (table, cluster, model, plan) = (req.table, req.cluster, req.model, req.plan);
    let codec = &req.codec;
    assert_eq!(
        sched.sharding,
        Sharding::SampleShard,
        "sim::price prices sample-sharded schedules (got {:?})",
        sched.sharding
    );
    assert_eq!(sched.num_micro, plan.num_micro, "schedule/plan micro mismatch");
    assert_eq!(sched.num_stages, plan.stages.len(), "schedule/plan stage mismatch");
    let rounds = sched.rounds.max(1);

    let mut states: BTreeMap<usize, ExecDev> = sched
        .timelines
        .iter()
        .map(|tl| {
            (
                tl.device,
                ExecDev {
                    tl,
                    pos: 0,
                    split_bwd: tl.tasks.iter().any(|t| matches!(t, Task::BwdW { .. })),
                    running: false,
                    busy_total: 0.0,
                    first_start: f64::INFINITY,
                    first_end: f64::INFINITY,
                    last_end: 0.0,
                    inflight: 0,
                    peak_inflight: 0,
                    fwd_done: 0,
                    bwd_done: 0,
                },
            )
        })
        .collect();

    let mut q = EventQueue::new();
    let mut links = LinkSet::new(cluster);
    let mut mailbox: HashSet<(usize, usize, usize, Payload)> = HashSet::new();
    let mut bytes_on_network: u64 = 0;
    let mut ar_ready = vec![0.0f64; plan.stages.len()];

    // Advance a device's cursor as far as the timeline allows at `now`:
    // issue Sends, consume delivered Recvs, start at most one compute.
    #[allow(clippy::too_many_arguments)]
    fn advance(
        d: usize,
        st: &mut ExecDev<'_>,
        plan: &Plan,
        table: &ProfileTable,
        now: f64,
        q: &mut EventQueue<Ev>,
        links: &mut LinkSet,
        mailbox: &mut HashSet<(usize, usize, usize, Payload)>,
        bytes_on_network: &mut u64,
        ar_ready: &mut [f64],
        codec: &CodecSpec,
    ) {
        while !st.running && st.pos < st.tl.tasks.len() {
            match st.tl.tasks[st.pos] {
                Task::Send { micro, to, payload, bytes } => {
                    // The schedule IR carries logical (fp32) byte
                    // counts; the producing stage's boundary decides
                    // which codec this link runs.
                    let boundary = match payload {
                        Payload::Activation => plan.stages[st.tl.stage].layers.1,
                        Payload::Gradient => plan.stages[st.tl.stage].layers.0,
                    };
                    let wire = codec.wire_activation_bytes(boundary, bytes);
                    *bytes_on_network += wire;
                    let arrive = links.send(d, to, wire, now);
                    q.push(arrive, Ev::Msg { to, from: d, micro, payload });
                    st.pos += 1;
                }
                Task::Recv { micro, from, payload, .. } => {
                    if mailbox.remove(&(d, from, micro, payload)) {
                        st.pos += 1;
                    } else {
                        return; // blocked until the matching Send arrives
                    }
                }
                Task::Fwd { .. } | Task::Bwd { .. } | Task::BwdW { .. } => {
                    let (i, j) = plan.stages[st.tl.stage].layers;
                    let t = match st.tl.tasks[st.pos] {
                        Task::Fwd { .. } => {
                            st.inflight += 1;
                            st.peak_inflight = st.peak_inflight.max(st.inflight);
                            table.time_fwd(d, i, j, st.tl.share)
                        }
                        Task::Bwd { .. } => {
                            let tb = table.time_bwd(d, i, j, st.tl.share);
                            if st.split_bwd { tb * BWD_INPUT_FRAC } else { tb }
                        }
                        Task::BwdW { .. } => {
                            table.time_bwd(d, i, j, st.tl.share) * (1.0 - BWD_INPUT_FRAC)
                        }
                        _ => unreachable!(),
                    };
                    st.running = true;
                    st.first_start = st.first_start.min(now);
                    st.busy_total += t;
                    q.push(now + t, Ev::Done { dev: d });
                }
                Task::AllReduce { .. } => {
                    let s = st.tl.stage;
                    ar_ready[s] = ar_ready[s].max(now);
                    st.pos += 1;
                }
            }
        }
    }

    // Kick off every device at t = 0 (stage-0 forwards have no Recv
    // gates; everyone else blocks on their first Recv).
    let dev_ids: Vec<usize> = states.keys().copied().collect();
    for &d in &dev_ids {
        let st = states.get_mut(&d).unwrap();
        advance(
            d, st, plan, table, 0.0, &mut q, &mut links, &mut mailbox,
            &mut bytes_on_network, &mut ar_ready, codec,
        );
    }

    let mut now = 0.0f64;
    while let Some((t, ev)) = q.pop() {
        now = t;
        match ev {
            Ev::Done { dev } => {
                let st = states.get_mut(&dev).unwrap();
                st.running = false;
                st.last_end = now;
                st.first_end = st.first_end.min(now);
                match st.tl.tasks[st.pos] {
                    Task::Fwd { .. } => st.fwd_done += 1,
                    Task::Bwd { .. } => {
                        st.bwd_done += 1;
                        st.inflight -= 1;
                    }
                    // Weight-grad halves occupy the device but neither
                    // hold activations nor count toward BP completion
                    // (their micro's Bwd already did).
                    Task::BwdW { .. } => {}
                    _ => unreachable!("Done for a non-compute task"),
                }
                st.pos += 1;
                advance(
                    dev, st, plan, table, now, &mut q, &mut links, &mut mailbox,
                    &mut bytes_on_network, &mut ar_ready, codec,
                );
            }
            Ev::Msg { to, from, micro, payload } => {
                mailbox.insert((to, from, micro, payload));
                let st = states.get_mut(&to).unwrap();
                advance(
                    to, st, plan, table, now, &mut q, &mut links, &mut mailbox,
                    &mut bytes_on_network, &mut ar_ready, codec,
                );
            }
        }
    }

    // Every timeline must have drained; anything else is an invalid
    // schedule (would also fail Schedule::validate).
    for st in states.values() {
        assert_eq!(
            st.pos,
            st.tl.tasks.len(),
            "schedule deadlock: device {} stopped at {:?}",
            st.tl.device,
            st.tl.tasks.get(st.pos)
        );
        debug_assert_eq!(st.fwd_done, st.tl.num_fwd(), "fp incomplete");
        debug_assert_eq!(st.fwd_done, st.bwd_done, "bp incomplete");
    }

    // --- AllReduce + result assembly --------------------------------------
    // Eq. 5 over the request's collective topology: ring puts
    // 2(g-1)/g * W through the slowest intra-group link and 2(g-1)W on
    // the network total; driver-star mediation moves the full flat 2W
    // per worker (2gW total) through the driver.
    let mut round_end = now;
    for (p, stage) in plan.stages.iter().enumerate() {
        let g = stage.devices.len();
        if g > 1 {
            let w =
                codec.wire_sync_bytes(model.weight_bytes_range(stage.layers.0, stage.layers.1));
            let bw = cluster.min_bandwidth(&stage.devices);
            let ta = req.sync.allreduce_time(w, g, bw);
            bytes_on_network += rounds as u64 * req.sync.total_wire_bytes(w, g);
            round_end = round_end.max(ar_ready[p] + ta);
        }
    }

    let n_dev = cluster.n();
    let mut busy = vec![0.0; n_dev];
    let mut bubble = vec![0.0; n_dev];
    let mut peak_inflight = vec![0usize; n_dev];
    let mut peak_memory = vec![0u64; n_dev];
    let mut fill_latency = 0.0f64;
    for (&d, st) in &states {
        busy[d] = st.busy_total;
        let span = (st.last_end - st.first_start).max(1e-12);
        bubble[d] = (1.0 - st.busy_total / span).max(0.0);
        peak_inflight[d] = st.peak_inflight;
        if st.first_end.is_finite() {
            fill_latency = fill_latency.max(st.first_end);
        }
        let stage = &plan.stages[st.tl.stage];
        let mem = crate::planner::memory::stage_memory(
            model,
            &crate::config::TrainConfig::new(
                plan.microbatch * plan.num_micro,
                plan.microbatch,
            ),
            stage.layers.0,
            stage.layers.1,
            st.tl.share,
            st.peak_inflight.max(1),
        );
        // Bounded-staleness schedules additionally pin their weight
        // stash; the copy count was recorded on the timeline by the
        // policy (`weight_stash_copies`), so the priced memory is
        // exactly what the planner budgeted.
        let stash = st.tl.stash_copies as u64
            * model.weight_bytes_range(stage.layers.0, stage.layers.1);
        peak_memory[d] = mem.total() + stash;
    }

    let active = busy.iter().filter(|&&b| b > 0.0).count().max(1);
    let round_bubble_ratio =
        (1.0 - busy.iter().sum::<f64>() / (active as f64 * round_end)).max(0.0);

    SimResult {
        round_latency: round_end,
        throughput: (plan.samples_per_round() * rounds) as f64 / round_end,
        busy,
        bubble_fraction: bubble,
        round_bubble_ratio,
        peak_inflight,
        peak_memory,
        bytes_on_network,
        fill_latency,
        rounds_priced: rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, TrainConfig};
    use crate::model::zoo;
    use crate::planner::cost::{plan_steps, round_latency};
    use crate::planner::dp::{plan_hpp, PlannerConfig};
    use crate::planner::plan::{Plan, Stage};
    use crate::profiler::ProfileTable;
    use crate::schedule::GpipeFillDrain;

    fn fixture(env: &str) -> (ClusterSpec, crate::model::ModelDesc, ProfileTable) {
        let cluster = ClusterSpec::env(env, 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        (cluster, model, table)
    }

    #[test]
    fn simulates_planned_mobilenet() {
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let sim = simulate_round(&table, &cluster, &model, &out.plan);
        assert!(sim.round_latency > 0.0);
        assert!(sim.throughput > 0.0);
        assert!(sim.fill_latency > 0.0 && sim.fill_latency <= sim.round_latency);
        // Every participating device did work.
        for &d in &out.plan.devices() {
            assert!(sim.busy[d] > 0.0, "device {d} idle");
        }
    }

    #[test]
    fn wrapper_equals_explicit_default_schedule_pricing() {
        // simulate_round is definitionally a default PriceRequest, and
        // an explicit-schedule request for the default policy's own
        // schedule prices bit-identically — the parity the old
        // price_schedule/price_policy wrapper pair guaranteed.
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let sched = Schedule::for_sim(&out.plan, &model, DEFAULT_POLICY);
        sched.validate().unwrap();
        let a = simulate_round(&table, &cluster, &model, &out.plan);
        let b = price(&PriceRequest::new(&table, &cluster, &model, &out.plan).schedule(&sched));
        let c = price(&PriceRequest::new(&table, &cluster, &model, &out.plan));
        assert_eq!(a.round_latency, b.round_latency);
        assert_eq!(a.bytes_on_network, b.bytes_on_network);
        assert_eq!(a.round_latency, c.round_latency);
        assert_eq!(a.bytes_on_network, c.bytes_on_network);
    }

    #[test]
    fn sim_close_to_analytic_prediction() {
        // The dominant-step model approximates the event-accurate
        // schedule; they must agree within a modest factor.
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let steps = plan_steps(&table, &cluster, &model, &out.plan);
        let predicted = round_latency(&steps, out.plan.num_micro);
        let sim = simulate_round(&table, &cluster, &model, &out.plan);
        let ratio = sim.round_latency / predicted;
        assert!(
            (0.6..1.7).contains(&ratio),
            "sim {} vs predicted {predicted} (ratio {ratio})",
            sim.round_latency
        );
    }

    #[test]
    fn single_stage_dp_has_no_network_activations() {
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 1, 2, 3, 4],
                alloc: vec![4, 3, 3, 3, 3],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 4,
        };
        let sim = simulate_round(&table, &cluster, &model, &plan);
        // Only AllReduce bytes, no inter-stage messages.
        assert_eq!(
            sim.bytes_on_network,
            2 * 4 * model.total_weight_bytes()
        );
    }

    #[test]
    fn driver_star_sync_prices_more_volume_and_never_faster() {
        // Same single-stage 5-device DP plan under both collective
        // topologies: ring puts 2(g-1)W on the network, driver-star
        // mediation 2gW, and the star round is strictly longer because
        // its Eq. 5 term 2W/bw exceeds ring's 2(g-1)W/(g*bw).
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 1, 2, 3, 4],
                alloc: vec![4, 3, 3, 3, 3],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 4,
        };
        let base = PriceRequest::new(&table, &cluster, &model, &plan);
        let ring = price(&base);
        let star = price(&base.sync(SyncMode::DriverStar));
        let w = model.total_weight_bytes();
        assert_eq!(ring.bytes_on_network, 2 * 4 * w);
        assert_eq!(star.bytes_on_network, 2 * 5 * w);
        assert!(
            star.round_latency > ring.round_latency,
            "star {} !> ring {}",
            star.round_latency,
            ring.round_latency
        );
        // Compute is topology-independent.
        assert_eq!(star.busy, ring.busy);
    }

    #[test]
    fn codec_pricing_compresses_network_volume_not_compute() {
        use crate::codec::{Codec, CodecSpec};
        // env-C chain with a 2-device first stage: both the boundary
        // activations/gradients and the AllReduce flat params ride the
        // wire, so int8 must cut bytes_on_network while leaving
        // per-device compute untouched.
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0, 1], alloc: vec![4, 4], kp: 3 },
                Stage { layers: (nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let fp = price(&PriceRequest::new(&table, &cluster, &model, &plan));
        let int8 = CodecSpec::uniform(Codec::Int8);
        let cp = price(&PriceRequest::new(&table, &cluster, &model, &plan).codec(int8));
        assert!(
            cp.bytes_on_network < fp.bytes_on_network / 3,
            "int8 wire {} !<< fp32 wire {}",
            cp.bytes_on_network,
            fp.bytes_on_network
        );
        assert!(cp.round_latency <= fp.round_latency);
        for d in [0usize, 1, 3] {
            assert_eq!(cp.busy[d], fp.busy[d], "compute is codec-independent");
        }
        // The identity spec prices bit-identically to the fp32 path.
        let id = price(
            &PriceRequest::new(&table, &cluster, &model, &plan).codec(CodecSpec::default()),
        );
        assert_eq!(id.bytes_on_network, fp.bytes_on_network);
        assert_eq!(id.round_latency, fp.round_latency);
    }

    #[test]
    fn price_cache_keys_on_codec_fingerprint() {
        use crate::codec::{Codec, CodecSpec};
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let mut cache = PriceCache::new();
        let base = PriceRequest::new(&table, &cluster, &model, &out.plan);
        let fp = cache.price(&base);
        let int8 = CodecSpec::uniform(Codec::Int8);
        let cp = cache.price(&base.codec(int8));
        // Different codecs on the same (plan, policy) are distinct
        // entries: no false hit, and the prices genuinely differ.
        assert_eq!(cache.hits(), 0);
        assert!(cp.bytes_on_network < fp.bytes_on_network);
        // Re-pricing each spec hits its own memo exactly.
        let fp2 = cache.price(&base);
        let cp2 = cache.price(&base.codec(int8));
        assert_eq!(cache.hits(), 2);
        assert_eq!(fp2.bytes_on_network, fp.bytes_on_network);
        assert_eq!(cp2.bytes_on_network, cp.bytes_on_network);
    }

    #[test]
    fn price_cache_keys_on_sync_mode() {
        // Ring and driver-star prices for the same (plan, policy,
        // codec) must never alias — the sync tag is part of the key.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 1, 2],
                alloc: vec![6, 5, 5],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 4,
        };
        let mut cache = PriceCache::new();
        let base = PriceRequest::new(&table, &cluster, &model, &plan);
        let ring = cache.price(&base);
        let star = cache.price(&base.sync(SyncMode::DriverStar));
        assert_eq!(cache.hits(), 0, "ring/star must be distinct entries");
        assert!(star.bytes_on_network > ring.bytes_on_network);
        let ring2 = cache.price(&base);
        let star2 = cache.price(&base.sync(SyncMode::DriverStar));
        assert_eq!(cache.hits(), 2);
        assert_eq!(ring2.round_latency, ring.round_latency);
        assert_eq!(star2.round_latency, star.round_latency);
    }

    #[test]
    fn kp_bounds_inflight_microbatches() {
        // 1F1B with K_p must never hold more than K_p activations.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kp0: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kp0 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let sim_ours = simulate_round(&table, &cluster, &model, &mk(3));
        assert!(sim_ours.peak_inflight[0] <= 3);
        let sim_gpipe = simulate_round(&table, &cluster, &model, &mk(8));
        assert!(sim_gpipe.peak_inflight[0] > 3, "gpipe should buffer more");
        assert!(sim_gpipe.peak_memory[0] > sim_ours.peak_memory[0]);
    }

    #[test]
    fn gpipe_policy_equals_kp_saturated_default() {
        // Two routes to fill-drain: the GPipe policy, or 1F1B with
        // K_p >= M.  Same IR semantics, same price.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kp0: usize, kp1: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kp0 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: kp1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let saturated = mk(8, 8);
        let via_kp = simulate_round(&table, &cluster, &model, &saturated);
        let gp_plan = mk(1, 1);
        let gp_sched = Schedule::for_sim(&gp_plan, &model, &GpipeFillDrain);
        gp_sched.validate().unwrap();
        let via_policy =
            price(&PriceRequest::new(&table, &cluster, &model, &gp_plan).schedule(&gp_sched));
        assert_eq!(via_kp.round_latency, via_policy.round_latency);
        assert_eq!(via_kp.peak_inflight, via_policy.peak_inflight);
    }

    #[test]
    fn gpipe_memory_grows_with_m_but_ours_does_not() {
        // Fig. 15(b): O(M) vs O(K_p) activation residency.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |m: usize, kp: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: m,
        };
        let ours_m8 = simulate_round(&table, &cluster, &model, &mk(8, 3));
        let ours_m32 = simulate_round(&table, &cluster, &model, &mk(32, 3));
        assert_eq!(ours_m8.peak_inflight[0], ours_m32.peak_inflight[0]);
        let gpipe_m8 = simulate_round(&table, &cluster, &model, &mk(8, 8));
        let gpipe_m32 = simulate_round(&table, &cluster, &model, &mk(32, 32));
        assert!(gpipe_m32.peak_inflight[0] > gpipe_m8.peak_inflight[0]);
    }

    #[test]
    fn zero_bubble_strictly_beats_1f1b_on_heterogeneous_chain() {
        // The reference heterogeneous cluster fixture: env C's NX
        // (device 0) feeds a Nano (device 3) that owns the larger layer
        // slice — the classic setup where 1F1B's upstream drain idles
        // waiting for downstream gradients.  ZB-H1 sends each
        // input-gradient as soon as its half-backward finishes and
        // fills the drain gaps with deferred weight-grad work, so the
        // observed round makespan must be *strictly* lower while total
        // per-device compute is conserved.
        use crate::schedule::{OneFOneBKp, ZeroBubbleH1};
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0], alloc: vec![8], kp: 3 },
                Stage { layers: (nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let one_sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        let zb_sched = Schedule::for_sim(&plan, &model, &ZeroBubbleH1);
        zb_sched.validate().unwrap();
        let base = PriceRequest::new(&table, &cluster, &model, &plan);
        let one = price(&base.schedule(&one_sched));
        let zb = price(&base.schedule(&zb_sched));
        assert!(
            zb.round_latency < one.round_latency,
            "zb-h1 {} !< 1f1b {}",
            zb.round_latency,
            one.round_latency
        );
        // Splitting conserves compute (B + W = full backward) and the
        // 1F1B activation window.
        for d in [0usize, 3] {
            assert!(
                (zb.busy[d] - one.busy[d]).abs() < 1e-9 * one.busy[d].max(1e-12),
                "device {d}: zb busy {} vs 1f1b {}",
                zb.busy[d],
                one.busy[d]
            );
        }
        assert_eq!(zb.peak_inflight, one.peak_inflight);
        assert_eq!(zb.bytes_on_network, one.bytes_on_network);
    }

    #[test]
    fn async_pipe_strictly_beats_zero_bubble_on_heterogeneous_chain() {
        // Same env-C NX -> Nano chain as the ZB-H1 test.  ZB-H1 fills
        // the drain with deferred weight-grad work but still pays the
        // fill and the round barrier every round; bounded staleness
        // removes the barrier entirely — in steady state round r+1's
        // warm-up forwards run inside round r's drain — so with
        // per-device compute conserved, both the per-round latency and
        // the pipeline bubble ratio must be *strictly* lower.
        use crate::schedule::{AsyncPipe, OneFOneBKp, ZeroBubbleH1};
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0], alloc: vec![8], kp: 3 },
                Stage { layers: (nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let base = PriceRequest::new(&table, &cluster, &model, &plan);
        let asy = price(&base.policy(&AsyncPipe { max_staleness: 2 }));
        let zb = price(&base.policy(&ZeroBubbleH1));
        let one = price(&base.policy(&OneFOneBKp));
        assert_eq!(asy.rounds_priced, ASYNC_STEADY_ROUNDS);
        assert_eq!(zb.rounds_priced, 1);
        assert!(
            asy.round_latency < zb.round_latency,
            "async {} !< zb-h1 {}",
            asy.round_latency,
            zb.round_latency
        );
        assert!(
            asy.round_bubble_ratio < zb.round_bubble_ratio,
            "async bubble {} !< zb-h1 bubble {}",
            asy.round_bubble_ratio,
            zb.round_bubble_ratio
        );
        // ... and transitively below plain 1F1B on both metrics.
        assert!(asy.round_latency < one.round_latency);
        assert!(asy.round_bubble_ratio < one.round_bubble_ratio);
        // Steady-state normalisation conserves per-device compute and
        // per-round network volume.
        for d in [0usize, 3] {
            assert!(
                (asy.busy[d] - one.busy[d]).abs() < 1e-9 * one.busy[d].max(1e-12),
                "device {d}: async busy {} vs 1f1b {}",
                asy.busy[d],
                one.busy[d]
            );
        }
        assert_eq!(asy.bytes_on_network, one.bytes_on_network);
        // The widened window shows up as extra in-flight residency,
        // bounded by K_p + sigma.
        assert!(asy.peak_inflight[0] > one.peak_inflight[0]);
        assert!(asy.peak_inflight[0] <= 3 + 2);
        assert!(asy.peak_memory[0] > one.peak_memory[0], "stash copies must be charged");
    }

    #[test]
    fn interleaved_prices_like_1f1b_on_symmetric_micros() {
        // In the sample-sharded sim every micro is identical, so the
        // chunk-major permutation must not change the makespan — the
        // policy's value is its schedule shape, not sim throughput.
        use crate::schedule::{Interleaved, OneFOneBKp};
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mut plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: 1 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        plan.apply_default_kp();
        let il_sched = Schedule::for_sim(&plan, &model, &Interleaved { virtual_per_device: 2 });
        il_sched.validate().unwrap();
        let base = PriceRequest::new(&table, &cluster, &model, &plan);
        let il = price(&base.schedule(&il_sched));
        let one_sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        let one = price(&base.schedule(&one_sched));
        assert!((il.round_latency - one.round_latency).abs() < 1e-9 * one.round_latency);
        assert_eq!(il.peak_inflight, one.peak_inflight);
    }

    #[test]
    fn kp_one_serialises_stages() {
        // K_p = 1 for all stages means only one stage active at a time:
        // throughput strictly below the K_p policy pipeline.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kps: [usize; 2]| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kps[0] },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: kps[1] },
            ],
            microbatch: 8,
            num_micro: 16,
        };
        let serial = simulate_round(&table, &cluster, &model, &mk([1, 1]));
        let ours = simulate_round(&table, &cluster, &model, &mk([3, 1]));
        assert!(
            ours.throughput > serial.throughput,
            "ours {} vs serial {}",
            ours.throughput,
            serial.throughput
        );
    }

    #[test]
    fn more_microbatches_amortise_bubbles() {
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |m: usize| {
            let mut p = Plan {
                stages: vec![
                    Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: 1 },
                    Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
                ],
                microbatch: 8,
                num_micro: m,
            };
            p.apply_default_kp();
            p
        };
        let s4 = simulate_round(&table, &cluster, &model, &mk(4));
        let s32 = simulate_round(&table, &cluster, &model, &mk(32));
        assert!(s32.throughput > s4.throughput);
    }

    #[test]
    fn heterogeneous_alloc_beats_equal_split_in_sim() {
        // End-to-end: Alg. 1's allocation must beat a naive equal split
        // when the group mixes NX and Nano.
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let equal = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 3], // NX + Nano
                alloc: vec![8, 8],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 8,
        };
        let mut skewed = equal.clone();
        skewed.stages[0].alloc = vec![13, 3];
        let sim_eq = simulate_round(&table, &cluster, &model, &equal);
        let sim_sk = simulate_round(&table, &cluster, &model, &skewed);
        assert!(
            sim_sk.throughput > sim_eq.throughput,
            "skewed {} vs equal {}",
            sim_sk.throughput,
            sim_eq.throughput
        );
    }
}
