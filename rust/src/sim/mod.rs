//! Discrete-event pricing of HPP-Round schedules.
//!
//! The planner's cost model (Eqs. 4-6) is an *approximation* built on
//! the dominant-step idea; this module prices the *explicit* schedule:
//! [`price_schedule`] walks each device's `schedule::Schedule` timeline
//! task by task against the `ProfileTable` (compute durations) and the
//! `LinkSet` (serialised inter-device transfers), and reports observed
//! round latency, per-device busy time, bubble fractions and in-flight
//! activation peaks.  Every paper table/figure that reports throughput
//! is measured here, with the analytic prediction as a cross-check.
//!
//! The simulator owns **no scheduling logic**: which task runs next on
//! a device — 1F1B order, the K_p warm-up window, GPipe fill-drain —
//! is entirely encoded in the `Schedule` IR by its `SchedulePolicy`.
//! [`simulate_round`] is a thin wrapper that builds the default
//! (1F1B-K_p, sample-sharded) schedule for a plan and prices it.
//! [`price_policy`] is the policy-aware entry: synchronous policies
//! price as one barriered round, bounded-staleness policies as a
//! barrier-free [`ASYNC_STEADY_ROUNDS`]-round chain normalised to
//! per-round figures (their fill/drain amortises away — the async
//! payoff).

pub mod convergence;
pub mod engine;

use std::collections::{BTreeMap, HashSet};

use crate::codec::CodecSpec;
use crate::config::ClusterSpec;
use crate::model::ModelDesc;
use crate::planner::plan::Plan;
use crate::profiler::ProfileTable;
use crate::schedule::{
    Payload, Schedule, SchedulePolicy, Sharding, Task, BWD_INPUT_FRAC, DEFAULT_POLICY,
};

use engine::{EventQueue, LinkSet};

/// How many HPP-Rounds [`price_policy`] chains back-to-back when
/// pricing a bounded-staleness policy: without an inter-round barrier
/// the fill/drain of consecutive rounds overlap, and the per-round
/// steady-state latency is the chained makespan divided by the round
/// count.  Large enough to amortise the one fill + one drain that
/// remain at the window edges.
pub const ASYNC_STEADY_ROUNDS: usize = 6;

/// Result of pricing one HPP-Round.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock of the round (first FP start to last AllReduce end).
    /// For a steady-state (multi-round async) pricing this is the
    /// per-round figure: chained makespan / rounds.
    pub round_latency: f64,
    /// Samples per second.
    pub throughput: f64,
    /// Per device: total busy compute time within the round.
    pub busy: Vec<f64>,
    /// Per device: 1 - busy/span over the device's active span.
    pub bubble_fraction: Vec<f64>,
    /// Pipeline bubble ratio of the whole round: 1 - total busy time /
    /// (participating devices x round latency).  The cross-policy
    /// comparison metric — per-device compute is conserved across
    /// policies, so a strictly lower ratio means a strictly shorter
    /// round.
    pub round_bubble_ratio: f64,
    /// Per device: peak in-flight micro-batches (drives Eq. 3 memory).
    pub peak_inflight: Vec<usize>,
    /// Per device: peak memory bytes (Eq. 3 with observed in-flight,
    /// plus the weight-stash copies of a bounded-staleness schedule).
    pub peak_memory: Vec<u64>,
    /// Total bytes moved across links during the round.
    pub bytes_on_network: u64,
    /// Pipeline fill latency: the instant every device has completed
    /// its first compute task.  This is the warm-up cost the fault
    /// machinery charges a freshly replayed pipeline.
    pub fill_latency: f64,
    /// HPP-Rounds the priced timeline encoded (1 for synchronous
    /// policies; [`ASYNC_STEADY_ROUNDS`] for bounded-staleness
    /// steady-state pricing).
    pub rounds_priced: usize,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// The compute task at the device's cursor finished.
    Done { dev: usize },
    /// A transfer arrived at its destination.
    Msg { to: usize, from: usize, micro: usize, payload: Payload },
}

/// Per-device execution cursor over its timeline.
struct ExecDev<'a> {
    tl: &'a crate::schedule::DeviceTimeline,
    /// Index of the next task to start (the task a `Done` refers to
    /// while `running`).
    pos: usize,
    /// Whether this timeline splits backwards (contains BwdW tasks):
    /// its Bwd tasks then price the input-gradient fraction only, and
    /// BwdW tasks carry the weight-gradient remainder, conserving
    /// total backward compute.
    split_bwd: bool,
    running: bool,
    busy_total: f64,
    first_start: f64,
    first_end: f64,
    last_end: f64,
    inflight: usize,
    peak_inflight: usize,
    fwd_done: usize,
    bwd_done: usize,
}

/// Simulate one HPP-Round of `plan` under the default schedule policy.
pub fn simulate_round(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
) -> SimResult {
    let sched = Schedule::for_sim(plan, model, DEFAULT_POLICY);
    price_schedule(&sched, table, cluster, model, plan)
}

/// Price `plan` under `policy`, choosing the pricing form the policy's
/// semantics call for: a synchronous policy is priced as one barriered
/// HPP-Round ([`Schedule::for_sim`] + [`price_schedule`]); a
/// bounded-staleness policy ([`SchedulePolicy::max_staleness`] > 0) is
/// priced in **steady state** — [`ASYNC_STEADY_ROUNDS`] rounds chained
/// without a barrier, per-round figures normalised by the round count —
/// because its whole point is that round r+1's warm-up fills round r's
/// drain.  This is the single entry the planner's `sim_select`, the
/// session's `SimBackend` and the fault re-pricing all use, so every
/// reported throughput compares policies on their honest semantics.
pub fn price_policy(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    policy: &dyn SchedulePolicy,
) -> SimResult {
    price_policy_codec(table, cluster, model, plan, policy, &CodecSpec::default())
}

/// [`price_policy`] under a wire [`CodecSpec`]: every boundary transfer
/// and AllReduce is priced at its *wire* bytes (`bytes_on_network`
/// included), so the simulator agrees byte-for-byte with what the
/// framed-TCP data plane would actually put on the network.
pub fn price_policy_codec(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    policy: &dyn SchedulePolicy,
    codec: &CodecSpec,
) -> SimResult {
    if policy.max_staleness() == 0 {
        let sched = Schedule::for_sim(plan, model, policy);
        return price_schedule_codec(&sched, table, cluster, model, plan, codec);
    }
    let rounds = ASYNC_STEADY_ROUNDS;
    let sched = Schedule::for_sim_rounds(plan, model, policy, rounds);
    let mut sim = price_schedule_codec(&sched, table, cluster, model, plan, codec);
    // Normalise the chained run to per-round figures.  Ratios
    // (bubbles, throughput) are already steady-state: numerator and
    // denominator scale together.
    let r = rounds as f64;
    sim.round_latency /= r;
    for b in &mut sim.busy {
        *b /= r;
    }
    sim.bytes_on_network /= rounds as u64;
    sim
}

/// Memo for repeated [`price_policy`] calls over identical
/// (plan, policy) pairs.  `sim_select` prices up to `max_stages`
/// finalists per planning run, and replans — micro-batch sweeps,
/// fault-time incremental replans — re-price mostly-identical
/// finalists.  The cache keys on an FNV fingerprint of the plan and
/// policy name, with full `Plan` equality verified on hit, so a hit is
/// exact, never heuristic.  Prices are only valid for the
/// (table, cluster, model) the cache was populated under — callers
/// thread one cache per planning context (`planner::StagePricer` owns
/// one and `plan_hpp` threads it through replans).
#[derive(Debug, Clone, Default)]
pub struct PriceCache {
    entries: std::collections::HashMap<u64, Vec<(Plan, &'static str, u64, SimResult)>>,
    hits: u64,
}

impl PriceCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Exact-hit count so far (observability for bench/test assertions).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    fn fingerprint(plan: &Plan, policy: &str, codec_fp: u64) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325_u64;
        let mut put = |h: &mut u64, x: u64| {
            *h ^= x;
            *h = h.wrapping_mul(0x0100_0000_01b3);
        };
        put(&mut h, plan.microbatch as u64);
        put(&mut h, plan.num_micro as u64);
        for s in &plan.stages {
            put(&mut h, s.layers.0 as u64);
            put(&mut h, s.layers.1 as u64);
            put(&mut h, s.kp as u64);
            for &d in &s.devices {
                put(&mut h, d as u64);
            }
            for &a in &s.alloc {
                put(&mut h, a as u64);
            }
        }
        for c in policy.bytes() {
            put(&mut h, c as u64);
        }
        put(&mut h, codec_fp);
        h
    }

    /// [`price_policy`] through the cache (fp32 wire format).
    pub fn price(
        &mut self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        plan: &Plan,
        policy: &dyn SchedulePolicy,
    ) -> SimResult {
        self.price_codec(table, cluster, model, plan, policy, &CodecSpec::default())
    }

    /// [`price_policy_codec`] through the cache.  The codec fingerprint
    /// is part of the memo key (and re-verified on hit), so prices for
    /// different wire formats never alias — fault-time incremental
    /// replans may reuse a cache across codec changes safely.
    pub fn price_codec(
        &mut self,
        table: &ProfileTable,
        cluster: &ClusterSpec,
        model: &ModelDesc,
        plan: &Plan,
        policy: &dyn SchedulePolicy,
        codec: &CodecSpec,
    ) -> SimResult {
        let name = policy.name();
        let cfp = codec.fingerprint();
        let key = Self::fingerprint(plan, name, cfp);
        if let Some(list) = self.entries.get(&key) {
            if let Some((_, _, _, r)) =
                list.iter().find(|(p, n, c, _)| *n == name && *c == cfp && p == plan)
            {
                self.hits += 1;
                return r.clone();
            }
        }
        let r = price_policy_codec(table, cluster, model, plan, policy, codec);
        self.entries.entry(key).or_default().push((plan.clone(), name, cfp, r.clone()));
        r
    }
}

/// [`price_policy`] through a [`PriceCache`] — the memoized entry the
/// planner's `sim_select` uses across finalists and replans.
pub fn price_policy_cached(
    cache: &mut PriceCache,
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    policy: &dyn SchedulePolicy,
) -> SimResult {
    cache.price(table, cluster, model, plan, policy)
}

/// Price an explicit sample-sharded `Schedule` against the profile and
/// link models.  Panics if the schedule deadlocks (i.e. it would fail
/// `Schedule::validate`) — callers price planner/policy output, which
/// is valid by construction.
pub fn price_schedule(
    sched: &Schedule,
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
) -> SimResult {
    price_schedule_codec(sched, table, cluster, model, plan, &CodecSpec::default())
}

/// [`price_schedule`] under a wire [`CodecSpec`]: each `Send` is priced
/// at the wire size of its payload — looked up per producing boundary
/// (an `Activation` leaving stage p crosses boundary `layers.1`, a
/// `Gradient` crosses `layers.0`) — and the Eq. 5 AllReduce term uses
/// compressed flat-parameter bytes.  Compute durations are untouched:
/// encode/decode cost is treated as negligible next to link time, the
/// same assumption the planner's cost model makes.
pub fn price_schedule_codec(
    sched: &Schedule,
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    codec: &CodecSpec,
) -> SimResult {
    assert_eq!(
        sched.sharding,
        Sharding::SampleShard,
        "price_schedule prices sample-sharded schedules (got {:?})",
        sched.sharding
    );
    assert_eq!(sched.num_micro, plan.num_micro, "schedule/plan micro mismatch");
    assert_eq!(sched.num_stages, plan.stages.len(), "schedule/plan stage mismatch");
    let rounds = sched.rounds.max(1);

    let mut states: BTreeMap<usize, ExecDev> = sched
        .timelines
        .iter()
        .map(|tl| {
            (
                tl.device,
                ExecDev {
                    tl,
                    pos: 0,
                    split_bwd: tl.tasks.iter().any(|t| matches!(t, Task::BwdW { .. })),
                    running: false,
                    busy_total: 0.0,
                    first_start: f64::INFINITY,
                    first_end: f64::INFINITY,
                    last_end: 0.0,
                    inflight: 0,
                    peak_inflight: 0,
                    fwd_done: 0,
                    bwd_done: 0,
                },
            )
        })
        .collect();

    let mut q = EventQueue::new();
    let mut links = LinkSet::new(cluster);
    let mut mailbox: HashSet<(usize, usize, usize, Payload)> = HashSet::new();
    let mut bytes_on_network: u64 = 0;
    let mut ar_ready = vec![0.0f64; plan.stages.len()];

    // Advance a device's cursor as far as the timeline allows at `now`:
    // issue Sends, consume delivered Recvs, start at most one compute.
    #[allow(clippy::too_many_arguments)]
    fn advance(
        d: usize,
        st: &mut ExecDev<'_>,
        plan: &Plan,
        table: &ProfileTable,
        now: f64,
        q: &mut EventQueue<Ev>,
        links: &mut LinkSet,
        mailbox: &mut HashSet<(usize, usize, usize, Payload)>,
        bytes_on_network: &mut u64,
        ar_ready: &mut [f64],
        codec: &CodecSpec,
    ) {
        while !st.running && st.pos < st.tl.tasks.len() {
            match st.tl.tasks[st.pos] {
                Task::Send { micro, to, payload, bytes } => {
                    // The schedule IR carries logical (fp32) byte
                    // counts; the producing stage's boundary decides
                    // which codec this link runs.
                    let boundary = match payload {
                        Payload::Activation => plan.stages[st.tl.stage].layers.1,
                        Payload::Gradient => plan.stages[st.tl.stage].layers.0,
                    };
                    let wire = codec.wire_activation_bytes(boundary, bytes);
                    *bytes_on_network += wire;
                    let arrive = links.send(d, to, wire, now);
                    q.push(arrive, Ev::Msg { to, from: d, micro, payload });
                    st.pos += 1;
                }
                Task::Recv { micro, from, payload, .. } => {
                    if mailbox.remove(&(d, from, micro, payload)) {
                        st.pos += 1;
                    } else {
                        return; // blocked until the matching Send arrives
                    }
                }
                Task::Fwd { .. } | Task::Bwd { .. } | Task::BwdW { .. } => {
                    let (i, j) = plan.stages[st.tl.stage].layers;
                    let t = match st.tl.tasks[st.pos] {
                        Task::Fwd { .. } => {
                            st.inflight += 1;
                            st.peak_inflight = st.peak_inflight.max(st.inflight);
                            table.time_fwd(d, i, j, st.tl.share)
                        }
                        Task::Bwd { .. } => {
                            let tb = table.time_bwd(d, i, j, st.tl.share);
                            if st.split_bwd { tb * BWD_INPUT_FRAC } else { tb }
                        }
                        Task::BwdW { .. } => {
                            table.time_bwd(d, i, j, st.tl.share) * (1.0 - BWD_INPUT_FRAC)
                        }
                        _ => unreachable!(),
                    };
                    st.running = true;
                    st.first_start = st.first_start.min(now);
                    st.busy_total += t;
                    q.push(now + t, Ev::Done { dev: d });
                }
                Task::AllReduce { .. } => {
                    let s = st.tl.stage;
                    ar_ready[s] = ar_ready[s].max(now);
                    st.pos += 1;
                }
            }
        }
    }

    // Kick off every device at t = 0 (stage-0 forwards have no Recv
    // gates; everyone else blocks on their first Recv).
    let dev_ids: Vec<usize> = states.keys().copied().collect();
    for &d in &dev_ids {
        let st = states.get_mut(&d).unwrap();
        advance(
            d, st, plan, table, 0.0, &mut q, &mut links, &mut mailbox,
            &mut bytes_on_network, &mut ar_ready, codec,
        );
    }

    let mut now = 0.0f64;
    while let Some((t, ev)) = q.pop() {
        now = t;
        match ev {
            Ev::Done { dev } => {
                let st = states.get_mut(&dev).unwrap();
                st.running = false;
                st.last_end = now;
                st.first_end = st.first_end.min(now);
                match st.tl.tasks[st.pos] {
                    Task::Fwd { .. } => st.fwd_done += 1,
                    Task::Bwd { .. } => {
                        st.bwd_done += 1;
                        st.inflight -= 1;
                    }
                    // Weight-grad halves occupy the device but neither
                    // hold activations nor count toward BP completion
                    // (their micro's Bwd already did).
                    Task::BwdW { .. } => {}
                    _ => unreachable!("Done for a non-compute task"),
                }
                st.pos += 1;
                advance(
                    dev, st, plan, table, now, &mut q, &mut links, &mut mailbox,
                    &mut bytes_on_network, &mut ar_ready, codec,
                );
            }
            Ev::Msg { to, from, micro, payload } => {
                mailbox.insert((to, from, micro, payload));
                let st = states.get_mut(&to).unwrap();
                advance(
                    to, st, plan, table, now, &mut q, &mut links, &mut mailbox,
                    &mut bytes_on_network, &mut ar_ready, codec,
                );
            }
        }
    }

    // Every timeline must have drained; anything else is an invalid
    // schedule (would also fail Schedule::validate).
    for st in states.values() {
        assert_eq!(
            st.pos,
            st.tl.tasks.len(),
            "schedule deadlock: device {} stopped at {:?}",
            st.tl.device,
            st.tl.tasks.get(st.pos)
        );
        debug_assert_eq!(st.fwd_done, st.tl.num_fwd(), "fp incomplete");
        debug_assert_eq!(st.fwd_done, st.bwd_done, "bp incomplete");
    }

    // --- AllReduce + result assembly --------------------------------------
    let mut round_end = now;
    for (p, stage) in plan.stages.iter().enumerate() {
        if stage.devices.len() > 1 {
            let ta = crate::planner::cost::allreduce_time_codec(cluster, model, stage, codec);
            let w =
                codec.wire_sync_bytes(model.weight_bytes_range(stage.layers.0, stage.layers.1));
            bytes_on_network += rounds as u64 * 2 * (stage.devices.len() as u64 - 1) * w;
            round_end = round_end.max(ar_ready[p] + ta);
        }
    }

    let n_dev = cluster.n();
    let mut busy = vec![0.0; n_dev];
    let mut bubble = vec![0.0; n_dev];
    let mut peak_inflight = vec![0usize; n_dev];
    let mut peak_memory = vec![0u64; n_dev];
    let mut fill_latency = 0.0f64;
    for (&d, st) in &states {
        busy[d] = st.busy_total;
        let span = (st.last_end - st.first_start).max(1e-12);
        bubble[d] = (1.0 - st.busy_total / span).max(0.0);
        peak_inflight[d] = st.peak_inflight;
        if st.first_end.is_finite() {
            fill_latency = fill_latency.max(st.first_end);
        }
        let stage = &plan.stages[st.tl.stage];
        let mem = crate::planner::memory::stage_memory(
            model,
            &crate::config::TrainConfig::new(
                plan.microbatch * plan.num_micro,
                plan.microbatch,
            ),
            stage.layers.0,
            stage.layers.1,
            st.tl.share,
            st.peak_inflight.max(1),
        );
        // Bounded-staleness schedules additionally pin their weight
        // stash; the copy count was recorded on the timeline by the
        // policy (`weight_stash_copies`), so the priced memory is
        // exactly what the planner budgeted.
        let stash = st.tl.stash_copies as u64
            * model.weight_bytes_range(stage.layers.0, stage.layers.1);
        peak_memory[d] = mem.total() + stash;
    }

    let active = busy.iter().filter(|&&b| b > 0.0).count().max(1);
    let round_bubble_ratio =
        (1.0 - busy.iter().sum::<f64>() / (active as f64 * round_end)).max(0.0);

    SimResult {
        round_latency: round_end,
        throughput: (plan.samples_per_round() * rounds) as f64 / round_end,
        busy,
        bubble_fraction: bubble,
        round_bubble_ratio,
        peak_inflight,
        peak_memory,
        bytes_on_network,
        fill_latency,
        rounds_priced: rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, TrainConfig};
    use crate::model::zoo;
    use crate::planner::cost::{plan_steps, round_latency};
    use crate::planner::dp::{plan_hpp, PlannerConfig};
    use crate::planner::plan::{Plan, Stage};
    use crate::profiler::ProfileTable;
    use crate::schedule::GpipeFillDrain;

    fn fixture(env: &str) -> (ClusterSpec, crate::model::ModelDesc, ProfileTable) {
        let cluster = ClusterSpec::env(env, 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        (cluster, model, table)
    }

    #[test]
    fn simulates_planned_mobilenet() {
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let sim = simulate_round(&table, &cluster, &model, &out.plan);
        assert!(sim.round_latency > 0.0);
        assert!(sim.throughput > 0.0);
        assert!(sim.fill_latency > 0.0 && sim.fill_latency <= sim.round_latency);
        // Every participating device did work.
        for &d in &out.plan.devices() {
            assert!(sim.busy[d] > 0.0, "device {d} idle");
        }
    }

    #[test]
    fn wrapper_equals_explicit_default_schedule_pricing() {
        // simulate_round is definitionally for_sim + price_schedule.
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let sched = Schedule::for_sim(&out.plan, &model, DEFAULT_POLICY);
        sched.validate().unwrap();
        let a = simulate_round(&table, &cluster, &model, &out.plan);
        let b = price_schedule(&sched, &table, &cluster, &model, &out.plan);
        assert_eq!(a.round_latency, b.round_latency);
        assert_eq!(a.bytes_on_network, b.bytes_on_network);
    }

    #[test]
    fn sim_close_to_analytic_prediction() {
        // The dominant-step model approximates the event-accurate
        // schedule; they must agree within a modest factor.
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let steps = plan_steps(&table, &cluster, &model, &out.plan);
        let predicted = round_latency(&steps, out.plan.num_micro);
        let sim = simulate_round(&table, &cluster, &model, &out.plan);
        let ratio = sim.round_latency / predicted;
        assert!(
            (0.6..1.7).contains(&ratio),
            "sim {} vs predicted {predicted} (ratio {ratio})",
            sim.round_latency
        );
    }

    #[test]
    fn single_stage_dp_has_no_network_activations() {
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 1, 2, 3, 4],
                alloc: vec![4, 3, 3, 3, 3],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 4,
        };
        let sim = simulate_round(&table, &cluster, &model, &plan);
        // Only AllReduce bytes, no inter-stage messages.
        assert_eq!(
            sim.bytes_on_network,
            2 * 4 * model.total_weight_bytes()
        );
    }

    #[test]
    fn codec_pricing_compresses_network_volume_not_compute() {
        use crate::codec::{Codec, CodecSpec};
        // env-C chain with a 2-device first stage: both the boundary
        // activations/gradients and the AllReduce flat params ride the
        // wire, so int8 must cut bytes_on_network while leaving
        // per-device compute untouched.
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0, 1], alloc: vec![4, 4], kp: 3 },
                Stage { layers: (nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let fp = price_policy(&table, &cluster, &model, &plan, DEFAULT_POLICY);
        let int8 = CodecSpec::uniform(Codec::Int8);
        let cp = price_policy_codec(&table, &cluster, &model, &plan, DEFAULT_POLICY, &int8);
        assert!(
            cp.bytes_on_network < fp.bytes_on_network / 3,
            "int8 wire {} !<< fp32 wire {}",
            cp.bytes_on_network,
            fp.bytes_on_network
        );
        assert!(cp.round_latency <= fp.round_latency);
        for d in [0usize, 1, 3] {
            assert_eq!(cp.busy[d], fp.busy[d], "compute is codec-independent");
        }
        // The identity spec prices bit-identically to the fp32 path.
        let id = price_policy_codec(
            &table, &cluster, &model, &plan, DEFAULT_POLICY, &CodecSpec::default(),
        );
        assert_eq!(id.bytes_on_network, fp.bytes_on_network);
        assert_eq!(id.round_latency, fp.round_latency);
    }

    #[test]
    fn price_cache_keys_on_codec_fingerprint() {
        use crate::codec::{Codec, CodecSpec};
        let (cluster, model, table) = fixture("B");
        let cfg = TrainConfig::new(256, 16);
        let out = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default()).unwrap();
        let mut cache = PriceCache::new();
        let fp = cache.price(&table, &cluster, &model, &out.plan, DEFAULT_POLICY);
        let int8 = CodecSpec::uniform(Codec::Int8);
        let cp =
            cache.price_codec(&table, &cluster, &model, &out.plan, DEFAULT_POLICY, &int8);
        // Different codecs on the same (plan, policy) are distinct
        // entries: no false hit, and the prices genuinely differ.
        assert_eq!(cache.hits(), 0);
        assert!(cp.bytes_on_network < fp.bytes_on_network);
        // Re-pricing each spec hits its own memo exactly.
        let fp2 = cache.price(&table, &cluster, &model, &out.plan, DEFAULT_POLICY);
        let cp2 =
            cache.price_codec(&table, &cluster, &model, &out.plan, DEFAULT_POLICY, &int8);
        assert_eq!(cache.hits(), 2);
        assert_eq!(fp2.bytes_on_network, fp.bytes_on_network);
        assert_eq!(cp2.bytes_on_network, cp.bytes_on_network);
    }

    #[test]
    fn kp_bounds_inflight_microbatches() {
        // 1F1B with K_p must never hold more than K_p activations.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kp0: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kp0 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let sim_ours = simulate_round(&table, &cluster, &model, &mk(3));
        assert!(sim_ours.peak_inflight[0] <= 3);
        let sim_gpipe = simulate_round(&table, &cluster, &model, &mk(8));
        assert!(sim_gpipe.peak_inflight[0] > 3, "gpipe should buffer more");
        assert!(sim_gpipe.peak_memory[0] > sim_ours.peak_memory[0]);
    }

    #[test]
    fn gpipe_policy_equals_kp_saturated_default() {
        // Two routes to fill-drain: the GPipe policy, or 1F1B with
        // K_p >= M.  Same IR semantics, same price.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kp0: usize, kp1: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kp0 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: kp1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let saturated = mk(8, 8);
        let via_kp = simulate_round(&table, &cluster, &model, &saturated);
        let gp_sched = Schedule::for_sim(&mk(1, 1), &model, &GpipeFillDrain);
        gp_sched.validate().unwrap();
        let via_policy = price_schedule(&gp_sched, &table, &cluster, &model, &mk(1, 1));
        assert_eq!(via_kp.round_latency, via_policy.round_latency);
        assert_eq!(via_kp.peak_inflight, via_policy.peak_inflight);
    }

    #[test]
    fn gpipe_memory_grows_with_m_but_ours_does_not() {
        // Fig. 15(b): O(M) vs O(K_p) activation residency.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |m: usize, kp: usize| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: m,
        };
        let ours_m8 = simulate_round(&table, &cluster, &model, &mk(8, 3));
        let ours_m32 = simulate_round(&table, &cluster, &model, &mk(32, 3));
        assert_eq!(ours_m8.peak_inflight[0], ours_m32.peak_inflight[0]);
        let gpipe_m8 = simulate_round(&table, &cluster, &model, &mk(8, 8));
        let gpipe_m32 = simulate_round(&table, &cluster, &model, &mk(32, 32));
        assert!(gpipe_m32.peak_inflight[0] > gpipe_m8.peak_inflight[0]);
    }

    #[test]
    fn zero_bubble_strictly_beats_1f1b_on_heterogeneous_chain() {
        // The reference heterogeneous cluster fixture: env C's NX
        // (device 0) feeds a Nano (device 3) that owns the larger layer
        // slice — the classic setup where 1F1B's upstream drain idles
        // waiting for downstream gradients.  ZB-H1 sends each
        // input-gradient as soon as its half-backward finishes and
        // fills the drain gaps with deferred weight-grad work, so the
        // observed round makespan must be *strictly* lower while total
        // per-device compute is conserved.
        use crate::schedule::{OneFOneBKp, ZeroBubbleH1};
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0], alloc: vec![8], kp: 3 },
                Stage { layers: (nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let one_sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        let zb_sched = Schedule::for_sim(&plan, &model, &ZeroBubbleH1);
        zb_sched.validate().unwrap();
        let one = price_schedule(&one_sched, &table, &cluster, &model, &plan);
        let zb = price_schedule(&zb_sched, &table, &cluster, &model, &plan);
        assert!(
            zb.round_latency < one.round_latency,
            "zb-h1 {} !< 1f1b {}",
            zb.round_latency,
            one.round_latency
        );
        // Splitting conserves compute (B + W = full backward) and the
        // 1F1B activation window.
        for d in [0usize, 3] {
            assert!(
                (zb.busy[d] - one.busy[d]).abs() < 1e-9 * one.busy[d].max(1e-12),
                "device {d}: zb busy {} vs 1f1b {}",
                zb.busy[d],
                one.busy[d]
            );
        }
        assert_eq!(zb.peak_inflight, one.peak_inflight);
        assert_eq!(zb.bytes_on_network, one.bytes_on_network);
    }

    #[test]
    fn async_pipe_strictly_beats_zero_bubble_on_heterogeneous_chain() {
        // Same env-C NX -> Nano chain as the ZB-H1 test.  ZB-H1 fills
        // the drain with deferred weight-grad work but still pays the
        // fill and the round barrier every round; bounded staleness
        // removes the barrier entirely — in steady state round r+1's
        // warm-up forwards run inside round r's drain — so with
        // per-device compute conserved, both the per-round latency and
        // the pipeline bubble ratio must be *strictly* lower.
        use crate::schedule::{AsyncPipe, OneFOneBKp, ZeroBubbleH1};
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0], alloc: vec![8], kp: 3 },
                Stage { layers: (nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        let asy = price_policy(&table, &cluster, &model, &plan, &AsyncPipe { max_staleness: 2 });
        let zb = price_policy(&table, &cluster, &model, &plan, &ZeroBubbleH1);
        let one = price_policy(&table, &cluster, &model, &plan, &OneFOneBKp);
        assert_eq!(asy.rounds_priced, ASYNC_STEADY_ROUNDS);
        assert_eq!(zb.rounds_priced, 1);
        assert!(
            asy.round_latency < zb.round_latency,
            "async {} !< zb-h1 {}",
            asy.round_latency,
            zb.round_latency
        );
        assert!(
            asy.round_bubble_ratio < zb.round_bubble_ratio,
            "async bubble {} !< zb-h1 bubble {}",
            asy.round_bubble_ratio,
            zb.round_bubble_ratio
        );
        // ... and transitively below plain 1F1B on both metrics.
        assert!(asy.round_latency < one.round_latency);
        assert!(asy.round_bubble_ratio < one.round_bubble_ratio);
        // Steady-state normalisation conserves per-device compute and
        // per-round network volume.
        for d in [0usize, 3] {
            assert!(
                (asy.busy[d] - one.busy[d]).abs() < 1e-9 * one.busy[d].max(1e-12),
                "device {d}: async busy {} vs 1f1b {}",
                asy.busy[d],
                one.busy[d]
            );
        }
        assert_eq!(asy.bytes_on_network, one.bytes_on_network);
        // The widened window shows up as extra in-flight residency,
        // bounded by K_p + sigma.
        assert!(asy.peak_inflight[0] > one.peak_inflight[0]);
        assert!(asy.peak_inflight[0] <= 3 + 2);
        assert!(asy.peak_memory[0] > one.peak_memory[0], "stash copies must be charged");
    }

    #[test]
    fn interleaved_prices_like_1f1b_on_symmetric_micros() {
        // In the sample-sharded sim every micro is identical, so the
        // chunk-major permutation must not change the makespan — the
        // policy's value is its schedule shape, not sim throughput.
        use crate::schedule::{Interleaved, OneFOneBKp};
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mut plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: 1 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        };
        plan.apply_default_kp();
        let il_sched = Schedule::for_sim(&plan, &model, &Interleaved { virtual_per_device: 2 });
        il_sched.validate().unwrap();
        let il = price_schedule(&il_sched, &table, &cluster, &model, &plan);
        let one = price_schedule(
            &Schedule::for_sim(&plan, &model, &OneFOneBKp),
            &table,
            &cluster,
            &model,
            &plan,
        );
        assert!((il.round_latency - one.round_latency).abs() < 1e-9 * one.round_latency);
        assert_eq!(il.peak_inflight, one.peak_inflight);
    }

    #[test]
    fn kp_one_serialises_stages() {
        // K_p = 1 for all stages means only one stage active at a time:
        // throughput strictly below the K_p policy pipeline.
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |kps: [usize; 2]| Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: kps[0] },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: kps[1] },
            ],
            microbatch: 8,
            num_micro: 16,
        };
        let serial = simulate_round(&table, &cluster, &model, &mk([1, 1]));
        let ours = simulate_round(&table, &cluster, &model, &mk([3, 1]));
        assert!(
            ours.throughput > serial.throughput,
            "ours {} vs serial {}",
            ours.throughput,
            serial.throughput
        );
    }

    #[test]
    fn more_microbatches_amortise_bubbles() {
        let (cluster, model, table) = fixture("A");
        let nl = model.num_layers();
        let mk = |m: usize| {
            let mut p = Plan {
                stages: vec![
                    Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: 1 },
                    Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![8], kp: 1 },
                ],
                microbatch: 8,
                num_micro: m,
            };
            p.apply_default_kp();
            p
        };
        let s4 = simulate_round(&table, &cluster, &model, &mk(4));
        let s32 = simulate_round(&table, &cluster, &model, &mk(32));
        assert!(s32.throughput > s4.throughput);
    }

    #[test]
    fn heterogeneous_alloc_beats_equal_split_in_sim() {
        // End-to-end: Alg. 1's allocation must beat a naive equal split
        // when the group mixes NX and Nano.
        let cluster = ClusterSpec::env("C", 100.0).unwrap();
        let model = zoo::mobilenet_v2();
        let table = ProfileTable::new(&cluster, &model);
        let nl = model.num_layers();
        let equal = Plan {
            stages: vec![Stage {
                layers: (0, nl),
                devices: vec![0, 3], // NX + Nano
                alloc: vec![8, 8],
                kp: 1,
            }],
            microbatch: 16,
            num_micro: 8,
        };
        let mut skewed = equal.clone();
        skewed.stages[0].alloc = vec![13, 3];
        let sim_eq = simulate_round(&table, &cluster, &model, &equal);
        let sim_sk = simulate_round(&table, &cluster, &model, &skewed);
        assert!(
            sim_sk.throughput > sim_eq.throughput,
            "skewed {} vs equal {}",
            sim_sk.throughput,
            sim_eq.throughput
        );
    }
}
