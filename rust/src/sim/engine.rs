//! Discrete-event machinery: a time-ordered event queue and serialised
//! point-to-point links.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::ClusterSpec;

/// Min-heap event queue over f64 timestamps with stable FIFO tiebreak.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

struct Entry<E> {
    time: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, time: f64, ev: E) {
        debug_assert!(time.is_finite(), "event at non-finite time");
        self.heap.push(Entry { time, seq: self.seq, ev });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.time, e.ev))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// Serialised directed links: one transfer at a time per (from, to)
/// pair; messages queue FIFO behind the link's busy horizon.
pub struct LinkSet {
    n: usize,
    /// bytes/s per directed pair (flattened n x n).
    bandwidth: Vec<f64>,
    latency: f64,
    /// busy-until horizon per directed pair.
    free_at: Vec<f64>,
}

impl LinkSet {
    pub fn new(cluster: &ClusterSpec) -> LinkSet {
        let n = cluster.n();
        let mut bandwidth = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                bandwidth[i * n + j] = cluster.bandwidth[i][j];
            }
        }
        LinkSet { n, bandwidth, latency: cluster.latency_s, free_at: vec![0.0; n * n] }
    }

    /// Enqueue a transfer at `now`; returns the arrival time.
    pub fn send(&mut self, from: usize, to: usize, bytes: u64, now: f64) -> f64 {
        if from == to {
            return now; // local, free
        }
        let k = from * self.n + to;
        let start = self.free_at[k].max(now);
        let dur = bytes as f64 / self.bandwidth[k];
        let end = start + dur;
        self.free_at[k] = end;
        end + self.latency
    }

    /// The link's current horizon (for tests / diagnostics).
    pub fn free_at(&self, from: usize, to: usize) -> f64 {
        self.free_at[from * self.n + to]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;

    #[test]
    fn queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, "b");
        q.push(1.0, "a");
        q.push(2.0, "c");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((2.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn queue_len() {
        let mut q = EventQueue::new();
        assert_eq!(q.len(), 0);
        q.push(1.0, ());
        q.push(2.0, ());
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn link_serialises_messages() {
        let cluster = ClusterSpec::nanos(2, 100.0); // 12.5 MB/s
        let mut links = LinkSet::new(&cluster);
        let bw = 100.0 * 1e6 / 8.0;
        let t1 = links.send(0, 1, (bw as u64) / 2, 0.0); // 0.5 s transfer
        let t2 = links.send(0, 1, (bw as u64) / 2, 0.0); // queues behind
        assert!((t1 - (0.5 + cluster.latency_s)).abs() < 1e-9);
        assert!((t2 - (1.0 + cluster.latency_s)).abs() < 1e-9);
        // Reverse direction is independent.
        let t3 = links.send(1, 0, (bw as u64) / 2, 0.0);
        assert!((t3 - t1).abs() < 1e-12);
    }

    #[test]
    fn local_send_is_free() {
        let cluster = ClusterSpec::nanos(2, 100.0);
        let mut links = LinkSet::new(&cluster);
        assert_eq!(links.send(0, 0, 1_000_000, 5.0), 5.0);
    }

    #[test]
    fn later_send_starts_later() {
        let cluster = ClusterSpec::nanos(2, 100.0);
        let mut links = LinkSet::new(&cluster);
        links.send(0, 1, 12_500_000, 0.0); // busy until 1.0
        let t = links.send(0, 1, 12_500_000, 3.0); // idle again at 3.0
        assert!((t - (4.0 + cluster.latency_s)).abs() < 1e-9);
        assert!((links.free_at(0, 1) - 4.0).abs() < 1e-9);
    }
}
