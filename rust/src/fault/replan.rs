//! Layer-wise lightweight pipeline re-planning (paper §3.4, module 3).
//!
//! Instead of re-running Algorithm 2 (heavy), the failed device's
//! workload is re-absorbed by a *minor adjustment of the layer
//! partitioning points*: total model FLOPs are redistributed across the
//! surviving stages in proportion to their remaining computing capacity
//! sum(v_d), and only the layers whose owner changed migrate —
//! concurrently between adjacent stages (Fig. 9 right).

use anyhow::{bail, Result};

use crate::config::{ClusterSpec, TrainConfig};
use crate::model::ModelDesc;
use crate::planner::alloc::{allocate_microbatch, AllocOpts};
use crate::planner::plan::{Plan, Stage};
use crate::profiler::ProfileTable;

/// One migration flow: weights of layers moving between device groups.
#[derive(Debug, Clone)]
pub struct Migration {
    pub from_stage_old: usize,
    pub to_stage_new: usize,
    pub bytes: u64,
}

/// Result of the lightweight re-planning.
#[derive(Debug, Clone)]
pub struct Replan {
    pub plan: Plan,
    pub migrations: Vec<Migration>,
    /// Layers that lived on the failed device and must come from the
    /// backup instead of a live peer (bytes).
    pub restored_bytes: u64,
    /// Wall-clock of the re-planning computation itself.
    pub compute_s: f64,
}

/// Compute the new plan after `failed_dev` exits.
pub fn lightweight_replan(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    failed_dev: usize,
) -> Result<Replan> {
    let t0 = std::time::Instant::now();
    let nl = model.num_layers();

    // ---- survivors: drop the failed device; drop empty stages ------------
    let failed_stage = plan
        .stages
        .iter()
        .position(|s| s.devices.contains(&failed_dev));
    let Some(failed_stage) = failed_stage else {
        bail!("device {failed_dev} is not part of the plan");
    };
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new(); // (old stage idx, devices)
    for (p, s) in plan.stages.iter().enumerate() {
        let devs: Vec<usize> = s
            .devices
            .iter()
            .copied()
            .filter(|&d| d != failed_dev)
            .collect();
        if !devs.is_empty() {
            groups.push((p, devs));
        }
    }
    if groups.is_empty() {
        bail!("no surviving devices");
    }

    // ---- FLOPs-proportional layer redistribution --------------------------
    // Capacity of each surviving group = sum of whole-model v_d.
    let caps: Vec<f64> = groups
        .iter()
        .map(|(_, devs)| {
            devs.iter()
                .map(|&d| table.capacity(d, 0, nl, plan.microbatch))
                .sum::<f64>()
        })
        .collect();
    let cap_sum: f64 = caps.iter().sum();
    let total_flops: f64 = model.flops_range(0, nl);

    let g_cnt = groups.len();
    let mut bounds = vec![0usize; g_cnt + 1];
    bounds[g_cnt] = nl;
    let mut acc = 0.0;
    let mut layer = 0usize;
    for s in 0..g_cnt - 1 {
        let target = total_flops * caps[s] / cap_sum;
        let mut stage_acc = 0.0;
        // at least one layer per stage, and leave enough for the rest
        let reserve = g_cnt - 1 - s;
        while layer < nl - reserve
            && (stage_acc < target || layer == bounds[s])
        {
            stage_acc += model.flops_range(layer, layer + 1);
            layer += 1;
            if stage_acc >= target && layer > bounds[s] {
                break;
            }
        }
        bounds[s + 1] = layer;
        acc += stage_acc;
    }
    let _ = acc;

    // ---- assemble the new plan --------------------------------------------
    let m = plan.num_micro;
    let mut stages = Vec::with_capacity(g_cnt);
    for (s, (_, devs)) in groups.iter().enumerate() {
        let (i, j) = (bounds[s], bounds[s + 1]);
        let kp = (2 * (g_cnt - s)).saturating_sub(1).clamp(1, m);
        let alloc = allocate_microbatch(
            table,
            cluster,
            model,
            cfg,
            i,
            j,
            devs,
            plan.microbatch,
            kp,
            AllocOpts::default(),
        )?;
        stages.push(Stage { layers: (i, j), devices: devs.clone(), alloc, kp });
    }
    let new_plan = Plan { stages, microbatch: plan.microbatch, num_micro: m };
    new_plan.validate(model, cluster)?;

    // ---- migration accounting ----------------------------------------------
    // owner(layer) old vs new; layers owned by the failed single-device
    // stage count as restored-from-backup bytes.
    let old_owner = |l: usize| plan.stages.iter().position(|s| l >= s.layers.0 && l < s.layers.1);
    let new_owner =
        |l: usize| new_plan.stages.iter().position(|s| l >= s.layers.0 && l < s.layers.1);
    let failed_was_single = plan.stages[failed_stage].devices.len() == 1;
    let mut restored_bytes = 0u64;
    let mut flows: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    for l in 0..nl {
        let o = old_owner(l).unwrap();
        let n = new_owner(l).unwrap();
        let bytes = model.weight_bytes_range(l, l + 1);
        if o == failed_stage && failed_was_single {
            restored_bytes += bytes;
        } else {
            // same group still holding it?
            let same = groups.get(n).map(|(old_idx, _)| *old_idx == o).unwrap_or(false);
            if !same {
                *flows.entry((o, n)).or_insert(0) += bytes;
            }
        }
    }
    let migrations = flows
        .into_iter()
        .map(|((o, n), bytes)| Migration { from_stage_old: o, to_stage_new: n, bytes })
        .collect();

    Ok(Replan {
        plan: new_plan,
        migrations,
        restored_bytes,
        compute_s: t0.elapsed().as_secs_f64(),
    })
}

/// Migration wall-clock: flows run concurrently (paper: concurrent
/// layer migration between adjacent stages), so the slowest flow
/// bounds the time; restored bytes come from the backup node link.
pub fn migration_time(
    cluster: &ClusterSpec,
    replan: &Replan,
    plan_old: &Plan,
    backup_bandwidth: f64,
) -> f64 {
    let mut worst: f64 = 0.0;
    for mig in &replan.migrations {
        let from = &plan_old.stages[mig.from_stage_old].devices;
        let to = &replan.plan.stages[mig.to_stage_new].devices;
        let bw = cluster.group_bandwidth(from, to);
        worst = worst.max(mig.bytes as f64 / bw);
    }
    if replan.restored_bytes > 0 {
        worst = worst.max(replan.restored_bytes as f64 / backup_bandwidth);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::planner::dp::{plan_hpp, PlannerConfig};

    fn setup() -> (ClusterSpec, ModelDesc, ProfileTable, TrainConfig, Plan) {
        let cluster = ClusterSpec::env("D", 100.0).unwrap(); // TX2 + 3 Nano
        let model = zoo::efficientnet_b1();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let plan = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default())
            .unwrap()
            .plan;
        (cluster, model, table, cfg, plan)
    }

    #[test]
    fn replan_covers_model_without_failed_device() {
        let (cluster, model, table, cfg, plan) = setup();
        for &failed in &plan.devices() {
            let r = lightweight_replan(&table, &cluster, &model, &cfg, &plan, failed).unwrap();
            r.plan.validate(&model, &cluster).unwrap();
            assert!(!r.plan.devices().contains(&failed), "failed dev kept");
            assert_eq!(
                r.plan.devices().len(),
                plan.devices().len() - 1,
                "exactly one device removed"
            );
        }
    }

    #[test]
    fn replan_is_fast() {
        // The whole point: re-planning must be orders of magnitude
        // cheaper than Algorithm 2.
        let (cluster, model, table, cfg, plan) = setup();
        let failed = plan.devices()[0];
        let r = lightweight_replan(&table, &cluster, &model, &cfg, &plan, failed).unwrap();
        assert!(r.compute_s < 0.5, "replan took {}s", r.compute_s);
    }

    #[test]
    fn migration_moves_less_than_full_model() {
        let (cluster, model, table, cfg, plan) = setup();
        let failed = *plan.devices().last().unwrap();
        let r = lightweight_replan(&table, &cluster, &model, &cfg, &plan, failed).unwrap();
        let moved: u64 = r.migrations.iter().map(|m| m.bytes).sum::<u64>() + r.restored_bytes;
        assert!(
            moved < model.total_weight_bytes(),
            "moved {moved} of {} total",
            model.total_weight_bytes()
        );
        let t = migration_time(&cluster, &r, &plan, 12.5e6);
        assert!(t.is_finite() && t >= 0.0);
    }

    #[test]
    fn unknown_device_rejected() {
        let (cluster, model, table, cfg, plan) = setup();
        assert!(lightweight_replan(&table, &cluster, &model, &cfg, &plan, 999).is_err());
    }

    #[test]
    fn capacity_weighted_cuts_give_bigger_share_to_faster_group() {
        let (cluster, model, table, cfg, plan) = setup();
        // Fail a Nano; the TX2's stage should carry more FLOPs than any
        // single-Nano stage afterwards.
        let nano = *plan.devices().last().unwrap();
        let r = lightweight_replan(&table, &cluster, &model, &cfg, &plan, nano).unwrap();
        let flops: Vec<f64> = r
            .plan
            .stages
            .iter()
            .map(|s| model.flops_range(s.layers.0, s.layers.1) * 1.0)
            .collect();
        // sanity: every stage carries some work
        assert!(flops.iter().all(|&f| f > 0.0));
    }
}
