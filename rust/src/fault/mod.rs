//! Fault-tolerant pipeline replay (paper §3.4): heartbeat failure
//! detection, topology-driven model replication, FLOPs-based
//! layer-wise lightweight re-planning, and the heavy-rescheduling
//! baseline it is compared against (Figs. 16-17).

pub mod churn;
pub mod heartbeat;
pub mod replan;
pub mod replay;
pub mod replication;

pub use churn::{ChurnEvent, ChurnTrace, TimedEvent};
pub use heartbeat::{DriftDetector, HeartbeatCfg, HeartbeatMonitor, Liveness, StragglerCfg};
pub use replan::{lightweight_replan, migration_time, Replan};
pub use replay::{
    degraded_reschedule, heavy_reschedule, heavy_reschedule_incremental, lightweight_replay,
    rejoin_replan, throughput_timeline, RecoveryReport,
};
pub use replication::{replication_plan, BackupStore, RecoverySource, ReplicationPlan};
