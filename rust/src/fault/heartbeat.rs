//! Heartbeat-guided failure detection (paper §3.4, module 1).
//!
//! Every device periodically emits a heartbeat to the coordinator;
//! missing `miss_threshold` consecutive beats marks the device
//! *suspected*, after which the coordinator sends a probe and waits one
//! RTT for confirmation.  The monitor here is real (wall-clock based,
//! usable by the live engine); `detection_time` is the closed form the
//! Fig. 16 recovery model charges.

use std::collections::{BTreeMap, BTreeSet};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Detector configuration.
#[derive(Debug, Clone, Copy)]
pub struct HeartbeatCfg {
    /// Interval between heartbeats.
    pub interval: Duration,
    /// Consecutive missed beats before suspicion.
    pub miss_threshold: u32,
    /// Probe round-trip allowance for confirmation.
    pub probe_rtt: Duration,
}

impl Default for HeartbeatCfg {
    fn default() -> Self {
        HeartbeatCfg {
            interval: Duration::from_millis(500),
            miss_threshold: 2,
            probe_rtt: Duration::from_millis(100),
        }
    }
}

impl HeartbeatCfg {
    /// Minimum beat interval a configuration may use: below this, OS
    /// scheduling jitter on a loaded CI runner is the same order as
    /// the interval and a healthy worker gets declared dead — the
    /// validated floor is what lets integration tests run *tight*
    /// timings without flaking.
    pub const MIN_INTERVAL: Duration = Duration::from_millis(10);

    /// Explicit timing constructor — validated, so a mistyped
    /// zero-interval or zero-threshold config fails at build time
    /// instead of spinning or never detecting.
    pub fn new(interval: Duration, miss_threshold: u32, probe_rtt: Duration) -> Result<Self> {
        let cfg = HeartbeatCfg { interval, miss_threshold, probe_rtt };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Tight-but-safe timing for tests and CI fault injection:
    /// detection in ~0.17 s instead of the default ~1.1 s.  Respects
    /// the validated floor with 5x headroom.
    pub fn tight() -> HeartbeatCfg {
        HeartbeatCfg {
            interval: Duration::from_millis(50),
            miss_threshold: 3,
            probe_rtt: Duration::from_millis(20),
        }
    }

    /// Validate the timing: a positive interval at or above
    /// [`Self::MIN_INTERVAL`], at least one tolerated miss, and a
    /// probe allowance that does not dwarf the silence deadline (a
    /// probe slower than the whole deadline means the "detection"
    /// would mostly measure the probe).
    pub fn validate(&self) -> Result<()> {
        if self.interval < Self::MIN_INTERVAL {
            bail!(
                "heartbeat interval {:?} is below the {:?} floor (CI scheduling \
                 jitter would fake device deaths)",
                self.interval,
                Self::MIN_INTERVAL
            );
        }
        if self.miss_threshold == 0 {
            bail!("heartbeat miss_threshold must be >= 1 (0 suspects a live device instantly)");
        }
        if self.probe_rtt > self.deadline() {
            bail!(
                "probe_rtt {:?} exceeds the silence deadline {:?} (interval x misses)",
                self.probe_rtt,
                self.deadline()
            );
        }
        Ok(())
    }

    /// The silence deadline after which a device is suspected:
    /// `interval * miss_threshold`.  The live monitor and the closed
    /// form both derive from this, so sim and RPC agree on detection
    /// latency by construction.
    pub fn deadline(&self) -> Duration {
        self.interval * self.miss_threshold
    }

    /// Expected worst-case detection latency: the device dies right
    /// after beating, so `miss_threshold` intervals elapse before
    /// suspicion, plus the probe RTT.
    pub fn detection_time(&self) -> f64 {
        self.deadline().as_secs_f64() + self.probe_rtt.as_secs_f64()
    }
}

/// Device liveness as seen by the monitor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    Alive,
    Suspected,
    Confirmed, // confirmed failed
}

/// Wall-clock heartbeat monitor (coordinator side).
#[derive(Debug)]
pub struct HeartbeatMonitor {
    cfg: HeartbeatCfg,
    last_beat: BTreeMap<usize, Instant>,
    confirmed: BTreeMap<usize, bool>,
}

impl HeartbeatMonitor {
    pub fn new(cfg: HeartbeatCfg, devices: &[usize]) -> HeartbeatMonitor {
        let now = Instant::now();
        HeartbeatMonitor {
            cfg,
            last_beat: devices.iter().map(|&d| (d, now)).collect(),
            confirmed: devices.iter().map(|&d| (d, false)).collect(),
        }
    }

    /// Record a heartbeat from `device`.
    pub fn beat(&mut self, device: usize) {
        if let Some(t) = self.last_beat.get_mut(&device) {
            *t = Instant::now();
        }
        if let Some(c) = self.confirmed.get_mut(&device) {
            *c = false;
        }
    }

    /// Probe response confirms death (no response within RTT).
    pub fn confirm_failure(&mut self, device: usize) {
        if let Some(c) = self.confirmed.get_mut(&device) {
            *c = true;
        }
    }

    /// Current liveness classification of `device`.
    pub fn liveness(&self, device: usize) -> Liveness {
        if self.confirmed.get(&device).copied().unwrap_or(false) {
            return Liveness::Confirmed;
        }
        let Some(last) = self.last_beat.get(&device) else {
            return Liveness::Confirmed;
        };
        let deadline = self.cfg.deadline();
        if last.elapsed() > deadline {
            Liveness::Suspected
        } else {
            Liveness::Alive
        }
    }

    /// All devices currently suspected (need a probe).
    pub fn suspects(&self) -> Vec<usize> {
        self.last_beat
            .keys()
            .copied()
            .filter(|&d| self.liveness(d) == Liveness::Suspected)
            .collect()
    }

    /// Re-baseline liveness for a (re-)assignment: every listed device
    /// gets a fresh deadline anchored at *now* and a cleared suspicion
    /// flag; devices not listed are forgotten.  Without this, a worker
    /// re-Assigned after a mid-round recovery — or a rejoined worker —
    /// inherits the deadline of its previous incarnation (last beat
    /// long before the re-assign) and can be re-declared dead before
    /// its first new heartbeat lands.
    pub fn rearm(&mut self, devices: &[usize]) {
        let now = Instant::now();
        self.last_beat = devices.iter().map(|&d| (d, now)).collect();
        self.confirmed = devices.iter().map(|&d| (d, false)).collect();
    }
}

/// Timing-drift straggler detection: the failure mode that never trips
/// a heartbeat.  A straggler keeps beating — what changes is its
/// per-round compute wall-clock.  The detector keeps a per-device
/// baseline from the first `warmup_rounds` observations and flags a
/// device only after `consecutive` rounds in a row beyond
/// `drift_factor` × its baseline, so ordinary noise (CI jitter, a
/// transient GC pause) never fires it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerCfg {
    /// Rounds used to establish each device's baseline (no detection
    /// can fire during warm-up).
    pub warmup_rounds: usize,
    /// Flag when a round's compute time exceeds this multiple of the
    /// device's baseline mean.
    pub drift_factor: f64,
    /// Consecutive drifted rounds required before the detector fires —
    /// the noise gate.
    pub consecutive: usize,
}

impl Default for StragglerCfg {
    fn default() -> Self {
        StragglerCfg { warmup_rounds: 3, drift_factor: 2.0, consecutive: 2 }
    }
}

impl StragglerCfg {
    pub fn validate(&self) -> Result<()> {
        if self.warmup_rounds == 0 {
            bail!("straggler warmup_rounds must be >= 1 (no baseline, no drift)");
        }
        if self.drift_factor <= 1.0 {
            bail!(
                "straggler drift_factor must be > 1.0 (got {}): at or below 1 every \
                 healthy round drifts",
                self.drift_factor
            );
        }
        if self.consecutive == 0 {
            bail!("straggler consecutive must be >= 1");
        }
        Ok(())
    }
}

/// Per-round compute-time drift detector (driver side).  Feed it every
/// device's round compute wall-clock; [`DriftDetector::observe`]
/// returns the drift ratio the first time a device crosses into the
/// flagged state.
#[derive(Debug, Clone, Default)]
pub struct DriftDetector {
    cfg: StragglerCfg,
    /// Per-device (sum, count) of warm-up observations.
    base: BTreeMap<usize, (f64, usize)>,
    /// Per-device run of consecutive drifted rounds.
    streak: BTreeMap<usize, usize>,
    flagged: BTreeSet<usize>,
}

impl DriftDetector {
    pub fn new(cfg: StragglerCfg) -> DriftDetector {
        DriftDetector { cfg, ..DriftDetector::default() }
    }

    /// The device's warm-up baseline mean, once established.
    pub fn baseline(&self, device: usize) -> Option<f64> {
        match self.base.get(&device) {
            Some(&(sum, n)) if n >= self.cfg.warmup_rounds => Some(sum / n as f64),
            _ => None,
        }
    }

    pub fn is_flagged(&self, device: usize) -> bool {
        self.flagged.contains(&device)
    }

    /// Record one round's compute time for `device`.  Returns
    /// `Some(ratio)` exactly when this observation completes
    /// `consecutive` drifted rounds and newly flags the device.
    pub fn observe(&mut self, device: usize, compute_s: f64) -> Option<f64> {
        let Some(baseline) = self.baseline(device) else {
            let e = self.base.entry(device).or_insert((0.0, 0));
            e.0 += compute_s;
            e.1 += 1;
            return None;
        };
        if baseline <= 0.0 || self.flagged.contains(&device) {
            return None;
        }
        let ratio = compute_s / baseline;
        if ratio >= self.cfg.drift_factor {
            let streak = self.streak.entry(device).or_insert(0);
            *streak += 1;
            if *streak >= self.cfg.consecutive {
                self.flagged.insert(device);
                return Some(ratio);
            }
        } else {
            self.streak.remove(&device);
        }
        None
    }

    /// Forget everything about `device` — called after a reschedule
    /// re-assigns it (a new stage means a new, legitimate baseline).
    pub fn reset(&mut self, device: usize) {
        self.base.remove(&device);
        self.streak.remove(&device);
        self.flagged.remove(&device);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> HeartbeatCfg {
        HeartbeatCfg {
            interval: Duration::from_millis(20),
            miss_threshold: 2,
            probe_rtt: Duration::from_millis(5),
        }
    }

    #[test]
    fn alive_while_beating() {
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0, 1]);
        for _ in 0..3 {
            std::thread::sleep(Duration::from_millis(15));
            m.beat(0);
            m.beat(1);
        }
        assert_eq!(m.liveness(0), Liveness::Alive);
        assert!(m.suspects().is_empty());
    }

    #[test]
    fn silent_device_becomes_suspected_then_confirmed() {
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0, 1]);
        std::thread::sleep(Duration::from_millis(15));
        m.beat(1); // device 0 goes silent
        std::thread::sleep(Duration::from_millis(35));
        assert_eq!(m.liveness(0), Liveness::Suspected);
        assert_eq!(m.suspects(), vec![0]);
        m.confirm_failure(0);
        assert_eq!(m.liveness(0), Liveness::Confirmed);
    }

    #[test]
    fn beat_clears_suspicion() {
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0]);
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(m.liveness(0), Liveness::Suspected);
        m.beat(0);
        assert_eq!(m.liveness(0), Liveness::Alive);
    }

    #[test]
    fn unknown_device_is_confirmed_dead() {
        let m = HeartbeatMonitor::new(fast_cfg(), &[0]);
        assert_eq!(m.liveness(42), Liveness::Confirmed);
    }

    #[test]
    fn detection_time_formula() {
        let cfg = HeartbeatCfg {
            interval: Duration::from_millis(500),
            miss_threshold: 2,
            probe_rtt: Duration::from_millis(100),
        };
        assert!((cfg.detection_time() - 1.1).abs() < 1e-9);
        assert_eq!(cfg.deadline(), Duration::from_secs(1));
    }

    #[test]
    fn rearm_resets_deadlines_for_reassigned_workers() {
        // The mid-round-recovery bug: a re-Assigned (or rejoined)
        // worker must not inherit its previous incarnation's deadline.
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0, 1]);
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(m.liveness(0), Liveness::Suspected);
        m.confirm_failure(1);
        assert_eq!(m.liveness(1), Liveness::Confirmed);
        // Re-assign devices 0 and 1 plus a rejoined device 2: all three
        // start Alive with a fresh deadline and no suspicion carryover.
        m.rearm(&[0, 1, 2]);
        for d in [0, 1, 2] {
            assert_eq!(m.liveness(d), Liveness::Alive, "device {d} after rearm");
        }
        assert!(m.suspects().is_empty());
        // The fresh deadline still expires normally afterwards.
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(m.liveness(2), Liveness::Suspected);
    }

    /// Deterministic LCG in [-1, 1] for seeded timing noise.
    fn noise(seed: &mut u64) -> f64 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((*seed >> 33) as f64 / (1u64 << 31) as f64) - 1.0
    }

    #[test]
    fn drift_detector_ignores_noisy_but_healthy_traces() {
        // ±25% seeded jitter around a 1 s round never reaches the 2x
        // drift factor: no false positives over a long healthy trace.
        let mut det = DriftDetector::new(StragglerCfg::default());
        let mut seed = 42u64;
        for _ in 0..200 {
            for dev in 0..3usize {
                let t = 1.0 + 0.25 * noise(&mut seed);
                assert_eq!(det.observe(dev, t), None, "false positive on device {dev}");
            }
        }
        for dev in 0..3usize {
            assert!(!det.is_flagged(dev));
            let b = det.baseline(dev).unwrap();
            assert!((b - 1.0).abs() < 0.3, "baseline {b} drifted from the trace mean");
        }
    }

    #[test]
    fn drift_detector_fires_after_consecutive_drifted_rounds() {
        let cfg = StragglerCfg { warmup_rounds: 3, drift_factor: 2.0, consecutive: 2 };
        let mut det = DriftDetector::new(cfg);
        for _ in 0..3 {
            assert_eq!(det.observe(7, 1.0), None); // warm-up
        }
        // First drifted round: streak 1 of 2 — not yet.
        assert_eq!(det.observe(7, 3.0), None);
        // A healthy round in between resets the streak (noise gate).
        assert_eq!(det.observe(7, 1.1), None);
        assert_eq!(det.observe(7, 3.0), None);
        let ratio = det.observe(7, 3.0).expect("second consecutive drifted round fires");
        assert!(ratio >= 2.0);
        assert!(det.is_flagged(7));
        // Once flagged, stays flagged silently until reset.
        assert_eq!(det.observe(7, 5.0), None);
        det.reset(7);
        assert!(!det.is_flagged(7));
        assert_eq!(det.baseline(7), None, "reset starts a fresh baseline");
    }

    #[test]
    fn drift_detector_threshold_is_sharp() {
        // Just under the factor never fires; just over does (after the
        // consecutive gate) — detection is threshold-driven, not
        // magnitude-driven.
        let cfg = StragglerCfg { warmup_rounds: 2, drift_factor: 2.0, consecutive: 2 };
        let mut under = DriftDetector::new(cfg);
        let mut over = DriftDetector::new(cfg);
        for det in [&mut under, &mut over] {
            det.observe(0, 1.0);
            det.observe(0, 1.0);
        }
        for _ in 0..50 {
            assert_eq!(under.observe(0, 1.99), None);
        }
        assert!(!under.is_flagged(0));
        assert_eq!(over.observe(0, 2.01), None);
        assert!(over.observe(0, 2.01).is_some());
    }

    #[test]
    fn straggler_cfg_validation() {
        StragglerCfg::default().validate().unwrap();
        assert!(StragglerCfg { warmup_rounds: 0, ..Default::default() }.validate().is_err());
        assert!(StragglerCfg { drift_factor: 1.0, ..Default::default() }.validate().is_err());
        assert!(StragglerCfg { consecutive: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn validation_rejects_degenerate_timings() {
        // Interval below the CI-jitter floor.
        assert!(HeartbeatCfg::new(Duration::from_millis(1), 2, Duration::ZERO).is_err());
        // Zero misses tolerated: instant false suspicion.
        assert!(HeartbeatCfg::new(Duration::from_millis(50), 0, Duration::ZERO).is_err());
        // Probe slower than the whole silence deadline.
        assert!(HeartbeatCfg::new(
            Duration::from_millis(50),
            2,
            Duration::from_millis(500)
        )
        .is_err());
        // Defaults and the tight preset both validate.
        HeartbeatCfg::default().validate().unwrap();
        HeartbeatCfg::tight().validate().unwrap();
        assert!(HeartbeatCfg::tight().detection_time() < 0.25);
        assert!(HeartbeatCfg::tight().detection_time() < HeartbeatCfg::default().detection_time());
    }
}
