//! Heartbeat-guided failure detection (paper §3.4, module 1).
//!
//! Every device periodically emits a heartbeat to the coordinator;
//! missing `miss_threshold` consecutive beats marks the device
//! *suspected*, after which the coordinator sends a probe and waits one
//! RTT for confirmation.  The monitor here is real (wall-clock based,
//! usable by the live engine); `detection_time` is the closed form the
//! Fig. 16 recovery model charges.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Detector configuration.
#[derive(Debug, Clone, Copy)]
pub struct HeartbeatCfg {
    /// Interval between heartbeats.
    pub interval: Duration,
    /// Consecutive missed beats before suspicion.
    pub miss_threshold: u32,
    /// Probe round-trip allowance for confirmation.
    pub probe_rtt: Duration,
}

impl Default for HeartbeatCfg {
    fn default() -> Self {
        HeartbeatCfg {
            interval: Duration::from_millis(500),
            miss_threshold: 2,
            probe_rtt: Duration::from_millis(100),
        }
    }
}

impl HeartbeatCfg {
    /// Minimum beat interval a configuration may use: below this, OS
    /// scheduling jitter on a loaded CI runner is the same order as
    /// the interval and a healthy worker gets declared dead — the
    /// validated floor is what lets integration tests run *tight*
    /// timings without flaking.
    pub const MIN_INTERVAL: Duration = Duration::from_millis(10);

    /// Explicit timing constructor — validated, so a mistyped
    /// zero-interval or zero-threshold config fails at build time
    /// instead of spinning or never detecting.
    pub fn new(interval: Duration, miss_threshold: u32, probe_rtt: Duration) -> Result<Self> {
        let cfg = HeartbeatCfg { interval, miss_threshold, probe_rtt };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Tight-but-safe timing for tests and CI fault injection:
    /// detection in ~0.17 s instead of the default ~1.1 s.  Respects
    /// the validated floor with 5x headroom.
    pub fn tight() -> HeartbeatCfg {
        HeartbeatCfg {
            interval: Duration::from_millis(50),
            miss_threshold: 3,
            probe_rtt: Duration::from_millis(20),
        }
    }

    /// Validate the timing: a positive interval at or above
    /// [`Self::MIN_INTERVAL`], at least one tolerated miss, and a
    /// probe allowance that does not dwarf the silence deadline (a
    /// probe slower than the whole deadline means the "detection"
    /// would mostly measure the probe).
    pub fn validate(&self) -> Result<()> {
        if self.interval < Self::MIN_INTERVAL {
            bail!(
                "heartbeat interval {:?} is below the {:?} floor (CI scheduling \
                 jitter would fake device deaths)",
                self.interval,
                Self::MIN_INTERVAL
            );
        }
        if self.miss_threshold == 0 {
            bail!("heartbeat miss_threshold must be >= 1 (0 suspects a live device instantly)");
        }
        if self.probe_rtt > self.deadline() {
            bail!(
                "probe_rtt {:?} exceeds the silence deadline {:?} (interval x misses)",
                self.probe_rtt,
                self.deadline()
            );
        }
        Ok(())
    }

    /// The silence deadline after which a device is suspected:
    /// `interval * miss_threshold`.  The live monitor and the closed
    /// form both derive from this, so sim and RPC agree on detection
    /// latency by construction.
    pub fn deadline(&self) -> Duration {
        self.interval * self.miss_threshold
    }

    /// Expected worst-case detection latency: the device dies right
    /// after beating, so `miss_threshold` intervals elapse before
    /// suspicion, plus the probe RTT.
    pub fn detection_time(&self) -> f64 {
        self.deadline().as_secs_f64() + self.probe_rtt.as_secs_f64()
    }
}

/// Device liveness as seen by the monitor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    Alive,
    Suspected,
    Confirmed, // confirmed failed
}

/// Wall-clock heartbeat monitor (coordinator side).
#[derive(Debug)]
pub struct HeartbeatMonitor {
    cfg: HeartbeatCfg,
    last_beat: BTreeMap<usize, Instant>,
    confirmed: BTreeMap<usize, bool>,
}

impl HeartbeatMonitor {
    pub fn new(cfg: HeartbeatCfg, devices: &[usize]) -> HeartbeatMonitor {
        let now = Instant::now();
        HeartbeatMonitor {
            cfg,
            last_beat: devices.iter().map(|&d| (d, now)).collect(),
            confirmed: devices.iter().map(|&d| (d, false)).collect(),
        }
    }

    /// Record a heartbeat from `device`.
    pub fn beat(&mut self, device: usize) {
        if let Some(t) = self.last_beat.get_mut(&device) {
            *t = Instant::now();
        }
        if let Some(c) = self.confirmed.get_mut(&device) {
            *c = false;
        }
    }

    /// Probe response confirms death (no response within RTT).
    pub fn confirm_failure(&mut self, device: usize) {
        if let Some(c) = self.confirmed.get_mut(&device) {
            *c = true;
        }
    }

    /// Current liveness classification of `device`.
    pub fn liveness(&self, device: usize) -> Liveness {
        if self.confirmed.get(&device).copied().unwrap_or(false) {
            return Liveness::Confirmed;
        }
        let Some(last) = self.last_beat.get(&device) else {
            return Liveness::Confirmed;
        };
        let deadline = self.cfg.deadline();
        if last.elapsed() > deadline {
            Liveness::Suspected
        } else {
            Liveness::Alive
        }
    }

    /// All devices currently suspected (need a probe).
    pub fn suspects(&self) -> Vec<usize> {
        self.last_beat
            .keys()
            .copied()
            .filter(|&d| self.liveness(d) == Liveness::Suspected)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> HeartbeatCfg {
        HeartbeatCfg {
            interval: Duration::from_millis(20),
            miss_threshold: 2,
            probe_rtt: Duration::from_millis(5),
        }
    }

    #[test]
    fn alive_while_beating() {
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0, 1]);
        for _ in 0..3 {
            std::thread::sleep(Duration::from_millis(15));
            m.beat(0);
            m.beat(1);
        }
        assert_eq!(m.liveness(0), Liveness::Alive);
        assert!(m.suspects().is_empty());
    }

    #[test]
    fn silent_device_becomes_suspected_then_confirmed() {
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0, 1]);
        std::thread::sleep(Duration::from_millis(15));
        m.beat(1); // device 0 goes silent
        std::thread::sleep(Duration::from_millis(35));
        assert_eq!(m.liveness(0), Liveness::Suspected);
        assert_eq!(m.suspects(), vec![0]);
        m.confirm_failure(0);
        assert_eq!(m.liveness(0), Liveness::Confirmed);
    }

    #[test]
    fn beat_clears_suspicion() {
        let mut m = HeartbeatMonitor::new(fast_cfg(), &[0]);
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(m.liveness(0), Liveness::Suspected);
        m.beat(0);
        assert_eq!(m.liveness(0), Liveness::Alive);
    }

    #[test]
    fn unknown_device_is_confirmed_dead() {
        let m = HeartbeatMonitor::new(fast_cfg(), &[0]);
        assert_eq!(m.liveness(42), Liveness::Confirmed);
    }

    #[test]
    fn detection_time_formula() {
        let cfg = HeartbeatCfg {
            interval: Duration::from_millis(500),
            miss_threshold: 2,
            probe_rtt: Duration::from_millis(100),
        };
        assert!((cfg.detection_time() - 1.1).abs() < 1e-9);
        assert_eq!(cfg.deadline(), Duration::from_secs(1));
    }

    #[test]
    fn validation_rejects_degenerate_timings() {
        // Interval below the CI-jitter floor.
        assert!(HeartbeatCfg::new(Duration::from_millis(1), 2, Duration::ZERO).is_err());
        // Zero misses tolerated: instant false suspicion.
        assert!(HeartbeatCfg::new(Duration::from_millis(50), 0, Duration::ZERO).is_err());
        // Probe slower than the whole silence deadline.
        assert!(HeartbeatCfg::new(
            Duration::from_millis(50),
            2,
            Duration::from_millis(500)
        )
        .is_err());
        // Defaults and the tight preset both validate.
        HeartbeatCfg::default().validate().unwrap();
        HeartbeatCfg::tight().validate().unwrap();
        assert!(HeartbeatCfg::tight().detection_time() < 0.25);
        assert!(HeartbeatCfg::tight().detection_time() < HeartbeatCfg::default().detection_time());
    }
}
