//! Churn traces: elastic membership as data.
//!
//! A [`ChurnTrace`] generalises the single-exit `FaultSpec` into an
//! ordered list of timed membership events — device exits, rejoins of
//! restarted workers, compute slowdowns (the straggler injection the
//! drift detector catches) and link degradations.  The trace itself is
//! pure data: both execution backends interpret it — `SimBackend` on a
//! deterministic event clock, `RpcBackend` against real worker
//! processes — and the CLI parses one from `--churn`.
//!
//! Grammar (comma-separated, each event suffixed with `@<round>`):
//!
//! ```text
//! exit:<dev>@<round>            device <dev> exits before <round>
//! join:<dev>@<round>            device <dev> rejoins before <round>
//! slow:<dev>:<factor>@<round>   device <dev> slows by <factor>x
//! link:<a>-<b>:<mbps>@<round>   link a<->b degrades to <mbps> Mbps
//! ```
//!
//! e.g. `--churn exit:2@1,join:2@3` or `--churn slow:1:3.0@2`.

use std::str::FromStr;

use anyhow::{bail, Context, Result};

use crate::config::ClusterSpec;

/// One membership event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnEvent {
    /// The device's process dies (detected by heartbeat silence).
    Exit { device: usize },
    /// A previously-exited cluster device reconnects (its restarted
    /// `asteroid-worker` listens on the same address) and is
    /// re-Assigned; the plan re-expands through the join fast path.
    Join { device: usize },
    /// The device's compute degrades by `factor` (> 1.0) — it keeps
    /// heartbeating; only the timing-drift straggler detector sees it.
    Slowdown { device: usize, factor: f64 },
    /// The link between `a` and `b` degrades to `mbps` Mbps.
    LinkDegrade { a: usize, b: usize, mbps: f64 },
}

impl ChurnEvent {
    /// Stable event-kind name (what reports serialise).
    pub fn kind(&self) -> &'static str {
        match self {
            ChurnEvent::Exit { .. } => "exit",
            ChurnEvent::Join { .. } => "join",
            ChurnEvent::Slowdown { .. } => "slowdown",
            ChurnEvent::LinkDegrade { .. } => "link-degrade",
        }
    }

    /// The device the event targets (for `LinkDegrade`: endpoint `a`).
    pub fn device(&self) -> usize {
        match *self {
            ChurnEvent::Exit { device }
            | ChurnEvent::Join { device }
            | ChurnEvent::Slowdown { device, .. } => device,
            ChurnEvent::LinkDegrade { a, .. } => a,
        }
    }
}

/// One trace entry: the event fires *before* round `round` executes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedEvent {
    pub round: usize,
    pub event: ChurnEvent,
}

/// An ordered, timed membership-event trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChurnTrace {
    pub events: Vec<TimedEvent>,
}

impl ChurnTrace {
    pub fn new() -> ChurnTrace {
        ChurnTrace::default()
    }

    pub fn exit(mut self, round: usize, device: usize) -> ChurnTrace {
        self.events.push(TimedEvent { round, event: ChurnEvent::Exit { device } });
        self
    }

    pub fn join(mut self, round: usize, device: usize) -> ChurnTrace {
        self.events.push(TimedEvent { round, event: ChurnEvent::Join { device } });
        self
    }

    pub fn slowdown(mut self, round: usize, device: usize, factor: f64) -> ChurnTrace {
        self.events.push(TimedEvent { round, event: ChurnEvent::Slowdown { device, factor } });
        self
    }

    pub fn link_degrade(mut self, round: usize, a: usize, b: usize, mbps: f64) -> ChurnTrace {
        self.events.push(TimedEvent { round, event: ChurnEvent::LinkDegrade { a, b, mbps } });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Canonical `--churn` form of the trace.
    pub fn describe(&self) -> String {
        self.events
            .iter()
            .map(|te| match te.event {
                ChurnEvent::Exit { device } => format!("exit:{device}@{}", te.round),
                ChurnEvent::Join { device } => format!("join:{device}@{}", te.round),
                ChurnEvent::Slowdown { device, factor } => {
                    format!("slow:{device}:{factor}@{}", te.round)
                }
                ChurnEvent::LinkDegrade { a, b, mbps } => {
                    format!("link:{a}-{b}:{mbps}@{}", te.round)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Validate the trace against a cluster, the initially planned
    /// device set, and the run length: rounds must be non-decreasing
    /// and inside the run, every device a cluster device, slowdown
    /// factors > 1, bandwidths > 0 — and membership must stay
    /// consistent step by step (exits target active devices, joins
    /// target exited ones, at least one device always remains).
    pub fn validate(&self, cluster: &ClusterSpec, planned: &[usize], rounds: usize) -> Result<()> {
        if self.events.is_empty() {
            bail!("empty churn trace (drop .churn() instead)");
        }
        let mut active: Vec<usize> = planned.to_vec();
        let mut last_round = 0usize;
        for (idx, te) in self.events.iter().enumerate() {
            let at = format!("churn event {idx} ({})", te.event.kind());
            if te.round < last_round {
                bail!("{at}: rounds must be non-decreasing ({} < {last_round})", te.round);
            }
            if te.round >= rounds {
                bail!("{at}: round {} is outside the {rounds}-round run", te.round);
            }
            last_round = te.round;
            match te.event {
                ChurnEvent::Exit { device } => {
                    let pos = active
                        .iter()
                        .position(|&d| d == device)
                        .with_context(|| format!("{at}: device {device} is not active"))?;
                    active.remove(pos);
                    if active.is_empty() {
                        bail!("{at}: trace leaves no active devices");
                    }
                }
                ChurnEvent::Join { device } => {
                    if device >= cluster.n() {
                        bail!("{at}: device {device} is not a cluster device");
                    }
                    if active.contains(&device) {
                        bail!("{at}: device {device} is already active");
                    }
                    active.push(device);
                }
                ChurnEvent::Slowdown { device, factor } => {
                    if !active.contains(&device) {
                        bail!("{at}: device {device} is not active");
                    }
                    if !(factor > 1.0) || !factor.is_finite() {
                        bail!("{at}: slowdown factor must be a finite value > 1 (got {factor})");
                    }
                }
                ChurnEvent::LinkDegrade { a, b, mbps } => {
                    if a >= cluster.n() || b >= cluster.n() {
                        bail!("{at}: link {a}-{b} names a non-cluster device");
                    }
                    if a == b {
                        bail!("{at}: link {a}-{b} is not a link");
                    }
                    if !(mbps > 0.0) || !mbps.is_finite() {
                        bail!("{at}: link bandwidth must be a finite value > 0 (got {mbps} Mbps)");
                    }
                }
            }
        }
        Ok(())
    }
}

impl FromStr for ChurnTrace {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ChurnTrace> {
        let mut trace = ChurnTrace::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (body, round) = part
                .rsplit_once('@')
                .with_context(|| format!("churn event {part:?}: missing @<round>"))?;
            let round: usize = round
                .parse()
                .with_context(|| format!("churn event {part:?}: bad round {round:?}"))?;
            let mut fields = body.split(':');
            let kind = fields.next().unwrap_or_default();
            let rest: Vec<&str> = fields.collect();
            let event = match (kind, rest.as_slice()) {
                ("exit", [dev]) => ChurnEvent::Exit { device: parse_dev(part, dev)? },
                ("join", [dev]) => ChurnEvent::Join { device: parse_dev(part, dev)? },
                ("slow", [dev, factor]) => ChurnEvent::Slowdown {
                    device: parse_dev(part, dev)?,
                    factor: factor
                        .parse()
                        .with_context(|| format!("churn event {part:?}: bad factor"))?,
                },
                ("link", [ab, mbps]) => {
                    let (a, b) = ab
                        .split_once('-')
                        .with_context(|| format!("churn event {part:?}: want link:<a>-<b>"))?;
                    ChurnEvent::LinkDegrade {
                        a: parse_dev(part, a)?,
                        b: parse_dev(part, b)?,
                        mbps: mbps
                            .parse()
                            .with_context(|| format!("churn event {part:?}: bad Mbps"))?,
                    }
                }
                _ => bail!(
                    "churn event {part:?}: want exit:<dev>@r, join:<dev>@r, \
                     slow:<dev>:<factor>@r or link:<a>-<b>:<mbps>@r"
                ),
            };
            trace.events.push(TimedEvent { round, event });
        }
        if trace.is_empty() {
            bail!("empty churn trace {s:?}");
        }
        Ok(trace)
    }
}

fn parse_dev(part: &str, s: &str) -> Result<usize> {
    s.parse().with_context(|| format!("churn event {part:?}: bad device id {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let t: ChurnTrace = "exit:2@1,join:2@3,slow:1:3.5@4,link:0-1:20@5".parse().unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t.events[0], TimedEvent { round: 1, event: ChurnEvent::Exit { device: 2 } });
        assert_eq!(t.events[1], TimedEvent { round: 3, event: ChurnEvent::Join { device: 2 } });
        assert_eq!(
            t.events[2],
            TimedEvent { round: 4, event: ChurnEvent::Slowdown { device: 1, factor: 3.5 } }
        );
        assert_eq!(
            t.events[3],
            TimedEvent { round: 5, event: ChurnEvent::LinkDegrade { a: 0, b: 1, mbps: 20.0 } }
        );
        // describe() round-trips through the parser.
        let again: ChurnTrace = t.describe().parse().unwrap();
        assert_eq!(again, t);
    }

    #[test]
    fn parser_rejects_malformed_events() {
        for bad in [
            "",
            "exit:2",          // missing round
            "exit@1",          // missing device
            "slow:1@2",        // missing factor
            "link:0:20@1",     // missing endpoint pair
            "flood:1@2",       // unknown kind
            "exit:x@1",        // non-numeric device
        ] {
            assert!(bad.parse::<ChurnTrace>().is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn validate_tracks_membership() {
        let cluster = ClusterSpec::env("A", 100.0).unwrap();
        let planned: Vec<usize> = (0..cluster.n()).collect();
        // Exit then rejoin of the same device is fine.
        ChurnTrace::new().exit(1, 2).join(2, 2).validate(&cluster, &planned, 4).unwrap();
        // Joining an active device is not.
        assert!(ChurnTrace::new().join(1, 2).validate(&cluster, &planned, 4).is_err());
        // Exiting an inactive device is not.
        assert!(ChurnTrace::new()
            .exit(1, 2)
            .exit(2, 2)
            .validate(&cluster, &planned, 4)
            .is_err());
        // Rounds must not run backwards or past the run.
        assert!(ChurnTrace::new().exit(2, 1).join(1, 1).validate(&cluster, &planned, 4).is_err());
        assert!(ChurnTrace::new().exit(9, 1).validate(&cluster, &planned, 4).is_err());
        // Slowdown factors <= 1 and zero-bandwidth links are rejected.
        assert!(ChurnTrace::new()
            .slowdown(1, 0, 1.0)
            .validate(&cluster, &planned, 4)
            .is_err());
        assert!(ChurnTrace::new()
            .link_degrade(1, 0, 1, 0.0)
            .validate(&cluster, &planned, 4)
            .is_err());
        // The trace may not exit everyone.
        let mut t = ChurnTrace::new();
        for d in 0..cluster.n() {
            t = t.exit(1, d);
        }
        assert!(t.validate(&cluster, &planned, 4).is_err());
    }
}
