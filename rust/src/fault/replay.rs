//! Fault-tolerant pipeline replay vs heavy rescheduling (paper §3.4,
//! Figs. 16-17).
//!
//! *Lightweight replay* (ours): heartbeat detection -> restore lost
//! weights from the backup topology -> FLOPs-based layer re-planning ->
//! concurrent boundary-layer migration -> resume.
//!
//! *Heavy rescheduling* (baseline): aggregate every stage model at the
//! coordinator, re-run the full Algorithm-2 planner on the most
//! powerful remaining device, redistribute all weights per the new
//! configuration.
//!
//! Recovery *ordering* is not re-derived here: both mechanisms build
//! the pre- and post-failure `schedule::Schedule`s and [`diff`] them —
//! the diff names the micro-batches whose in-flight activations died
//! with the failed device (the replay re-injection set) and which
//! surviving devices actually need a new script.

use anyhow::Result;

use crate::codec::CodecSpec;
use crate::comm::SyncMode;
use crate::config::{ClusterSpec, TrainConfig};
use crate::fault::heartbeat::HeartbeatCfg;
use crate::fault::replan::{lightweight_replan, migration_time};
use crate::fault::replication::{replication_plan, restore_time};
use crate::model::ModelDesc;
use crate::planner::dp::{
    plan_hpp, plan_hpp_incremental, plan_hpp_incremental_join, plan_hpp_subset, DpState,
    PlannerConfig,
};
use crate::planner::plan::Plan;
use crate::profiler::ProfileTable;
use crate::schedule::{diff, Schedule, SchedulePolicy, ScheduleDiff};

/// How much slower the planner re-run is in the paper's heavy-
/// rescheduling baseline than our in-process run: the baseline re-plans
/// *on the strongest remaining edge device* in the authors' Python
/// implementation (Table 7: 480 s for EfficientNet-B1 on a Jetson NX),
/// whereas we measure a Rust planner on the host.  The factor combines
/// Rust-vs-Python (~50x) with host-core-vs-Carmel-core (~6x); see
/// DESIGN.md §Substitutions.
pub const EDGE_PLANNER_SLOWDOWN: f64 = 300.0;

/// Breakdown of one recovery.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    pub mechanism: &'static str,
    pub detection_s: f64,
    pub restore_s: f64,
    pub replan_s: f64,
    pub migration_s: f64,
    pub new_plan: Plan,
    pub new_throughput: f64,
    /// Micro-batches whose in-flight activations died with the failed
    /// device, in re-injection order — computed by diffing the pre-
    /// and post-failure schedules (old schedule's warm-up window on
    /// the failed device), never by re-implementing the K_p rules.
    pub replay_micros: Vec<usize>,
    /// Devices whose per-round script actually changed and need a new
    /// dispatch (from the same schedule diff).
    pub retasked_devices: Vec<usize>,
    /// Pipeline refill latency of the post-recovery schedule (the new
    /// schedule's warm-up).  Reported separately from `total_s` —
    /// both mechanisms pay it identically inside the first resumed
    /// round, so Fig. 16/17 comparisons exclude it.
    pub refill_s: f64,
}

impl RecoveryReport {
    pub fn total_s(&self) -> f64 {
        self.detection_s + self.restore_s + self.replan_s + self.migration_s
    }
}

/// Lightweight pipeline replay after `failed_dev` exits.  `policy` is
/// the session's round schedule policy: the recovery diff and the
/// re-priced post-failure round must describe the timeline the session
/// actually executes, not a hardcoded default.  `codec` and `sync` are
/// the session's wire codec and collective topology for the same
/// reason — the re-priced round's throughput must reflect the
/// compressed bytes and the AllReduce shape the recovered pipeline
/// actually moves.
#[allow(clippy::too_many_arguments)]
pub fn lightweight_replay(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    failed_dev: usize,
    hb: &HeartbeatCfg,
    policy: &'static dyn SchedulePolicy,
    codec: &CodecSpec,
    sync: SyncMode,
) -> Result<RecoveryReport> {
    let repl = replication_plan(model, plan);
    let failed_stage = plan
        .stages
        .iter()
        .position(|s| s.devices.contains(&failed_dev))
        .ok_or_else(|| anyhow::anyhow!("device {failed_dev} not in plan"))?;
    let group: Vec<usize> = (0..cluster.n()).filter(|&d| d != failed_dev).collect();
    let bw = cluster.min_bandwidth(&group);

    let restore_s = restore_time(model, plan, &repl, failed_stage, bw);
    let r = lightweight_replan(table, cluster, model, cfg, plan, failed_dev)?;
    let migration_s = migration_time(cluster, &r, plan, bw);
    let sdiff = recovery_diff(plan, &r.plan, policy);
    let sim = price_round(table, cluster, model, &r.plan, policy, codec, sync);

    Ok(RecoveryReport {
        mechanism: "lightweight",
        detection_s: hb.detection_time(),
        restore_s,
        replan_s: r.compute_s,
        migration_s,
        new_throughput: sim.throughput,
        new_plan: r.plan,
        replay_micros: sdiff.replay_micros,
        retasked_devices: sdiff.retasked,
        refill_s: sim.fill_latency,
    })
}

/// Diff the pre- and post-failure round schedules built with the
/// session's policy: the single source of recovery ordering for both
/// mechanisms.  The policy matters — a fill-drain session has its
/// whole micro load in flight at the failure point, so its replay set
/// is far larger than 1F1B's K_p window; diffing a default-policy
/// timeline would replay micros nobody lost and skip micros nobody
/// saved.  Uses the *runtime* (round-robin) sharding so `replay_micros`
/// names the micro-batches that were actually resident on the failed
/// device in the executing pipeline — under sample sharding every
/// device touches every micro, which would over-approximate the replay
/// set on replicated stages.
fn recovery_diff(
    old_plan: &Plan,
    new_plan: &Plan,
    policy: &dyn SchedulePolicy,
) -> ScheduleDiff {
    let old = Schedule::for_runtime(old_plan, policy);
    let new = Schedule::for_runtime(new_plan, policy);
    diff(&old, &new)
}

/// Price one round of `plan` under the session's policy (what
/// `new_throughput`/`refill_s` report — the schedule the recovered
/// pipeline actually runs).  Routed through `sim::price`, so a
/// bounded-staleness session's recovered throughput is its steady-state
/// rate and the AllReduce term matches the session's collective
/// topology, same as everywhere else in the stack.
fn price_round(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    plan: &Plan,
    policy: &dyn SchedulePolicy,
    codec: &CodecSpec,
    sync: SyncMode,
) -> crate::sim::SimResult {
    crate::sim::price(
        &crate::sim::PriceRequest::new(table, cluster, model, plan)
            .policy(policy)
            .codec(*codec)
            .sync(sync),
    )
}

/// Heavy rescheduling baseline after `failed_dev` exits.
#[allow(clippy::too_many_arguments)]
pub fn heavy_reschedule(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    failed_dev: usize,
    hb: &HeartbeatCfg,
    policy: &'static dyn SchedulePolicy,
    codec: &CodecSpec,
    sync: SyncMode,
) -> Result<RecoveryReport> {
    // Surviving sub-cluster (device ids preserved by masking memory of
    // the failed device to zero is messy — rebuild a cluster without it
    // and map ids).
    let keep: Vec<usize> = (0..cluster.n()).filter(|&d| d != failed_dev).collect();
    let mut sub = cluster.clone();
    sub.devices = keep.iter().map(|&d| cluster.devices[d].clone()).collect();
    for (new_id, d) in sub.devices.iter_mut().enumerate() {
        d.id = new_id;
    }
    sub.bandwidth = keep
        .iter()
        .map(|&a| keep.iter().map(|&b| cluster.bandwidth[a][b]).collect())
        .collect();

    let sub_table = ProfileTable::new(&sub, model);
    let outcome = plan_hpp(
        &sub_table,
        &sub,
        model,
        cfg,
        &PlannerConfig { policy, codec: *codec, sync, ..PlannerConfig::default() },
    )?;

    // Weight traffic: every stage model flows to the coordinator, then
    // the full model flows back out — all through one device's links,
    // so the transfers serialise.
    let bw = cluster.min_bandwidth(&keep);
    let p_bytes = model.total_weight_bytes() as f64;
    let gather_s = p_bytes / bw;
    let redistribute_s = p_bytes / bw;

    // Map the sub-cluster plan back onto original device ids.
    let mut new_plan = outcome.plan.clone();
    for s in &mut new_plan.stages {
        for d in &mut s.devices {
            *d = keep[*d];
        }
    }
    let sdiff = recovery_diff(plan, &new_plan, policy);
    let sim = price_round(table, cluster, model, &new_plan, policy, codec, sync);

    Ok(RecoveryReport {
        mechanism: "heavy",
        detection_s: hb.detection_time(),
        restore_s: gather_s,
        replan_s: outcome.planning_time_s * EDGE_PLANNER_SLOWDOWN,
        migration_s: redistribute_s,
        new_throughput: sim.throughput,
        new_plan,
        replay_micros: sdiff.replay_micros,
        retasked_devices: sdiff.retasked,
        refill_s: sim.fill_latency,
    })
}

/// Heavy rescheduling through the planner's incremental fast path:
/// the same full-quality Algorithm-2 replan as [`heavy_reschedule`],
/// but reusing the session's previous [`DpState`] so only DP cells and
/// stage prices the removal actually invalidated are recomputed — the
/// plan is bit-for-bit what a from-scratch rebuild would emit
/// (`plan_hpp_incremental`'s contract).  Unlike the baseline there is
/// no sub-cluster remap: planning runs in *original device-id space*
/// over the survivors, and the returned state is ready for the next
/// failure.  With `prev = None` (or a state from a different
/// model/cluster/config) it degrades to a full subset rebuild — still
/// in original id space, still returning a reusable state.
///
/// The weight gather/redistribute costs and the `EDGE_PLANNER_SLOWDOWN`
/// scaling mirror [`heavy_reschedule`], so Fig. 16/17-style comparisons
/// isolate exactly the replan-time savings.
#[allow(clippy::too_many_arguments)]
pub fn heavy_reschedule_incremental(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    failed_dev: usize,
    hb: &HeartbeatCfg,
    policy: &'static dyn SchedulePolicy,
    codec: &CodecSpec,
    sync: SyncMode,
    prev: Option<&DpState>,
) -> Result<(RecoveryReport, DpState)> {
    let keep: Vec<usize> = (0..cluster.n()).filter(|&d| d != failed_dev).collect();
    let pc = PlannerConfig { policy, codec: *codec, sync, ..PlannerConfig::default() };
    let (outcome, state) = match prev {
        Some(p) if p.order().contains(&failed_dev) => {
            plan_hpp_incremental(p, table, cluster, model, cfg, &pc, failed_dev)?
        }
        _ => plan_hpp_subset(table, cluster, model, cfg, &pc, &keep)?,
    };

    let bw = cluster.min_bandwidth(&keep);
    let p_bytes = model.total_weight_bytes() as f64;
    let gather_s = p_bytes / bw;
    let redistribute_s = p_bytes / bw;

    let new_plan = outcome.plan;
    let sdiff = recovery_diff(plan, &new_plan, policy);
    let sim = price_round(table, cluster, model, &new_plan, policy, codec, sync);

    Ok((
        RecoveryReport {
            mechanism: "heavy-incremental",
            detection_s: hb.detection_time(),
            restore_s: gather_s,
            replan_s: outcome.planning_time_s * EDGE_PLANNER_SLOWDOWN,
            migration_s: redistribute_s,
            new_throughput: sim.throughput,
            new_plan,
            replay_micros: sdiff.replay_micros,
            retasked_devices: sdiff.retasked,
            refill_s: sim.fill_latency,
        },
        state,
    ))
}

/// Per-layer weight traffic a plan change implies, split into bytes
/// that flow *to* `joined` (warm-start restore from the driver
/// checkpoint) and bytes that move between surviving devices (boundary
/// migration).  Ownership is compared stage-wise: a layer whose device
/// group is unchanged costs nothing.
fn weight_move_split(model: &ModelDesc, old: &Plan, new: &Plan, joined: Option<usize>) -> (u64, u64) {
    let owner = |p: &Plan, l: usize| {
        p.stages
            .iter()
            .find(|s| l >= s.layers.0 && l < s.layers.1)
            .map(|s| s.devices.clone())
    };
    let mut to_joined = 0u64;
    let mut moved = 0u64;
    for l in 0..model.num_layers() {
        let old_owner = owner(old, l);
        let new_owner = owner(new, l);
        if old_owner == new_owner {
            continue;
        }
        let b = model.weight_bytes_range(l, l + 1);
        let lands_on_joined =
            matches!((joined, &new_owner), (Some(j), Some(devs)) if devs.contains(&j));
        if lands_on_joined {
            to_joined += b;
        } else {
            moved += b;
        }
    }
    (to_joined, moved)
}

/// Replan after a previously-exited device *rejoins* (its restarted
/// `asteroid-worker` reconnected).  The symmetric twin of
/// [`heavy_reschedule_incremental`]: with the session's surviving
/// [`DpState`] the planner re-expands through
/// [`plan_hpp_incremental_join`] — reusing every DP cell whose
/// device-order suffix the insertion left intact — and the result is
/// bit-for-bit what a full rebuild over the grown set would emit.
/// Without a usable state it degrades to a full subset rebuild.
///
/// Cost model: `detection_s` is zero (a join is announced by the
/// reconnect handshake, not detected by heartbeat silence — the RPC
/// driver overwrites it with the measured reconnect wall-clock);
/// `restore_s` is the warm-start weights flowing from the driver
/// checkpoint to the joined device; `migration_s` is the boundary
/// weights that shift between survivors as stages re-balance.  Unlike
/// the heavy baseline, `replan_s` is the *measured* planner time with
/// no `EDGE_PLANNER_SLOWDOWN` scaling — rejoin is our mechanism and
/// runs in-process on the driver, not on the strongest edge device.
#[allow(clippy::too_many_arguments)]
pub fn rejoin_replan(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    joined: usize,
    policy: &'static dyn SchedulePolicy,
    codec: &CodecSpec,
    sync: SyncMode,
    prev: Option<&DpState>,
) -> Result<(RecoveryReport, DpState)> {
    let active = plan.devices();
    if active.contains(&joined) {
        anyhow::bail!("device {joined} is already in the plan");
    }
    if joined >= cluster.n() {
        anyhow::bail!("device {joined} is not a cluster device");
    }
    let mut union = active.clone();
    union.push(joined);
    union.sort_unstable();

    let pc = PlannerConfig { policy, codec: *codec, sync, ..PlannerConfig::default() };
    // The previous state must cover exactly the surviving set for the
    // join fast path to re-expand it; anything else (stale state from
    // before an unrelated exit, no state at all) falls back to a full
    // subset rebuild — same plan, no cell reuse.
    let sorted = |mut v: Vec<usize>| {
        v.sort_unstable();
        v
    };
    let (outcome, state) = match prev {
        Some(p) if sorted(p.order().to_vec()) == active => {
            plan_hpp_incremental_join(p, table, cluster, model, cfg, &pc, joined)?
        }
        _ => plan_hpp_subset(table, cluster, model, cfg, &pc, &union)?,
    };

    let bw = cluster.min_bandwidth(&union);
    let new_plan = outcome.plan;
    let (restore_bytes, moved_bytes) = weight_move_split(model, plan, &new_plan, Some(joined));
    let sdiff = recovery_diff(plan, &new_plan, policy);
    let sim = price_round(table, cluster, model, &new_plan, policy, codec, sync);

    Ok((
        RecoveryReport {
            mechanism: "rejoin",
            detection_s: 0.0,
            restore_s: restore_bytes as f64 / bw,
            replan_s: outcome.planning_time_s,
            migration_s: moved_bytes as f64 / bw,
            new_throughput: sim.throughput,
            new_plan,
            replay_micros: sdiff.replay_micros,
            retasked_devices: sdiff.retasked,
            refill_s: sim.fill_latency,
        },
        state,
    ))
}

/// Full replan over the *current* membership after the cluster itself
/// degraded — a straggler derated a device's compute (`mechanism:
/// "straggler"`) or a link's bandwidth dropped (`"link-degrade"`).
/// `table`/`cluster` describe the degraded fleet; the previous
/// `DpState` cannot help because every stage price moved with the
/// hardware, so this is always a fresh subset DP (the returned state
/// seeds future incremental replans *on the degraded cluster*).
///
/// Nobody died: weights are resident, so there is no gather/restore —
/// only the boundary layers that shift between devices migrate.
/// `detection_s` is supplied by the caller (the drift detector's
/// observation window for stragglers, zero for driver-observed link
/// telemetry), and `replan_s` is the measured in-process planner time,
/// as in [`rejoin_replan`].
#[allow(clippy::too_many_arguments)]
pub fn degraded_reschedule(
    table: &ProfileTable,
    cluster: &ClusterSpec,
    model: &ModelDesc,
    cfg: &TrainConfig,
    plan: &Plan,
    mechanism: &'static str,
    detection_s: f64,
    policy: &'static dyn SchedulePolicy,
    codec: &CodecSpec,
    sync: SyncMode,
) -> Result<(RecoveryReport, DpState)> {
    let active = plan.devices();
    let pc = PlannerConfig { policy, codec: *codec, sync, ..PlannerConfig::default() };
    let (outcome, state) = plan_hpp_subset(table, cluster, model, cfg, &pc, &active)?;

    let bw = cluster.min_bandwidth(&active);
    let new_plan = outcome.plan;
    let (_, moved_bytes) = weight_move_split(model, plan, &new_plan, None);
    let sdiff = recovery_diff(plan, &new_plan, policy);
    let sim = price_round(table, cluster, model, &new_plan, policy, codec, sync);

    Ok((
        RecoveryReport {
            mechanism,
            detection_s,
            restore_s: 0.0,
            replan_s: outcome.planning_time_s,
            migration_s: moved_bytes as f64 / bw,
            new_throughput: sim.throughput,
            new_plan,
            replay_micros: sdiff.replay_micros,
            retasked_devices: sdiff.retasked,
            refill_s: sim.fill_latency,
        },
        state,
    ))
}

/// Fig. 17: throughput over a time window with a failure at `t_fail`.
/// Returns (time, samples/s) points sampled every `dt`.
pub fn throughput_timeline(
    before_tput: f64,
    recovery: &RecoveryReport,
    t_fail: f64,
    horizon: f64,
    dt: f64,
) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    let recover_at = t_fail + recovery.total_s();
    let mut t = 0.0;
    while t <= horizon {
        let tput = if t < t_fail {
            before_tput
        } else if t < recover_at {
            0.0 // pipeline stalled during recovery
        } else {
            recovery.new_throughput
        };
        out.push((t, tput));
        t += dt;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::model::zoo;
    use crate::schedule::{GpipeFillDrain, DEFAULT_POLICY};

    fn setup() -> (ClusterSpec, ModelDesc, ProfileTable, TrainConfig, Plan) {
        let cluster = ClusterSpec::env("D", 100.0).unwrap();
        let model = zoo::efficientnet_b1();
        let table = ProfileTable::new(&cluster, &model);
        let cfg = TrainConfig::new(256, 16);
        let plan = plan_hpp(&table, &cluster, &model, &cfg, &PlannerConfig::default())
            .unwrap()
            .plan;
        (cluster, model, table, cfg, plan)
    }

    #[test]
    fn lightweight_recovers_much_faster_than_heavy() {
        // Fig. 16/17's headline: lightweight replay is ~an order of
        // magnitude faster to recover.
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let mut best_ratio: f64 = 0.0;
        for &failed in &plan.devices() {
            let lite = lightweight_replay(
                &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                    &CodecSpec::default(), SyncMode::default(),
            )
            .unwrap();
            let heavy = heavy_reschedule(
                &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                    &CodecSpec::default(), SyncMode::default(),
            )
            .unwrap();
            let ratio = heavy.total_s() / lite.total_s();
            best_ratio = best_ratio.max(ratio);
            // Every scenario recovers at least 2x faster (wall-clock of
            // the measured planner varies with test-runner load) ...
            assert!(
                ratio > 2.0,
                "failed={failed}: heavy {} vs lite {}",
                heavy.total_s(),
                lite.total_s()
            );
        }
        // ... and the typical gap is much larger (paper: 14x).
        assert!(best_ratio > 4.0, "best ratio only {best_ratio}");
    }

    #[test]
    fn lightweight_throughput_close_to_heavy() {
        // ... while keeping ~90% of the re-planned throughput (§5.5).
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let failed = *plan.devices().last().unwrap();
        let lite = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        let heavy = heavy_reschedule(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        assert!(
            lite.new_throughput > 0.6 * heavy.new_throughput,
            "lite {} vs heavy {}",
            lite.new_throughput,
            heavy.new_throughput
        );
    }

    #[test]
    fn timeline_shape() {
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let failed = *plan.devices().last().unwrap();
        let lite = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        let tl = throughput_timeline(100.0, &lite, 10.0, 40.0, 1.0);
        assert_eq!(tl.len(), 41);
        assert_eq!(tl[0].1, 100.0);
        // stall right after the failure
        let stall = tl.iter().find(|&&(t, _)| t > 10.0 && t < 10.0 + lite.total_s());
        if let Some(&(_, tput)) = stall {
            assert_eq!(tput, 0.0);
        }
        // recovered by the end
        assert!(tl.last().unwrap().1 > 0.0);
    }

    #[test]
    fn replay_ordering_comes_from_schedule_diff() {
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let failed = plan.devices()[0];
        let lite = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        // The failed device's warm-up window is re-injected: micros
        // start at 0 and never exceed the stage's effective K_p.
        let stage = plan
            .stages
            .iter()
            .find(|s| s.devices.contains(&failed))
            .unwrap();
        assert!(!lite.replay_micros.is_empty());
        assert!(lite.replay_micros.len() <= stage.kp.min(plan.num_micro));
        assert_eq!(lite.replay_micros[0], 0);
        // Refill is a real but sub-round cost, excluded from total_s.
        assert!(lite.refill_s > 0.0);
        assert!(!lite.retasked_devices.contains(&failed));
        // Heavy rescheduling reports the same diff-derived fields.
        let heavy = heavy_reschedule(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        assert!(!heavy.replay_micros.is_empty());
        assert!(heavy.refill_s > 0.0);
    }

    #[test]
    fn gpipe_session_recovery_replays_its_whole_in_flight_load() {
        // Regression for the policy-blind diff: a fill-drain session
        // has *every* assigned micro in flight when the device dies
        // (its warm-up prefix is all of its forwards), so the replay
        // set must be the device's whole round-robin load — not the
        // 1F1B K_p window a DEFAULT_POLICY diff would report.
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let failed = plan.devices()[0];
        let one = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        let gp = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, &GpipeFillDrain,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        let stage = plan
            .stages
            .iter()
            .find(|s| s.devices.contains(&failed))
            .unwrap();
        let g = stage.devices.len();
        let slot = stage.devices.iter().position(|&d| d == failed).unwrap();
        let assigned = (0..plan.num_micro).filter(|m| m % g == slot).count();
        assert_eq!(gp.replay_micros.len(), assigned);
        assert!(
            gp.replay_micros.len() >= one.replay_micros.len(),
            "gpipe replay {} < 1f1b replay {}",
            gp.replay_micros.len(),
            one.replay_micros.len()
        );
        // The recovered round is priced under the session's policy.
        assert!(gp.new_throughput > 0.0 && gp.refill_s > 0.0);
    }

    #[test]
    fn async_session_recovery_replays_the_full_in_flight_window() {
        // A bounded-staleness session has K_p + sigma micros in flight
        // when a device dies — the schedule diff must re-inject that
        // whole widened window, not the 1F1B K_p prefix.
        use crate::schedule::AsyncPipe;
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let failed = plan.devices()[0];
        let one = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        static ASYNC2: AsyncPipe = AsyncPipe { max_staleness: 2 };
        let asy = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, &ASYNC2,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        let stage = plan
            .stages
            .iter()
            .find(|s| s.devices.contains(&failed))
            .unwrap();
        let g = stage.devices.len();
        let slot = stage.devices.iter().position(|&d| d == failed).unwrap();
        let assigned = (0..plan.num_micro).filter(|m| m % g == slot).count();
        // Warm-up prefix of the failed device's round-robin timeline
        // under the widened window (K_p + sigma forwards admitted
        // before its first backward), clamped to its assigned load.
        let window = (stage.kp + 2).min(assigned);
        assert_eq!(asy.replay_micros.len(), window);
        assert!(
            asy.replay_micros.len() >= one.replay_micros.len(),
            "async replay {} < 1f1b replay {}",
            asy.replay_micros.len(),
            one.replay_micros.len()
        );
        // The recovered round is priced at the async steady-state rate.
        assert!(asy.new_throughput > 0.0 && asy.refill_s > 0.0);
    }

    #[test]
    fn incremental_heavy_matches_baseline_heavy_plan() {
        // The fast path must not change *what* heavy rescheduling
        // plans — only how fast the planner gets there.  The baseline
        // plans on a remapped sub-cluster and maps ids back; the
        // incremental path plans in original-id space.  Same profile
        // values, same sorted order, same DP — same plan.
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let (_, state) = crate::planner::dp::plan_hpp_with_state(
            &table,
            &cluster,
            &model,
            &cfg,
            &PlannerConfig::default(),
        )
        .unwrap();
        for &failed in &plan.devices() {
            let heavy = heavy_reschedule(
                &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                    &CodecSpec::default(), SyncMode::default(),
            )
            .unwrap();
            let (inc, next_state) = heavy_reschedule_incremental(
                &table,
                &cluster,
                &model,
                &cfg,
                &plan,
                failed,
                &hb,
                DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
                Some(&state),
            )
            .unwrap();
            assert_eq!(inc.mechanism, "heavy-incremental");
            assert_eq!(inc.new_plan, heavy.new_plan, "failed={failed}");
            assert_eq!(inc.replay_micros, heavy.replay_micros, "failed={failed}");
            assert_eq!(next_state.order().len(), cluster.n() - 1);
            inc.new_plan.validate(&model, &cluster).unwrap();
        }
    }

    #[test]
    fn incremental_heavy_states_chain_across_failures() {
        // The state a recovery returns must itself replan the *next*
        // failure, and without a previous state the path degrades to a
        // full subset rebuild with the same answer.
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let devs = plan.devices();
        let (first, second) = (devs[0], devs[1]);
        let (r1, s1) = heavy_reschedule_incremental(
            &table, &cluster, &model, &cfg, &plan, first, &hb, DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(), None,
        )
        .unwrap();
        let (r2, s2) = heavy_reschedule_incremental(
            &table,
            &cluster,
            &model,
            &cfg,
            &r1.new_plan,
            second,
            &hb,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
            Some(&s1),
        )
        .unwrap();
        let (cold, _) = heavy_reschedule_incremental(
            &table,
            &cluster,
            &model,
            &cfg,
            &r1.new_plan,
            second,
            &hb,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
            None,
        )
        .unwrap();
        assert_eq!(r2.new_plan, cold.new_plan);
        assert!(!r2.new_plan.devices().contains(&first));
        assert!(!r2.new_plan.devices().contains(&second));
        assert_eq!(s2.order().len(), cluster.n() - 2);
    }

    #[test]
    fn rejoin_re_expands_to_the_original_plan() {
        // Exit a device through the incremental heavy path, then bring
        // it back through rejoin_replan: on an otherwise-unchanged
        // cluster the re-expanded plan must be bit-for-bit the original
        // full-fleet plan, and the chained state must cover the whole
        // cluster again.
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let (_, state) = crate::planner::dp::plan_hpp_with_state(
            &table,
            &cluster,
            &model,
            &cfg,
            &PlannerConfig::default(),
        )
        .unwrap();
        let dev = plan.devices()[0];
        let (exit_rep, s1) = heavy_reschedule_incremental(
            &table,
            &cluster,
            &model,
            &cfg,
            &plan,
            dev,
            &hb,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
            Some(&state),
        )
        .unwrap();
        let (rej, s2) = rejoin_replan(
            &table,
            &cluster,
            &model,
            &cfg,
            &exit_rep.new_plan,
            dev,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
            Some(&s1),
        )
        .unwrap();
        assert_eq!(rej.mechanism, "rejoin");
        assert_eq!(rej.new_plan, plan);
        assert_eq!(s2.order().len(), cluster.n());
        assert_eq!(rej.detection_s, 0.0);
        assert!(rej.replan_s > 0.0);
        rej.new_plan.validate(&model, &cluster).unwrap();
        // Cold path (no surviving state) emits the identical plan.
        let (cold, _) = rejoin_replan(
            &table,
            &cluster,
            &model,
            &cfg,
            &exit_rep.new_plan,
            dev,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
            None,
        )
        .unwrap();
        assert_eq!(cold.new_plan, rej.new_plan);
        // Rejoining an already-active device is refused.
        assert!(rejoin_replan(
            &table,
            &cluster,
            &model,
            &cfg,
            &plan,
            dev,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
            None,
        )
        .is_err());
    }

    #[test]
    fn degraded_reschedule_replans_on_the_derated_cluster() {
        let (cluster, model, _table, cfg, plan) = setup();
        // Derate one planned device's compute 8x and replan.
        let slow = plan.devices()[0];
        let mut derated = cluster.clone();
        derated.devices[slow].peak_flops /= 8.0;
        derated.devices[slow].overhead_s *= 8.0;
        let dtable = ProfileTable::new(&derated, &model);
        let (rep, state) = degraded_reschedule(
            &dtable,
            &derated,
            &model,
            &cfg,
            &plan,
            "straggler",
            1.25,
            DEFAULT_POLICY,
            &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        assert_eq!(rep.mechanism, "straggler");
        assert_eq!(rep.detection_s, 1.25);
        assert_eq!(rep.restore_s, 0.0);
        assert!(rep.new_throughput > 0.0);
        rep.new_plan.validate(&model, &derated).unwrap();
        // Membership is preserved — a straggler is rebalanced around,
        // not evicted.
        assert_eq!(rep.new_plan.devices(), plan.devices());
        assert_eq!(state.order().len(), plan.devices().len());
    }

    #[test]
    fn recovery_plans_are_valid() {
        let (cluster, model, table, cfg, plan) = setup();
        let hb = HeartbeatCfg::default();
        let failed = plan.devices()[0];
        let lite = lightweight_replay(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        lite.new_plan.validate(&model, &cluster).unwrap();
        let heavy = heavy_reschedule(
            &table, &cluster, &model, &cfg, &plan, failed, &hb, DEFAULT_POLICY,
                &CodecSpec::default(), SyncMode::default(),
        )
        .unwrap();
        heavy.new_plan.validate(&model, &cluster).unwrap();
        assert!(!heavy.new_plan.devices().contains(&failed));
    }
}
