//! Topology-driven model replication (paper §3.4, module 2).
//!
//! Single-device stages periodically back up their stage model to a
//! *backup node* in the next stage (the last stage backs up to the
//! first); devices in multi-device stages need no explicit backup —
//! their replicas hold identical weights.  On failure, weights are
//! restored from the backup node (single-device stage) or from a
//! surviving replica (multi-device stage).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::model::ModelDesc;
use crate::planner::plan::Plan;

/// Where a stage's weights can be recovered from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecoverySource {
    /// Backup node: (device holding the copy, owner stage).
    BackupNode { holder: usize },
    /// Any surviving replica within the same group.
    IntraStageReplica,
}

/// The replication topology of a plan.
#[derive(Debug, Clone)]
pub struct ReplicationPlan {
    /// stage index -> recovery source.
    pub sources: Vec<RecoverySource>,
    /// stage index -> bytes shipped per periodic checkpoint (0 for
    /// replica-protected stages).
    pub checkpoint_bytes: Vec<u64>,
}

/// Derive the backup topology for `plan` (Fig. 9 left).
pub fn replication_plan(model: &ModelDesc, plan: &Plan) -> ReplicationPlan {
    let p_total = plan.stages.len();
    let mut sources = Vec::with_capacity(p_total);
    let mut checkpoint_bytes = Vec::with_capacity(p_total);
    for (p, stage) in plan.stages.iter().enumerate() {
        if stage.devices.len() > 1 {
            sources.push(RecoverySource::IntraStageReplica);
            checkpoint_bytes.push(0);
        } else {
            // Next stage's first device; last stage wraps to the first.
            let holder_stage = if p + 1 < p_total { p + 1 } else { 0 };
            // A single-stage pipeline has nowhere to back up to.
            let holder = plan.stages[holder_stage].devices[0];
            sources.push(RecoverySource::BackupNode { holder });
            checkpoint_bytes.push(model.weight_bytes_range(stage.layers.0, stage.layers.1));
        }
    }
    ReplicationPlan { sources, checkpoint_bytes }
}

/// In-memory backup store used by the live engine and the replay
/// demos: stage -> serialized weights (flat f32).
#[derive(Debug, Default)]
pub struct BackupStore {
    snapshots: BTreeMap<usize, Vec<f32>>,
    pub version: BTreeMap<usize, u64>,
}

impl BackupStore {
    pub fn new() -> BackupStore {
        BackupStore::default()
    }

    /// Checkpoint stage weights (called periodically by the owner).
    pub fn checkpoint(&mut self, stage: usize, weights: Vec<f32>) {
        *self.version.entry(stage).or_insert(0) += 1;
        self.snapshots.insert(stage, weights);
    }

    /// Restore stage weights after a failure.
    pub fn restore(&self, stage: usize) -> Result<&[f32]> {
        match self.snapshots.get(&stage) {
            Some(w) => Ok(w),
            None => bail!("no backup for stage {stage}"),
        }
    }

    pub fn has(&self, stage: usize) -> bool {
        self.snapshots.contains_key(&stage)
    }
}

/// Time to restore a failed device's stage weights (Fig. 16's restore
/// component): backup-node transfer for single-device stages, free for
/// replica-protected stages (weights already resident elsewhere).
pub fn restore_time(
    model: &ModelDesc,
    plan: &Plan,
    repl: &ReplicationPlan,
    failed_stage: usize,
    bandwidth: f64,
) -> f64 {
    match repl.sources[failed_stage] {
        RecoverySource::IntraStageReplica => 0.0,
        RecoverySource::BackupNode { .. } => {
            let s = &plan.stages[failed_stage];
            model.weight_bytes_range(s.layers.0, s.layers.1) as f64 / bandwidth
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::planner::plan::{Plan, Stage};

    fn plan3(model: &ModelDesc) -> Plan {
        let nl = model.num_layers();
        Plan {
            stages: vec![
                Stage { layers: (0, nl / 3), devices: vec![0, 1], alloc: vec![4, 4], kp: 5 },
                Stage { layers: (nl / 3, 2 * nl / 3), devices: vec![2], alloc: vec![8], kp: 3 },
                Stage { layers: (2 * nl / 3, nl), devices: vec![3], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 8,
        }
    }

    #[test]
    fn topology_matches_paper_fig9() {
        let model = zoo::mobilenet_v2();
        let plan = plan3(&model);
        let repl = replication_plan(&model, &plan);
        // Multi-device stage: replica-protected, no checkpoint traffic.
        assert_eq!(repl.sources[0], RecoverySource::IntraStageReplica);
        assert_eq!(repl.checkpoint_bytes[0], 0);
        // Middle single-device stage backs up to next stage's device.
        assert_eq!(repl.sources[1], RecoverySource::BackupNode { holder: 3 });
        assert!(repl.checkpoint_bytes[1] > 0);
        // Last stage wraps to the first stage's device.
        assert_eq!(repl.sources[2], RecoverySource::BackupNode { holder: 0 });
    }

    #[test]
    fn backup_store_roundtrip() {
        let mut store = BackupStore::new();
        assert!(!store.has(1));
        assert!(store.restore(1).is_err());
        store.checkpoint(1, vec![1.0, 2.0, 3.0]);
        assert!(store.has(1));
        assert_eq!(store.restore(1).unwrap(), &[1.0, 2.0, 3.0]);
        store.checkpoint(1, vec![9.0]);
        assert_eq!(store.restore(1).unwrap(), &[9.0]);
        assert_eq!(store.version[&1], 2);
    }

    #[test]
    fn restore_time_free_for_replicated_stage() {
        let model = zoo::mobilenet_v2();
        let plan = plan3(&model);
        let repl = replication_plan(&model, &plan);
        let bw = 12.5e6;
        assert_eq!(restore_time(&model, &plan, &repl, 0, bw), 0.0);
        let t1 = restore_time(&model, &plan, &repl, 1, bw);
        let w1 = model.weight_bytes_range(plan.stages[1].layers.0, plan.stages[1].layers.1);
        assert!((t1 - w1 as f64 / bw).abs() < 1e-12);
    }
}
