//! `asteroid` — the coordinator CLI (leader entrypoint).
//!
//! ```text
//! asteroid plan     --model <zoo|lm|cnn> --env B --mbps 100 [--minibatch N --micro B]
//! asteroid simulate --model <zoo|lm|cnn> --env B --mbps 100 [...]
//! asteroid train    --model lm|cnn --env B [--steps N --lr X --emulate]
//! asteroid replay   --model effnet --env D --fail <device-id>
//! asteroid envs
//! ```
//!
//! `plan`/`simulate` accept the paper's zoo models (efficientnet-b1,
//! mobilenetv2, resnet50, bert-small) or the AOT-compiled `lm`/`cnn`
//! manifest models; `train` runs the real PJRT pipeline (manifest
//! models only).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::coordinator::Coordinator;
use asteroid::data::{LmTask, VisionTask};
use asteroid::model::from_manifest::Manifest;
use asteroid::model::zoo;
use asteroid::pipeline::{OptimizerCfg, TrainOpts};
use asteroid::util::cli::Args;
use asteroid::util::stats::{human_bytes, human_secs};

fn cluster_from(args: &Args) -> Result<ClusterSpec> {
    let mbps = args.f64_or("mbps", 100.0)?;
    if let Some(path) = args.get("cluster") {
        return ClusterSpec::load(std::path::Path::new(path));
    }
    ClusterSpec::env(&args.str_or("env", "B"), mbps)
}

fn coordinator_from(args: &Args) -> Result<Coordinator> {
    let model = args.str_or("model", "mobilenetv2");
    let cluster = cluster_from(args)?;
    if zoo::by_name(&model).is_some() {
        let cfg = TrainConfig::new(
            args.usize_or("minibatch", 2048)?,
            args.usize_or("micro", 32)?,
        );
        Coordinator::for_zoo_model(&model, cluster, cfg)
    } else {
        let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
        let manifest = Manifest::load(&dir)?;
        let micro = manifest.model(&model)?.microbatch;
        let cfg = TrainConfig::new(args.usize_or("minibatch", micro * 8)?, micro);
        Coordinator::for_artifact_model(&dir, &model, cluster, cfg)
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let c = coordinator_from(args)?;
    let out = c.plan()?;
    println!("model     : {}", c.model.name);
    println!("cluster   : {}", c.cluster.describe());
    println!("mini-batch: {} (micro {}, M {})", c.cfg.minibatch, c.cfg.microbatch,
             c.cfg.num_microbatches());
    println!("plan      : {}", out.plan.describe(&c.cluster));
    println!("predicted : {:.2} samples/s (round {})",
             out.predicted_throughput, human_secs(out.predicted_latency));
    println!("planning  : {}", human_secs(out.planning_time_s));
    for (p, s) in out.plan.stages.iter().enumerate() {
        let w = c.model.weight_bytes_range(s.layers.0, s.layers.1);
        println!(
            "  stage {p}: layers [{}, {}) on {:?} alloc {:?} K_p={} weights {}",
            s.layers.0, s.layers.1,
            s.devices.iter().map(|&d| c.cluster.devices[d].name.clone()).collect::<Vec<_>>(),
            s.alloc, s.kp, human_bytes(w),
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let c = coordinator_from(args)?;
    let out = c.plan()?;
    let sim = c.simulate(&out.plan);
    println!("plan        : {}", out.plan.describe(&c.cluster));
    println!("predicted   : {:.2} samples/s", out.predicted_throughput);
    println!("simulated   : {:.2} samples/s (round {})",
             sim.throughput, human_secs(sim.round_latency));
    println!("network     : {} per round", human_bytes(sim.bytes_on_network));
    for &d in &out.plan.devices() {
        println!(
            "  {}: busy {} bubbles {:.0}% inflight {} peak-mem {}",
            c.cluster.devices[d].name,
            human_secs(sim.busy[d]),
            100.0 * sim.bubble_fraction[d],
            sim.peak_inflight[d],
            human_bytes(sim.peak_memory[d]),
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "lm");
    let c = coordinator_from(args)?;
    c.artifacts
        .as_ref()
        .context("`train` needs an AOT model (lm or cnn); run `make artifacts`")?;
    let out = c.plan()?;
    println!("plan: {}", out.plan.describe(&c.cluster));
    let opts = TrainOpts {
        steps: args.usize_or("steps", 30)?,
        opt: OptimizerCfg::Sgd {
            lr: args.f64_or("lr", 0.05)? as f32,
            momentum: args.f64_or("momentum", 0.9)? as f32,
        },
        seed: args.u64_or("seed", 42)?,
        emulate: if args.has_flag("emulate") { Some(c.cluster.clone()) } else { None },
        log_every: args.usize_or("log-every", 5)?,
        initial_params: None,
    };
    let manifest = Manifest::load(c.artifacts.as_ref().unwrap().0.as_path())?;
    let mm = manifest.model(&model)?;
    let stats = match mm.kind.as_str() {
        "transformer" => {
            let vocab = *mm.config.get("vocab").unwrap() as usize;
            let seq = *mm.config.get("seq").unwrap() as usize;
            let mut data = LmTask::new(vocab, seq, mm.microbatch, opts.seed);
            c.train(&out.plan, &opts, &mut data)?
        }
        _ => {
            let hw = *mm.config.get("hw").unwrap() as usize;
            let ch = *mm.config.get("in_ch").unwrap() as usize;
            let classes = *mm.config.get("classes").unwrap() as usize;
            let mut data = VisionTask::new(hw, ch, classes, mm.microbatch, opts.seed);
            c.train(&out.plan, &opts, &mut data)?
        }
    };
    println!(
        "trained {} rounds: loss {:.4} -> {:.4}, {:.1} samples/s",
        stats.losses.len(),
        stats.losses.first().unwrap(),
        stats.losses.last().unwrap(),
        stats.samples_per_sec,
    );
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let c = coordinator_from(args)?;
    let plan = c.plan()?.plan;
    let failed = args.usize_or("fail", *plan.devices().last().unwrap())?;
    println!("plan: {}", plan.describe(&c.cluster));
    println!("before: {:.2} samples/s", c.simulate(&plan).throughput);
    println!("failing device {} ({})", failed, c.cluster.devices[failed].name);
    for (name, r) in [
        ("lightweight", c.recover_lightweight(&plan, failed)?),
        ("heavy", c.recover_heavy(&plan, failed)?),
    ] {
        println!(
            "{name:<12} detect {:.2}s restore {:.2}s replan {:.2}s migrate {:.2}s \
             = {:.2}s -> {:.2} samples/s  [{}]",
            r.detection_s, r.restore_s, r.replan_s, r.migration_s, r.total_s(),
            r.new_throughput, r.new_plan.describe(&c.cluster),
        );
    }
    Ok(())
}

fn cmd_envs() -> Result<()> {
    println!("built-in environments (paper Table 6):");
    for env in ["A", "B", "C", "D", "A100"] {
        let c = ClusterSpec::env(env, 100.0)?;
        println!("  {env}: {}", c.describe());
    }
    println!("zoo models: efficientnet-b1, mobilenetv2, resnet50, bert-small");
    println!("AOT models: lm, cnn (run `make artifacts`)");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&["emulate"])?;
    match args.positional.first().map(String::as_str) {
        Some("plan") => cmd_plan(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("train") => cmd_train(&args),
        Some("replay") => cmd_replay(&args),
        Some("envs") => cmd_envs(),
        other => {
            eprintln!(
                "asteroid: unknown command {other:?}\n\
                 usage: asteroid <plan|simulate|train|replay|envs> [--model M --env E --mbps N ...]"
            );
            if other.is_none() {
                cmd_envs()?;
            }
            bail!("no command")
        }
    }
}
