//! `asteroid` — the coordinator CLI (leader entrypoint).
//!
//! ```text
//! asteroid plan     --model <zoo|lm|cnn> --env B --mbps 100 [--method dp|pp|...]
//! asteroid simulate --model <zoo|lm|cnn> --env B --mbps 100 [--method M --schedule gpipe|zb-h1|async:<s>]
//! asteroid train    --model lm|cnn --env B [--steps N --lr X --emulate]
//! asteroid train    --backend rpc --connect h:p,h:p,h:p --env nanos:3 --method pp \
//!                   [--fail-after N --resume N --heartbeat-ms M] \
//!                   [--churn "exit:2@1,join:2@3,slow:0:3@5"] [--report out.json]
//! asteroid replay   --model effnet --env D --fail <device-id>
//! asteroid lint     [--format json] [--model M --env E --schedule P --codec C]
//! asteroid envs
//! ```
//!
//! Every command assembles one [`Session`] (preprocessing + planning)
//! and, where it executes, runs it through an [`ExecutionBackend`]:
//! `simulate`/`replay` price with [`SimBackend`], `train` runs the
//! live [`PjrtBackend`] by default (manifest models + `--features
//! pjrt` only), or — with `--backend rpc --connect <addrs>` — drives
//! separate `asteroid-worker` processes over TCP (works featureless;
//! zoo models train on the reference kernel).  `--method` selects any
//! paper baseline planner without code edits; `--report` writes the
//! machine-readable `RunReport` JSON the CI integration job asserts
//! on.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use asteroid::codec::{Codec, CodecSpec};
use asteroid::comm::SyncMode;
use asteroid::config::{ClusterSpec, TrainConfig};
use asteroid::fault::{ChurnTrace, HeartbeatCfg};
use asteroid::model::zoo;
use asteroid::pipeline::OptimizerCfg;
use asteroid::planner::baselines::Method;
use asteroid::planner::Planner;
use asteroid::schedule::{builtin_policies, policy_by_name, SchedulePolicy};
use asteroid::session::{
    ChurnSpec, ExecutionBackend, FaultSpec, PjrtBackend, RecoveryKind, RpcBackend, RunReport,
    Session, SimBackend,
};
use asteroid::util::bench::synthetic_fleet;
use asteroid::util::cli::Args;
use asteroid::util::stats::{human_bytes, human_secs};
use asteroid::verify;

fn cluster_from(args: &Args) -> Result<ClusterSpec> {
    let mbps = args.f64_or("mbps", 100.0)?;
    if let Some(path) = args.get("cluster") {
        return ClusterSpec::load(std::path::Path::new(path));
    }
    ClusterSpec::env(&args.str_or("env", "B"), mbps)
}

fn planner_from(args: &Args) -> Result<Planner> {
    let method: Method = args.str_or("method", "asteroid").parse()?;
    Ok(match method {
        Method::Asteroid => Planner::Asteroid,
        other => Planner::Baseline(other),
    })
}

fn policy_from(args: &Args) -> Result<&'static dyn SchedulePolicy> {
    let name = args.str_or("schedule", "1f1b");
    policy_by_name(&name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown schedule policy {name:?} (expected one of: {}, or async:<s> \
             for a bounded-staleness budget of s)",
            builtin_policies()
                .iter()
                .map(|p| p.name())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })
}

/// Declarative device-exit injection from flags: `--fail-after N`
/// arms a [`FaultSpec`] (`--fail <dev>` picks the device, default
/// last-planned; `--recovery heavy` the baseline mechanism,
/// `heavy-incremental` the same replan through the planner's
/// incremental fast path;
/// `--resume N` post-recovery rounds; `--heartbeat-ms M` a tight
/// validated detection config for CI).
fn fault_from(args: &Args) -> Result<Option<FaultSpec>> {
    let Some(after) = args.get("fail-after") else {
        return Ok(None);
    };
    let after: usize = after
        .parse()
        .with_context(|| format!("--fail-after expects an integer, got {after:?}"))?;
    let mut spec = match args.get("fail") {
        Some(_) => FaultSpec::device(args.usize_or("fail", 0)?),
        None => FaultSpec::last_planned(),
    };
    spec = spec.after(after).resume_for(args.usize_or("resume", 2)?);
    match args.str_or("recovery", "lightweight").as_str() {
        "lightweight" | "lite" => {}
        "heavy" => spec = spec.with_recovery(RecoveryKind::Heavy),
        "heavy-incremental" | "heavy-inc" => {
            spec = spec.with_recovery(RecoveryKind::HeavyIncremental)
        }
        other => bail!(
            "--recovery expects lightweight|heavy|heavy-incremental, got {other:?}"
        ),
    }
    if let Some(ms) = args.get("heartbeat-ms") {
        let ms: u64 = ms
            .parse()
            .with_context(|| format!("--heartbeat-ms expects an integer, got {ms:?}"))?;
        spec = spec.with_heartbeat(HeartbeatCfg::new(
            Duration::from_millis(ms),
            3,
            Duration::from_millis(ms / 2),
        )?);
    }
    Ok(Some(spec))
}

/// Elastic-membership churn from `--churn <trace>`: an ordered timed
/// event list in the [`ChurnTrace`] grammar, e.g.
/// `exit:2@1,join:2@3,slow:0:3@5,link:0-1:40@7` (device 2 exits before
/// round 1 and rejoins before round 3; device 0 slows 3x before round
/// 5; the 0-1 link degrades to 40 Mbps before round 7).
/// `--heartbeat-ms M` tightens exit detection exactly as for
/// `--fail-after`; `--exit-recovery lightweight|heavy-incremental`
/// picks the exit mechanism (default heavy-incremental, which keeps
/// the planner state chained for later joins).
fn churn_from(args: &Args) -> Result<Option<ChurnSpec>> {
    let Some(trace) = args.get("churn") else {
        return Ok(None);
    };
    let trace: ChurnTrace = trace.parse()?;
    let mut spec = ChurnSpec::from(trace);
    match args.str_or("exit-recovery", "heavy-incremental").as_str() {
        "heavy-incremental" | "heavy-inc" => {}
        "lightweight" | "lite" => spec = spec.with_exit_recovery(RecoveryKind::Lightweight),
        other => bail!("--exit-recovery expects lightweight|heavy-incremental, got {other:?}"),
    }
    if let Some(ms) = args.get("heartbeat-ms") {
        let ms: u64 = ms
            .parse()
            .with_context(|| format!("--heartbeat-ms expects an integer, got {ms:?}"))?;
        spec = spec.with_heartbeat(HeartbeatCfg::new(
            Duration::from_millis(ms),
            3,
            Duration::from_millis(ms / 2),
        )?);
    }
    Ok(Some(spec))
}

/// Assemble the session every command starts from: model (zoo or AOT
/// manifest), cluster, training config, planner, schedule policy and
/// run options — one builder, no per-command phase wiring.
fn session_from(args: &Args, default_model: &str) -> Result<Session> {
    let model = args.str_or("model", default_model);
    let cluster = cluster_from(args)?;
    let mut b = Session::builder()
        .cluster(cluster)
        .planner(planner_from(args)?)
        .schedule(policy_from(args)?)
        .steps(args.usize_or("steps", 30)?)
        .optimizer(OptimizerCfg::Sgd {
            lr: args.f64_or("lr", 0.05)? as f32,
            momentum: args.f64_or("momentum", 0.9)? as f32,
        })
        .seed(args.u64_or("seed", 42)?)
        .emulate(args.has_flag("emulate"))
        .log_every(args.usize_or("log-every", 5)?);
    // `--codec fp32|fp16|bf16|int8[,<boundary>=<codec>...]` — the wire
    // codec reaches the planner's cost model *and* the data plane, so a
    // lossy codec can change the plan, not just the transfer time.
    if let Some(spec) = args.get("codec") {
        b = b.codec(CodecSpec::parse(spec)?);
    }
    // `--sync ring|driver` — the data-plane collective topology.  Ring
    // (the default) runs gradient sync worker-to-worker and prices
    // Eq. 5 as 2(g-1)/g * W over the slowest intra-group link; driver
    // mediation is the star fallback.  Reaches the planner *and* the
    // RPC data plane, same as `--codec`.
    if let Some(mode) = args.get("sync") {
        b = b.sync(SyncMode::parse(mode)?);
    }
    if let Some(fault) = fault_from(args)? {
        b = b.fault(fault);
    }
    if let Some(churn) = churn_from(args)? {
        b = b.churn(churn);
    }
    if zoo::by_name(&model).is_some() {
        b = b.model(&model).train(TrainConfig::new(
            args.usize_or("minibatch", 2048)?,
            args.usize_or("micro", 32)?,
        ));
    } else {
        b = b.artifact_model(args.str_or("artifacts", "artifacts"), &model);
        // Micro-batch is compiled into the artifact; `--minibatch`
        // alone scales the round and the manifest supplies the rest.
        if let Some(mb) = args.get("minibatch") {
            let minibatch: usize = mb
                .parse()
                .with_context(|| format!("--minibatch expects an integer, got {mb:?}"))?;
            b = match args.get("micro") {
                Some(_) => b.train(TrainConfig::new(minibatch, args.usize_or("micro", 0)?)),
                None => b.minibatch(minibatch),
            };
        }
    }
    b.build()
}

fn print_plan(s: &Session) {
    let out = s.outcome();
    let cfg = s.train_config();
    println!("model     : {}", s.model().name);
    println!("cluster   : {}", s.cluster().describe());
    println!("planner   : {}", s.planner().describe());
    println!("schedule  : {}", s.schedule().policy);
    println!("codec     : {}", s.codec().describe());
    println!("sync      : {}", s.sync_mode().name());
    println!(
        "mini-batch: {} (micro {}, M {})",
        cfg.minibatch,
        cfg.microbatch,
        cfg.num_microbatches()
    );
    println!("plan      : {}", out.plan.describe(s.cluster()));
    println!(
        "predicted : {:.2} samples/s (round {})",
        out.predicted_throughput,
        human_secs(out.predicted_latency)
    );
    println!("planning  : {}", human_secs(out.planning_time_s));
    for (p, st) in out.plan.stages.iter().enumerate() {
        let w = s.model().weight_bytes_range(st.layers.0, st.layers.1);
        println!(
            "  stage {p}: layers [{}, {}) on {:?} alloc {:?} K_p={} weights {}",
            st.layers.0,
            st.layers.1,
            st.devices
                .iter()
                .map(|&d| s.cluster().devices[d].name.clone())
                .collect::<Vec<_>>(),
            st.alloc,
            st.kp,
            human_bytes(w),
        );
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let s = session_from(args, "mobilenetv2")?;
    print_plan(&s);
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let s = session_from(args, "mobilenetv2")?;
    let report = s.run(&mut SimBackend::default())?;
    let sim = report.sim.as_ref().expect("sim backend always prices");
    println!("planner     : {}", s.planner().describe());
    println!("plan        : {}", report.plan.describe(s.cluster()));
    println!("predicted   : {:.2} samples/s", report.predicted_throughput);
    println!(
        "simulated   : {:.2} samples/s (round {})",
        report.throughput,
        human_secs(sim.round_latency)
    );
    println!("network     : {} per round", human_bytes(report.bytes_on_network));
    for &d in &report.plan.devices() {
        println!(
            "  {}: busy {} bubbles {:.0}% inflight {} peak-mem {}",
            s.cluster().devices[d].name,
            human_secs(sim.busy[d]),
            100.0 * sim.bubble_fraction[d],
            sim.peak_inflight[d],
            human_bytes(sim.peak_memory[d]),
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let backend_name = args.str_or("backend", "pjrt");
    // The RPC backend trains zoo models on the reference kernel, so
    // its natural default model differs from the artifact-only pjrt
    // engine.
    let default_model = if backend_name == "rpc" { "mobilenetv2" } else { "lm" };
    let s = session_from(args, default_model)?;
    println!("plan: {}", s.plan().describe(s.cluster()));
    let mut backend: Box<dyn ExecutionBackend> = match backend_name.as_str() {
        "pjrt" => Box::new(PjrtBackend::new()),
        "sim" => Box::new(SimBackend),
        "rpc" => {
            let addrs: Vec<String> = args
                .require("connect")
                .context("--backend rpc needs --connect host:port[,host:port,...]")?
                .split(',')
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .map(String::from)
                .collect();
            anyhow::ensure!(!addrs.is_empty(), "--connect lists no worker addresses");
            Box::new(RpcBackend::connect(addrs))
        }
        other => bail!("unknown backend {other:?} (want sim|pjrt|rpc)"),
    };
    let report = s.run(backend.as_mut())?;
    match (report.first_loss(), report.last_loss()) {
        (Some(first), Some(last)) => println!(
            "trained {} rounds [{}]: loss {first:.4} -> {last:.4}, {:.1} samples/s",
            report.rounds, report.backend, report.throughput,
        ),
        // Pricing backends have no numerics; the round count and rate
        // are still the answer.
        _ => println!(
            "priced {} rounds [{}]: {:.1} samples/s",
            report.rounds, report.backend, report.throughput,
        ),
    }
    for ev in &report.recoveries {
        println!(
            "recovery [{}] device {} at round {} via {} in {:.2}s \
             (replayed {} micros, retasked {} devices)",
            ev.kind.name(),
            ev.failed_device,
            ev.round,
            ev.report.mechanism,
            ev.report.total_s(),
            ev.report.replay_micros.len(),
            ev.report.retasked_devices.len(),
        );
    }
    if let Some(path) = args.get("report") {
        std::fs::write(path, report_json(&report))
            .with_context(|| format!("writing report to {path}"))?;
        println!("report written to {path}");
    }
    Ok(())
}

/// Schema version stamped into every `--report` JSON.  Contract (see
/// docs/API.md "Report schema"): within one major version, existing
/// fields keep their name, type and meaning — consumers may pin exact
/// jq paths; new fields may be *added* without a bump; any rename,
/// removal or semantic change bumps this number.  v2 added
/// `schema_version` itself, the top-level `sync` mode, the per-device
/// `sync_bytes`/`sync_wall_s`/`ctrl_msgs_tx`/`ctrl_msgs_rx` meters and
/// the fleet `sync_msgs` counter.
const REPORT_SCHEMA_VERSION: u32 = 2;

/// Machine-readable `RunReport` summary — what the CI integration job
/// parses and asserts on.  Hand-rolled (all values numeric or fixed
/// strings), matching the repo's offline no-serde substrate.
fn report_json(r: &RunReport) -> String {
    let list = |v: &[f64]| -> String {
        v.iter().map(|x| format!("{x:.6}")).collect::<Vec<_>>().join(", ")
    };
    let recoveries: Vec<String> = r
        .recoveries
        .iter()
        .map(|e| {
            format!(
                "{{\"round\": {}, \"failed_device\": {}, \"mechanism\": \"{}\", \
                 \"kind\": \"{}\", \"total_s\": {:.6}, \"detection_s\": {:.6}, \
                 \"replan_s\": {:.6}, \"replan_wall_s\": {:.6}, \
                 \"replay_micros\": {}, \"retasked_devices\": {}}}",
                e.round,
                e.failed_device,
                e.report.mechanism,
                e.kind.name(),
                e.report.total_s(),
                e.report.detection_s,
                e.report.replan_s,
                e.replan_wall_s,
                e.report.replay_micros.len(),
                e.report.retasked_devices.len(),
            )
        })
        .collect();
    let rpc = match &r.rpc {
        None => "null".to_string(),
        Some(stats) => {
            let rows: Vec<String> = stats
                .per_device
                .iter()
                .map(|d| {
                    format!(
                        "{{\"device\": {}, \"addr\": \"{}\", \"heartbeats\": {}, \
                         \"rounds_reported\": {}, \"mean_round_compute_s\": {:.6}, \
                         \"bytes_tx\": {}, \"bytes_rx\": {}, \
                         \"dp_logical_bytes\": {}, \"dp_wire_bytes\": {}, \
                         \"sync_bytes\": {}, \"sync_wall_s\": {:.6}, \
                         \"ctrl_msgs_tx\": {}, \"ctrl_msgs_rx\": {}}}",
                        d.device,
                        d.addr,
                        d.heartbeats,
                        d.rounds_reported,
                        d.mean_round_compute_s,
                        d.bytes_tx,
                        d.bytes_rx,
                        d.dp_logical_bytes,
                        d.dp_wire_bytes,
                        d.sync_bytes,
                        d.sync_wall_s,
                        d.ctrl_msgs_tx,
                        d.ctrl_msgs_rx,
                    )
                })
                .collect();
            let detect = match stats.detection_wall_s {
                Some(s) => format!("{s:.6}"),
                None => "null".to_string(),
            };
            // Fleet-wide data-plane totals: the measured compression
            // ratio is dp_wire_bytes / dp_logical_bytes (1.0 for fp32).
            let logical: u64 = stats.per_device.iter().map(|d| d.dp_logical_bytes).sum();
            let wire: u64 = stats.per_device.iter().map(|d| d.dp_wire_bytes).sum();
            format!(
                "{{\"detection_wall_s\": {detect}, \
                 \"dp_logical_bytes\": {logical}, \"dp_wire_bytes\": {wire}, \
                 \"sync_msgs\": {}, \
                 \"per_device\": [{}]}}",
                stats.sync_msgs,
                rows.join(", ")
            )
        }
    };
    format!(
        "{{\n  \"schema_version\": {REPORT_SCHEMA_VERSION},\n  \
         \"backend\": \"{}\",\n  \"policy\": \"{}\",\n  \"codec\": \"{}\",\n  \
         \"sync\": \"{}\",\n  \"max_staleness\": {},\n  \
         \"rounds\": {},\n  \"throughput\": {:.6},\n  \"predicted_throughput\": {:.6},\n  \
         \"losses\": [{}],\n  \"round_secs\": [{}],\n  \"recoveries\": [{}],\n  \
         \"rpc\": {}\n}}\n",
        r.backend,
        r.schedule.policy,
        r.codec,
        r.sync.name(),
        r.max_staleness,
        r.rounds,
        r.throughput,
        r.predicted_throughput,
        list(&r.losses),
        list(&r.round_secs),
        recoveries.join(", "),
        rpc,
    )
}

/// `asteroid lint [--format json] [<session flags>]` — run the static
/// verifier (`verify::all`: deadlock-freedom, memory abstract
/// interpretation, version/staleness dataflow, codec-override
/// validity, RPC protocol tables).
///
/// With any session flag (`--model/--env/--schedule/--codec/...`) the
/// single described session is linted.  With no flags it sweeps the
/// curated grid — every builtin policy x {fp32, int8, int8 plus a
/// per-boundary override on a real cut} x {env C, a 128-device
/// synthetic fleet} — the same grid CI's `lint-ir` job gates on.
/// Exits nonzero on any diagnostic.
fn cmd_lint(args: &Args) -> Result<()> {
    let as_json = args.str_or("format", "text") == "json";
    // (target label, finding) pairs, in discovery order.
    let mut findings: Vec<(String, verify::Diagnostic)> = Vec::new();
    let mut planner_errors: Vec<String> = Vec::new();
    let mut checked = 0usize;

    let lint_one = |label: String, s: &Session, findings: &mut Vec<(String, verify::Diagnostic)>| {
        for d in verify::all(&verify::Target::of_session(s)) {
            findings.push((label.clone(), d));
        }
    };

    let single = ["model", "env", "cluster", "schedule", "codec", "method", "minibatch", "micro"]
        .iter()
        .any(|k| args.get(k).is_some());
    if single {
        let s = session_from(args, "mobilenetv2")?;
        checked += 1;
        let label = format!(
            "{} {} {}",
            s.model().name,
            s.schedule().policy,
            s.codec().describe()
        );
        lint_one(label, &s, &mut findings);
    } else {
        let clusters: Vec<(&str, ClusterSpec)> = vec![
            ("env-C", ClusterSpec::env("C", 100.0)?),
            ("fleet128", synthetic_fleet(128, 100.0)),
        ];
        for (cname, cluster) in &clusters {
            for policy in builtin_policies() {
                let build = |codec: CodecSpec| -> Result<Session> {
                    Session::builder()
                        .model("mobilenetv2")
                        .cluster(cluster.clone())
                        .train(TrainConfig::new(256, 16))
                        .schedule(policy)
                        .codec(codec)
                        .build()
                };
                // fp32 and int8 uniform, then int8 with an explicit
                // override pinned to a cut the int8 plan actually has
                // (identical pricing, so the plan is stable and the
                // override provably applies).
                let mut points: Vec<(String, CodecSpec)> = vec![
                    ("fp32".into(), CodecSpec::uniform(Codec::Fp32)),
                    ("int8".into(), CodecSpec::uniform(Codec::Int8)),
                ];
                match build(CodecSpec::uniform(Codec::Int8)) {
                    Ok(s) if s.plan().num_stages() > 1 => {
                        let cut = s.plan().stages[0].layers.1;
                        points.push((
                            format!("int8,{cut}=int8"),
                            CodecSpec::uniform(Codec::Int8).with_override(cut, Codec::Int8)?,
                        ));
                    }
                    _ => {} // single-stage or infeasible: covered below
                }
                for (cdesc, codec) in points {
                    match build(codec) {
                        Ok(s) => {
                            checked += 1;
                            lint_one(
                                format!("{cname} {} {cdesc}", policy.name()),
                                &s,
                                &mut findings,
                            );
                        }
                        // An infeasible grid point is a planner
                        // limitation, not a schedule defect — record
                        // it (no silent shrink) without failing lint.
                        Err(e) => planner_errors
                            .push(format!("{cname} {} {cdesc}: {e:#}", policy.name())),
                    }
                }
            }
        }
    }

    if as_json {
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let rows: Vec<String> = findings
            .iter()
            .map(|(label, d)| {
                let device = match d.device {
                    Some(dev) => dev.to_string(),
                    None => "null".to_string(),
                };
                format!(
                    "{{\"target\": \"{}\", \"code\": \"{}\", \"title\": \"{}\", \
                     \"device\": {}, \"message\": \"{}\"}}",
                    esc(label),
                    d.code.id(),
                    esc(d.code.title()),
                    device,
                    esc(&d.message),
                )
            })
            .collect();
        let errs: Vec<String> =
            planner_errors.iter().map(|e| format!("\"{}\"", esc(e))).collect();
        println!(
            "{{\n  \"checked\": {checked},\n  \"planner_errors\": [{}],\n  \
             \"diagnostics\": [{}]\n}}",
            errs.join(", "),
            rows.join(", ")
        );
    } else {
        for (label, d) in &findings {
            println!("{} [{label}] {}", d.code.id(), d.message);
        }
        for e in &planner_errors {
            eprintln!("note: grid point not planned: {e}");
        }
        println!(
            "lint: {} session(s) checked, {} diagnostic(s)",
            checked,
            findings.len()
        );
    }
    if !findings.is_empty() {
        bail!("{} lint diagnostic(s)", findings.len());
    }
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let base = session_from(args, "efficientnet-b1")?;
    let devices = base.plan().devices();
    let failed = args.usize_or("fail", *devices.last().unwrap())?;
    anyhow::ensure!(
        devices.contains(&failed),
        "--fail {failed} is not a planned device (plan uses {devices:?})"
    );
    println!("plan: {}", base.plan().describe(base.cluster()));
    let before = base.run(&mut SimBackend::default())?;
    println!("before: {:.2} samples/s", before.throughput);
    println!(
        "failing device {} ({})",
        failed,
        base.cluster().devices[failed].name
    );
    for kind in [
        RecoveryKind::Lightweight,
        RecoveryKind::Heavy,
        RecoveryKind::HeavyIncremental,
    ] {
        let s = base
            .clone()
            .with_fault(FaultSpec::device(failed).with_recovery(kind));
        let report = s.run(&mut SimBackend::default())?;
        let ev = &report.recoveries[0];
        let r = &ev.report;
        println!(
            "{:<12} detect {:.2}s restore {:.2}s replan {:.2}s migrate {:.2}s \
             = {:.2}s -> {:.2} samples/s  [{}]",
            r.mechanism,
            r.detection_s,
            r.restore_s,
            r.replan_s,
            r.migration_s,
            r.total_s(),
            r.new_throughput,
            r.new_plan.describe(base.cluster()),
        );
    }
    Ok(())
}

fn cmd_envs() -> Result<()> {
    println!("built-in environments (paper Table 6):");
    for env in ["A", "B", "C", "D", "A100"] {
        let c = ClusterSpec::env(env, 100.0)?;
        println!("  {env}: {}", c.describe());
    }
    println!("  nanos:<n>: n homogeneous Jetson Nanos (RPC quickstart shape)");
    println!("zoo models: efficientnet-b1, mobilenetv2, resnet50, bert-small");
    println!("AOT models: lm, cnn (run `make artifacts`)");
    println!("backends  : sim, pjrt (--features pjrt), rpc (--backend rpc --connect ...)");
    println!(
        "schedules : {}, async:<s>  (--schedule)",
        builtin_policies()
            .iter()
            .map(|p| p.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!(
        "codecs    : {}  (--codec, optional per-boundary: int8,12=fp16)",
        Codec::ALL
            .iter()
            .map(|c| c.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!("sync      : ring (default, worker-to-worker), driver  (--sync)");
    println!(
        "methods   : {}",
        Method::ALL
            .iter()
            .map(|m| m.name().to_ascii_lowercase())
            .collect::<Vec<_>>()
            .join(", ")
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&["emulate"])?;
    match args.positional.first().map(String::as_str) {
        Some("plan") => cmd_plan(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("train") => cmd_train(&args),
        Some("replay") => cmd_replay(&args),
        Some("lint") => cmd_lint(&args),
        Some("envs") => cmd_envs(),
        other => {
            eprintln!(
                "asteroid: unknown command {other:?}\n\
                 usage: asteroid <plan|simulate|train|replay|lint|envs> \
                 [--model M --env E --mbps N --method P ...]"
            );
            if other.is_none() {
                cmd_envs()?;
            }
            bail!("no command")
        }
    }
}
