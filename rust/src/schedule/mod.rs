//! The Schedule IR: one explicit HPP-Round timeline, four consumers.
//!
//! Asteroid's central artifact is the HPP-Round schedule — per-device
//! 1F1B ordering with a per-stage K_p warm-up window (§3.2).  This
//! module makes that schedule an explicit, plan-derived intermediate
//! representation: a typed per-device timeline of [`Task`]s generated
//! once from a [`Plan`] by a pluggable [`SchedulePolicy`].
//!
//! Consumers (see `docs/SCHEDULE.md` for the worked example):
//!   * `sim::price` — prices a `Schedule` (explicit or policy-built)
//!     against the `ProfileTable` and `LinkSet`; `sim::simulate_round`
//!     is now a thin wrapper that builds the default-policy
//!     `PriceRequest` and prices it.
//!   * `pipeline::worker` — each live worker executes its device's
//!     [`ComputeOp`] script instead of re-deriving 1F1B order from
//!     message-arrival heuristics.
//!   * `planner::dp` — `sim_select` prices candidate schedules, and
//!     `PlanOutcome` carries the chosen `Schedule` downstream.
//!   * `fault::replay` — recovery ordering comes from [`diff`]ing the
//!     pre- and post-failure schedules instead of re-implementing the
//!     warm-up rules.
//!
//! Two sharding modes mirror the two execution substrates:
//! [`Sharding::SampleShard`] is the paper's Fig. 10 intra-stage data
//! parallelism (each micro-batch sample-sliced across the group — what
//! the simulator prices), [`Sharding::RoundRobin`] assigns whole
//! micro-batches round-robin (what the live runtime executes; see
//! `pipeline::worker` docs for why).
//!
//! Since the [`AsyncPipe`] policy landed, the IR carries **weight
//! semantics**, not just task order: compute tasks are tagged with the
//! weight version they read/apply, a schedule declares its
//! bounded-staleness budget (`Schedule::max_staleness`), and the
//! validator enforces either the synchronous all-versions-zero
//! guarantee or the staleness bound (see [`Schedule::validate`]).

pub mod policy;

use std::collections::{BTreeMap, HashMap, HashSet};

use anyhow::{bail, Result};

use crate::model::ModelDesc;
use crate::planner::plan::Plan;

pub use policy::{
    builtin_policies, policy_by_name, AsyncPipe, ComputeOp, GpipeFillDrain, Interleaved,
    OneFOneBKp, SchedulePolicy, ZeroBubbleH1, BWD_INPUT_FRAC,
};

/// The policy a consumer falls back to when no per-run policy was
/// chosen: the paper's 1F1B with K_p warm-up.  This constant is only
/// legitimate in *defaults* (`SessionBuilder::default`,
/// `PlannerConfig::default`, `TrainOpts::default`, the
/// `sim::simulate_round` convenience wrapper, and tests); every
/// planning/execution/replay path takes the session's threaded
/// `&'static dyn SchedulePolicy` instead of calling this directly, so
/// `Session::builder().schedule(..)` governs the whole run.
pub const DEFAULT_POLICY: &dyn SchedulePolicy = &OneFOneBKp;

/// What an inter-stage transfer carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Payload {
    /// Forward boundary activations (stage p -> p+1).
    Activation,
    /// Backward boundary gradients (stage p -> p-1).
    Gradient,
}

/// One scheduled unit of work on a device timeline.
///
/// Compute tasks carry a **weight-version tag**: the number of
/// intra-round weight updates applied on this device before the task
/// runs.  Synchronous policies accumulate gradients across the round
/// (no intra-round updates), so all their tags are 0 — a guarantee the
/// validator enforces.  A bounded-staleness policy
/// ([`AsyncPipe`], `max_staleness` > 0) applies one update per
/// backward: its `Fwd` tag names the version the forward *reads*, its
/// `Bwd`/`BwdW` tags name the stashed version the gradient is computed
/// against (weight stashing — always the version its own `Fwd` read),
/// and the validator bounds how far any read may lag the update
/// frontier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Forward pass of one micro-batch (this device's share of it),
    /// reading weight version `version`.
    Fwd { micro: usize, version: usize },
    /// Backward pass of one micro-batch, computed against stashed
    /// weight version `version` (= its `Fwd`'s tag).  Under a
    /// split-backward policy this is the input-gradient half only (the
    /// part that feeds the upstream `Send`); otherwise it is the full
    /// backward.
    Bwd { micro: usize, version: usize },
    /// Deferred weight-gradient half of a split backward (zero-bubble
    /// policies), against the same stashed version as its `Bwd`.
    /// Purely local compute: no transfers, and the micro's activation
    /// residency was already released by its `Bwd`.
    BwdW { micro: usize, version: usize },
    /// Transfer to a peer device; placed right after the producing
    /// compute task.  `bytes` may be 0 in runtime-built schedules,
    /// where actual tensor sizes are only known at execution time.
    Send { micro: usize, to: usize, payload: Payload, bytes: u64 },
    /// Transfer from a peer device; placed right before the consuming
    /// compute task (a dependency gate, not device-occupying work).
    Recv { micro: usize, from: usize, payload: Payload, bytes: u64 },
    /// Intra-stage ring AllReduce of the stage gradients — the group
    /// barrier that closes the round (bytes = stage weight bytes).
    AllReduce { bytes: u64 },
}

/// The ordered task list of one device for one HPP-Round.
#[derive(Debug, Clone)]
pub struct DeviceTimeline {
    /// Global device id.
    pub device: usize,
    /// Pipeline stage index.
    pub stage: usize,
    /// Slot within the stage group (parallel to `Stage::devices`).
    pub slot: usize,
    /// Samples per micro-batch this device computes: the stage
    /// allocation Y_s share under `SampleShard`, the full micro-batch
    /// size under `RoundRobin` (0 for idle slots).
    pub share: usize,
    /// The in-flight bound actually encoded in `tasks` (the policy's
    /// effective K_p, e.g. the whole micro load for GPipe; always the
    /// *per-round* window, also for multi-round steady-state builds).
    pub kp: usize,
    /// Weight-stash copies the policy charges for this timeline
    /// (`SchedulePolicy::weight_stash_copies` — recorded here so the
    /// simulator prices exactly what the planner budgeted, one source
    /// of truth).
    pub stash_copies: usize,
    pub tasks: Vec<Task>,
}

impl DeviceTimeline {
    /// The compute ops (Fwd/Bwd) of this timeline, in order.
    pub fn compute_ops(&self) -> Vec<ComputeOp> {
        self.tasks
            .iter()
            .filter_map(|t| match *t {
                Task::Fwd { micro, .. } => Some(ComputeOp::Fwd(micro)),
                Task::Bwd { micro, .. } => Some(ComputeOp::Bwd(micro)),
                Task::BwdW { micro, .. } => Some(ComputeOp::BwdW(micro)),
                _ => None,
            })
            .collect()
    }

    /// Number of forward tasks on this timeline (= its assigned micro
    /// count, times the encoded round count for steady-state builds).
    pub fn num_fwd(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| matches!(t, Task::Fwd { .. }))
            .count()
    }

    fn same_work(&self, other: &DeviceTimeline) -> bool {
        self.stage == other.stage && self.share == other.share && self.tasks == other.tasks
    }
}

/// How micro-batches map onto the devices of a stage group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sharding {
    /// Paper Fig. 10: every device processes its sample slice of every
    /// micro-batch; inter-stage transfers carry exactly the activation
    /// rows two devices share.  This is what the simulator prices.
    SampleShard,
    /// Whole micro-batches round-robin across the group (micro m ->
    /// slot m mod g).  This is what the live runtime executes, because
    /// the AOT stage executables are shape-specialised to the planned
    /// micro-batch size (see `pipeline::worker`).
    RoundRobin,
}

/// A full HPP-Round schedule: one timeline per participating device.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// One ordered task list per participating device.
    pub timelines: Vec<DeviceTimeline>,
    /// Micro-batches per HPP-Round (`rounds` rounds are encoded when
    /// the schedule was built for steady-state pricing, with round r's
    /// micros offset by `r * num_micro`).
    pub num_micro: usize,
    /// Pipeline depth of the generating plan.
    pub num_stages: usize,
    /// How micro-batches map onto the devices of a stage group.
    pub sharding: Sharding,
    /// Name of the policy that generated the compute order.
    pub policy: &'static str,
    /// The policy's bounded-staleness budget σ (0 = synchronous; the
    /// validator then requires every weight-version tag to be 0).
    pub max_staleness: usize,
    /// HPP-Rounds encoded back-to-back in the timelines (1 for every
    /// consumer except the steady-state async pricing path).
    pub rounds: usize,
}

/// Sharding-specific wiring consumed by the single schedule builder:
/// which micros a slot runs, its per-micro sample share, and the peer
/// fan-out toward the previous/next stage.  Gradient routing is always
/// the mirror of activation routing, so two direction queries suffice.
/// Peer queries fill a caller-provided buffer (cleared first): the
/// build loop runs them once per compute task, and for both routers
/// the peer *count* is micro-independent — which also lets the builder
/// pre-size each timeline's task vector exactly.
trait Router {
    /// Micro ids assigned to (stage, slot), ascending.
    fn assign(&self, p: usize, slot: usize) -> Vec<usize>;
    /// Samples per micro-batch this slot computes (0 = idle).
    fn share(&self, p: usize, slot: usize) -> usize;
    /// Previous-stage peers feeding (stage, slot) for `micro`:
    /// (device, bytes).  Also the Gradient-Send fan-out of Bwd.
    fn from_prev_into(&self, p: usize, slot: usize, micro: usize, out: &mut Vec<(usize, u64)>);
    /// Next-stage peers fed by (stage, slot) for `micro`.  Also the
    /// Gradient-Recv fan-in of Bwd.
    fn to_next_into(&self, p: usize, slot: usize, micro: usize, out: &mut Vec<(usize, u64)>);
    /// Ring-AllReduce payload of stage `p` (0 if unknown at build time).
    fn allreduce_bytes(&self, p: usize) -> u64;
}

/// Fig. 10 sample sharding: every device runs every micro on its
/// sample slice; transfers carry exactly the overlapping rows.
struct SampleShardRouter<'a> {
    plan: &'a Plan,
    model: &'a ModelDesc,
    /// Per adjacent stage pair: bytes[from_slot][to_slot] of shared
    /// activation rows for one micro-batch.
    routes: Vec<Vec<Vec<u64>>>,
}

impl<'a> SampleShardRouter<'a> {
    fn new(plan: &'a Plan, model: &'a ModelDesc) -> Self {
        // Two range buffers reused across every adjacent stage pair.
        let mut from_ranges: Vec<(usize, usize)> = Vec::new();
        let mut to_ranges: Vec<(usize, usize)> = Vec::new();
        let mut routes = Vec::with_capacity(plan.stages.len().saturating_sub(1));
        for w in plan.stages.windows(2) {
            let a = model.boundary_bytes(w[0].layers.1); // per sample
            ranges_into(&w[0].alloc, &mut from_ranges);
            ranges_into(&w[1].alloc, &mut to_ranges);
            routes.push(
                from_ranges
                    .iter()
                    .map(|fr| {
                        to_ranges
                            .iter()
                            .map(|tr| a * overlap(*fr, *tr) as u64)
                            .collect()
                    })
                    .collect(),
            );
        }
        SampleShardRouter { plan, model, routes }
    }
}

impl Router for SampleShardRouter<'_> {
    fn assign(&self, p: usize, slot: usize) -> Vec<usize> {
        if self.plan.stages[p].alloc[slot] > 0 {
            (0..self.plan.num_micro).collect()
        } else {
            Vec::new()
        }
    }

    fn share(&self, p: usize, slot: usize) -> usize {
        self.plan.stages[p].alloc[slot]
    }

    fn from_prev_into(&self, p: usize, slot: usize, _micro: usize, out: &mut Vec<(usize, u64)>) {
        out.clear();
        let prev = &self.plan.stages[p - 1];
        out.extend(
            prev.devices
                .iter()
                .enumerate()
                .map(|(fs, &fd)| (fd, self.routes[p - 1][fs][slot]))
                .filter(|&(_, bytes)| bytes > 0),
        );
    }

    fn to_next_into(&self, p: usize, slot: usize, _micro: usize, out: &mut Vec<(usize, u64)>) {
        out.clear();
        let next = &self.plan.stages[p + 1];
        out.extend(
            next.devices
                .iter()
                .enumerate()
                .map(|(ts, &td)| (td, self.routes[p][slot][ts]))
                .filter(|&(_, bytes)| bytes > 0),
        );
    }

    fn allreduce_bytes(&self, p: usize) -> u64 {
        let s = &self.plan.stages[p];
        self.model.weight_bytes_range(s.layers.0, s.layers.1)
    }
}

/// Runtime sharding: whole micro-batches round-robin (micro m -> slot
/// m mod g); transfer sizes are only known at execution time (0 here).
struct RoundRobinRouter<'a> {
    plan: &'a Plan,
}

impl Router for RoundRobinRouter<'_> {
    fn assign(&self, p: usize, slot: usize) -> Vec<usize> {
        let g = self.plan.stages[p].devices.len();
        (0..self.plan.num_micro).filter(|m| m % g == slot).collect()
    }

    fn share(&self, p: usize, slot: usize) -> usize {
        if self.assign(p, slot).is_empty() {
            0
        } else {
            self.plan.microbatch
        }
    }

    fn from_prev_into(&self, p: usize, _slot: usize, micro: usize, out: &mut Vec<(usize, u64)>) {
        out.clear();
        let prev = &self.plan.stages[p - 1];
        out.push((prev.devices[micro % prev.devices.len()], 0));
    }

    fn to_next_into(&self, p: usize, _slot: usize, micro: usize, out: &mut Vec<(usize, u64)>) {
        out.clear();
        let next = &self.plan.stages[p + 1];
        out.push((next.devices[micro % next.devices.len()], 0));
    }

    fn allreduce_bytes(&self, _p: usize) -> u64 {
        0
    }
}

impl Schedule {
    /// Build the sample-sharded schedule the simulator prices: bytes on
    /// every transfer come from the model's boundary activation sizes
    /// and the Fig. 10 sample-overlap routing.
    pub fn for_sim(plan: &Plan, model: &ModelDesc, policy: &dyn SchedulePolicy) -> Schedule {
        Schedule::for_sim_rounds(plan, model, policy, 1)
    }

    /// Like [`Schedule::for_sim`], but encoding `rounds` HPP-Rounds
    /// back-to-back in one continuous timeline (round r's micros are
    /// offset by `r * num_micro`).  For a bounded-staleness policy this
    /// is the steady-state form: there is no inter-round barrier, so
    /// the policy's admission window lets round r+1's forwards fill
    /// round r's drain — what `sim::price` prices to measure
    /// async throughput honestly.  The round-closing AllReduce is
    /// charged once with `rounds`× the volume (the σ-bounded group
    /// syncs overlap compute in steady state).
    pub fn for_sim_rounds(
        plan: &Plan,
        model: &ModelDesc,
        policy: &dyn SchedulePolicy,
        rounds: usize,
    ) -> Schedule {
        Schedule::build(
            plan,
            policy,
            Sharding::SampleShard,
            &SampleShardRouter::new(plan, model),
            rounds,
        )
    }

    /// Build the round-robin schedule the live runtime executes: micro
    /// m runs on slot `m % g`, and transfers carry whole micro-batch
    /// tensors (bytes unknown until execution time, recorded as 0).
    pub fn for_runtime(plan: &Plan, policy: &dyn SchedulePolicy) -> Schedule {
        Schedule::build(plan, policy, Sharding::RoundRobin, &RoundRobinRouter { plan }, 1)
    }

    /// The one task-emission core both builders share: Recvs gate the
    /// compute that consumes them, Sends trail the compute that
    /// produces them, AllReduce closes multi-device stages, and every
    /// compute task is tagged with the weight version it reads (all 0
    /// under a synchronous policy; incremented per backward under a
    /// bounded-staleness one).
    fn build(
        plan: &Plan,
        policy: &dyn SchedulePolicy,
        sharding: Sharding,
        router: &dyn Router,
        rounds: usize,
    ) -> Schedule {
        let rounds = rounds.max(1);
        let m_total = plan.num_micro;
        let n_stages = plan.stages.len();
        // Per-micro weight updates only under bounded staleness;
        // synchronous rounds accumulate and keep version 0 throughout.
        let versioned = policy.max_staleness() > 0;
        let mut timelines =
            Vec::with_capacity(plan.stages.iter().map(|s| s.devices.len()).sum());
        // Peer scratch reused across every task emission below.
        let mut peers: Vec<(usize, u64)> = Vec::new();
        for (p, stage) in plan.stages.iter().enumerate() {
            for (slot, &d) in stage.devices.iter().enumerate() {
                // Round r repeats the base assignment offset by
                // r * m_total; extend in place instead of cloning.
                let mut micros = router.assign(p, slot);
                let base_len = micros.len();
                for r in 1..rounds {
                    for i in 0..base_len {
                        let m = micros[i] + r * m_total;
                        micros.push(m);
                    }
                }
                let mut ops = policy.compute_order(&micros, stage.kp);
                // The per-round admission window — what the planner's
                // Eq. 3 budget charged (effective_kp clamps at the
                // per-round load).  A multi-round chain must respect
                // the same bound: a policy whose raw window exceeds the
                // per-round load would otherwise admit more in-flight
                // micros across the round boundary than any budget
                // ever priced, so the chained order is re-windowed.
                let round_kp = policy.effective_kp(stage.kp, base_len);
                if rounds > 1 {
                    ops = rewindow(ops, round_kp);
                }
                // Both routers' peer counts are micro-independent, so
                // one probe prices the exact task count: each Fwd/Bwd
                // is 1 compute + fanin + fanout transfers, each BwdW is
                // 1, plus the closing AllReduce on multi-device stages.
                let (fanin, fanout) = match micros.first() {
                    Some(&m0) => {
                        let m0 = m0 % m_total;
                        let fanin = if p > 0 {
                            router.from_prev_into(p, slot, m0, &mut peers);
                            peers.len()
                        } else {
                            0
                        };
                        let fanout = if p + 1 < n_stages {
                            router.to_next_into(p, slot, m0, &mut peers);
                            peers.len()
                        } else {
                            0
                        };
                        (fanin, fanout)
                    }
                    None => (0, 0),
                };
                let (mut nf, mut nb, mut nw) = (0usize, 0usize, 0usize);
                for op in &ops {
                    match op {
                        ComputeOp::Fwd(_) => nf += 1,
                        ComputeOp::Bwd(_) => nb += 1,
                        ComputeOp::BwdW(_) => nw += 1,
                    }
                }
                let cap = nf * (1 + fanin + fanout)
                    + nb * (1 + fanin + fanout)
                    + nw
                    + usize::from(stage.devices.len() > 1);
                let mut tasks = Vec::with_capacity(cap);
                let mut updates = 0usize; // backwards applied so far
                let mut read_version: HashMap<usize, usize> = HashMap::new();
                for op in ops {
                    match op {
                        ComputeOp::Fwd(m) => {
                            if p > 0 {
                                router.from_prev_into(p, slot, m % m_total, &mut peers);
                                for &(from, bytes) in &peers {
                                    tasks.push(Task::Recv {
                                        micro: m,
                                        from,
                                        payload: Payload::Activation,
                                        bytes,
                                    });
                                }
                            }
                            let version = if versioned { updates } else { 0 };
                            read_version.insert(m, version);
                            tasks.push(Task::Fwd { micro: m, version });
                            if p + 1 < n_stages {
                                router.to_next_into(p, slot, m % m_total, &mut peers);
                                for &(to, bytes) in &peers {
                                    tasks.push(Task::Send {
                                        micro: m,
                                        to,
                                        payload: Payload::Activation,
                                        bytes,
                                    });
                                }
                            }
                        }
                        ComputeOp::Bwd(m) => {
                            if p + 1 < n_stages {
                                router.to_next_into(p, slot, m % m_total, &mut peers);
                                for &(from, bytes) in &peers {
                                    tasks.push(Task::Recv {
                                        micro: m,
                                        from,
                                        payload: Payload::Gradient,
                                        bytes,
                                    });
                                }
                            }
                            // Weight stashing: the backward runs against
                            // the version its forward read.
                            let version = read_version.get(&m).copied().unwrap_or(0);
                            tasks.push(Task::Bwd { micro: m, version });
                            if versioned {
                                updates += 1;
                            }
                            if p > 0 {
                                router.from_prev_into(p, slot, m % m_total, &mut peers);
                                for &(to, bytes) in &peers {
                                    tasks.push(Task::Send {
                                        micro: m,
                                        to,
                                        payload: Payload::Gradient,
                                        bytes,
                                    });
                                }
                            }
                        }
                        // Weight-grad halves are pure local compute:
                        // no transfer fan-out in either direction.
                        ComputeOp::BwdW(m) => tasks.push(Task::BwdW {
                            micro: m,
                            version: read_version.get(&m).copied().unwrap_or(0),
                        }),
                    }
                }
                if stage.devices.len() > 1 {
                    tasks.push(Task::AllReduce {
                        bytes: router.allreduce_bytes(p) * rounds as u64,
                    });
                }
                debug_assert_eq!(
                    tasks.len(),
                    cap,
                    "task emission must match the pre-sized capacity"
                );
                timelines.push(DeviceTimeline {
                    device: d,
                    stage: p,
                    slot,
                    share: router.share(p, slot),
                    kp: round_kp,
                    stash_copies: policy.weight_stash_copies(stage.kp, base_len),
                    tasks,
                });
            }
        }
        Schedule {
            timelines,
            num_micro: m_total,
            num_stages: n_stages,
            sharding,
            policy: policy.name(),
            max_staleness: policy.max_staleness(),
            rounds,
        }
    }

    /// Timeline of a global device id.
    pub fn timeline(&self, device: usize) -> Option<&DeviceTimeline> {
        self.timelines.iter().find(|t| t.device == device)
    }

    /// Timeline of a (stage, slot) position.
    pub fn timeline_at(&self, stage: usize, slot: usize) -> Option<&DeviceTimeline> {
        self.timelines
            .iter()
            .find(|t| t.stage == stage && t.slot == slot)
    }

    /// The compute script a live worker at (stage, slot) executes.
    pub fn compute_script(&self, stage: usize, slot: usize) -> Vec<ComputeOp> {
        self.timeline_at(stage, slot)
            .map(|t| t.compute_ops())
            .unwrap_or_default()
    }

    /// Total task count across every timeline (bench/diagnostic aid).
    pub fn total_tasks(&self) -> usize {
        self.timelines.iter().map(|t| t.tasks.len()).sum()
    }

    /// Validate the IR's dependency invariants:
    ///   * every micro appears exactly once as Fwd and once as Bwd, in
    ///     that order, on each non-idle timeline;
    ///   * a split-backward timeline has exactly one BwdW per micro,
    ///     after that micro's Bwd (all-or-none per timeline);
    ///   * the **staleness bound**: the running in-flight count never
    ///     exceeds the timeline's effective K_p (which includes the
    ///     policy's staleness budget).  Under a synchronous schedule
    ///     (`max_staleness` = 0) every weight-version tag must be 0 —
    ///     the old strict guarantee, kept exactly.  Under bounded
    ///     staleness the tags must be consistent (a Fwd reads the
    ///     update count at its position; Bwd/BwdW carry their Fwd's
    ///     stashed version) and no backward may apply a gradient
    ///     computed more than `effective K_p − 1` updates ago — the
    ///     weight-stash window implied by the staleness bound;
    ///   * Send follows its producing compute, Recv precedes its
    ///     consuming compute;
    ///   * every Recv has exactly one matching Send (same endpoints,
    ///     micro, payload, bytes) and vice versa;
    ///   * the whole schedule is deadlock-free: an abstract execution
    ///     (which only delivers a Recv after its matching Send has
    ///     executed on the peer) drains every timeline.
    pub fn validate(&self) -> Result<()> {
        let versioned = self.max_staleness > 0;
        for tl in &self.timelines {
            let d = tl.device;
            let mut fwd_pos: HashMap<usize, usize> = HashMap::new();
            let mut fwd_ver: HashMap<usize, usize> = HashMap::new();
            let mut bwd_pos: HashMap<usize, usize> = HashMap::new();
            let mut bww_pos: HashMap<usize, usize> = HashMap::new();
            let mut inflight: usize = 0;
            let mut peak: usize = 0;
            let mut updates: usize = 0;
            for (k, t) in tl.tasks.iter().enumerate() {
                match *t {
                    Task::Fwd { micro, version } => {
                        if fwd_pos.insert(micro, k).is_some() {
                            bail!("device {d}: duplicate Fwd for micro {micro}");
                        }
                        let expect = if versioned { updates } else { 0 };
                        if version != expect {
                            bail!(
                                "device {d}: Fwd of micro {micro} tagged version \
                                 {version}, expected {expect}"
                            );
                        }
                        fwd_ver.insert(micro, version);
                        inflight += 1;
                        peak = peak.max(inflight);
                    }
                    Task::Bwd { micro, version } => {
                        if !fwd_pos.contains_key(&micro) {
                            bail!("device {d}: Bwd before Fwd for micro {micro}");
                        }
                        if bwd_pos.insert(micro, k).is_some() {
                            bail!("device {d}: duplicate Bwd for micro {micro}");
                        }
                        if version != fwd_ver[&micro] {
                            bail!(
                                "device {d}: Bwd of micro {micro} tagged version \
                                 {version}, its Fwd read {}",
                                fwd_ver[&micro]
                            );
                        }
                        if versioned {
                            // Staleness bound: the applied gradient was
                            // computed inside the weight-stash window.
                            let lag = updates - version;
                            if lag + 1 > tl.kp.max(1) {
                                bail!(
                                    "device {d}: Bwd of micro {micro} applies a \
                                     gradient {lag} updates stale (window {})",
                                    tl.kp
                                );
                            }
                            updates += 1;
                        }
                        inflight -= 1;
                    }
                    Task::BwdW { micro, version } => {
                        if !bwd_pos.contains_key(&micro) {
                            bail!("device {d}: BwdW before Bwd for micro {micro}");
                        }
                        if bww_pos.insert(micro, k).is_some() {
                            bail!("device {d}: duplicate BwdW for micro {micro}");
                        }
                        if version != fwd_ver[&micro] {
                            bail!(
                                "device {d}: BwdW of micro {micro} tagged version \
                                 {version}, its Fwd read {}",
                                fwd_ver[&micro]
                            );
                        }
                    }
                    _ => {}
                }
            }
            if !bww_pos.is_empty() && bww_pos.len() != bwd_pos.len() {
                bail!(
                    "device {d}: partial backward split ({} BwdW for {} Bwd)",
                    bww_pos.len(),
                    bwd_pos.len()
                );
            }
            if peak > tl.kp.max(1) {
                bail!(
                    "device {d}: in-flight peak {peak} exceeds the K_p + staleness \
                     bound {}",
                    tl.kp
                );
            }
            if fwd_pos.len() != bwd_pos.len() {
                bail!(
                    "device {d}: {} forwards but {} backwards",
                    fwd_pos.len(),
                    bwd_pos.len()
                );
            }
            for (k, t) in tl.tasks.iter().enumerate() {
                match *t {
                    Task::Send { micro, payload, .. } => {
                        let pos = match payload {
                            Payload::Activation => fwd_pos.get(&micro),
                            Payload::Gradient => bwd_pos.get(&micro),
                        };
                        match pos {
                            Some(&p) if p < k => {}
                            _ => bail!(
                                "device {d}: Send of micro {micro} {payload:?} \
                                 before its producing compute"
                            ),
                        }
                    }
                    Task::Recv { micro, payload, .. } => {
                        let pos = match payload {
                            Payload::Activation => fwd_pos.get(&micro),
                            Payload::Gradient => bwd_pos.get(&micro),
                        };
                        match pos {
                            Some(&p) if p > k => {}
                            _ => bail!(
                                "device {d}: Recv of micro {micro} {payload:?} \
                                 after its consuming compute"
                            ),
                        }
                    }
                    _ => {}
                }
            }
        }

        // Cross-timeline matching: the send multiset equals the recv
        // multiset, keyed (from, to, micro, payload) -> bytes.
        let mut sends: HashMap<(usize, usize, usize, Payload), u64> = HashMap::new();
        let mut recvs: HashMap<(usize, usize, usize, Payload), u64> = HashMap::new();
        for tl in &self.timelines {
            for t in &tl.tasks {
                match *t {
                    Task::Send { micro, to, payload, bytes } => {
                        if sends.insert((tl.device, to, micro, payload), bytes).is_some() {
                            bail!(
                                "duplicate Send {}->{to} micro {micro} {payload:?}",
                                tl.device
                            );
                        }
                    }
                    Task::Recv { micro, from, payload, bytes } => {
                        if recvs.insert((from, tl.device, micro, payload), bytes).is_some() {
                            bail!(
                                "duplicate Recv {from}->{} micro {micro} {payload:?}",
                                tl.device
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
        if sends != recvs {
            for k in sends.keys() {
                if !recvs.contains_key(k) {
                    bail!("Send without matching Recv: {k:?}");
                }
            }
            for k in recvs.keys() {
                if !sends.contains_key(k) {
                    bail!("Recv without matching Send: {k:?}");
                }
            }
            bail!("Send/Recv byte mismatch");
        }

        self.check_executable()
    }

    /// Abstract (untimed) execution: repeatedly advance every timeline,
    /// delivering a Recv only once its matching Send has executed on
    /// the peer.  Fails on deadlock (a dependency cycle between the
    /// per-device total orders).
    fn check_executable(&self) -> Result<()> {
        let mut pos: Vec<usize> = vec![0; self.timelines.len()];
        let mut delivered: HashSet<(usize, usize, usize, Payload)> = HashSet::new();
        loop {
            let mut progressed = false;
            for (idx, tl) in self.timelines.iter().enumerate() {
                while pos[idx] < tl.tasks.len() {
                    match tl.tasks[pos[idx]] {
                        Task::Recv { micro, from, payload, .. } => {
                            if delivered.remove(&(from, tl.device, micro, payload)) {
                                pos[idx] += 1;
                                progressed = true;
                            } else {
                                break;
                            }
                        }
                        Task::Send { micro, to, payload, .. } => {
                            delivered.insert((tl.device, to, micro, payload));
                            pos[idx] += 1;
                            progressed = true;
                        }
                        _ => {
                            pos[idx] += 1;
                            progressed = true;
                        }
                    }
                }
            }
            if pos
                .iter()
                .zip(&self.timelines)
                .all(|(&p, tl)| p == tl.tasks.len())
            {
                return Ok(());
            }
            if !progressed {
                let (idx, _) = pos
                    .iter()
                    .zip(&self.timelines)
                    .enumerate()
                    .map(|(i, (p, tl))| (i, tl.tasks.len() - p))
                    .find(|&(_, rem)| rem > 0)
                    .unwrap();
                let tl = &self.timelines[idx];
                bail!(
                    "schedule deadlocks: device {} blocked at task {:?} \
                     (position {}/{})",
                    tl.device,
                    tl.tasks[pos[idx]],
                    pos[idx],
                    tl.tasks.len()
                );
            }
        }
    }
}

/// What changed between two schedules — the basis for fault-recovery
/// ordering: replay re-injects exactly the micro-batches whose
/// in-flight activations died with the removed devices, and only
/// retasked devices need new scripts.
#[derive(Debug, Clone, Default)]
pub struct ScheduleDiff {
    /// Devices present before but not after (the failed set).
    pub removed: Vec<usize>,
    /// Devices present after but not before.
    pub added: Vec<usize>,
    /// Devices whose timeline changed (stage, share or task order).
    pub retasked: Vec<usize>,
    /// Devices whose timeline is byte-identical (no re-dispatch).
    pub unchanged: Vec<usize>,
    /// Micro-batches in-flight on the removed devices (their warm-up
    /// prefix in the old schedule), in re-injection order.
    pub replay_micros: Vec<usize>,
}

/// Diff two schedules of the same workload (old: pre-failure, new:
/// post-failure).
pub fn diff(old: &Schedule, new: &Schedule) -> ScheduleDiff {
    let o: BTreeMap<usize, &DeviceTimeline> =
        old.timelines.iter().map(|t| (t.device, t)).collect();
    let n: BTreeMap<usize, &DeviceTimeline> =
        new.timelines.iter().map(|t| (t.device, t)).collect();
    let mut out = ScheduleDiff::default();
    let mut replay: Vec<usize> = Vec::new();
    for (&d, tl) in &o {
        match n.get(&d) {
            None => {
                out.removed.push(d);
                replay.extend(warmup_prefix(tl));
            }
            Some(ntl) => {
                if tl.same_work(ntl) {
                    out.unchanged.push(d);
                } else {
                    out.retasked.push(d);
                }
            }
        }
    }
    for &d in n.keys() {
        if !o.contains_key(&d) {
            out.added.push(d);
        }
    }
    replay.sort_unstable();
    replay.dedup();
    out.replay_micros = replay;
    out
}

/// Re-window a 1F1B-shaped compute order to an in-flight bound of
/// `window`: forwards that would exceed it are deferred (FIFO) until a
/// backward frees a slot.  Used by multi-round steady-state builds,
/// where the policy emitted its order over `rounds x M` micros and its
/// raw window may exceed the per-round budget the planner charged.
/// Preserves each micro's Fwd-before-Bwd order: a deferred `Fwd(m)` is
/// re-admitted by one of the at-least-`window` backwards that precede
/// `Bwd(m)` in the source order.
fn rewindow(ops: Vec<ComputeOp>, window: usize) -> Vec<ComputeOp> {
    let window = window.max(1);
    let mut out = Vec::with_capacity(ops.len());
    let mut deferred: std::collections::VecDeque<ComputeOp> = Default::default();
    let mut inflight = 0usize;
    for op in ops {
        match op {
            ComputeOp::Fwd(_) => {
                if inflight < window {
                    inflight += 1;
                    out.push(op);
                } else {
                    deferred.push_back(op);
                }
            }
            ComputeOp::Bwd(_) => {
                out.push(op);
                inflight -= 1;
                if let Some(f) = deferred.pop_front() {
                    inflight += 1;
                    out.push(f);
                }
            }
            ComputeOp::BwdW(_) => out.push(op),
        }
    }
    debug_assert!(deferred.is_empty(), "rewindow left forwards undrained");
    out.extend(deferred);
    out
}

/// The forwards a timeline admits before its first backward — the
/// micro-batches whose activations are resident during warm-up.
fn warmup_prefix(tl: &DeviceTimeline) -> Vec<usize> {
    let mut v = Vec::new();
    for t in &tl.tasks {
        match *t {
            Task::Bwd { .. } => break,
            Task::Fwd { micro, .. } => v.push(micro),
            _ => {}
        }
    }
    v
}

/// Contiguous sample ranges implied by an allocation, e.g. [3,5] ->
/// [(0,3), (3,8)] (Fig. 10 routing).
pub(crate) fn ranges(alloc: &[usize]) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(alloc.len());
    ranges_into(alloc, &mut out);
    out
}

/// [`ranges`] into a caller-provided buffer (cleared first), so hot
/// paths can reuse one allocation across many stage windows.
pub(crate) fn ranges_into(alloc: &[usize], out: &mut Vec<(usize, usize)>) {
    out.clear();
    out.reserve(alloc.len());
    let mut start = 0;
    for &y in alloc {
        out.push((start, start + y));
        start += y;
    }
}

pub(crate) fn overlap(a: (usize, usize), b: (usize, usize)) -> usize {
    a.1.min(b.1).saturating_sub(a.0.max(b.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::planner::plan::{Plan, Stage};

    fn two_stage_plan(model: &ModelDesc) -> Plan {
        let nl = model.num_layers();
        let mut p = Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0, 1], alloc: vec![5, 3], kp: 1 },
                Stage { layers: (nl / 2, nl), devices: vec![2], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 4,
        };
        p.apply_default_kp();
        p
    }

    #[test]
    fn ranges_and_overlap() {
        assert_eq!(ranges(&[3, 5]), vec![(0, 3), (3, 8)]);
        assert_eq!(overlap((0, 3), (2, 8)), 1);
        assert_eq!(overlap((0, 3), (3, 8)), 0);
        assert_eq!(overlap((0, 8), (2, 5)), 3);
    }

    #[test]
    fn sim_schedule_validates_and_routes_overlaps() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        sched.validate().unwrap();
        assert_eq!(sched.timelines.len(), 3);
        // Stage-1's device receives one activation chunk from each
        // stage-0 device per micro (both share samples with it).
        let tl2 = sched.timeline(2).unwrap();
        let recvs = tl2
            .tasks
            .iter()
            .filter(|t| {
                matches!(t, Task::Recv { payload: Payload::Activation, .. })
            })
            .count();
        assert_eq!(recvs, 2 * plan.num_micro);
        // Boundary bytes split 5:3 between the stage-0 devices.
        let a = model.boundary_bytes(plan.stages[0].layers.1);
        let mut seen = Vec::new();
        for t in &tl2.tasks {
            if let Task::Recv { bytes, payload: Payload::Activation, micro: 0, .. } = *t {
                seen.push(bytes);
            }
        }
        assert_eq!(seen, vec![5 * a, 3 * a]);
    }

    #[test]
    fn runtime_schedule_round_robins_micros() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let sched = Schedule::for_runtime(&plan, &OneFOneBKp);
        sched.validate().unwrap();
        // Slot 0 of stage 0 gets micros 0 and 2; slot 1 gets 1 and 3.
        let s00: Vec<ComputeOp> = sched.compute_script(0, 0);
        let s01: Vec<ComputeOp> = sched.compute_script(0, 1);
        let fwd_micros = |s: &[ComputeOp]| -> Vec<usize> {
            s.iter().filter(|o| o.is_fwd()).map(|o| o.micro()).collect()
        };
        assert_eq!(fwd_micros(&s00), vec![0, 2]);
        assert_eq!(fwd_micros(&s01), vec![1, 3]);
        // The single stage-1 device runs every micro.
        assert_eq!(fwd_micros(&sched.compute_script(1, 0)), vec![0, 1, 2, 3]);
    }

    #[test]
    fn gpipe_policy_produces_valid_fill_drain() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let sched = Schedule::for_sim(&plan, &model, &GpipeFillDrain);
        sched.validate().unwrap();
        // Every timeline's effective kp is its whole micro load.
        for tl in &sched.timelines {
            assert_eq!(tl.kp, plan.num_micro);
        }
    }

    #[test]
    fn zero_bubble_and_interleaved_schedules_validate() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let sched = Schedule::for_sim(&plan, &model, &ZeroBubbleH1);
        sched.validate().unwrap();
        for tl in &sched.timelines {
            // Same warm-up window as 1F1B, plus one BwdW per micro.
            let n_w = tl
                .tasks
                .iter()
                .filter(|t| matches!(t, Task::BwdW { .. }))
                .count();
            assert_eq!(n_w, plan.num_micro);
            assert_eq!(tl.kp, plan.stages[tl.stage].kp.min(plan.num_micro));
        }
        Schedule::for_runtime(&plan, &ZeroBubbleH1).validate().unwrap();
        let il = Interleaved { virtual_per_device: 2 };
        Schedule::for_sim(&plan, &model, &il).validate().unwrap();
        Schedule::for_runtime(&plan, &il).validate().unwrap();
    }

    #[test]
    fn validate_rejects_partial_backward_split() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let mut sched = Schedule::for_sim(&plan, &model, &ZeroBubbleH1);
        // Drop one weight-grad task: the split is no longer total.
        let tl = &mut sched.timelines[2];
        let w = tl
            .tasks
            .iter()
            .position(|t| matches!(t, Task::BwdW { .. }))
            .unwrap();
        tl.tasks.remove(w);
        assert!(sched.validate().is_err());
    }

    #[test]
    fn validate_rejects_bwd_before_fwd() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let mut sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        // Corrupt one timeline: swap the first Fwd with the first Bwd.
        let tl = &mut sched.timelines[2];
        let f = tl.tasks.iter().position(|t| matches!(t, Task::Fwd { .. })).unwrap();
        let b = tl.tasks.iter().position(|t| matches!(t, Task::Bwd { .. })).unwrap();
        tl.tasks.swap(f, b);
        assert!(sched.validate().is_err());
    }

    #[test]
    fn validate_rejects_dangling_recv() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let mut sched = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        // Drop the peer's first Send: the matching Recv now dangles.
        let tl = &mut sched.timelines[0];
        let s = tl.tasks.iter().position(|t| matches!(t, Task::Send { .. })).unwrap();
        tl.tasks.remove(s);
        assert!(sched.validate().is_err());
    }

    #[test]
    fn diff_reports_replay_window() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let old = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        // Post-failure plan: device 1 gone, stage 0 re-absorbed on 0.
        let nl = model.num_layers();
        let mut new_plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![8], kp: 1 },
                Stage { layers: (nl / 2, nl), devices: vec![2], alloc: vec![8], kp: 1 },
            ],
            microbatch: 8,
            num_micro: 4,
        };
        new_plan.apply_default_kp();
        let new = Schedule::for_sim(&new_plan, &model, &OneFOneBKp);
        let d = diff(&old, &new);
        assert_eq!(d.removed, vec![1]);
        assert!(d.added.is_empty());
        // Device 1 sat in stage 0 with K_p = 3: its warm-up window (3
        // forwards before the first backward) is the replay set.
        assert_eq!(d.replay_micros, vec![0, 1, 2]);
        // Device 0's share changed (5 -> 8 samples): retasked.
        assert!(d.retasked.contains(&0));
    }

    #[test]
    fn async_schedule_tags_versions_and_validates() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let a = AsyncPipe { max_staleness: 2 };
        let sched = Schedule::for_sim(&plan, &model, &a);
        sched.validate().unwrap();
        assert_eq!(sched.max_staleness, 2);
        assert_eq!(sched.rounds, 1);
        for tl in &sched.timelines {
            // Window = stage K_p + σ, clamped to the load.
            assert_eq!(tl.kp, (plan.stages[tl.stage].kp + 2).min(plan.num_micro));
            // Version tags: Fwd reads the update count at its position,
            // Bwd applies against its Fwd's stashed version.
            let mut updates = 0usize;
            let mut read: HashMap<usize, usize> = HashMap::new();
            for t in &tl.tasks {
                match *t {
                    Task::Fwd { micro, version } => {
                        assert_eq!(version, updates);
                        read.insert(micro, version);
                    }
                    Task::Bwd { micro, version } => {
                        assert_eq!(version, read[&micro]);
                        assert!(updates - version < tl.kp, "stash window exceeded");
                        updates += 1;
                    }
                    _ => {}
                }
            }
        }
        Schedule::for_runtime(&plan, &a).validate().unwrap();
        // Synchronous policies keep the all-versions-zero guarantee.
        let sync = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        for tl in &sync.timelines {
            for t in &tl.tasks {
                if let Task::Fwd { version, .. } | Task::Bwd { version, .. } = *t {
                    assert_eq!(version, 0);
                }
            }
        }
    }

    #[test]
    fn validate_rejects_corrupted_version_tag() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let mut sched = Schedule::for_sim(&plan, &model, &AsyncPipe { max_staleness: 1 });
        let tl = &mut sched.timelines[2];
        let b = tl
            .tasks
            .iter()
            .position(|t| matches!(t, Task::Bwd { .. }))
            .unwrap();
        if let Task::Bwd { version, .. } = &mut tl.tasks[b] {
            *version += 1; // claims to apply against a version its Fwd never read
        }
        assert!(sched.validate().is_err());
        // A synchronous schedule with a non-zero tag is equally invalid.
        let mut sync = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        let tl = &mut sync.timelines[0];
        let f = tl.tasks.iter().position(|t| matches!(t, Task::Fwd { .. })).unwrap();
        if let Task::Fwd { version, .. } = &mut tl.tasks[f] {
            *version = 1;
        }
        assert!(sync.validate().is_err());
    }

    #[test]
    fn multi_round_async_schedule_pipelines_across_the_boundary() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model); // M = 4
        let a = AsyncPipe { max_staleness: 2 };
        let sched = Schedule::for_sim_rounds(&plan, &model, &a, 3);
        sched.validate().unwrap();
        assert_eq!(sched.rounds, 3);
        assert_eq!(sched.num_micro, plan.num_micro);
        for tl in &sched.timelines {
            // All 3 rounds' micros flow through one continuous window.
            assert_eq!(tl.num_fwd(), 3 * plan.num_micro);
            // Round 1's first forwards are admitted before round 0 has
            // fully drained — the cross-round overlap a barrier forbids.
            let first_r1_fwd = tl
                .tasks
                .iter()
                .position(|t| matches!(t, Task::Fwd { micro, .. } if *micro >= plan.num_micro))
                .unwrap();
            let last_r0_bwd = tl
                .tasks
                .iter()
                .rposition(|t| matches!(t, Task::Bwd { micro, .. } if *micro < plan.num_micro))
                .unwrap();
            assert!(
                first_r1_fwd < last_r0_bwd,
                "device {}: no cross-round overlap",
                tl.device
            );
        }
    }

    #[test]
    fn multi_round_chain_respects_the_per_round_window() {
        // Regression: with kp + sigma exceeding the per-round load, the
        // raw chained order could admit up to rounds x M in-flight
        // micros — more than the Eq. 3 budget (clamped at M) the
        // planner validated.  The chain is re-windowed to the
        // per-round effective K_p.
        let model = zoo::mobilenet_v2();
        let nl = model.num_layers();
        let plan = Plan {
            stages: vec![
                Stage { layers: (0, nl / 2), devices: vec![0], alloc: vec![4], kp: 1 },
                Stage { layers: (nl / 2, nl), devices: vec![1], alloc: vec![4], kp: 1 },
            ],
            microbatch: 4,
            num_micro: 2, // M = 2 < kp + sigma = 4
        };
        let a = AsyncPipe { max_staleness: 3 };
        let sched = Schedule::for_sim_rounds(&plan, &model, &a, 4);
        sched.validate().unwrap(); // includes the peak <= tl.kp check
        for tl in &sched.timelines {
            assert_eq!(tl.kp, a.effective_kp(1, plan.num_micro)); // = 2
            let mut cur = 0usize;
            let mut peak = 0usize;
            for t in &tl.tasks {
                match t {
                    Task::Fwd { .. } => {
                        cur += 1;
                        peak = peak.max(cur);
                    }
                    Task::Bwd { .. } => cur -= 1,
                    _ => {}
                }
            }
            assert_eq!(peak, tl.kp, "chain admitted beyond the per-round window");
            assert_eq!(tl.num_fwd(), 4 * plan.num_micro);
        }
    }

    #[test]
    fn diff_identical_schedules_is_empty() {
        let model = zoo::mobilenet_v2();
        let plan = two_stage_plan(&model);
        let a = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        let b = Schedule::for_sim(&plan, &model, &OneFOneBKp);
        let d = diff(&a, &b);
        assert!(d.removed.is_empty() && d.added.is_empty() && d.retasked.is_empty());
        assert_eq!(d.unchanged.len(), 3);
        assert!(d.replay_micros.is_empty());
    }
}
