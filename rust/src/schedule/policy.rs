//! Schedule policies: how a device orders its FP/BP work.
//!
//! A policy turns "this device must forward and backward these
//! micro-batches, with warm-up depth K_p" into an explicit op order.
//! Everything downstream (simulator pricing, live workers, fault
//! replay) consumes the emitted order; no consumer re-derives it.
//!
//! Four built-in policies:
//!   * [`OneFOneBKp`] — the paper's 1F1B with a K_p warm-up window
//!     (§3.2): K_p forwards fill the pipeline, then strict
//!     one-backward-one-forward, then the backward drain.
//!   * [`GpipeFillDrain`] — GPipe-style fill-drain: every forward of
//!     the round, then every backward.  Its activation residency is
//!     O(M) instead of O(K_p) (Fig. 15(b)).
//!   * [`ZeroBubbleH1`] — ZB-H1-style split backward (Qi et al.): each
//!     backward is split into an input-gradient op ([`ComputeOp::Bwd`],
//!     which unblocks the upstream stage) and a deferred weight-gradient
//!     op ([`ComputeOp::BwdW`]) that fills the drain bubbles.
//!   * [`Interleaved`] — Megatron-style virtual chunks: the device's
//!     micros are partitioned round-robin into `virtual_per_device`
//!     chunks and run 1F1B in chunk-major order, so the next chunk's
//!     forwards overlap the previous chunk's backward drain.
//!
//! Adding a new schedule means adding a policy here — not touching the
//! simulator, the workers, or the fault machinery.

use std::collections::VecDeque;
use std::fmt;

/// One unit of compute work on a device: forward, backward, or (for
/// split-backward policies) the deferred weight-gradient half of a
/// backward, each identified by its round-global micro id.
///
/// Under a split-backward policy `Bwd` means the *input-gradient* half
/// only — the part on the inter-stage critical path — and `BwdW`
/// carries the weight-gradient half, schedulable anywhere after its
/// micro's `Bwd`.  Policies that do not split simply never emit `BwdW`,
/// and `Bwd` keeps its full-backward meaning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeOp {
    Fwd(usize),
    Bwd(usize),
    /// Deferred weight-gradient computation of a split backward.
    BwdW(usize),
}

impl ComputeOp {
    pub fn micro(&self) -> usize {
        match *self {
            ComputeOp::Fwd(m) | ComputeOp::Bwd(m) | ComputeOp::BwdW(m) => m,
        }
    }

    pub fn is_fwd(&self) -> bool {
        matches!(self, ComputeOp::Fwd(_))
    }
}

/// Fraction of the profiled full-backward time charged to the
/// input-gradient half (`Bwd`) when a policy splits the backward; the
/// weight-gradient half (`BwdW`) gets the rest.  Backward is roughly
/// one activation-gradient plus one weight-gradient GEMM of similar
/// cost, so the split conserves total compute: B + W = full backward.
pub const BWD_INPUT_FRAC: f64 = 0.5;

/// A schedule policy orders one device's FP/BP ops for an HPP-Round.
pub trait SchedulePolicy: fmt::Debug + Sync {
    fn name(&self) -> &'static str;

    /// Ordered FP/BP ops over this device's assigned micro ids
    /// (ascending), under the stage's warm-up depth `kp`.  Every micro
    /// must appear exactly once as `Fwd` and once as `Bwd`, with the
    /// `Fwd` first.  A split-backward policy additionally emits exactly
    /// one `BwdW` per micro, after that micro's `Bwd` (all-or-none: an
    /// order either splits every backward or none).
    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp>;

    /// The in-flight activation bound the emitted order actually
    /// respects (what Eq. 3 memory accounting should use).
    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize;
}

/// The paper's 1F1B with K_p warm-up (default policy, §3.2).
#[derive(Debug, Clone, Copy, Default)]
pub struct OneFOneBKp;

impl SchedulePolicy for OneFOneBKp {
    fn name(&self) -> &'static str {
        "1f1b-kp"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let n = micros.len();
        let k = self.effective_kp(kp, n);
        let mut ops = Vec::with_capacity(2 * n);
        // Warm-up: K_p forwards admitted before the first backward.
        for &m in micros.iter().take(k) {
            ops.push(ComputeOp::Fwd(m));
        }
        // Steady state: strict one-backward-one-forward.
        for i in k..n {
            ops.push(ComputeOp::Bwd(micros[i - k]));
            ops.push(ComputeOp::Fwd(micros[i]));
        }
        // Drain: the last K_p backwards.
        for &m in micros.iter().skip(n.saturating_sub(k)) {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        kp.clamp(1, n_micros.max(1))
    }
}

/// GPipe-style fill-drain: all forwards, then all backwards.  Ignores
/// K_p; the effective in-flight bound is the device's whole micro load.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpipeFillDrain;

impl SchedulePolicy for GpipeFillDrain {
    fn name(&self) -> &'static str {
        "gpipe-fill-drain"
    }

    fn compute_order(&self, micros: &[usize], _kp: usize) -> Vec<ComputeOp> {
        let mut ops = Vec::with_capacity(2 * micros.len());
        for &m in micros {
            ops.push(ComputeOp::Fwd(m));
        }
        for &m in micros {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, _kp: usize, n_micros: usize) -> usize {
        n_micros.max(1)
    }
}

/// Zero-bubble H1 (after Qi et al., "Zero Bubble Pipeline
/// Parallelism"): the 1F1B/K_p skeleton with every backward split into
/// an input-gradient op (`Bwd`, emitted in the 1F1B position so the
/// upstream gradient leaves as early as possible) and a weight-gradient
/// op (`BwdW`, deferred into the drain phase where 1F1B idles waiting
/// for downstream gradients, then flushed before the round closes).
/// The inter-stage critical path only carries the `Bwd` halves, so the
/// drain bubble of every non-dominant stage is filled with `BwdW` work
/// instead of idle time.
///
/// Activation residency is charged as in 1F1B (`Fwd` acquires, `Bwd`
/// releases): this reproduction's Eq. 3 model treats the weight-grad
/// half as operating on the stage's retained boundary input, a
/// simplification relative to the ZB paper's exact memory profile
/// (documented in `docs/SCHEDULE.md`).
#[derive(Debug, Clone, Copy, Default)]
pub struct ZeroBubbleH1;

impl SchedulePolicy for ZeroBubbleH1 {
    fn name(&self) -> &'static str {
        "zb-h1"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let n = micros.len();
        let k = self.effective_kp(kp, n);
        let mut ops = Vec::with_capacity(3 * n);
        let mut pending_w: VecDeque<usize> = VecDeque::new();
        // Warm-up: identical to 1F1B.
        for &m in micros.iter().take(k) {
            ops.push(ComputeOp::Fwd(m));
        }
        // Steady state: B (input-grad only) then F; W deferred.
        for i in k..n {
            ops.push(ComputeOp::Bwd(micros[i - k]));
            pending_w.push_back(micros[i - k]);
            ops.push(ComputeOp::Fwd(micros[i]));
        }
        // Drain: each remaining B is chased by one deferred W — the
        // slot where 1F1B waits on the downstream gradient.
        for &m in micros.iter().skip(n.saturating_sub(k)) {
            ops.push(ComputeOp::Bwd(m));
            pending_w.push_back(m);
            if let Some(w) = pending_w.pop_front() {
                ops.push(ComputeOp::BwdW(w));
            }
        }
        // Flush the rest before the round's AllReduce.
        while let Some(w) = pending_w.pop_front() {
            ops.push(ComputeOp::BwdW(w));
        }
        ops
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        kp.clamp(1, n_micros.max(1))
    }
}

/// Megatron-style interleaved schedule, expressed at the policy level:
/// the device's micros are partitioned round-robin into
/// `virtual_per_device` virtual chunks (chunk c = micros with
/// `m % v == c`) and the 1F1B/K_p order runs chunk-major, so chunk
/// c+1's forwards fill chunk c's backward drain.  The chunk key is a
/// function of the round-global micro id alone, so every stage and slot
/// orders its micros consistently with one global priority — the
/// property that keeps the cross-stage schedule deadlock-free under
/// both sharding modes.
///
/// Scope note: the chunk-major reordering is effective under
/// `Sharding::SampleShard` (the planner/simulator path, where every
/// device runs every micro).  Under the runtime's `RoundRobin`
/// sharding, a slot whose group size shares a factor with `v` sees a
/// constant `m % v` (its residue class *is* a virtual chunk), so the
/// local order intentionally reduces to plain 1F1B — a non-constant
/// key there would break the single-global-priority property and
/// reintroduce cross-stage deadlocks.
#[derive(Debug, Clone, Copy)]
pub struct Interleaved {
    /// Virtual stage chunks per device (Megatron's v); 2 is the
    /// built-in CLI variant.  Values are clamped to >= 1.
    pub virtual_per_device: usize,
}

impl Default for Interleaved {
    fn default() -> Self {
        Interleaved { virtual_per_device: 2 }
    }
}

impl SchedulePolicy for Interleaved {
    fn name(&self) -> &'static str {
        "interleaved"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let v = self.virtual_per_device.max(1);
        let mut perm: Vec<usize> = micros.to_vec();
        perm.sort_by_key(|&m| (m % v, m / v));
        OneFOneBKp.compute_order(&perm, kp)
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        OneFOneBKp.effective_kp(kp, n_micros)
    }
}

/// Every built-in policy, in presentation order — what the CLI, the
/// property tests and the per-policy benches iterate over.
pub fn builtin_policies() -> [&'static dyn SchedulePolicy; 4] {
    [
        &OneFOneBKp,
        &GpipeFillDrain,
        &ZeroBubbleH1,
        &Interleaved { virtual_per_device: 2 },
    ]
}

/// Resolve a `--schedule` flag value to a policy.  Accepts each
/// policy's `name()` plus the common short spellings.
pub fn policy_by_name(name: &str) -> Option<&'static dyn SchedulePolicy> {
    Some(match name {
        "1f1b" | "1f1b-kp" | "default" => &OneFOneBKp,
        "gpipe" | "fill-drain" | "gpipe-fill-drain" => &GpipeFillDrain,
        "zb" | "zb-h1" | "zero-bubble" => &ZeroBubbleH1,
        "interleaved" | "interleaved-2" | "vpp" => &Interleaved { virtual_per_device: 2 },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inflight_peak(ops: &[ComputeOp]) -> usize {
        let mut cur = 0usize;
        let mut peak = 0usize;
        for op in ops {
            match op {
                ComputeOp::Fwd(_) => {
                    cur += 1;
                    peak = peak.max(cur);
                }
                ComputeOp::Bwd(_) => cur -= 1,
                ComputeOp::BwdW(_) => {}
            }
        }
        peak
    }

    #[test]
    fn one_f_one_b_canonical_order() {
        let ops = OneFOneBKp.compute_order(&[0, 1, 2, 3], 2);
        use ComputeOp::*;
        assert_eq!(
            ops,
            vec![Fwd(0), Fwd(1), Bwd(0), Fwd(2), Bwd(1), Fwd(3), Bwd(2), Bwd(3)]
        );
        assert_eq!(inflight_peak(&ops), 2);
    }

    #[test]
    fn one_f_one_b_kp_one_serialises() {
        let ops = OneFOneBKp.compute_order(&[0, 1, 2], 1);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Bwd(0), Fwd(1), Bwd(1), Fwd(2), Bwd(2)]);
    }

    #[test]
    fn one_f_one_b_kp_clamped_to_load() {
        // kp larger than the micro count degenerates to fill-drain.
        let ops = OneFOneBKp.compute_order(&[0, 1], 8);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Fwd(1), Bwd(0), Bwd(1)]);
        assert_eq!(OneFOneBKp.effective_kp(8, 2), 2);
    }

    #[test]
    fn gpipe_fill_drain_shape() {
        let ops = GpipeFillDrain.compute_order(&[0, 2, 4], 1);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Fwd(2), Fwd(4), Bwd(0), Bwd(2), Bwd(4)]);
        assert_eq!(inflight_peak(&ops), 3);
        assert_eq!(GpipeFillDrain.effective_kp(1, 3), 3);
    }

    #[test]
    fn zero_bubble_canonical_order() {
        // n = 4, kp = 3: warm-up F0..F2, one steady pair, then the
        // drain interleaves deferred weight-grads, then the flush.
        let ops = ZeroBubbleH1.compute_order(&[0, 1, 2, 3], 3);
        use ComputeOp::*;
        assert_eq!(
            ops,
            vec![
                Fwd(0),
                Fwd(1),
                Fwd(2),
                Bwd(0),
                Fwd(3),
                Bwd(1),
                BwdW(0),
                Bwd(2),
                BwdW(1),
                Bwd(3),
                BwdW(2),
                BwdW(3),
            ]
        );
        // Same 1F1B activation window; the W ops never hold activations.
        assert_eq!(inflight_peak(&ops), 3);
        assert_eq!(ZeroBubbleH1.effective_kp(3, 4), 3);
    }

    #[test]
    fn zero_bubble_every_weight_grad_after_its_input_grad() {
        for kp in 1..=6 {
            let micros: Vec<usize> = (0..7).collect();
            let ops = ZeroBubbleH1.compute_order(&micros, kp);
            assert_eq!(ops.len(), 3 * micros.len(), "kp={kp}");
            for &m in &micros {
                let b = ops.iter().position(|o| *o == ComputeOp::Bwd(m)).unwrap();
                let w = ops.iter().position(|o| *o == ComputeOp::BwdW(m)).unwrap();
                assert!(b < w, "kp={kp}: micro {m} weight-grad before input-grad");
            }
        }
    }

    #[test]
    fn interleaved_runs_chunks_in_global_key_order() {
        // v = 2 over micros 0..6: chunk 0 = evens, chunk 1 = odds,
        // chunk-major — the next chunk's forwards fill the drain.
        let ops = Interleaved { virtual_per_device: 2 }.compute_order(&[0, 1, 2, 3, 4, 5], 2);
        let fwd_order: Vec<usize> =
            ops.iter().filter(|o| o.is_fwd()).map(|o| o.micro()).collect();
        assert_eq!(fwd_order, vec![0, 2, 4, 1, 3, 5]);
        assert_eq!(inflight_peak(&ops), 2);
        // v = 1 degenerates to plain 1F1B.
        let one = Interleaved { virtual_per_device: 1 }.compute_order(&[0, 1, 2], 1);
        assert_eq!(one, OneFOneBKp.compute_order(&[0, 1, 2], 1));
    }

    #[test]
    fn empty_load_is_empty() {
        for policy in builtin_policies() {
            assert!(policy.compute_order(&[], 3).is_empty(), "{}", policy.name());
        }
    }

    #[test]
    fn every_micro_once_fwd_then_bwd() {
        for policy in builtin_policies() {
            for kp in 1..=5 {
                let micros: Vec<usize> = (0..7).map(|i| i * 3).collect();
                let ops = policy.compute_order(&micros, kp);
                for &m in &micros {
                    let f = ops.iter().position(|o| *o == ComputeOp::Fwd(m)).unwrap();
                    let b = ops.iter().position(|o| *o == ComputeOp::Bwd(m)).unwrap();
                    assert!(f < b, "{}: micro {m} bwd before fwd", policy.name());
                }
                // Split policies emit one BwdW per micro; others none.
                let n_w = ops.iter().filter(|o| matches!(o, ComputeOp::BwdW(_))).count();
                assert!(
                    n_w == 0 || n_w == micros.len(),
                    "{}: partial backward split",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn policy_by_name_resolves_all_builtins() {
        for policy in builtin_policies() {
            let resolved = policy_by_name(policy.name()).unwrap();
            assert_eq!(resolved.name(), policy.name());
        }
        assert!(policy_by_name("1f1b").is_some());
        assert!(policy_by_name("zb").is_some());
        assert!(policy_by_name("nope").is_none());
    }
}
