//! Schedule policies: how a device orders its FP/BP work.
//!
//! A policy turns "this device must forward and backward these
//! micro-batches, with warm-up depth K_p" into an explicit op order.
//! Everything downstream (simulator pricing, live workers, fault
//! replay) consumes the emitted order; no consumer re-derives it.
//!
//! Five built-in policies:
//!   * [`OneFOneBKp`] — the paper's 1F1B with a K_p warm-up window
//!     (§3.2): K_p forwards fill the pipeline, then strict
//!     one-backward-one-forward, then the backward drain.
//!   * [`GpipeFillDrain`] — GPipe-style fill-drain: every forward of
//!     the round, then every backward.  Its activation residency is
//!     O(M) instead of O(K_p) (Fig. 15(b)).
//!   * [`ZeroBubbleH1`] — ZB-H1-style split backward (Qi et al.): each
//!     backward is split into an input-gradient op ([`ComputeOp::Bwd`],
//!     which unblocks the upstream stage) and a deferred weight-gradient
//!     op ([`ComputeOp::BwdW`]) that fills the drain bubbles.
//!   * [`Interleaved`] — Megatron-style virtual chunks: the device's
//!     micros are partitioned round-robin into `virtual_per_device`
//!     chunks and run 1F1B in chunk-major order, so the next chunk's
//!     forwards overlap the previous chunk's backward drain.
//!   * [`AsyncPipe`] — AshPipe/PipeDream-flavoured bounded staleness:
//!     a stage may admit `Fwd(m + s)` (s ≤ `max_staleness`) before
//!     `Bwd(m)` has returned, applying weight updates per micro-batch
//!     against version-stashed parameters.  The first policy that
//!     changes the IR's *semantics* (weight-version tags on tasks,
//!     see `schedule::Task`) rather than just the task order.
//!
//! Adding a new schedule means adding a policy here — not touching the
//! simulator, the workers, or the fault machinery.

use std::collections::VecDeque;
use std::fmt;

/// One unit of compute work on a device: forward, backward, or (for
/// split-backward policies) the deferred weight-gradient half of a
/// backward, each identified by its round-global micro id.
///
/// Under a split-backward policy `Bwd` means the *input-gradient* half
/// only — the part on the inter-stage critical path — and `BwdW`
/// carries the weight-gradient half, schedulable anywhere after its
/// micro's `Bwd`.  Policies that do not split simply never emit `BwdW`,
/// and `Bwd` keeps its full-backward meaning.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeOp {
    /// Forward pass of the given micro-batch.
    Fwd(usize),
    /// Backward pass (or its input-gradient half under a split policy).
    Bwd(usize),
    /// Deferred weight-gradient computation of a split backward.
    BwdW(usize),
}

impl ComputeOp {
    /// The round-global micro-batch id this op works on.
    pub fn micro(&self) -> usize {
        match *self {
            ComputeOp::Fwd(m) | ComputeOp::Bwd(m) | ComputeOp::BwdW(m) => m,
        }
    }

    /// True for the forward variant.
    pub fn is_fwd(&self) -> bool {
        matches!(self, ComputeOp::Fwd(_))
    }
}

/// Fraction of the profiled full-backward time charged to the
/// input-gradient half (`Bwd`) when a policy splits the backward; the
/// weight-gradient half (`BwdW`) gets the rest.  Backward is roughly
/// one activation-gradient plus one weight-gradient GEMM of similar
/// cost, so the split conserves total compute: B + W = full backward.
pub const BWD_INPUT_FRAC: f64 = 0.5;

/// A schedule policy orders one device's FP/BP ops for an HPP-Round.
pub trait SchedulePolicy: fmt::Debug + Sync {
    /// Stable policy name; also the canonical `--schedule` spelling.
    fn name(&self) -> &'static str;

    /// Ordered FP/BP ops over this device's assigned micro ids
    /// (ascending), under the stage's warm-up depth `kp`.  Every micro
    /// must appear exactly once as `Fwd` and once as `Bwd`, with the
    /// `Fwd` first.  A split-backward policy additionally emits exactly
    /// one `BwdW` per micro, after that micro's `Bwd` (all-or-none: an
    /// order either splits every backward or none).
    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp>;

    /// The in-flight activation bound the emitted order actually
    /// respects (what Eq. 3 memory accounting should use).  For a
    /// bounded-staleness policy this *includes* the staleness budget:
    /// `effective_kp - max_staleness` is the policy's synchronous
    /// baseline window.
    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize;

    /// Bounded-staleness budget of the policy: how many weight updates
    /// a `Fwd` may miss relative to the policy's K_p-synchronous
    /// frontier (equivalently, how far the admission window extends
    /// beyond the synchronous `effective_kp`).  Synchronous policies
    /// return 0 — their rounds accumulate gradients and every task
    /// reads weight version 0 — and the IR validator holds them to
    /// that guarantee.  A non-zero value switches the whole stack to
    /// version-tagged semantics: `Schedule::build` tags every compute
    /// task with the weight version it reads/applies, the validator
    /// enforces the staleness bound instead of the strict
    /// one-Fwd-one-Bwd alternation, and the simulator prices the
    /// schedule in steady state (rounds pipelined through the drain).
    fn max_staleness(&self) -> usize {
        0
    }

    /// Extra whole-stage weight copies the policy's weight-version
    /// stash ring holds beyond the live parameters (what Eq. 3 charges;
    /// 0 for synchronous policies).  One snapshot is pinned per
    /// in-flight micro-batch, so the ring depth — and the worst-case
    /// distinct-version count — is the effective admission window,
    /// K_p + `max_staleness`.
    fn weight_stash_copies(&self, kp: usize, n_micros: usize) -> usize {
        if self.max_staleness() == 0 {
            0
        } else {
            self.effective_kp(kp, n_micros).saturating_sub(1)
        }
    }
}

/// The paper's 1F1B with K_p warm-up (default policy, §3.2).
#[derive(Debug, Clone, Copy, Default)]
pub struct OneFOneBKp;

impl SchedulePolicy for OneFOneBKp {
    fn name(&self) -> &'static str {
        "1f1b-kp"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let n = micros.len();
        let k = self.effective_kp(kp, n);
        let mut ops = Vec::with_capacity(2 * n);
        // Warm-up: K_p forwards admitted before the first backward.
        for &m in micros.iter().take(k) {
            ops.push(ComputeOp::Fwd(m));
        }
        // Steady state: strict one-backward-one-forward.
        for i in k..n {
            ops.push(ComputeOp::Bwd(micros[i - k]));
            ops.push(ComputeOp::Fwd(micros[i]));
        }
        // Drain: the last K_p backwards.
        for &m in micros.iter().skip(n.saturating_sub(k)) {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        kp.clamp(1, n_micros.max(1))
    }
}

/// GPipe-style fill-drain: all forwards, then all backwards.  Ignores
/// K_p; the effective in-flight bound is the device's whole micro load.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpipeFillDrain;

impl SchedulePolicy for GpipeFillDrain {
    fn name(&self) -> &'static str {
        "gpipe-fill-drain"
    }

    fn compute_order(&self, micros: &[usize], _kp: usize) -> Vec<ComputeOp> {
        let mut ops = Vec::with_capacity(2 * micros.len());
        for &m in micros {
            ops.push(ComputeOp::Fwd(m));
        }
        for &m in micros {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, _kp: usize, n_micros: usize) -> usize {
        n_micros.max(1)
    }
}

/// Zero-bubble H1 (after Qi et al., "Zero Bubble Pipeline
/// Parallelism"): the 1F1B/K_p skeleton with every backward split into
/// an input-gradient op (`Bwd`, emitted in the 1F1B position so the
/// upstream gradient leaves as early as possible) and a weight-gradient
/// op (`BwdW`, deferred into the drain phase where 1F1B idles waiting
/// for downstream gradients, then flushed before the round closes).
/// The inter-stage critical path only carries the `Bwd` halves, so the
/// drain bubble of every non-dominant stage is filled with `BwdW` work
/// instead of idle time.
///
/// Activation residency is charged as in 1F1B (`Fwd` acquires, `Bwd`
/// releases): this reproduction's Eq. 3 model treats the weight-grad
/// half as operating on the stage's retained boundary input, a
/// simplification relative to the ZB paper's exact memory profile
/// (documented in `docs/SCHEDULE.md`).
#[derive(Debug, Clone, Copy, Default)]
pub struct ZeroBubbleH1;

impl SchedulePolicy for ZeroBubbleH1 {
    fn name(&self) -> &'static str {
        "zb-h1"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let n = micros.len();
        let k = self.effective_kp(kp, n);
        let mut ops = Vec::with_capacity(3 * n);
        let mut pending_w: VecDeque<usize> = VecDeque::new();
        // Warm-up: identical to 1F1B.
        for &m in micros.iter().take(k) {
            ops.push(ComputeOp::Fwd(m));
        }
        // Steady state: B (input-grad only) then F; W deferred.
        for i in k..n {
            ops.push(ComputeOp::Bwd(micros[i - k]));
            pending_w.push_back(micros[i - k]);
            ops.push(ComputeOp::Fwd(micros[i]));
        }
        // Drain: each remaining B is chased by one deferred W — the
        // slot where 1F1B waits on the downstream gradient.
        for &m in micros.iter().skip(n.saturating_sub(k)) {
            ops.push(ComputeOp::Bwd(m));
            pending_w.push_back(m);
            if let Some(w) = pending_w.pop_front() {
                ops.push(ComputeOp::BwdW(w));
            }
        }
        // Flush the rest before the round's AllReduce.
        while let Some(w) = pending_w.pop_front() {
            ops.push(ComputeOp::BwdW(w));
        }
        ops
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        kp.clamp(1, n_micros.max(1))
    }
}

/// Megatron-style interleaved schedule, expressed at the policy level:
/// the device's micros are partitioned round-robin into
/// `virtual_per_device` virtual chunks (chunk c = micros with
/// `m % v == c`) and the 1F1B/K_p order runs chunk-major, so chunk
/// c+1's forwards fill chunk c's backward drain.  The chunk key is a
/// function of the round-global micro id alone, so every stage and slot
/// orders its micros consistently with one global priority — the
/// property that keeps the cross-stage schedule deadlock-free under
/// both sharding modes.
///
/// Scope note: the chunk-major reordering is effective under
/// `Sharding::SampleShard` (the planner/simulator path, where every
/// device runs every micro).  Under the runtime's `RoundRobin`
/// sharding, a slot whose group size shares a factor with `v` sees a
/// constant `m % v` (its residue class *is* a virtual chunk), so the
/// local order intentionally reduces to plain 1F1B — a non-constant
/// key there would break the single-global-priority property and
/// reintroduce cross-stage deadlocks.
#[derive(Debug, Clone, Copy)]
pub struct Interleaved {
    /// Virtual stage chunks per device (Megatron's v); 2 is the
    /// built-in CLI variant.  Values are clamped to >= 1.
    pub virtual_per_device: usize,
}

impl Default for Interleaved {
    fn default() -> Self {
        Interleaved { virtual_per_device: 2 }
    }
}

impl SchedulePolicy for Interleaved {
    fn name(&self) -> &'static str {
        "interleaved"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let v = self.virtual_per_device.max(1);
        let mut perm: Vec<usize> = micros.to_vec();
        perm.sort_by_key(|&m| (m % v, m / v));
        OneFOneBKp.compute_order(&perm, kp)
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        OneFOneBKp.effective_kp(kp, n_micros)
    }
}

/// AshPipe-style bounded-staleness pipeline (the async member of the
/// policy family, after PipeDream's weight stashing and SSP's bounded
/// staleness): the 1F1B/K_p skeleton with the admission window widened
/// by `max_staleness` — a stage may run `Fwd(m + s)` (s ≤
/// `max_staleness`) before `Bwd(m)` has returned, reading weights that
/// miss up to `max_staleness` updates relative to the K_p-synchronous
/// frontier.  Weight updates apply per micro-batch (not per round), so
/// backwards must run against the *stashed* version their forward read
/// — the live workers keep a bounded ring of parameter snapshots
/// (`runtime::ParamStash`), and Eq. 3 charges those stash copies via
/// [`SchedulePolicy::weight_stash_copies`].
///
/// This is the first policy that relaxes the IR's synchronous
/// invariant: its tasks carry non-zero weight-version tags, and the
/// validator checks the staleness bound (window ≤ K_p + σ, every
/// backward applied at most window − 1 updates after its read) instead
/// of the all-versions-zero guarantee the synchronous policies keep.
/// The payoff is priced in steady state: without a round barrier the
/// drain of round r overlaps the fill of round r+1, so the per-round
/// bubble strictly shrinks on heterogeneous chains (see
/// `sim::price` and the env-C test).
#[derive(Debug, Clone, Copy)]
pub struct AsyncPipe {
    /// Staleness budget σ: extra forwards admitted beyond the K_p
    /// window = weight updates a forward may miss.  0 degenerates to
    /// plain 1F1B/K_p order (but keeps per-micro update semantics).
    pub max_staleness: usize,
}

impl Default for AsyncPipe {
    fn default() -> Self {
        AsyncPipe { max_staleness: 1 }
    }
}

impl SchedulePolicy for AsyncPipe {
    fn name(&self) -> &'static str {
        // Exact `async:<s>` spelling for every sigma, so the recorded
        // policy name always round-trips through `policy_by_name` to
        // the same staleness budget.  Names beyond the static table
        // are interned once per distinct sigma.
        match self.max_staleness {
            0 => "async:0",
            1 => "async:1",
            2 => "async:2",
            3 => "async:3",
            4 => "async:4",
            s => interned_async_name(s),
        }
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        // The 1F1B shape over the widened window: σ extra in-flight
        // micros hide gradient latency the K_p window cannot.
        let n = micros.len();
        let k = self.effective_kp(kp, n);
        let mut ops = Vec::with_capacity(2 * n);
        for &m in micros.iter().take(k) {
            ops.push(ComputeOp::Fwd(m));
        }
        for i in k..n {
            ops.push(ComputeOp::Bwd(micros[i - k]));
            ops.push(ComputeOp::Fwd(micros[i]));
        }
        for &m in micros.iter().skip(n.saturating_sub(k)) {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        (kp + self.max_staleness).clamp(1, n_micros.max(1))
    }

    fn max_staleness(&self) -> usize {
        self.max_staleness
    }
}

/// The statically-allocated `AsyncPipe` variants `policy_by_name`
/// resolves without allocation (σ = index).
static ASYNC_PIPES: [AsyncPipe; 5] = [
    AsyncPipe { max_staleness: 0 },
    AsyncPipe { max_staleness: 1 },
    AsyncPipe { max_staleness: 2 },
    AsyncPipe { max_staleness: 3 },
    AsyncPipe { max_staleness: 4 },
];

/// `&'static AsyncPipe` for any σ: the table for the common budgets,
/// an interning map beyond it (policies are `&'static` by design, so
/// out-of-table instances are allocated once per distinct σ and kept
/// for the process lifetime — never once per lookup).
fn async_policy(sigma: usize) -> &'static AsyncPipe {
    use std::sync::{Mutex, OnceLock};
    if let Some(p) = ASYNC_PIPES.get(sigma) {
        return p;
    }
    static EXTRA: OnceLock<Mutex<std::collections::BTreeMap<usize, &'static AsyncPipe>>> =
        OnceLock::new();
    let mut map = EXTRA
        .get_or_init(|| Mutex::new(std::collections::BTreeMap::new()))
        .lock()
        .unwrap();
    *map.entry(sigma)
        .or_insert_with(|| Box::leak(Box::new(AsyncPipe { max_staleness: sigma })))
}

/// Interned `"async:<s>"` label for an out-of-table σ (one allocation
/// per distinct σ for the process lifetime).
fn interned_async_name(sigma: usize) -> &'static str {
    use std::sync::{Mutex, OnceLock};
    static NAMES: OnceLock<Mutex<std::collections::BTreeMap<usize, &'static str>>> =
        OnceLock::new();
    let mut map = NAMES
        .get_or_init(|| Mutex::new(std::collections::BTreeMap::new()))
        .lock()
        .unwrap();
    *map.entry(sigma)
        .or_insert_with(|| Box::leak(format!("async:{sigma}").into_boxed_str()))
}

/// Every built-in policy, in presentation order — what the CLI, the
/// property tests and the per-policy benches iterate over.
pub fn builtin_policies() -> [&'static dyn SchedulePolicy; 5] {
    [
        &OneFOneBKp,
        &GpipeFillDrain,
        &ZeroBubbleH1,
        &Interleaved { virtual_per_device: 2 },
        &ASYNC_PIPES[1],
    ]
}

/// Resolve a `--schedule` flag value to a policy.  Accepts each
/// policy's `name()` plus the common short spellings, and the
/// parameterised `async:<s>` staleness form (any σ; out-of-table
/// budgets are interned once per distinct σ).
pub fn policy_by_name(name: &str) -> Option<&'static dyn SchedulePolicy> {
    Some(match name {
        "1f1b" | "1f1b-kp" | "default" => &OneFOneBKp,
        "gpipe" | "fill-drain" | "gpipe-fill-drain" => &GpipeFillDrain,
        "zb" | "zb-h1" | "zero-bubble" => &ZeroBubbleH1,
        "interleaved" | "interleaved-2" | "vpp" => &Interleaved { virtual_per_device: 2 },
        "async" | "async-pipe" | "ashpipe" => &ASYNC_PIPES[1],
        other => async_policy(other.strip_prefix("async:")?.parse().ok()?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inflight_peak(ops: &[ComputeOp]) -> usize {
        let mut cur = 0usize;
        let mut peak = 0usize;
        for op in ops {
            match op {
                ComputeOp::Fwd(_) => {
                    cur += 1;
                    peak = peak.max(cur);
                }
                ComputeOp::Bwd(_) => cur -= 1,
                ComputeOp::BwdW(_) => {}
            }
        }
        peak
    }

    #[test]
    fn one_f_one_b_canonical_order() {
        let ops = OneFOneBKp.compute_order(&[0, 1, 2, 3], 2);
        use ComputeOp::*;
        assert_eq!(
            ops,
            vec![Fwd(0), Fwd(1), Bwd(0), Fwd(2), Bwd(1), Fwd(3), Bwd(2), Bwd(3)]
        );
        assert_eq!(inflight_peak(&ops), 2);
    }

    #[test]
    fn one_f_one_b_kp_one_serialises() {
        let ops = OneFOneBKp.compute_order(&[0, 1, 2], 1);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Bwd(0), Fwd(1), Bwd(1), Fwd(2), Bwd(2)]);
    }

    #[test]
    fn one_f_one_b_kp_clamped_to_load() {
        // kp larger than the micro count degenerates to fill-drain.
        let ops = OneFOneBKp.compute_order(&[0, 1], 8);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Fwd(1), Bwd(0), Bwd(1)]);
        assert_eq!(OneFOneBKp.effective_kp(8, 2), 2);
    }

    #[test]
    fn gpipe_fill_drain_shape() {
        let ops = GpipeFillDrain.compute_order(&[0, 2, 4], 1);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Fwd(2), Fwd(4), Bwd(0), Bwd(2), Bwd(4)]);
        assert_eq!(inflight_peak(&ops), 3);
        assert_eq!(GpipeFillDrain.effective_kp(1, 3), 3);
    }

    #[test]
    fn zero_bubble_canonical_order() {
        // n = 4, kp = 3: warm-up F0..F2, one steady pair, then the
        // drain interleaves deferred weight-grads, then the flush.
        let ops = ZeroBubbleH1.compute_order(&[0, 1, 2, 3], 3);
        use ComputeOp::*;
        assert_eq!(
            ops,
            vec![
                Fwd(0),
                Fwd(1),
                Fwd(2),
                Bwd(0),
                Fwd(3),
                Bwd(1),
                BwdW(0),
                Bwd(2),
                BwdW(1),
                Bwd(3),
                BwdW(2),
                BwdW(3),
            ]
        );
        // Same 1F1B activation window; the W ops never hold activations.
        assert_eq!(inflight_peak(&ops), 3);
        assert_eq!(ZeroBubbleH1.effective_kp(3, 4), 3);
    }

    #[test]
    fn zero_bubble_every_weight_grad_after_its_input_grad() {
        for kp in 1..=6 {
            let micros: Vec<usize> = (0..7).collect();
            let ops = ZeroBubbleH1.compute_order(&micros, kp);
            assert_eq!(ops.len(), 3 * micros.len(), "kp={kp}");
            for &m in &micros {
                let b = ops.iter().position(|o| *o == ComputeOp::Bwd(m)).unwrap();
                let w = ops.iter().position(|o| *o == ComputeOp::BwdW(m)).unwrap();
                assert!(b < w, "kp={kp}: micro {m} weight-grad before input-grad");
            }
        }
    }

    #[test]
    fn interleaved_runs_chunks_in_global_key_order() {
        // v = 2 over micros 0..6: chunk 0 = evens, chunk 1 = odds,
        // chunk-major — the next chunk's forwards fill the drain.
        let ops = Interleaved { virtual_per_device: 2 }.compute_order(&[0, 1, 2, 3, 4, 5], 2);
        let fwd_order: Vec<usize> =
            ops.iter().filter(|o| o.is_fwd()).map(|o| o.micro()).collect();
        assert_eq!(fwd_order, vec![0, 2, 4, 1, 3, 5]);
        assert_eq!(inflight_peak(&ops), 2);
        // v = 1 degenerates to plain 1F1B.
        let one = Interleaved { virtual_per_device: 1 }.compute_order(&[0, 1, 2], 1);
        assert_eq!(one, OneFOneBKp.compute_order(&[0, 1, 2], 1));
    }

    #[test]
    fn empty_load_is_empty() {
        for policy in builtin_policies() {
            assert!(policy.compute_order(&[], 3).is_empty(), "{}", policy.name());
        }
    }

    #[test]
    fn every_micro_once_fwd_then_bwd() {
        for policy in builtin_policies() {
            for kp in 1..=5 {
                let micros: Vec<usize> = (0..7).map(|i| i * 3).collect();
                let ops = policy.compute_order(&micros, kp);
                for &m in &micros {
                    let f = ops.iter().position(|o| *o == ComputeOp::Fwd(m)).unwrap();
                    let b = ops.iter().position(|o| *o == ComputeOp::Bwd(m)).unwrap();
                    assert!(f < b, "{}: micro {m} bwd before fwd", policy.name());
                }
                // Split policies emit one BwdW per micro; others none.
                let n_w = ops.iter().filter(|o| matches!(o, ComputeOp::BwdW(_))).count();
                assert!(
                    n_w == 0 || n_w == micros.len(),
                    "{}: partial backward split",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn policy_by_name_resolves_all_builtins() {
        for policy in builtin_policies() {
            let resolved = policy_by_name(policy.name()).unwrap();
            assert_eq!(resolved.name(), policy.name());
        }
        assert!(policy_by_name("1f1b").is_some());
        assert!(policy_by_name("zb").is_some());
        assert!(policy_by_name("nope").is_none());
    }

    #[test]
    fn async_pipe_widens_the_window_by_its_staleness_budget() {
        // σ = 2 over kp = 1: the admission window is 3 — Fwd(m + 2)
        // runs before Bwd(m) has returned, which 1F1B forbids.
        let a = AsyncPipe { max_staleness: 2 };
        let ops = a.compute_order(&[0, 1, 2, 3, 4], 1);
        use ComputeOp::*;
        assert_eq!(
            ops,
            vec![
                Fwd(0),
                Fwd(1),
                Fwd(2),
                Bwd(0),
                Fwd(3),
                Bwd(1),
                Fwd(4),
                Bwd(2),
                Bwd(3),
                Bwd(4),
            ]
        );
        assert_eq!(inflight_peak(&ops), 3);
        assert_eq!(a.effective_kp(1, 5), 3);
        // The widened window never exceeds the sync window by more
        // than σ, and σ = 0 degenerates to exactly 1F1B.
        for kp in 1..=4 {
            for n in 1..=8 {
                let sync = OneFOneBKp.effective_kp(kp, n);
                assert!(a.effective_kp(kp, n) <= sync + a.max_staleness);
            }
        }
        let a0 = AsyncPipe { max_staleness: 0 };
        assert_eq!(a0.compute_order(&[0, 1, 2], 2), OneFOneBKp.compute_order(&[0, 1, 2], 2));
    }

    #[test]
    fn async_pipe_charges_stash_copies_sync_policies_none() {
        let a = AsyncPipe { max_staleness: 2 };
        // Ring depth = effective window; one copy is the live weights.
        assert_eq!(a.weight_stash_copies(3, 8), 4); // window 5 -> 4 extra
        assert_eq!(a.weight_stash_copies(1, 1), 0); // window clamps to 1
        for policy in [
            &OneFOneBKp as &dyn SchedulePolicy,
            &GpipeFillDrain,
            &ZeroBubbleH1,
            &Interleaved { virtual_per_device: 2 },
        ] {
            assert_eq!(policy.max_staleness(), 0, "{}", policy.name());
            assert_eq!(policy.weight_stash_copies(3, 8), 0, "{}", policy.name());
        }
    }

    #[test]
    fn policy_by_name_parses_async_staleness() {
        for (spec, sigma) in
            [("async", 1), ("async-pipe", 1), ("async:0", 0), ("async:2", 2), ("async:4", 4)]
        {
            let p = policy_by_name(spec).unwrap();
            assert_eq!(p.max_staleness(), sigma, "{spec}");
        }
        // σ beyond the static table resolves, round-trips its exact
        // name, and is interned (same instance on every lookup, not a
        // fresh allocation per call).
        let p7 = policy_by_name("async:7").unwrap();
        assert_eq!(p7.max_staleness(), 7);
        assert_eq!(p7.name(), "async:7");
        assert_eq!(policy_by_name(p7.name()).unwrap().max_staleness(), 7);
        let again = policy_by_name("async:7").unwrap();
        assert!(std::ptr::eq(
            p7 as *const dyn SchedulePolicy as *const (),
            again as *const dyn SchedulePolicy as *const ()
        ));
        assert!(policy_by_name("async:x").is_none());
    }
}
