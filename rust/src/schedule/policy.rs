//! Schedule policies: how a device orders its FP/BP work.
//!
//! A policy turns "this device must forward and backward these
//! micro-batches, with warm-up depth K_p" into an explicit op order.
//! Everything downstream (simulator pricing, live workers, fault
//! replay) consumes the emitted order; no consumer re-derives it.
//!
//! Two built-in policies prove the abstraction:
//!   * [`OneFOneBKp`] — the paper's 1F1B with a K_p warm-up window
//!     (§3.2): K_p forwards fill the pipeline, then strict
//!     one-backward-one-forward, then the backward drain.
//!   * [`GpipeFillDrain`] — GPipe-style fill-drain: every forward of
//!     the round, then every backward.  Its activation residency is
//!     O(M) instead of O(K_p) (Fig. 15(b)).
//!
//! Adding a new schedule (zero-bubble, interleaved, ...) means adding a
//! policy here — not touching the simulator, the workers, or the fault
//! machinery.

/// One unit of compute work on a device: forward or backward of one
/// micro-batch (identified by its round-global micro id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeOp {
    Fwd(usize),
    Bwd(usize),
}

impl ComputeOp {
    pub fn micro(&self) -> usize {
        match *self {
            ComputeOp::Fwd(m) | ComputeOp::Bwd(m) => m,
        }
    }

    pub fn is_fwd(&self) -> bool {
        matches!(self, ComputeOp::Fwd(_))
    }
}

/// A schedule policy orders one device's FP/BP ops for an HPP-Round.
pub trait SchedulePolicy {
    fn name(&self) -> &'static str;

    /// Ordered FP/BP ops over this device's assigned micro ids
    /// (ascending), under the stage's warm-up depth `kp`.  Every micro
    /// must appear exactly once as `Fwd` and once as `Bwd`, with the
    /// `Fwd` first.
    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp>;

    /// The in-flight activation bound the emitted order actually
    /// respects (what Eq. 3 memory accounting should use).
    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize;
}

/// The paper's 1F1B with K_p warm-up (default policy, §3.2).
#[derive(Debug, Clone, Copy, Default)]
pub struct OneFOneBKp;

impl SchedulePolicy for OneFOneBKp {
    fn name(&self) -> &'static str {
        "1f1b-kp"
    }

    fn compute_order(&self, micros: &[usize], kp: usize) -> Vec<ComputeOp> {
        let n = micros.len();
        let k = self.effective_kp(kp, n);
        let mut ops = Vec::with_capacity(2 * n);
        // Warm-up: K_p forwards admitted before the first backward.
        for &m in micros.iter().take(k) {
            ops.push(ComputeOp::Fwd(m));
        }
        // Steady state: strict one-backward-one-forward.
        for i in k..n {
            ops.push(ComputeOp::Bwd(micros[i - k]));
            ops.push(ComputeOp::Fwd(micros[i]));
        }
        // Drain: the last K_p backwards.
        for &m in micros.iter().skip(n.saturating_sub(k)) {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, kp: usize, n_micros: usize) -> usize {
        kp.clamp(1, n_micros.max(1))
    }
}

/// GPipe-style fill-drain: all forwards, then all backwards.  Ignores
/// K_p; the effective in-flight bound is the device's whole micro load.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpipeFillDrain;

impl SchedulePolicy for GpipeFillDrain {
    fn name(&self) -> &'static str {
        "gpipe-fill-drain"
    }

    fn compute_order(&self, micros: &[usize], _kp: usize) -> Vec<ComputeOp> {
        let mut ops = Vec::with_capacity(2 * micros.len());
        for &m in micros {
            ops.push(ComputeOp::Fwd(m));
        }
        for &m in micros {
            ops.push(ComputeOp::Bwd(m));
        }
        ops
    }

    fn effective_kp(&self, _kp: usize, n_micros: usize) -> usize {
        n_micros.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inflight_peak(ops: &[ComputeOp]) -> usize {
        let mut cur = 0usize;
        let mut peak = 0usize;
        for op in ops {
            match op {
                ComputeOp::Fwd(_) => {
                    cur += 1;
                    peak = peak.max(cur);
                }
                ComputeOp::Bwd(_) => cur -= 1,
            }
        }
        peak
    }

    #[test]
    fn one_f_one_b_canonical_order() {
        let ops = OneFOneBKp.compute_order(&[0, 1, 2, 3], 2);
        use ComputeOp::*;
        assert_eq!(
            ops,
            vec![Fwd(0), Fwd(1), Bwd(0), Fwd(2), Bwd(1), Fwd(3), Bwd(2), Bwd(3)]
        );
        assert_eq!(inflight_peak(&ops), 2);
    }

    #[test]
    fn one_f_one_b_kp_one_serialises() {
        let ops = OneFOneBKp.compute_order(&[0, 1, 2], 1);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Bwd(0), Fwd(1), Bwd(1), Fwd(2), Bwd(2)]);
    }

    #[test]
    fn one_f_one_b_kp_clamped_to_load() {
        // kp larger than the micro count degenerates to fill-drain.
        let ops = OneFOneBKp.compute_order(&[0, 1], 8);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Fwd(1), Bwd(0), Bwd(1)]);
        assert_eq!(OneFOneBKp.effective_kp(8, 2), 2);
    }

    #[test]
    fn gpipe_fill_drain_shape() {
        let ops = GpipeFillDrain.compute_order(&[0, 2, 4], 1);
        use ComputeOp::*;
        assert_eq!(ops, vec![Fwd(0), Fwd(2), Fwd(4), Bwd(0), Bwd(2), Bwd(4)]);
        assert_eq!(inflight_peak(&ops), 3);
        assert_eq!(GpipeFillDrain.effective_kp(1, 3), 3);
    }

    #[test]
    fn empty_load_is_empty() {
        assert!(OneFOneBKp.compute_order(&[], 3).is_empty());
        assert!(GpipeFillDrain.compute_order(&[], 3).is_empty());
    }

    #[test]
    fn every_micro_once_fwd_then_bwd() {
        for policy in [&OneFOneBKp as &dyn SchedulePolicy, &GpipeFillDrain] {
            for kp in 1..=5 {
                let micros: Vec<usize> = (0..7).map(|i| i * 3).collect();
                let ops = policy.compute_order(&micros, kp);
                assert_eq!(ops.len(), 2 * micros.len(), "{}", policy.name());
                for &m in &micros {
                    let f = ops.iter().position(|o| *o == ComputeOp::Fwd(m)).unwrap();
                    let b = ops.iter().position(|o| *o == ComputeOp::Bwd(m)).unwrap();
                    assert!(f < b, "{}: micro {m} bwd before fwd", policy.name());
                }
            }
        }
    }
}
