//! `RpcBackend`: the multi-process edge execution backend.
//!
//! Each pipeline stage slot runs as a separate OS process (the
//! `asteroid-worker` binary) reachable over TCP; this driver speaks the
//! [`crate::comm::rpc`] protocol to them: it distributes the plan slice +
//! schedule script to every worker (control plane), feeds micro-batch
//! inputs/targets each HPP-Round, mediates replicated-stage round
//! sync, consumes heartbeats into the §3.4
//! [`HeartbeatMonitor`](crate::fault::HeartbeatMonitor), and — when the
//! session carries a [`FaultSpec`](super::FaultSpec) — injects a *real*
//! device exit: the target worker process dies unclean mid-round, the
//! monitor detects the silence, the session's recovery mechanism
//! re-plans, and the surviving processes are re-tasked over live
//! connections (warm-started from the driver-side checkpoint) to
//! replay the failed round and resume training.
//!
//! Workers execute the session's schedule policy end-to-end (all five,
//! including `async:<s>` weight-version stashing) over the
//! feature-independent
//! [`ReferenceStage`](crate::pipeline::step::ReferenceStage) kernel —
//! tensor shapes and transfer bytes are the planned model's, the
//! arithmetic is a learnable surrogate (see `pipeline::step`).  That is
//! what makes this backend exercisable in CI with no accelerator
//! binding: zoo sessions become live-runnable, not simulation-only.

use std::collections::{BTreeMap, BTreeSet};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::codec::Codec;
use crate::comm::rpc::{
    read_frame, send_msg, write_frame, AssignSpec, ConnRole, LayerState, RpcMsg, HEADER_LEN,
};
use crate::comm::SyncMode;
use crate::fault::{ChurnEvent, DriftDetector, HeartbeatCfg, HeartbeatMonitor, Liveness};
use crate::pipeline::rpc_worker::dial_with_retry;
use crate::pipeline::step::{reference_layers, RefTask};
use crate::planner::plan::Plan;
use crate::runtime::Tensor;
use crate::schedule::Schedule;

use super::churn::{ChurnSpec, ChurnState};
use super::{ExecutionBackend, RecoveryEvent, RecoveryKind, RunReport, Session};

/// How long the driver keeps dialling a worker address.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);
/// Deadline for all workers to acknowledge an assignment.
const READY_TIMEOUT: Duration = Duration::from_secs(60);
/// Deadline for one HPP-Round (and for shutdown/param collection).
const ROUND_TIMEOUT: Duration = Duration::from_secs(180);

/// Per-device control-plane accounting surfaced in
/// [`RunReport::rpc`](super::RunReport::rpc).
#[derive(Debug, Clone)]
pub struct RpcDeviceStats {
    /// Cluster device id this worker played.
    pub device: usize,
    /// The worker's listen address.
    pub addr: String,
    /// Heartbeats the driver consumed from this worker.
    pub heartbeats: u64,
    /// Rounds this worker reported complete.
    pub rounds_reported: u64,
    /// Mean worker-side round compute wall-clock (seconds).
    pub mean_round_compute_s: f64,
    /// Control-plane bytes driver -> worker (including the stage-0
    /// inputs / head targets the driver feeds).
    pub bytes_tx: u64,
    /// Control-plane bytes worker -> driver.
    pub bytes_rx: u64,
    /// Data-plane tensor payload bytes this worker sent, before the
    /// wire codec (worker-reported via `RoundDone`).
    pub dp_logical_bytes: u64,
    /// The same payloads as the codec put them on the wire — the
    /// measured compression ratio is `dp_wire / dp_logical`.
    pub dp_wire_bytes: u64,
    /// Round-sync wire bytes this worker transmitted (ring chunks
    /// under `SyncMode::Ring`, the `SyncRequest` upload under
    /// `DriverStar`) — worker-reported via `RoundDone`.
    pub sync_bytes: u64,
    /// Total wall-clock this worker spent in round-sync exchanges.
    pub sync_wall_s: f64,
    /// Control-plane messages the driver sent this worker.
    pub ctrl_msgs_tx: u64,
    /// Control-plane messages received from this worker (heartbeats
    /// included).
    pub ctrl_msgs_rx: u64,
}

/// RPC run telemetry: one row per worker the driver drove, plus the
/// measured failure-detection wall-clock when an exit was injected.
#[derive(Debug, Clone, Default)]
pub struct RpcStats {
    pub per_device: Vec<RpcDeviceStats>,
    /// Wall-clock from fault injection to heartbeat-confirmed death
    /// (None without a fault).  Compare with
    /// `HeartbeatCfg::detection_time`, the closed form the sim and the
    /// recovery report charge.
    pub detection_wall_s: Option<f64>,
    /// Round-sync frames the driver mediated (`SyncRequest` received +
    /// `SyncResult` sent).  Under `SyncMode::Ring` this is 0: the
    /// driver's per-round involvement is O(1) control messages per
    /// worker (StartRound out, RoundDone back) independent of replica
    /// width — the CI integration run asserts exactly that.
    pub sync_msgs: u64,
}

/// The multi-process execution backend: drives `asteroid-worker`
/// processes, one per (stage, slot) of the planned pipeline in
/// stage-major order.  Surplus addresses are ignored (those workers
/// are never contacted — recovery re-tasks survivors only).
pub struct RpcBackend {
    addrs: Vec<String>,
}

impl RpcBackend {
    /// A driver for already-running workers (`asteroid-worker --listen
    /// <addr>`).
    pub fn connect<S: Into<String>>(addrs: Vec<S>) -> RpcBackend {
        RpcBackend { addrs: addrs.into_iter().map(Into::into).collect() }
    }
}

impl ExecutionBackend for RpcBackend {
    fn name(&self) -> &'static str {
        "rpc"
    }

    fn run(&mut self, s: &Session) -> Result<RunReport> {
        let mut driver = Driver::new(&self.addrs, s)?;
        driver.run()
    }
}

// --------------------------------------------------------------- driver

enum Event {
    Msg(RpcMsg),
    Eof,
}

/// A polled, pre-filtered inbox item (heartbeats and sync requests are
/// absorbed before call sites see anything).
enum Polled {
    Msg(usize, RpcMsg),
    Eof(usize),
}

/// Driver-side handle of one worker process.
struct Remote {
    device: usize,
    addr: String,
    writer: TcpStream,
    alive: bool,
    heartbeats: u64,
    rounds_reported: u64,
    compute_s_sum: f64,
    bytes_tx: u64,
    bytes_rx: Arc<AtomicU64>,
    msgs_tx: u64,
    msgs_rx: Arc<AtomicU64>,
    dp_logical: u64,
    dp_wire: u64,
    sync_bytes: u64,
    sync_wall_s: f64,
}

impl Remote {
    fn send(&mut self, msg: &RpcMsg) -> Result<()> {
        self.send_codec(msg, Codec::Fp32)
    }

    /// Send with the wire codec applied to compressible payloads (the
    /// driver uses this for its `SyncResult` replies, mirroring the
    /// workers' compressed `SyncRequest` flats).
    fn send_codec(&mut self, msg: &RpcMsg, codec: Codec) -> Result<()> {
        let payload = msg.encode_with(codec);
        self.bytes_tx += payload.len() as u64 + HEADER_LEN as u64;
        self.msgs_tx += 1;
        write_frame(&mut self.writer, &payload)
            .with_context(|| format!("sending {} to device {}", msg.kind(), self.device))
    }
}

struct Driver<'s> {
    session: &'s Session,
    hb_cfg: HeartbeatCfg,
    /// Device id -> worker address (recovery plans reuse the surviving
    /// devices' workers; churn joins reconnect a restarted worker on
    /// the device's previous address, or draw from `spare_addrs`).
    remotes: BTreeMap<usize, Remote>,
    inbox: Receiver<(usize, Event)>,
    /// Sender half of the inbox — kept so churn joins can spawn reader
    /// threads for reconnected workers.
    tx: Sender<(usize, Event)>,
    /// Worker addresses beyond the initial plan's slots: the join pool
    /// for churn devices that never had a worker this run.
    spare_addrs: Vec<String>,
    /// Per-device compute wall-clock of the round in flight — the
    /// drift detector's feed in churn mode.
    last_round_compute: BTreeMap<usize, f64>,
    /// The plan currently executing (switches after a recovery).
    plan: Plan,
    sched: Schedule,
    monitor: HeartbeatMonitor,
    /// Layer -> state, refreshed after each round while a fault is
    /// spec'd — the coordinator-side replication store §3.4 restores
    /// from.
    checkpoint: BTreeMap<usize, LayerState>,
    /// Round-sync contributions per stage index: (device, kind, flat).
    sync_pending: BTreeMap<usize, Vec<(usize, u8, Vec<f32>)>>,
    /// Assignment generation (bumped per `assign_all`); every
    /// data-plane frame is tagged with it so stale in-flight tensors
    /// of an aborted round can never leak into the replayed one.
    generation: u64,
    detection_wall_s: Option<f64>,
    /// Driver-mediated sync frames (rx + tx); stays 0 under ring sync.
    sync_msgs: u64,
}

/// Churn-mode runtime the driver threads through a run: the trace
/// cursor, the evolving fleet state, the drift detector and the
/// injected-but-undetected slowdowns.
struct ChurnRt {
    spec: ChurnSpec,
    state: ChurnState,
    detector: DriftDetector,
    /// device -> (factor, injected_at) awaiting drift detection.
    pending: BTreeMap<usize, (f64, Instant)>,
    /// Index of the next unfired trace event.
    next: usize,
}

impl ChurnRt {
    /// Restart the drift detector after a replan: the new scripts give
    /// every device a new, legitimate compute baseline — judging them
    /// against pre-replan baselines would fake drift.
    fn reset_detector(&mut self) {
        self.detector = DriftDetector::new(self.spec.straggler);
    }
}

impl<'s> Driver<'s> {
    fn new(addrs: &[String], s: &'s Session) -> Result<Driver<'s>> {
        let plan = s.plan().clone();
        let slots: usize = plan.stages.iter().map(|st| st.devices.len()).sum();
        anyhow::ensure!(
            addrs.len() >= slots,
            "RpcBackend: plan needs {slots} workers (one per stage slot), \
             only {} address(es) given",
            addrs.len()
        );
        let sched = Schedule::for_runtime(&plan, s.policy());
        sched.validate().context("invalid round schedule")?;

        let hb_cfg = s
            .fault()
            .map(|f| f.heartbeat)
            .or_else(|| s.churn().map(|c| c.heartbeat))
            .unwrap_or_default();
        hb_cfg.validate()?;

        // Connect a control link per plan slot, stage-major.
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Event)>();
        let mut remotes = BTreeMap::new();
        let mut next_addr = 0usize;
        for stage in &plan.stages {
            for &device in &stage.devices {
                let addr = addrs[next_addr].clone();
                next_addr += 1;
                let remote = connect_remote(device, &addr, &tx)
                    .with_context(|| format!("worker for device {device} at {addr}"))?;
                remotes.insert(device, remote);
            }
        }

        let spare_addrs: Vec<String> = addrs[next_addr..].to_vec();
        let devices = plan.devices();
        Ok(Driver {
            session: s,
            hb_cfg,
            remotes,
            inbox: rx,
            tx,
            spare_addrs,
            last_round_compute: BTreeMap::new(),
            plan,
            sched,
            monitor: HeartbeatMonitor::new(hb_cfg, &devices),
            checkpoint: BTreeMap::new(),
            sync_pending: BTreeMap::new(),
            generation: 0,
            detection_wall_s: None,
            sync_msgs: 0,
        })
    }

    // ------------------------------------------------------ event pump

    /// Wait at most `timeout` for one inbox item.  Background traffic
    /// (heartbeats, sync mediation) is absorbed and yields `None`, as
    /// does a timeout — so call sites can interleave their own checks
    /// (liveness, deadlines) between events.
    fn poll_once(&mut self, timeout: Duration) -> Result<Option<Polled>> {
        match self.inbox.recv_timeout(timeout) {
            Ok((device, Event::Msg(msg))) => match msg {
                RpcMsg::Heartbeat { device: d, .. } => {
                    self.monitor.beat(d);
                    if let Some(r) = self.remotes.get_mut(&d) {
                        r.heartbeats += 1;
                    }
                    Ok(None)
                }
                RpcMsg::SyncRequest { device: d, kind, flat } => {
                    self.sync_msgs += 1;
                    self.handle_sync(d, kind, flat)?;
                    Ok(None)
                }
                RpcMsg::Fatal { device: d, error } => {
                    bail!("worker for device {d} failed: {error}");
                }
                other => Ok(Some(Polled::Msg(device, other))),
            },
            Ok((device, Event::Eof)) => {
                if let Some(r) = self.remotes.get_mut(&device) {
                    r.alive = false;
                }
                Ok(Some(Polled::Eof(device)))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => bail!("driver inbox closed"),
        }
    }

    /// Receive the next non-background event before `deadline`.
    fn poll(&mut self, deadline: Instant) -> Result<Polled> {
        loop {
            let now = Instant::now();
            if now >= deadline {
                bail!("timed out waiting for workers");
            }
            let step = (deadline - now).min(Duration::from_millis(100));
            if let Some(p) = self.poll_once(step)? {
                return Ok(p);
            }
        }
    }

    /// Mediate one replicated-stage round-sync contribution: when the
    /// whole group reported, reply with the reduction (sum of gradients
    /// for synchronous policies, parameter mean for bounded-staleness
    /// ones).
    fn handle_sync(&mut self, device: usize, kind: u8, flat: Vec<f32>) -> Result<()> {
        let stage_idx = self
            .plan
            .stages
            .iter()
            .position(|st| st.devices.contains(&device))
            .with_context(|| format!("sync from device {device} outside the plan"))?;
        let group = self.plan.stages[stage_idx].devices.clone();
        let pending = self.sync_pending.entry(stage_idx).or_default();
        anyhow::ensure!(
            pending.iter().all(|(d, _, _)| *d != device),
            "device {device} double-contributed to the stage {stage_idx} round sync"
        );
        anyhow::ensure!(
            pending.iter().all(|(_, k, _)| *k == kind),
            "mixed sync kinds in stage {stage_idx}"
        );
        pending.push((device, kind, flat));
        if pending.len() < group.len() {
            return Ok(());
        }
        let contributions = self.sync_pending.remove(&stage_idx).unwrap();
        let n = contributions[0].2.len();
        anyhow::ensure!(
            contributions.iter().all(|(_, _, f)| f.len() == n),
            "sync length mismatch in stage {stage_idx}"
        );
        let mut reduced = vec![0.0f32; n];
        for (_, _, f) in &contributions {
            for (acc, v) in reduced.iter_mut().zip(f) {
                *acc += *v;
            }
        }
        if kind == 1 {
            let g = contributions.len() as f32;
            for v in &mut reduced {
                *v /= g;
            }
        }
        let codec_sync = self.session.codec().sync();
        for (d, _, _) in &contributions {
            let msg = RpcMsg::SyncResult { flat: reduced.clone() };
            self.sync_msgs += 1;
            self.remotes
                .get_mut(d)
                .with_context(|| format!("no remote for device {d}"))?
                .send_codec(&msg, codec_sync)?;
        }
        Ok(())
    }

    // ----------------------------------------------------- assignment

    /// (Re)distribute the current plan: every (stage, slot) worker gets
    /// its layer slice, compute script, stash depth, peer addresses and
    /// (after a fault) the checkpointed warm-start weights.
    fn assign_all(&mut self, warm: bool) -> Result<()> {
        // Deadline-reset bugfix: re-arm liveness for the devices being
        // (re-)assigned *before* the stage rebuild.  Tearing down and
        // redialling peers can exceed the heartbeat deadline, and a
        // deadline inherited from before the recovery would flag a
        // healthy survivor (or a rejoined worker whose previous
        // incarnation went silent long ago) as dead mid-assignment.
        self.monitor.rearm(&self.plan.devices());
        self.generation += 1;
        let s = self.session;
        let model = s.model();
        let rc = s.run_config();
        let heartbeat_ms = self.hb_cfg.interval.as_millis().max(1) as u64;
        let n_stages = self.plan.stages.len();
        let addr_of = |d: usize, remotes: &BTreeMap<usize, Remote>| -> Result<String> {
            Ok(remotes
                .get(&d)
                .with_context(|| format!("no worker address for device {d}"))?
                .addr
                .clone())
        };
        let versioned = s.policy().max_staleness() > 0;
        let sync_cfg = s.sync_mode();
        let mut specs: Vec<(usize, AssignSpec)> = Vec::new();
        for (p, stage) in self.plan.stages.iter().enumerate() {
            let mut next = Vec::new();
            if p + 1 < n_stages {
                for &d in &self.plan.stages[p + 1].devices {
                    next.push(addr_of(d, &self.remotes)?);
                }
            }
            let mut prev = Vec::new();
            if p > 0 {
                for &d in &self.plan.stages[p - 1].devices {
                    prev.push(addr_of(d, &self.remotes)?);
                }
            }
            // Ring sync topology: every replicated-stage member gets
            // the whole group's worker addresses in slot order plus its
            // own position; each dials only its successor.  Unreplicated
            // stages (and DriverStar mode) carry an empty ring.
            let use_ring = sync_cfg == SyncMode::Ring && stage.devices.len() > 1;
            let ring: Vec<String> = if use_ring {
                stage
                    .devices
                    .iter()
                    .map(|&d| addr_of(d, &self.remotes))
                    .collect::<Result<_>>()?
            } else {
                Vec::new()
            };
            let layers = reference_layers(model, stage.layers.0, stage.layers.1);
            let warm_start: Vec<LayerState> = if warm {
                (stage.layers.0..stage.layers.1)
                    .filter_map(|k| self.checkpoint.get(&k).cloned())
                    .collect()
            } else {
                Vec::new()
            };
            for (slot, &device) in stage.devices.iter().enumerate() {
                let stash_slots = if versioned {
                    self.sched.timeline_at(p, slot).map(|tl| tl.kp).unwrap_or(0)
                } else {
                    0
                };
                specs.push((
                    device,
                    AssignSpec {
                        generation: self.generation,
                        device,
                        stage: p,
                        slot,
                        num_stages: n_stages,
                        group_size: stage.devices.len(),
                        script: self.sched.compute_script(p, slot),
                        stash_slots,
                        num_micro: self.plan.num_micro,
                        microbatch: self.plan.microbatch,
                        seed: rc.seed,
                        opt: rc.opt,
                        heartbeat_ms,
                        // Wire codecs for this worker's outbound links,
                        // resolved from the session spec against the
                        // plan's layer cuts (activations cross the
                        // stage's output boundary, gradients its input
                        // boundary).
                        codec_act: s.codec().at_boundary(stage.layers.1),
                        codec_grad: s.codec().at_boundary(stage.layers.0),
                        codec_sync: s.codec().sync(),
                        layers: layers.clone(),
                        next: next.clone(),
                        prev: prev.clone(),
                        warm_start: warm_start.clone(),
                        sync: if use_ring { SyncMode::Ring } else { SyncMode::DriverStar },
                        ring_index: slot,
                        ring: ring.clone(),
                    },
                ));
            }
        }
        for (device, spec) in specs {
            self.remotes
                .get_mut(&device)
                .with_context(|| format!("no remote for device {device}"))?
                .send(&RpcMsg::Assign(Box::new(spec)))?;
        }
        self.wait_ready()?;
        // Fresh liveness baseline now that every worker acknowledged.
        self.monitor.rearm(&self.plan.devices());
        Ok(())
    }

    fn wait_ready(&mut self) -> Result<()> {
        let mut waiting: BTreeSet<usize> = self.plan.devices().into_iter().collect();
        let deadline = Instant::now() + READY_TIMEOUT;
        while !waiting.is_empty() {
            match self.poll(deadline)? {
                Polled::Msg(_, RpcMsg::Ready { device }) => {
                    waiting.remove(&device);
                }
                // Settled leftovers from an aborted round are harmless
                // here; anything else is a protocol error.
                Polled::Msg(_, RpcMsg::RoundFailed { .. }) => {}
                Polled::Msg(d, other) => {
                    bail!("device {d}: unexpected {} while assigning", other.kind())
                }
                Polled::Eof(d) => bail!("worker for device {d} died while assigning"),
            }
        }
        Ok(())
    }

    // --------------------------------------------------------- rounds

    /// Feed one round's micro-batches: inputs to stage 0, targets to
    /// the head stage (round-robin across each group, like the
    /// in-process engine).
    fn feed_round(&mut self, task: &RefTask, round: usize) -> Result<()> {
        let first: Vec<usize> = self.plan.stages[0].devices.clone();
        let last: Vec<usize> = self.plan.stages[self.plan.stages.len() - 1].devices.clone();
        let gen = self.generation;
        for m in 0..self.plan.num_micro {
            let (x, t) = task.microbatch(round, m);
            let d_in = first[m % first.len()];
            self.remotes
                .get_mut(&d_in)
                .context("missing stage-0 remote")?
                .send(&RpcMsg::Act { gen, micro: m, t: x })?;
            let d_tgt = last[m % last.len()];
            self.remotes
                .get_mut(&d_tgt)
                .context("missing head-stage remote")?
                .send(&RpcMsg::Targets { gen, micro: m, t })?;
        }
        Ok(())
    }

    /// One full HPP-Round: start, feed, await every worker's report.
    /// Returns the mean loss over the round's micro-batches.
    fn run_round(&mut self, task: &RefTask, round: usize) -> Result<f64> {
        self.last_round_compute.clear();
        let devices = self.plan.devices();
        for &d in &devices {
            self.remotes.get_mut(&d).unwrap().send(&RpcMsg::StartRound { round })?;
        }
        self.feed_round(task, round)?;

        let last_stage: BTreeSet<usize> =
            self.plan.stages[self.plan.stages.len() - 1].devices.iter().copied().collect();
        let mut waiting: BTreeSet<usize> = devices.iter().copied().collect();
        let mut loss_sum = 0.0f64;
        let mut micro_seen = 0usize;
        let deadline = Instant::now() + ROUND_TIMEOUT;
        while !waiting.is_empty() {
            match self.poll(deadline)? {
                Polled::Msg(
                    _,
                    RpcMsg::RoundDone {
                        device,
                        round: r,
                        loss_sum: l,
                        micros,
                        compute_s,
                        logical_bytes,
                        wire_bytes,
                        sync_bytes,
                        sync_wall_s,
                    },
                ) => {
                    if r != round {
                        continue; // settled leftover of an aborted round
                    }
                    waiting.remove(&device);
                    if let Some(rem) = self.remotes.get_mut(&device) {
                        rem.rounds_reported += 1;
                        rem.compute_s_sum += compute_s;
                        rem.dp_logical += logical_bytes;
                        rem.dp_wire += wire_bytes;
                        rem.sync_bytes += sync_bytes;
                        rem.sync_wall_s += sync_wall_s;
                    }
                    self.last_round_compute.insert(device, compute_s);
                    if last_stage.contains(&device) {
                        loss_sum += l;
                        micro_seen += micros;
                    }
                }
                Polled::Msg(d, RpcMsg::RoundFailed { device, error }) => {
                    bail!("device {device} (conn {d}) failed round {round}: {error}");
                }
                Polled::Msg(d, other) => {
                    bail!("device {d}: unexpected {} mid-round", other.kind())
                }
                Polled::Eof(d) => bail!("worker for device {d} died mid-round"),
            }
        }
        debug_assert_eq!(micro_seen, self.plan.num_micro);
        Ok(loss_sum / self.plan.num_micro as f64)
    }

    /// Pull a parameter checkpoint from slot 0 of every stage (the
    /// coordinator-side replication store).
    fn pull_checkpoint(&mut self) -> Result<BTreeMap<usize, LayerState>> {
        let firsts: Vec<usize> =
            self.plan.stages.iter().map(|st| st.devices[0]).collect();
        for &d in &firsts {
            self.remotes.get_mut(&d).unwrap().send(&RpcMsg::FetchParams)?;
        }
        let mut waiting: BTreeSet<usize> = firsts.into_iter().collect();
        let mut out = BTreeMap::new();
        let deadline = Instant::now() + ROUND_TIMEOUT;
        while !waiting.is_empty() {
            match self.poll(deadline)? {
                Polled::Msg(d, RpcMsg::Params { layers }) => {
                    waiting.remove(&d);
                    for l in layers {
                        out.insert(l.layer, l);
                    }
                }
                Polled::Msg(d, other) => {
                    bail!("device {d}: unexpected {} during checkpoint", other.kind())
                }
                Polled::Eof(d) => bail!("worker for device {d} died during checkpoint"),
            }
        }
        Ok(out)
    }

    // ---------------------------------------------------------- fault

    /// Inject the spec'd device exit mid-round and recover: kill the
    /// worker process, detect via heartbeat silence, abort the round on
    /// the survivors, run the session's §3.4 recovery mechanism,
    /// re-task the surviving workers under the recovery plan
    /// (warm-started from the checkpoint) and return the event.
    fn inject_and_recover(
        &mut self,
        task: &RefTask,
        round: usize,
        failed: usize,
    ) -> Result<RecoveryEvent> {
        let spec = self.session.fault().expect("fault spec present").clone();
        let devices = self.plan.devices();
        for &d in &devices {
            self.remotes.get_mut(&d).unwrap().send(&RpcMsg::StartRound { round })?;
        }
        self.feed_round(task, round)?;
        // The device exit: the worker process dies unclean, mid-round.
        let t0 = Instant::now();
        let _ = self.remotes.get_mut(&failed).unwrap().send(&RpcMsg::Die);

        // §3.4 module 1: heartbeat detection.  The monitor flags the
        // silence after miss_threshold intervals; the EOF on the
        // control connection is the probe confirmation.
        let mut eof_seen = false;
        let detect_deadline = Instant::now()
            + Duration::from_secs_f64(self.hb_cfg.detection_time() * 10.0 + 5.0);
        while !(eof_seen && self.monitor.liveness(failed) != Liveness::Alive) {
            if Instant::now() >= detect_deadline {
                bail!("failure detection timed out for device {failed}");
            }
            match self.poll_once(Duration::from_millis(20))? {
                None => {} // idle tick: recheck liveness
                Some(Polled::Eof(d)) if d == failed => eof_seen = true,
                Some(Polled::Eof(d)) => bail!("unrelated worker {d} died during fault"),
                // Survivors may still finish their half of the broken
                // round or report its failure; both are expected noise.
                Some(Polled::Msg(_, RpcMsg::RoundDone { .. })) => {}
                Some(Polled::Msg(_, RpcMsg::RoundFailed { .. })) => {}
                Some(Polled::Msg(d, other)) => {
                    bail!("device {d}: unexpected {} during detection", other.kind())
                }
            }
        }
        self.monitor.confirm_failure(failed);
        self.detection_wall_s = Some(t0.elapsed().as_secs_f64());
        self.remotes.get_mut(&failed).unwrap().alive = false;

        // Abort the broken round on every survivor and wait for each
        // to settle back to idle.
        let survivors: Vec<usize> = devices.iter().copied().filter(|&d| d != failed).collect();
        for &d in &survivors {
            self.remotes.get_mut(&d).unwrap().send(&RpcMsg::AbortRound)?;
        }
        let mut waiting: BTreeSet<usize> = survivors.iter().copied().collect();
        let deadline = Instant::now() + READY_TIMEOUT;
        while !waiting.is_empty() {
            match self.poll(deadline)? {
                Polled::Msg(_, RpcMsg::RoundFailed { device, .. }) => {
                    waiting.remove(&device);
                }
                Polled::Msg(_, RpcMsg::RoundDone { .. }) => {}
                Polled::Msg(d, other) => {
                    bail!("device {d}: unexpected {} during abort", other.kind())
                }
                Polled::Eof(d) => bail!("worker for device {d} died during abort"),
            }
        }
        self.sync_pending.clear();

        // §3.4 modules 2-4: restore / re-plan / migrate — the session's
        // declarative recovery mechanism (same path the sim and pjrt
        // backends price), then re-task the survivors for real.
        let t_replan = Instant::now();
        let report = self.session.recover(&spec, failed)?;
        let replan_wall_s = t_replan.elapsed().as_secs_f64();
        self.plan = report.new_plan.clone();
        self.sched = Schedule::for_runtime(&self.plan, self.session.policy());
        self.sched.validate().context("invalid recovery schedule")?;
        self.assign_all(true)?;
        Ok(RecoveryEvent {
            round,
            failed_device: failed,
            kind: spec.recovery,
            replan_wall_s,
            report,
        })
    }

    // ---------------------------------------------------------- churn

    /// Fire every churn-trace event due at `round` (between rounds —
    /// the trace's event clock is round-granular on this backend too).
    fn fire_churn_events(
        &mut self,
        rt: &mut ChurnRt,
        round: usize,
        recoveries: &mut Vec<RecoveryEvent>,
    ) -> Result<()> {
        while rt.next < rt.spec.trace.events.len() && rt.spec.trace.events[rt.next].round <= round
        {
            let ev = rt.spec.trace.events[rt.next].event;
            rt.next += 1;
            match ev {
                ChurnEvent::Exit { device } => {
                    let wall = self.kill_and_settle(device)?;
                    self.detection_wall_s = Some(wall);
                    let t0 = Instant::now();
                    let report = rt.state.exit(self.session, &rt.spec, device)?;
                    let replan_wall_s = t0.elapsed().as_secs_f64();
                    self.retask(&rt.state)?;
                    rt.reset_detector();
                    recoveries.push(RecoveryEvent {
                        round,
                        failed_device: device,
                        kind: rt.spec.exit_recovery,
                        replan_wall_s,
                        report,
                    });
                }
                ChurnEvent::Join { device } => {
                    // The restarted worker reconnects on the device's
                    // previous address (same port), or on a spare for a
                    // first-time join; then the join fast path
                    // re-expands the plan and everyone is re-Assigned
                    // warm from the driver checkpoint.
                    self.reconnect_worker(device)?;
                    let t0 = Instant::now();
                    let report = rt.state.join(self.session, device)?;
                    let replan_wall_s = t0.elapsed().as_secs_f64();
                    self.retask(&rt.state)?;
                    rt.reset_detector();
                    recoveries.push(RecoveryEvent {
                        round,
                        failed_device: device,
                        kind: RecoveryKind::Rejoin,
                        replan_wall_s,
                        report,
                    });
                }
                ChurnEvent::Slowdown { device, factor } => {
                    // Inject only: nothing replans until the drift
                    // detector actually catches the straggler.
                    self.remotes
                        .get_mut(&device)
                        .with_context(|| format!("churn slowdown: no remote for device {device}"))?
                        .send(&RpcMsg::Throttle { factor })?;
                    rt.state.inject_slowdown(device, factor);
                    rt.pending.insert(device, (factor, Instant::now()));
                }
                ChurnEvent::LinkDegrade { a, b, mbps } => {
                    let t0 = Instant::now();
                    let report = rt.state.link_degrade(self.session, a, b, mbps)?;
                    let replan_wall_s = t0.elapsed().as_secs_f64();
                    self.retask(&rt.state)?;
                    rt.reset_detector();
                    recoveries.push(RecoveryEvent {
                        round,
                        failed_device: a.min(b),
                        kind: RecoveryKind::Heavy,
                        replan_wall_s,
                        report,
                    });
                }
            }
        }
        Ok(())
    }

    /// Feed the finished round's per-device compute timings to the
    /// drift detector; a flagged device with a pending injection gets
    /// derated and the fleet replans around it.
    fn observe_drift(
        &mut self,
        rt: &mut ChurnRt,
        round: usize,
        recoveries: &mut Vec<RecoveryEvent>,
    ) -> Result<()> {
        let timings: Vec<(usize, f64)> =
            self.last_round_compute.iter().map(|(&d, &c)| (d, c)).collect();
        for (device, compute_s) in timings {
            if rt.detector.observe(device, compute_s).is_none() {
                continue;
            }
            // A flag with no pending injection is detector noise: the
            // device stays flagged (and therefore silent) but nothing
            // replans — the noise gate the churn tests assert on.
            let (factor, injected_at) = match rt.pending.remove(&device) {
                Some(p) => p,
                None => continue,
            };
            let detection_s = injected_at.elapsed().as_secs_f64();
            // The device really is slow now (its throttle stays); the
            // plan reschedules around the derated profile.
            let t0 = Instant::now();
            let report = rt.state.straggler(self.session, device, factor, detection_s)?;
            let replan_wall_s = t0.elapsed().as_secs_f64();
            self.retask(&rt.state)?;
            rt.reset_detector();
            recoveries.push(RecoveryEvent {
                round,
                failed_device: device,
                kind: RecoveryKind::Straggler,
                replan_wall_s,
                report,
            });
        }
        Ok(())
    }

    /// Adopt the churn state's plan and re-task the live workers,
    /// warm-started from the latest driver checkpoint.
    fn retask(&mut self, state: &ChurnState) -> Result<()> {
        self.plan = state.plan.clone();
        self.sched = Schedule::for_runtime(&self.plan, self.session.policy());
        self.sched.validate().context("invalid churn reschedule")?;
        self.assign_all(true)
    }

    /// Kill `device`'s worker (a real process death) and wait for the
    /// heartbeat monitor to see the silence plus the control-link EOF.
    /// Returns the measured detection wall-clock.
    fn kill_and_settle(&mut self, device: usize) -> Result<f64> {
        let t0 = Instant::now();
        let _ = self
            .remotes
            .get_mut(&device)
            .with_context(|| format!("churn exit: no remote for device {device}"))?
            .send(&RpcMsg::Die);
        let mut eof_seen = false;
        let deadline =
            Instant::now() + Duration::from_secs_f64(self.hb_cfg.detection_time() * 10.0 + 5.0);
        while !(eof_seen && self.monitor.liveness(device) != Liveness::Alive) {
            if Instant::now() >= deadline {
                bail!("churn exit detection timed out for device {device}");
            }
            match self.poll_once(Duration::from_millis(20))? {
                None => {} // idle tick: recheck liveness
                Some(Polled::Eof(d)) if d == device => eof_seen = true,
                Some(Polled::Eof(d)) => bail!("unrelated worker {d} died during churn exit"),
                // Settled leftovers from the previous round are noise.
                Some(Polled::Msg(_, RpcMsg::RoundDone { .. })) => {}
                Some(Polled::Msg(_, RpcMsg::RoundFailed { .. })) => {}
                Some(Polled::Msg(d, other)) => {
                    bail!("device {d}: unexpected {} during churn exit", other.kind())
                }
            }
        }
        self.monitor.confirm_failure(device);
        if let Some(r) = self.remotes.get_mut(&device) {
            r.alive = false;
        }
        self.sync_pending.clear();
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Connect the worker a joining device runs on: the restarted
    /// process on the device's previous address, or one drawn from the
    /// spare address pool for a first-time join.  The dial retries, so
    /// a worker still rebinding its port is waited out.
    fn reconnect_worker(&mut self, device: usize) -> Result<()> {
        let addr = match self.remotes.get(&device) {
            Some(r) => r.addr.clone(),
            None => self.spare_addrs.pop().with_context(|| {
                format!("churn join: no spare worker address for device {device}")
            })?,
        };
        let remote = connect_remote(device, &addr, &self.tx)
            .with_context(|| format!("rejoining worker for device {device} at {addr}"))?;
        self.remotes.insert(device, remote);
        Ok(())
    }

    // ------------------------------------------------------------ run

    fn run(&mut self) -> Result<RunReport> {
        let s = self.session;
        let rc = s.run_config();
        let task = RefTask::new(s.model(), self.plan.microbatch, rc.seed);
        let fault = s.fault().cloned();
        let failed_device = match &fault {
            Some(spec) => Some(s.resolve_fault_device(spec)?),
            None => None,
        };

        self.assign_all(false)?;

        let total_rounds = match &fault {
            Some(spec) => spec.fail_after + spec.resume_rounds,
            None => rc.steps,
        };
        let mut losses: Vec<f64> = Vec::with_capacity(total_rounds);
        let mut round_secs: Vec<f64> = Vec::with_capacity(total_rounds);
        let mut recoveries: Vec<RecoveryEvent> = Vec::new();

        // Elastic membership: churn traces drive real kills, restarts
        // and throttles against the worker fleet.
        let mut churn_rt: Option<ChurnRt> = s.churn().map(|spec| ChurnRt {
            spec: spec.clone(),
            state: ChurnState::new(s),
            detector: DriftDetector::new(spec.straggler),
            pending: BTreeMap::new(),
            next: 0,
        });

        let mut round = 0usize;
        while round < total_rounds {
            if let (Some(spec), Some(failed)) = (&fault, failed_device) {
                if round == spec.fail_after && recoveries.is_empty() {
                    let event = self.inject_and_recover(&task, round, failed)?;
                    recoveries.push(event);
                    // The failed round restarts on the recovery plan.
                }
            }
            if let Some(rt) = churn_rt.as_mut() {
                self.fire_churn_events(rt, round, &mut recoveries)?;
            }
            let t0 = Instant::now();
            let loss = self.run_round(&task, round)?;
            round_secs.push(t0.elapsed().as_secs_f64());
            losses.push(loss);
            if rc.log_every > 0 && (round % rc.log_every == 0 || round + 1 == total_rounds) {
                println!(
                    "round {round:>4}  loss {loss:.4}  ({:.3} s/round, rpc)",
                    round_secs.last().unwrap()
                );
            }
            if let Some(rt) = churn_rt.as_mut() {
                self.observe_drift(rt, round, &mut recoveries)?;
            }
            if fault.is_some() || churn_rt.is_some() {
                self.checkpoint = self.pull_checkpoint()?;
            }
            round += 1;
        }

        // Final checkpoint is the report's weight stream.
        let final_states = self.pull_checkpoint()?;

        // Clean shutdown: Exit everyone still alive, await Bye
        // best-effort.
        let live: Vec<usize> = self
            .remotes
            .values()
            .filter(|r| r.alive)
            .map(|r| r.device)
            .collect();
        for d in &live {
            let _ = self.remotes.get_mut(d).unwrap().send(&RpcMsg::Exit);
        }
        let bye_deadline = Instant::now() + Duration::from_secs(5);
        let mut waiting: BTreeSet<usize> = live.into_iter().collect();
        while !waiting.is_empty() {
            match self.poll(bye_deadline) {
                Ok(Polled::Msg(d, RpcMsg::Bye)) => {
                    waiting.remove(&d);
                }
                Ok(Polled::Eof(d)) => {
                    waiting.remove(&d);
                }
                Ok(_) => {}
                Err(_) => break, // shutdown is best-effort
            }
        }

        // ---- report ----------------------------------------------
        // Pre-fault throughput (every backend reports the pre-fault
        // pipeline's rate): pair the pre-fault round timings with the
        // *original* plan's round size — after a recovery `self.plan`
        // is the recovery plan, whose samples_per_round may differ.
        let first_churn_round =
            s.churn().and_then(|c| c.trace.events.first().map(|te| te.round));
        let (samples, window): (f64, &[f64]) = match (&fault, first_churn_round) {
            (Some(spec), _) if spec.fail_after > 0 && round_secs.len() >= spec.fail_after => {
                (s.plan().samples_per_round() as f64, &round_secs[..spec.fail_after])
            }
            (None, Some(first)) if first > 0 && round_secs.len() >= first => {
                // Pre-churn throughput: pair the undisturbed rounds
                // with the original plan's round size.
                (s.plan().samples_per_round() as f64, &round_secs[..first])
            }
            _ => (self.plan.samples_per_round() as f64, &round_secs[..]),
        };
        let mean_round = window.iter().sum::<f64>() / window.len().max(1) as f64;
        let throughput = if mean_round > 0.0 { samples / mean_round } else { 0.0 };

        let final_params: BTreeMap<usize, Vec<Tensor>> = final_states
            .into_iter()
            .map(|(k, st)| {
                let n_s = st.scale.len();
                let n_b = st.bias.len();
                (k, vec![
                    Tensor::from_f32(&[n_s], st.scale),
                    Tensor::from_f32(&[n_b], st.bias),
                ])
            })
            .collect();

        let per_device: Vec<RpcDeviceStats> = self
            .remotes
            .values()
            .map(|r| RpcDeviceStats {
                device: r.device,
                addr: r.addr.clone(),
                heartbeats: r.heartbeats,
                rounds_reported: r.rounds_reported,
                mean_round_compute_s: if r.rounds_reported > 0 {
                    r.compute_s_sum / r.rounds_reported as f64
                } else {
                    0.0
                },
                bytes_tx: r.bytes_tx,
                bytes_rx: r.bytes_rx.load(Ordering::Relaxed),
                dp_logical_bytes: r.dp_logical,
                dp_wire_bytes: r.dp_wire,
                sync_bytes: r.sync_bytes,
                sync_wall_s: r.sync_wall_s,
                ctrl_msgs_tx: r.msgs_tx,
                ctrl_msgs_rx: r.msgs_rx.load(Ordering::Relaxed),
            })
            .collect();

        Ok(RunReport {
            backend: "rpc",
            plan: s.plan().clone(),
            schedule: s.schedule().clone(),
            rounds: losses.len(),
            losses,
            round_secs,
            throughput,
            predicted_throughput: s.outcome().predicted_throughput,
            max_staleness: s.policy().max_staleness(),
            weight_stash_slots: s.weight_stash_slots(),
            bytes_on_network: 0,
            codec: s.codec().describe(),
            sync: s.sync_mode(),
            sim: None,
            recoveries,
            final_params: Some(final_params),
            rpc: Some(RpcStats {
                per_device,
                detection_wall_s: self.detection_wall_s,
                sync_msgs: self.sync_msgs,
            }),
        })
    }
}

/// Dial one worker's control link and spawn its reader thread.
fn connect_remote(
    device: usize,
    addr: &str,
    tx: &Sender<(usize, Event)>,
) -> Result<Remote> {
    let mut conn = dial_with_retry(addr, CONNECT_TIMEOUT)?;
    conn.set_nodelay(true).ok();
    send_msg(&mut conn, &RpcMsg::Hello { role: ConnRole::Control })?;
    let writer = conn.try_clone().context("cloning control stream")?;
    let bytes_rx = Arc::new(AtomicU64::new(0));
    let msgs_rx = Arc::new(AtomicU64::new(0));
    {
        let tx = tx.clone();
        let bytes_rx = bytes_rx.clone();
        let msgs_rx = msgs_rx.clone();
        std::thread::spawn(move || {
            loop {
                let payload = match read_frame(&mut conn) {
                    Ok(p) => p,
                    Err(_) => {
                        let _ = tx.send((device, Event::Eof));
                        return;
                    }
                };
                bytes_rx.fetch_add(payload.len() as u64 + HEADER_LEN as u64, Ordering::Relaxed);
                msgs_rx.fetch_add(1, Ordering::Relaxed);
                match RpcMsg::decode(&payload) {
                    Ok(msg) => {
                        if tx.send((device, Event::Msg(msg))).is_err() {
                            return;
                        }
                    }
                    Err(_) => {
                        let _ = tx.send((device, Event::Eof));
                        return;
                    }
                }
            }
        });
    }
    Ok(Remote {
        device,
        addr: addr.to_string(),
        writer,
        alive: true,
        heartbeats: 0,
        rounds_reported: 0,
        compute_s_sum: 0.0,
        bytes_tx: 0,
        bytes_rx,
        msgs_tx: 0,
        msgs_rx,
        dp_logical: 0,
        dp_wire: 0,
        sync_bytes: 0,
        sync_wall_s: 0.0,
    })
}
