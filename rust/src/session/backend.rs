//! Execution backends: how a planned [`Session`] becomes a
//! [`RunReport`].
//!
//! * [`SimBackend`] — event-accurate schedule pricing
//!   (`sim::price`): every throughput/latency number the
//!   paper tables report, with no numerics;
//! * [`PjrtBackend`] — the live in-process worker pipeline over
//!   AOT-compiled artifacts, with optional edge-link emulation.
//!   Requires an artifact-model session and a build with the `pjrt`
//!   feature;
//! * [`super::RpcBackend`] (in `session::rpc`) — the multi-process
//!   edge backend: each stage slot is a separate `asteroid-worker` OS
//!   process driven over TCP, feature-independent.
//!
//! Both honour the session's [`FaultSpec`](super::FaultSpec): the sim
//! backend prices the pre-failure schedule, runs the spec'd recovery
//! mechanism and re-prices the recovery plan; the live backend trains
//! to the exit round, recovers, warm-starts the new pipeline from the
//! streamed checkpoint and keeps training — the loss curve must
//! continue, which the integration tests assert.
//!
//! Elastic membership ([`ChurnSpec`](super::ChurnSpec)) generalises
//! this: the sim backend executes the whole timed trace on a
//! deterministic event clock (exits, rejoins, injected slowdowns
//! caught by the real drift detector, link degradations), and the RPC
//! backend executes it against live worker processes.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::{DataSource, LmTask, VisionTask};
use crate::fault::{ChurnEvent, DriftDetector};
use crate::model::from_manifest::ManifestModel;
use crate::pipeline::{train, TrainOpts, TrainStats};
use crate::sim::{price, PriceRequest};

use super::churn::ChurnState;
use super::{RecoveryEvent, RecoveryKind, RunReport, Session};

/// Turns a planned [`Session`] into a [`RunReport`].  Implementations
/// are free to carry their own state (a data source, a device handle);
/// the session itself is immutable during a run.
pub trait ExecutionBackend {
    fn name(&self) -> &'static str;

    fn run(&mut self, session: &Session) -> Result<RunReport>;
}

/// Event-accurate schedule pricing (no numerics, no artifacts
/// needed).  Works for every session, zoo or artifact.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimBackend;

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(&mut self, s: &Session) -> Result<RunReport> {
        // Policy-aware pricing: synchronous policies price the
        // session's one-round schedule; bounded-staleness policies
        // price their steady state (barrier-free multi-round chain).
        // Byte terms (sends, AllReduce) price the session's wire codec
        // and collective topology.
        let sim = price(
            &PriceRequest::new(s.table(), s.cluster(), s.model(), s.plan())
                .policy(s.policy())
                .codec(*s.codec())
                .sync(s.sync_mode()),
        );
        let rounds = s.run_config().steps;
        let mut round_secs = vec![sim.round_latency; rounds];
        let mut recoveries = Vec::new();

        if let Some(spec) = s.fault() {
            let failed = s.resolve_fault_device(spec)?;
            let t0 = Instant::now();
            let report = s.recover(spec, failed)?;
            let replan_wall_s = t0.elapsed().as_secs_f64();
            let at = spec.fail_after.min(rounds);
            let new_latency =
                report.new_plan.samples_per_round() as f64 / report.new_throughput;
            for r in round_secs.iter_mut().skip(at) {
                *r = new_latency;
            }
            recoveries.push(RecoveryEvent {
                round: at,
                failed_device: failed,
                kind: spec.recovery,
                replan_wall_s,
                report,
            });
        } else if let Some(spec) = s.churn() {
            // Deterministic event clock: fire each trace event before
            // its round, replan through the evolving ChurnState, and
            // price every round at the latency of whatever plan and
            // (possibly degraded) fleet is current.  `round_secs` stays
            // a pure per-round latency series — recovery stalls live in
            // each event's report, as on the FaultSpec path.
            let mut state = ChurnState::new(s);
            let mut detector = DriftDetector::new(spec.straggler);
            let mut latency = sim.round_latency;
            // Injected-but-undetected slowdowns: device -> (factor,
            // injection round).
            let mut pending: BTreeMap<usize, (f64, usize)> = BTreeMap::new();
            let mut events = spec.trace.events.iter().peekable();
            for round in 0..rounds {
                while events.peek().map_or(false, |te| te.round <= round) {
                    let te = *events.next().unwrap();
                    let t0 = Instant::now();
                    match te.event {
                        ChurnEvent::Exit { device } => {
                            let report = state.exit(s, spec, device)?;
                            latency = state.round_latency(s);
                            // New plan, new scripts: drift baselines
                            // from the old timeline are meaningless.
                            detector = DriftDetector::new(spec.straggler);
                            recoveries.push(RecoveryEvent {
                                round,
                                failed_device: device,
                                kind: spec.exit_recovery,
                                replan_wall_s: t0.elapsed().as_secs_f64(),
                                report,
                            });
                        }
                        ChurnEvent::Join { device } => {
                            let report = state.join(s, device)?;
                            latency = state.round_latency(s);
                            detector = DriftDetector::new(spec.straggler);
                            recoveries.push(RecoveryEvent {
                                round,
                                failed_device: device,
                                kind: RecoveryKind::Rejoin,
                                replan_wall_s: t0.elapsed().as_secs_f64(),
                                report,
                            });
                        }
                        ChurnEvent::Slowdown { device, factor } => {
                            // Nothing replans yet: the device keeps
                            // heartbeating and only the drift detector
                            // below can catch it.
                            state.inject_slowdown(device, factor);
                            pending.insert(device, (factor, round));
                        }
                        ChurnEvent::LinkDegrade { a, b, mbps } => {
                            let report = state.link_degrade(s, a, b, mbps)?;
                            latency = state.round_latency(s);
                            detector = DriftDetector::new(spec.straggler);
                            recoveries.push(RecoveryEvent {
                                round,
                                failed_device: a.min(b),
                                kind: RecoveryKind::Heavy,
                                replan_wall_s: t0.elapsed().as_secs_f64(),
                                report,
                            });
                        }
                    }
                }

                // Worst-case straggler model: the slowed device gates
                // its stage, so the whole round stretches by the
                // largest undetected factor.
                let degrade =
                    pending.values().map(|&(f, _)| f).fold(1.0f64, f64::max);
                round_secs[round] = latency * degrade;

                // Feed the drift detector the round's synthetic
                // per-device timings: everyone at the base latency, a
                // slowed device at factor x.
                let fired: Vec<usize> = state
                    .active
                    .clone()
                    .into_iter()
                    .filter(|d| {
                        let f = pending.get(d).map_or(1.0, |&(f, _)| f);
                        detector.observe(*d, latency * f).is_some()
                    })
                    .collect();
                for device in fired {
                    let (factor, since) = match pending.remove(&device) {
                        Some(p) => p,
                        None => continue, // flagged but never injected
                    };
                    // The observation window the report charges: the
                    // degraded rounds from injection through this one.
                    let detection_s = (round - since + 1) as f64 * latency * factor;
                    let t0 = Instant::now();
                    let report = state.straggler(s, device, factor, detection_s)?;
                    latency = state.round_latency(s);
                    detector = DriftDetector::new(spec.straggler);
                    recoveries.push(RecoveryEvent {
                        round,
                        failed_device: device,
                        kind: RecoveryKind::Straggler,
                        replan_wall_s: t0.elapsed().as_secs_f64(),
                        report,
                    });
                }
            }
        }

        Ok(RunReport {
            backend: self.name(),
            plan: s.plan().clone(),
            schedule: s.schedule().clone(),
            rounds,
            losses: Vec::new(),
            round_secs,
            throughput: sim.throughput,
            predicted_throughput: s.outcome().predicted_throughput,
            max_staleness: s.policy().max_staleness(),
            weight_stash_slots: s.weight_stash_slots(),
            bytes_on_network: sim.bytes_on_network,
            codec: s.codec().describe(),
            sync: s.sync_mode(),
            sim: Some(sim),
            recoveries,
            final_params: None,
            rpc: None,
        })
    }
}

/// The live multi-worker PJRT pipeline engine.  By default it
/// synthesises the model's own task stream (LM or vision, from the
/// manifest config); [`PjrtBackend::with_data`] substitutes a custom
/// [`DataSource`].
#[derive(Default)]
pub struct PjrtBackend {
    data: Option<Box<dyn DataSource>>,
}

impl PjrtBackend {
    pub fn new() -> PjrtBackend {
        PjrtBackend { data: None }
    }

    pub fn with_data(data: Box<dyn DataSource>) -> PjrtBackend {
        PjrtBackend { data: Some(data) }
    }
}

/// The synthetic task matching a manifest model's kind and config —
/// what the examples and CLI train on.
pub fn default_task(mm: &ManifestModel, seed: u64) -> Result<Box<dyn DataSource>> {
    Ok(match mm.kind.as_str() {
        "transformer" => Box::new(LmTask::new(
            mm.cfg_usize("vocab")?,
            mm.cfg_usize("seq")?,
            mm.microbatch,
            seed,
        )),
        _ => Box::new(VisionTask::new(
            mm.cfg_usize("hw")?,
            mm.cfg_usize("in_ch")?,
            mm.cfg_usize("classes")?,
            mm.microbatch,
            seed,
        )),
    })
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn run(&mut self, s: &Session) -> Result<RunReport> {
        let (dir, name) = s.artifacts().context(
            "live execution requires an artifact model \
             (SessionBuilder::artifact_model); zoo models are simulation-only",
        )?;
        // The live workers execute whatever compute script the
        // session's policy emits (the schedule is validated before the
        // workers spawn, and a worker that meets an op it cannot
        // execute reports a structured error) — no policy-name
        // allowlist here.
        let rc = s.run_config().clone();
        let opts = TrainOpts {
            steps: rc.steps,
            opt: rc.opt,
            seed: rc.seed,
            emulate: if rc.emulate { Some(s.cluster().clone()) } else { None },
            log_every: rc.log_every,
            initial_params: None,
            policy: s.policy(),
            codec: *s.codec(),
        };
        let mut owned;
        let data: &mut dyn DataSource = match self.data.as_mut() {
            Some(d) => d.as_mut(),
            None => {
                let mm = s
                    .manifest_model()
                    .context("artifact session is missing its manifest model")?;
                owned = default_task(mm, rc.seed)?;
                owned.as_mut()
            }
        };

        match s.fault() {
            None => {
                let stats = train(dir, name, s.plan(), &opts, data)?;
                Ok(live_report(s, stats, Vec::new()))
            }
            Some(spec) => {
                let failed = s.resolve_fault_device(spec)?;

                // Phase 1: train until the exit; final_params is the
                // live checkpoint (fault::replication topology).
                let mut before_opts = opts.clone();
                before_opts.steps = spec.fail_after;
                let before = train(dir, name, s.plan(), &before_opts, data)?;

                // Phase 2: the spec'd recovery mechanism (timing model
                // for the report; weights come from the checkpoint).
                let t0 = Instant::now();
                let report = s.recover(spec, failed)?;
                let replan_wall_s = t0.elapsed().as_secs_f64();

                // Phase 3: resume on the recovery plan, warm-started.
                let mut after_opts = opts.clone();
                after_opts.steps = spec.resume_rounds;
                after_opts.initial_params = Some(Arc::new(before.final_params.clone()));
                let after = train(dir, name, &report.new_plan, &after_opts, data)?;

                let event = RecoveryEvent {
                    round: spec.fail_after,
                    failed_device: failed,
                    kind: spec.recovery,
                    replan_wall_s,
                    report,
                };
                Ok(merge_live_phases(s, before, after, event))
            }
        }
    }
}

fn live_report(s: &Session, stats: TrainStats, recoveries: Vec<RecoveryEvent>) -> RunReport {
    RunReport {
        backend: "pjrt",
        plan: s.plan().clone(),
        schedule: s.schedule().clone(),
        rounds: stats.losses.len(),
        losses: stats.losses,
        round_secs: stats.round_secs,
        throughput: stats.samples_per_sec,
        predicted_throughput: s.outcome().predicted_throughput,
        max_staleness: s.policy().max_staleness(),
        weight_stash_slots: s.weight_stash_slots(),
        bytes_on_network: 0,
        codec: s.codec().describe(),
        sync: s.sync_mode(),
        sim: None,
        recoveries,
        final_params: Some(stats.final_params),
        rpc: None,
    }
}

fn merge_live_phases(
    s: &Session,
    before: TrainStats,
    after: TrainStats,
    event: RecoveryEvent,
) -> RunReport {
    // `throughput` is the pre-fault pipeline's rate on every backend
    // (the recovery event carries the post-fault rate); the per-phase
    // wall-clocks stay recoverable from `round_secs`.
    let pre_fault_throughput = before.samples_per_sec;
    let mut losses = before.losses;
    losses.extend(after.losses);
    let mut round_secs = before.round_secs;
    round_secs.extend(after.round_secs);
    RunReport {
        backend: "pjrt",
        plan: s.plan().clone(),
        schedule: s.schedule().clone(),
        rounds: losses.len(),
        losses,
        round_secs,
        throughput: pre_fault_throughput,
        predicted_throughput: s.outcome().predicted_throughput,
        max_staleness: s.policy().max_staleness(),
        weight_stash_slots: s.weight_stash_slots(),
        bytes_on_network: 0,
        codec: s.codec().describe(),
        sync: s.sync_mode(),
        sim: None,
        recoveries: vec![event],
        final_params: Some(after.final_params),
        rpc: None,
    }
}
