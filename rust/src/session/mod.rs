//! The Asteroid session: **one** typed path from (model, cluster,
//! training config) to a [`RunReport`], covering all three phases of
//! the paper's Fig. 3.
//!
//! * **Preprocessing** — [`SessionBuilder::build`] resolves the model
//!   source (zoo or AOT artifact manifest) and builds the
//!   [`ProfileTable`] for the cluster;
//! * **Planning** — the builder's declarative [`Planner`] choice runs
//!   through the unified `Planner::plan` dispatch (Algorithm 2 or any
//!   baseline) and the planned [`Session`] carries the resulting
//!   [`PlanOutcome`] plus the explicit round [`Schedule`];
//! * **Execution** — any [`ExecutionBackend`] turns the planned
//!   session into a [`RunReport`]: [`SimBackend`] prices the schedule
//!   event-accurately, [`PjrtBackend`] runs the live in-process worker
//!   pipeline, and [`RpcBackend`] drives separate `asteroid-worker`
//!   OS processes over TCP (real transport, heartbeats, and device
//!   exits that actually kill a process).
//!
//! Device-exit fault tolerance (paper §3.4) is a *property of the
//! session*, not a special entry point: attach a [`FaultSpec`] and
//! every backend injects the exit and recovers (lightweight replay or
//! heavy rescheduling), reporting the event in
//! [`RunReport::recoveries`].
//!
//! ```no_run
//! use asteroid::config::{ClusterSpec, TrainConfig};
//! use asteroid::planner::Planner;
//! use asteroid::session::{FaultSpec, Session, SimBackend};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::builder()
//!     .model("mobilenetv2")
//!     .cluster(ClusterSpec::env("B", 100.0)?)
//!     .train(TrainConfig::new(256, 16))
//!     .planner(Planner::Asteroid)
//!     .fault(FaultSpec::last_planned())
//!     .build()?;
//! let report = session.run(&mut SimBackend::default())?;
//! println!("{:.1} samples/s", report.throughput);
//! # Ok(())
//! # }
//! ```

pub mod backend;
pub mod churn;
pub mod rpc;

pub use backend::{ExecutionBackend, PjrtBackend, SimBackend};
pub use churn::ChurnSpec;
pub use rpc::{RpcBackend, RpcDeviceStats, RpcStats};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::codec::CodecSpec;
use crate::comm::SyncMode;
use crate::config::{ClusterSpec, TrainConfig};
use crate::fault::{
    heavy_reschedule, heavy_reschedule_incremental, lightweight_replay, ChurnTrace, HeartbeatCfg,
    RecoveryReport,
};
use crate::model::from_manifest::{Manifest, ManifestModel};
use crate::model::{zoo, ModelDesc};
use crate::pipeline::OptimizerCfg;
use crate::planner::dp::{DpState, PlanOutcome};
use crate::planner::{Plan, Planner};
use crate::profiler::ProfileTable;
use crate::runtime::Tensor;
use crate::schedule::{Schedule, SchedulePolicy, DEFAULT_POLICY};
use crate::sim::SimResult;

/// Where a session's model comes from.
#[derive(Debug, Clone)]
pub enum ModelSource {
    /// Analytic zoo model (simulation-only).
    Zoo(String),
    /// AOT-compiled manifest model (live execution available).
    Artifact { dir: PathBuf, name: String },
}

/// Which device exits in a [`FaultSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    /// A specific cluster device id.
    Device(usize),
    /// The last device of the planned pipeline (resolved after
    /// planning — handy for specs written before the plan exists).
    LastPlanned,
}

/// Which §3.4 recovery mechanism handles the exit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryKind {
    /// Ours: heartbeat detect → restore from the replication topology
    /// → FLOPs-based layer re-planning → boundary migration.
    Lightweight,
    /// Baseline: gather all weights, re-run the full planner on the
    /// strongest remaining device, redistribute everything.
    Heavy,
    /// Heavy rescheduling through the planner's incremental fast
    /// path: the same full-quality Algorithm-2 replan, but seeded with
    /// the session's retained [`DpState`] so only the DP cells the
    /// removal invalidated are recomputed (bit-for-bit the same plan;
    /// see `fault::heavy_reschedule_incremental`).  Falls back to a
    /// full rebuild when the session has no state — e.g. a baseline
    /// planner built it.
    HeavyIncremental,
    /// A previously-exited device reconnected and the plan re-expanded
    /// through the planner's join fast path
    /// (`fault::rejoin_replan` / `plan_hpp_incremental_join`).  Driven
    /// by churn traces ([`ChurnSpec`]), not by a `FaultSpec`.
    Rejoin,
    /// The timing-drift straggler detector flagged a device and the
    /// current membership was replanned around the derated hardware
    /// (`fault::degraded_reschedule`).  Driven by churn traces.
    Straggler,
}

impl RecoveryKind {
    /// Stable name, matching the mechanism strings reports serialise.
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryKind::Lightweight => "lightweight",
            RecoveryKind::Heavy => "heavy",
            RecoveryKind::HeavyIncremental => "heavy-incremental",
            RecoveryKind::Rejoin => "rejoin",
            RecoveryKind::Straggler => "straggler",
        }
    }
}

/// Declarative device-exit injection: *what* fails, *when*, and *how*
/// the session recovers.  Replaces the old bespoke
/// failure-training/recovery entry points.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// HPP-Rounds to run before the device exits.
    pub fail_after: usize,
    /// The exiting device.
    pub target: FaultTarget,
    pub recovery: RecoveryKind,
    /// Rounds to run on the recovered pipeline (live backends; the sim
    /// backend prices the remaining `steps - fail_after` rounds on the
    /// recovery plan instead).
    pub resume_rounds: usize,
    /// Heartbeat timing: the detection model the recovery report
    /// charges, *and* the live beat period / silence deadline the
    /// `RpcBackend` driver and its workers actually run with — one
    /// configuration, so sim and live agree on detection latency.
    /// Validated at `SessionBuilder::build` (see
    /// [`HeartbeatCfg::validate`]).
    pub heartbeat: HeartbeatCfg,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            fail_after: 4,
            target: FaultTarget::LastPlanned,
            recovery: RecoveryKind::Lightweight,
            resume_rounds: 4,
            heartbeat: HeartbeatCfg::default(),
        }
    }
}

impl FaultSpec {
    /// Exit of a specific device id.
    pub fn device(id: usize) -> FaultSpec {
        FaultSpec { target: FaultTarget::Device(id), ..FaultSpec::default() }
    }

    /// Exit of the last planned device.
    pub fn last_planned() -> FaultSpec {
        FaultSpec::default()
    }

    pub fn after(mut self, rounds: usize) -> FaultSpec {
        self.fail_after = rounds;
        self
    }

    pub fn resume_for(mut self, rounds: usize) -> FaultSpec {
        self.resume_rounds = rounds;
        self
    }

    pub fn with_recovery(mut self, kind: RecoveryKind) -> FaultSpec {
        self.recovery = kind;
        self
    }

    /// Shorthand for the heavy-rescheduling baseline.
    pub fn heavy(self) -> FaultSpec {
        self.with_recovery(RecoveryKind::Heavy)
    }

    /// Shorthand for heavy rescheduling through the planner's
    /// incremental fast path (see [`RecoveryKind::HeavyIncremental`]).
    pub fn heavy_incremental(self) -> FaultSpec {
        self.with_recovery(RecoveryKind::HeavyIncremental)
    }

    /// Override the heartbeat timing (beat interval, miss threshold,
    /// probe RTT).  Tight configurations ([`HeartbeatCfg::tight`])
    /// keep integration tests fast; the validated floor keeps them
    /// from flaking.  The same numbers drive the sim's detection model
    /// and the live RPC monitor.
    pub fn with_heartbeat(mut self, hb: HeartbeatCfg) -> FaultSpec {
        self.heartbeat = hb;
        self
    }
}

/// Per-run execution options shared by every backend.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// HPP-Rounds to execute (without a fault; with one, the live
    /// backend runs `fault.fail_after + fault.resume_rounds`).
    pub steps: usize,
    pub opt: OptimizerCfg,
    pub seed: u64,
    /// Shape live inter-worker links with the cluster's D2D bandwidth
    /// matrix (edge-network emulation).
    pub emulate: bool,
    /// Print a progress line every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            steps: 20,
            opt: OptimizerCfg::sgd(0.05),
            seed: 42,
            emulate: false,
            log_every: 5,
        }
    }
}

/// One membership event + recovery observed during a run: a device
/// exit ([`FaultSpec`] or a churn trace), a rejoin, a detected
/// straggler, or a link degradation.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Round index the recovery landed at (for stragglers: the round
    /// the drift detector fired, not the round the slowdown was
    /// injected).
    pub round: usize,
    /// The device the event concerns: the exited/rejoined/derated
    /// device (for link degradations: the link's lower endpoint).
    pub failed_device: usize,
    /// Which recovery path ran.
    pub kind: RecoveryKind,
    /// Wall-clock seconds the replan itself took in *this* process
    /// (detection + modelled costs live in `report`; live backends
    /// measure this around the actual replan call, the sim reports its
    /// in-process planning time).
    pub replan_wall_s: f64,
    /// Full §3.4 breakdown: detect/restore/replan/migrate, the
    /// recovery plan, its throughput, and the schedule-diff-derived
    /// replay set.
    pub report: RecoveryReport,
}

/// The unified result every [`ExecutionBackend`] returns.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Which backend produced this (`"sim"` / `"pjrt"` / `"rpc"`).
    pub backend: &'static str,
    /// The plan that was executed.
    pub plan: Plan,
    /// Its explicit HPP-Round schedule (the session's policy,
    /// sample-sharded form).
    pub schedule: Schedule,
    /// Rounds executed (sim: priced).
    pub rounds: usize,
    /// Mean loss per round.  Empty for the sim backend: schedule
    /// pricing has no numerics.
    pub losses: Vec<f64>,
    /// Wall-clock seconds per round (sim: the priced round latency,
    /// switching to the recovery plan's latency after a fault).
    pub round_secs: Vec<f64>,
    /// Samples/second of the (pre-fault) pipeline.
    pub throughput: f64,
    /// The planner's analytic Eq. 4-6 prediction, for cross-checks.
    pub predicted_throughput: f64,
    /// Bounded-staleness budget of the session's schedule policy (0 =
    /// synchronous: round-accumulated gradients, version-0 weights).
    pub max_staleness: usize,
    /// Weight-version stash ring depth the policy implies: the largest
    /// per-stage admission window (K_p + sigma) across the plan, i.e.
    /// how many parameter snapshots a worker may pin at once (1 = just
    /// the live weights; synchronous policies).
    pub weight_stash_slots: usize,
    /// Bytes moved across links in one round (sim backend; the live
    /// engine does not meter its channels).
    pub bytes_on_network: u64,
    /// The session's wire codec spec in canonical `describe()` form
    /// (`"fp32"`, `"int8"`, `"fp32,12=int8"`, ...) — what the data
    /// plane encoded with and the planner priced against.
    pub codec: String,
    /// The data-plane collective topology gradient/parameter sync ran
    /// over (`Ring` worker-to-worker by default, `DriverStar`
    /// mediation as fallback) — also what Eq. 5 pricing assumed.
    pub sync: SyncMode,
    /// Event-accurate pricing detail (sim backend only).
    pub sim: Option<SimResult>,
    /// Device exits injected via the session's [`FaultSpec`].
    pub recoveries: Vec<RecoveryEvent>,
    /// Per-device RPC timings and byte meters ([`RpcBackend`] only).
    pub rpc: Option<RpcStats>,
    /// Final weights by global layer index (live backend only) — the
    /// coordinator-side checkpoint.
    pub final_params: Option<BTreeMap<usize, Vec<Tensor>>>,
}

impl RunReport {
    pub fn first_loss(&self) -> Option<f64> {
        self.losses.first().copied()
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.losses.last().copied()
    }

    /// Mean seconds per round.
    pub fn mean_round_secs(&self) -> f64 {
        if self.round_secs.is_empty() {
            0.0
        } else {
            self.round_secs.iter().sum::<f64>() / self.round_secs.len() as f64
        }
    }
}

/// Builder for a planned [`Session`].  `build()` runs preprocessing
/// and planning; execution is a separate, backend-polymorphic step.
pub struct SessionBuilder {
    model: Option<ModelSource>,
    cluster: Option<ClusterSpec>,
    train: Option<TrainConfig>,
    minibatch: Option<usize>,
    planner: Planner,
    policy: &'static dyn SchedulePolicy,
    codec: CodecSpec,
    sync: SyncMode,
    fault: Option<FaultSpec>,
    churn: Option<ChurnSpec>,
    run: RunConfig,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            model: None,
            cluster: None,
            train: None,
            minibatch: None,
            planner: Planner::Asteroid,
            policy: DEFAULT_POLICY,
            codec: CodecSpec::default(),
            sync: SyncMode::default(),
            fault: None,
            churn: None,
            run: RunConfig::default(),
        }
    }
}

impl SessionBuilder {
    /// A zoo model by name (`mobilenetv2`, `efficientnet-b1`,
    /// `resnet50`, `bert-small`).  Simulation-only.
    pub fn model(mut self, zoo_name: &str) -> Self {
        self.model = Some(ModelSource::Zoo(zoo_name.to_string()));
        self
    }

    /// An AOT-compiled manifest model (built by `make artifacts`).
    /// Required for live execution through [`PjrtBackend`].
    pub fn artifact_model(mut self, dir: impl Into<PathBuf>, name: &str) -> Self {
        self.model = Some(ModelSource::Artifact { dir: dir.into(), name: name.to_string() });
        self
    }

    pub fn cluster(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = Some(cluster);
        self
    }

    /// Mini-batch / micro-batch configuration.  Required for zoo
    /// models; artifact models default to (8 × compiled micro-batch,
    /// compiled micro-batch).
    pub fn train(mut self, cfg: TrainConfig) -> Self {
        self.train = Some(cfg);
        self
    }

    /// Mini-batch size alone, with the micro-batch taken from the
    /// compiled manifest — artifact models only (a zoo model has no
    /// compiled micro-batch to default from; use [`Self::train`]).
    pub fn minibatch(mut self, minibatch: usize) -> Self {
        self.minibatch = Some(minibatch);
        self
    }

    pub fn planner(mut self, planner: Planner) -> Self {
        self.planner = planner;
        self
    }

    /// Round schedule policy (default: the paper's 1F1B/K_p).
    pub fn schedule(mut self, policy: &'static dyn SchedulePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Wire codec for the data plane (default: fp32 passthrough).
    /// Like the schedule policy, the codec governs *planning too*:
    /// Algorithm-2 comm and AllReduce terms price the compressed wire
    /// bytes, so the chosen cut points are optimal for the format the
    /// pipeline actually transmits.
    pub fn codec(mut self, codec: CodecSpec) -> Self {
        self.codec = codec;
        self
    }

    /// Collective topology for gradient/parameter synchronisation
    /// (default: [`SyncMode::Ring`] — workers exchange chunks directly
    /// over the data plane and the driver stays O(1) messages per
    /// round).  [`SyncMode::DriverStar`] restores driver-mediated
    /// sync.  Like the codec, the choice governs *planning too*: the
    /// Eq. 5 AllReduce term prices the selected topology, so stage
    /// groupings are optimal for the collective that actually runs.
    pub fn sync(mut self, mode: SyncMode) -> Self {
        self.sync = mode;
        self
    }

    /// Declarative device-exit injection (see [`FaultSpec`]).
    pub fn fault(mut self, spec: FaultSpec) -> Self {
        self.fault = Some(spec);
        self
    }

    /// Declarative elastic-membership injection: a timed
    /// [`ChurnTrace`] of exits, rejoins, slowdowns and link
    /// degradations (or a full [`ChurnSpec`] with detection knobs).
    /// Mutually exclusive with [`Self::fault`] — a churn trace *is*
    /// the generalised fault spec.
    pub fn churn(mut self, spec: impl Into<ChurnSpec>) -> Self {
        self.churn = Some(spec.into());
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.run.steps = steps;
        self
    }

    pub fn optimizer(mut self, opt: OptimizerCfg) -> Self {
        self.run.opt = opt;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.run.seed = seed;
        self
    }

    pub fn emulate(mut self, on: bool) -> Self {
        self.run.emulate = on;
        self
    }

    pub fn log_every(mut self, n: usize) -> Self {
        self.run.log_every = n;
        self
    }

    /// Preprocessing + planning: resolve the model, profile the
    /// cluster, and run the chosen planner.  Every validation error a
    /// mis-assembled session can produce surfaces here, before any
    /// execution.
    pub fn build(self) -> Result<Session> {
        let source = self
            .model
            .context("Session::builder(): .model(..) or .artifact_model(..) is required")?;
        let cluster = self
            .cluster
            .context("Session::builder(): .cluster(..) is required")?;
        anyhow::ensure!(!cluster.devices.is_empty(), "cluster has no devices");
        if let Some(f) = &self.fault {
            f.heartbeat
                .validate()
                .context("Session::builder(): invalid FaultSpec heartbeat timing")?;
        }
        if let Some(c) = &self.churn {
            anyhow::ensure!(
                self.fault.is_none(),
                ".fault(..) and .churn(..) are mutually exclusive — a churn trace is \
                 the generalised fault spec (use ChurnTrace::new().exit(r, d))"
            );
            c.heartbeat
                .validate()
                .context("Session::builder(): invalid ChurnSpec heartbeat timing")?;
            c.straggler
                .validate()
                .context("Session::builder(): invalid ChurnSpec straggler thresholds")?;
            anyhow::ensure!(
                matches!(
                    c.exit_recovery,
                    RecoveryKind::Lightweight | RecoveryKind::HeavyIncremental
                ),
                "churn exit_recovery must be Lightweight or HeavyIncremental — only \
                 those replan over the current active set (got {:?})",
                c.exit_recovery
            );
        }

        let (model, artifacts, manifest_model, cfg) = match &source {
            ModelSource::Zoo(name) => {
                let model = zoo::by_name(name).with_context(|| {
                    format!("unknown zoo model {name:?} (run `asteroid envs` for the list)")
                })?;
                anyhow::ensure!(
                    self.minibatch.is_none(),
                    "SessionBuilder::minibatch is for artifact models (micro-batch comes \
                     from the manifest); zoo sessions take a full .train(TrainConfig)"
                );
                let cfg = self.train.context(
                    "zoo sessions need an explicit .train(TrainConfig) — there is no \
                     compiled micro-batch to default from",
                )?;
                (model, None, None, cfg)
            }
            ModelSource::Artifact { dir, name } => {
                let manifest = Manifest::load(dir)?;
                let mm = manifest.model(name)?.clone();
                let cfg = match (self.train, self.minibatch) {
                    (Some(_), Some(_)) => anyhow::bail!(
                        ".train(..) and .minibatch(..) are mutually exclusive"
                    ),
                    (Some(cfg), None) => cfg,
                    (None, Some(mb)) => TrainConfig::new(mb, mm.microbatch),
                    (None, None) => TrainConfig::new(mm.microbatch * 8, mm.microbatch),
                };
                anyhow::ensure!(
                    cfg.microbatch == mm.microbatch,
                    "training micro-batch {} != compiled micro-batch {} (re-run aot.py)",
                    cfg.microbatch,
                    mm.microbatch
                );
                let model = mm.to_model_desc();
                (model, Some((dir.clone(), name.clone())), Some(mm), cfg)
            }
        };

        let table = ProfileTable::new(&cluster, &model);
        // The session's policy governs planning too: memory budgets,
        // sim_select pricing and the outcome schedule all honour it.
        // Algorithm-2 planners also hand back their DP state, which
        // the session retains so a device-exit recovery can take the
        // incremental replan fast path.
        let (outcome, dp_state) = self
            .planner
            .plan_with_state_codec(
                &table, &cluster, &model, &cfg, self.policy, &self.codec, self.sync,
            )
            .with_context(|| format!("planning ({})", self.planner.describe()))?;
        let schedule = outcome.schedule.clone();

        // A `--codec` per-boundary override only ever applies where a
        // planned stage cut crosses that layer index.  An override on
        // any other boundary is silently inert — reject it here (and
        // `asteroid lint` reports the same defect as ASTR014).
        let cuts: Vec<usize> = outcome
            .plan
            .stages
            .iter()
            .take(outcome.plan.stages.len().saturating_sub(1))
            .map(|s| s.layers.1)
            .collect();
        for (b, c) in self.codec.overrides() {
            if !cuts.contains(&(b as usize)) {
                anyhow::bail!(
                    "codec override {}={} names no planned stage boundary \
                     (the plan cuts at {:?}); the override would be silently inert",
                    b,
                    c.name(),
                    cuts
                );
            }
        }

        // The trace is checked against the *planned* membership and the
        // run length — exits of unplanned devices, joins of active
        // ones, or events past the last round all fail here.
        if let Some(c) = &self.churn {
            c.trace
                .validate(&cluster, &outcome.plan.devices(), self.run.steps)
                .context("Session::builder(): invalid churn trace")?;
        }

        Ok(Session {
            source,
            cluster,
            model,
            table,
            cfg,
            planner: self.planner,
            policy: self.policy,
            codec: self.codec,
            sync: self.sync,
            fault: self.fault,
            churn: self.churn,
            run_cfg: self.run,
            artifacts,
            manifest_model,
            outcome,
            schedule,
            dp_state: dp_state.map(std::sync::Arc::new),
        })
    }
}

/// A planned session: model + cluster + profiles + the chosen plan and
/// its round schedule.  Hand it to an [`ExecutionBackend`] (or call
/// [`Session::run`]) to get a [`RunReport`].
#[derive(Clone)]
pub struct Session {
    source: ModelSource,
    cluster: ClusterSpec,
    model: ModelDesc,
    table: ProfileTable,
    cfg: TrainConfig,
    planner: Planner,
    policy: &'static dyn SchedulePolicy,
    codec: CodecSpec,
    sync: SyncMode,
    fault: Option<FaultSpec>,
    churn: Option<ChurnSpec>,
    run_cfg: RunConfig,
    artifacts: Option<(PathBuf, String)>,
    /// Resolved at build so backends never re-parse the manifest.
    manifest_model: Option<ManifestModel>,
    outcome: PlanOutcome,
    schedule: Schedule,
    /// Retained Algorithm-2 planner state (`None` for baseline
    /// planners): the seed for incremental replans on device exit.
    dp_state: Option<std::sync::Arc<DpState>>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    pub fn cluster(&self) -> &ClusterSpec {
        &self.cluster
    }

    pub fn model(&self) -> &ModelDesc {
        &self.model
    }

    pub fn table(&self) -> &ProfileTable {
        &self.table
    }

    pub fn train_config(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn planner(&self) -> Planner {
        self.planner
    }

    pub fn policy(&self) -> &'static dyn SchedulePolicy {
        self.policy
    }

    /// The session's wire codec spec — what the data plane encodes
    /// with and what the planner priced against.
    pub fn codec(&self) -> &CodecSpec {
        &self.codec
    }

    /// The session's collective topology for gradient/parameter sync —
    /// what the data plane runs and what the Eq. 5 term priced.
    pub fn sync_mode(&self) -> SyncMode {
        self.sync
    }

    pub fn source(&self) -> &ModelSource {
        &self.source
    }

    pub fn fault(&self) -> Option<&FaultSpec> {
        self.fault.as_ref()
    }

    pub fn churn(&self) -> Option<&ChurnSpec> {
        self.churn.as_ref()
    }

    pub fn run_config(&self) -> &RunConfig {
        &self.run_cfg
    }

    /// Artifact directory + model name when this is a live-capable
    /// session.
    pub fn artifacts(&self) -> Option<(&Path, &str)> {
        self.artifacts.as_ref().map(|(d, n)| (d.as_path(), n.as_str()))
    }

    /// The parsed manifest model backing an artifact session.
    pub fn manifest_model(&self) -> Option<&ManifestModel> {
        self.manifest_model.as_ref()
    }

    /// The full planning outcome (plan, planner schedule, predictions,
    /// planning time).
    pub fn outcome(&self) -> &PlanOutcome {
        &self.outcome
    }

    pub fn plan(&self) -> &Plan {
        &self.outcome.plan
    }

    /// The session's explicit HPP-Round schedule (its policy,
    /// sample-sharded form — what [`SimBackend`] prices).
    pub fn schedule(&self) -> &Schedule {
        &self.schedule
    }

    /// The retained Algorithm-2 planner state, when the session's
    /// planner produced one (the incremental-replan seed).
    pub fn dp_state(&self) -> Option<&DpState> {
        self.dp_state.as_deref()
    }

    /// The same state as a cheap shared handle — what churn execution
    /// seeds its evolving state chain from.
    pub(crate) fn dp_state_arc(&self) -> Option<std::sync::Arc<DpState>> {
        self.dp_state.clone()
    }

    /// The weight-version stash ring depth the session's policy
    /// implies: the largest per-stage admission window of the plan
    /// (1 = live weights only; see [`RunReport::weight_stash_slots`]).
    pub fn weight_stash_slots(&self) -> usize {
        if self.policy.max_staleness() == 0 {
            return 1;
        }
        self.plan()
            .stages
            .iter()
            .map(|s| self.policy.effective_kp(s.kp, self.plan().num_micro))
            .max()
            .unwrap_or(1)
    }

    /// Re-attach a different fault spec without re-planning (the plan
    /// and profiles are unchanged by *how* we intend to break it).
    /// Clears any churn spec — the two are mutually exclusive.
    pub fn with_fault(mut self, spec: FaultSpec) -> Session {
        self.fault = Some(spec);
        self.churn = None;
        self
    }

    pub fn without_fault(mut self) -> Session {
        self.fault = None;
        self
    }

    /// Re-attach a different churn spec without re-planning.  The
    /// trace is re-validated against the planned membership and run
    /// length; clears any fault spec.
    pub fn with_churn(mut self, spec: impl Into<ChurnSpec>) -> Result<Session> {
        let spec = spec.into();
        spec.trace.validate(&self.cluster, &self.plan().devices(), self.run_cfg.steps)?;
        spec.heartbeat.validate()?;
        spec.straggler.validate()?;
        self.churn = Some(spec);
        self.fault = None;
        Ok(self)
    }

    /// Execute this session on a backend.  This is the single public
    /// entry path from a planned session to a [`RunReport`].
    pub fn run(&self, backend: &mut dyn ExecutionBackend) -> Result<RunReport> {
        backend.run(self)
    }

    /// Resolve a fault target against the planned pipeline.
    pub(crate) fn resolve_fault_device(&self, spec: &FaultSpec) -> Result<usize> {
        let devices = self.plan().devices();
        match spec.target {
            FaultTarget::LastPlanned => devices
                .last()
                .copied()
                .context("plan has no devices to fail"),
            FaultTarget::Device(id) => {
                anyhow::ensure!(
                    devices.contains(&id),
                    "fault target device {id} is not part of the plan (devices: {devices:?})"
                );
                Ok(id)
            }
        }
    }

    /// Run the spec'd §3.4 recovery mechanism for an exit of `failed`.
    pub(crate) fn recover(&self, spec: &FaultSpec, failed: usize) -> Result<RecoveryReport> {
        match spec.recovery {
            RecoveryKind::Lightweight => lightweight_replay(
                &self.table,
                &self.cluster,
                &self.model,
                &self.cfg,
                self.plan(),
                failed,
                &spec.heartbeat,
                self.policy,
                &self.codec,
                self.sync,
            ),
            RecoveryKind::Heavy => heavy_reschedule(
                &self.table,
                &self.cluster,
                &self.model,
                &self.cfg,
                self.plan(),
                failed,
                &spec.heartbeat,
                self.policy,
                &self.codec,
                self.sync,
            ),
            RecoveryKind::HeavyIncremental => heavy_reschedule_incremental(
                &self.table,
                &self.cluster,
                &self.model,
                &self.cfg,
                self.plan(),
                failed,
                &spec.heartbeat,
                self.policy,
                &self.codec,
                self.sync,
                self.dp_state.as_deref(),
            )
            .map(|(report, _)| report),
            RecoveryKind::Rejoin | RecoveryKind::Straggler => anyhow::bail!(
                "{:?} recoveries are driven by churn traces (.churn(..)), not by a \
                 FaultSpec device exit",
                spec.recovery
            ),
        }
    }

    /// One-line summary for CLI/report output.
    pub fn describe(&self) -> String {
        format!(
            "{} on {} via {} ({})",
            self.model.name,
            self.cluster.describe(),
            self.planner.describe(),
            self.plan().describe(&self.cluster)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::baselines::Method;

    fn zoo_session(env: &str) -> Session {
        Session::builder()
            .model("mobilenetv2")
            .cluster(ClusterSpec::env(env, 100.0).unwrap())
            .train(TrainConfig::new(256, 16))
            .build()
            .unwrap()
    }

    #[test]
    fn builds_plans_and_prices() {
        let s = zoo_session("B");
        let report = s.run(&mut SimBackend::default()).unwrap();
        assert!(report.throughput > 0.0);
        assert_eq!(report.backend, "sim");
        assert_eq!(&report.plan, s.plan());
    }

    #[test]
    fn builder_requires_model_and_cluster() {
        let err = Session::builder().build().unwrap_err().to_string();
        assert!(err.contains(".model"), "{err}");
        let err = Session::builder().model("mobilenetv2").build().unwrap_err().to_string();
        assert!(err.contains(".cluster"), "{err}");
        // Zoo sessions must pass an explicit training config.
        let err = Session::builder()
            .model("mobilenetv2")
            .cluster(ClusterSpec::env("A", 100.0).unwrap())
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("train"), "{err}");
    }

    #[test]
    fn unknown_zoo_model_rejected() {
        assert!(Session::builder()
            .model("nope")
            .cluster(ClusterSpec::env("A", 100.0).unwrap())
            .train(TrainConfig::new(64, 8))
            .build()
            .is_err());
    }

    #[test]
    fn baselines_reachable_through_builder() {
        for m in [
            Method::DataParallel,
            Method::GpipePP,
            Method::PipeDream,
            Method::Dapple,
            Method::OnDevice,
        ] {
            let s = Session::builder()
                .model("mobilenetv2")
                .cluster(ClusterSpec::env("A", 100.0).unwrap())
                .train(TrainConfig::new(128, 16))
                .planner(Planner::Baseline(m))
                .build()
                .unwrap();
            assert!(s.outcome().predicted_throughput > 0.0, "{m:?}");
        }
        assert!(Session::builder()
            .model("mobilenetv2")
            .cluster(ClusterSpec::env("A", 100.0).unwrap())
            .train(TrainConfig::new(128, 16))
            .planner(Planner::Baseline(Method::HetPipe))
            .build()
            .is_err());
    }

    #[test]
    fn fault_spec_drives_both_recovery_mechanisms() {
        let base = Session::builder()
            .model("efficientnet-b1")
            .cluster(ClusterSpec::env("D", 100.0).unwrap())
            .train(TrainConfig::new(256, 16))
            .steps(8)
            .build()
            .unwrap();
        let lite = base
            .clone()
            .with_fault(FaultSpec::last_planned().after(3))
            .run(&mut SimBackend::default())
            .unwrap();
        let heavy = base
            .with_fault(FaultSpec::last_planned().after(3).heavy())
            .run(&mut SimBackend::default())
            .unwrap();
        let (l, h) = (&lite.recoveries[0].report, &heavy.recoveries[0].report);
        assert!(l.total_s() < h.total_s(), "lite {} vs heavy {}", l.total_s(), h.total_s());
        assert!(!l.new_plan.devices().contains(&lite.recoveries[0].failed_device));
        // Post-fault rounds are priced on the recovery plan.
        assert_eq!(lite.round_secs.len(), 8);
        assert_ne!(lite.round_secs[0], lite.round_secs[7]);
    }

    #[test]
    fn heavy_incremental_recovery_matches_heavy_plan() {
        // The session retains the planner's DP state and the
        // incremental recovery replans to the *same* plan as the heavy
        // baseline — only the replan cost path differs.
        let base = Session::builder()
            .model("efficientnet-b1")
            .cluster(ClusterSpec::env("D", 100.0).unwrap())
            .train(TrainConfig::new(256, 16))
            .steps(8)
            .build()
            .unwrap();
        assert!(base.dp_state().is_some(), "Asteroid sessions retain DP state");
        let heavy = base
            .clone()
            .with_fault(FaultSpec::last_planned().after(3).heavy())
            .run(&mut SimBackend::default())
            .unwrap();
        let inc = base
            .with_fault(FaultSpec::last_planned().after(3).heavy_incremental())
            .run(&mut SimBackend::default())
            .unwrap();
        let (h, i) = (&heavy.recoveries[0].report, &inc.recoveries[0].report);
        assert_eq!(i.mechanism, "heavy-incremental");
        assert_eq!(i.new_plan, h.new_plan);
        // Baseline-planned sessions have no DP state and still recover
        // (full-rebuild fallback inside the fast path).
        let baseline = Session::builder()
            .model("efficientnet-b1")
            .cluster(ClusterSpec::env("D", 100.0).unwrap())
            .train(TrainConfig::new(256, 16))
            .planner(Planner::Baseline(Method::Dapple))
            .fault(FaultSpec::last_planned().after(3).heavy_incremental())
            .steps(8)
            .build()
            .unwrap();
        assert!(baseline.dp_state().is_none());
        let rep = baseline.run(&mut SimBackend::default()).unwrap();
        assert_eq!(rep.recoveries[0].report.mechanism, "heavy-incremental");
    }

    #[test]
    fn fault_target_must_be_planned() {
        let s = zoo_session("B");
        let spec = FaultSpec::device(999);
        assert!(s.resolve_fault_device(&spec).is_err());
    }

    #[test]
    fn churn_spec_validated_at_build() {
        let base = || {
            Session::builder()
                .model("efficientnet-b1")
                .cluster(ClusterSpec::env("D", 100.0).unwrap())
                .train(TrainConfig::new(256, 16))
                .steps(8)
        };
        let dev = base().build().unwrap().plan().devices()[0];
        // A well-formed exit→rejoin trace builds.
        let s = base().churn(ChurnTrace::new().exit(2, dev).join(4, dev)).build().unwrap();
        assert!(s.churn().is_some());
        assert!(s.fault().is_none());
        // Joining an already-active device is caught at build.
        let err = base()
            .churn(ChurnTrace::new().join(2, dev))
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("already active"), "{err}");
        // Events past the run length are caught at build.
        assert!(base().churn(ChurnTrace::new().exit(99, dev)).build().is_err());
        // .fault() and .churn() are mutually exclusive.
        assert!(base()
            .fault(FaultSpec::last_planned())
            .churn(ChurnTrace::new().exit(2, dev))
            .build()
            .is_err());
        // Exit recovery is restricted to the churn-capable mechanisms.
        assert!(base()
            .churn(
                ChurnSpec::from(ChurnTrace::new().exit(2, dev))
                    .with_exit_recovery(RecoveryKind::Heavy)
            )
            .build()
            .is_err());
        // with_churn re-validates against the existing plan.
        let planned = base().build().unwrap();
        let planned = planned.with_churn(ChurnTrace::new().exit(2, dev)).unwrap();
        assert_eq!(planned.churn().unwrap().trace.len(), 1);
        assert!(planned.with_churn(ChurnTrace::new().join(2, dev)).is_err());
    }
}
