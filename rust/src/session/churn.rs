//! Session-side churn execution: the evolving-membership state machine
//! behind `.churn(trace)`.
//!
//! [`ChurnSpec`] is what a session carries (the trace plus detection
//! knobs); [`ChurnState`] is the executor both backends drive — it owns
//! the *evolving* copies of the cluster, profile table, plan and
//! planner [`DpState`] so that a sequence of exits, rejoins, slowdowns
//! and link degradations each replans against the fleet as it actually
//! is at that point, not the fleet the session was built on.
//!
//! The chained `DpState` is the whole point of the join fast path: an
//! incremental-exit recovery returns the shrunk state, a rejoin
//! re-expands it through `plan_hpp_incremental_join`, and a hardware
//! mutation (slowdown / link degrade) invalidates it — the next replan
//! rebuilds a fresh state *on the degraded cluster*, which future
//! events chain from again.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ClusterSpec;
use crate::fault::churn::{ChurnEvent, ChurnTrace};
use crate::fault::{
    degraded_reschedule, heavy_reschedule_incremental, lightweight_replay, rejoin_replan,
    HeartbeatCfg, RecoveryReport, StragglerCfg,
};
use crate::planner::dp::{plan_hpp_subset, DpState, PlannerConfig};
use crate::planner::Plan;
use crate::profiler::ProfileTable;
use crate::session::{RecoveryKind, Session};

/// Declarative churn injection: the timed event trace plus the
/// detection knobs the backends run it with.
#[derive(Debug, Clone)]
pub struct ChurnSpec {
    pub trace: ChurnTrace,
    /// Recovery mechanism for `Exit` events.  Only
    /// [`RecoveryKind::Lightweight`] and
    /// [`RecoveryKind::HeavyIncremental`] are churn-capable: both
    /// replan over the *current* active set, which is what lets later
    /// joins re-expand the chained planner state.  (The `Heavy`
    /// baseline replans over every non-failed cluster device — wrong
    /// once membership has drifted.)
    pub exit_recovery: RecoveryKind,
    /// Heartbeat timing for exit detection (sim detection model and
    /// live monitor alike, as in [`crate::session::FaultSpec`]).
    pub heartbeat: HeartbeatCfg,
    /// Timing-drift straggler detection thresholds.
    pub straggler: StragglerCfg,
}

impl From<ChurnTrace> for ChurnSpec {
    fn from(trace: ChurnTrace) -> ChurnSpec {
        ChurnSpec {
            trace,
            exit_recovery: RecoveryKind::HeavyIncremental,
            heartbeat: HeartbeatCfg::default(),
            straggler: StragglerCfg::default(),
        }
    }
}

impl ChurnSpec {
    pub fn with_exit_recovery(mut self, kind: RecoveryKind) -> ChurnSpec {
        self.exit_recovery = kind;
        self
    }

    pub fn with_heartbeat(mut self, hb: HeartbeatCfg) -> ChurnSpec {
        self.heartbeat = hb;
        self
    }

    pub fn with_straggler(mut self, cfg: StragglerCfg) -> ChurnSpec {
        self.straggler = cfg;
        self
    }

    /// The [`RecoveryKind`] a trace event reports as.
    pub fn kind_for(&self, event: &ChurnEvent) -> RecoveryKind {
        match event {
            ChurnEvent::Exit { .. } => self.exit_recovery,
            ChurnEvent::Join { .. } => RecoveryKind::Rejoin,
            ChurnEvent::Slowdown { .. } => RecoveryKind::Straggler,
            // A link degradation is a full replan over unchanged
            // membership — reported as the heavy mechanism it runs.
            ChurnEvent::LinkDegrade { .. } => RecoveryKind::Heavy,
        }
    }
}

/// The evolving fleet a churn trace executes against.
pub(crate) struct ChurnState {
    /// Cluster as degraded so far (slowdowns derate devices, link
    /// events rewrite the bandwidth matrix).
    pub cluster: ClusterSpec,
    /// Profile table of `cluster` — rebuilt on every hardware mutation.
    pub table: ProfileTable,
    /// The plan currently executing.
    pub plan: Plan,
    /// Chained planner state covering exactly `active`, when one
    /// exists (`None` after a lightweight exit, which replans outside
    /// the DP).
    pub dp: Option<Arc<DpState>>,
    /// Sorted active device ids.
    pub active: Vec<usize>,
    /// Injected-but-not-yet-detected slowdown factors by device.
    pub slowdown: BTreeMap<usize, f64>,
}

impl ChurnState {
    pub fn new(s: &Session) -> ChurnState {
        ChurnState {
            cluster: s.cluster().clone(),
            table: s.table().clone(),
            plan: s.plan().clone(),
            dp: s.dp_state_arc(),
            active: s.plan().devices(),
            slowdown: BTreeMap::new(),
        }
    }

    fn planner_config(s: &Session) -> PlannerConfig {
        PlannerConfig {
            policy: s.policy(),
            codec: *s.codec(),
            sync: s.sync_mode(),
            ..PlannerConfig::default()
        }
    }

    /// Does the chained state cover exactly the current active set?
    fn dp_covers_active(&self) -> bool {
        self.dp.as_ref().map_or(false, |p| {
            let mut o = p.order().to_vec();
            o.sort_unstable();
            o == self.active
        })
    }

    /// Re-seed the planner state over the current active set when the
    /// chain was broken (e.g. by a lightweight exit) — so an
    /// exit-recovery replan never silently re-admits devices that
    /// already left.
    fn ensure_state(&mut self, s: &Session) -> Result<()> {
        if !self.dp_covers_active() {
            let pc = Self::planner_config(s);
            let (_, st) = plan_hpp_subset(
                &self.table,
                &self.cluster,
                s.model(),
                s.train_config(),
                &pc,
                &self.active,
            )?;
            self.dp = Some(Arc::new(st));
        }
        Ok(())
    }

    /// Device exit: run the spec'd mechanism over the current fleet.
    pub fn exit(&mut self, s: &Session, spec: &ChurnSpec, device: usize) -> Result<RecoveryReport> {
        anyhow::ensure!(self.active.contains(&device), "churn exit: device {device} not active");
        let report = match spec.exit_recovery {
            RecoveryKind::Lightweight => {
                let r = lightweight_replay(
                    &self.table,
                    &self.cluster,
                    s.model(),
                    s.train_config(),
                    &self.plan,
                    device,
                    &spec.heartbeat,
                    s.policy(),
                    s.codec(),
                    s.sync_mode(),
                )?;
                // Lightweight replans outside the DP — the chained
                // state no longer matches the executing plan's set.
                self.dp = None;
                r
            }
            _ => {
                self.ensure_state(s)?;
                let (r, st) = heavy_reschedule_incremental(
                    &self.table,
                    &self.cluster,
                    s.model(),
                    s.train_config(),
                    &self.plan,
                    device,
                    &spec.heartbeat,
                    s.policy(),
                    s.codec(),
                    s.sync_mode(),
                    self.dp.as_deref(),
                )?;
                self.dp = Some(Arc::new(st));
                r
            }
        };
        self.active.retain(|&d| d != device);
        self.slowdown.remove(&device);
        self.plan = report.new_plan.clone();
        Ok(report)
    }

    /// Device rejoin: re-expand through the join fast path when the
    /// chained state survived, full subset rebuild otherwise.
    pub fn join(&mut self, s: &Session, device: usize) -> Result<RecoveryReport> {
        let (report, st) = rejoin_replan(
            &self.table,
            &self.cluster,
            s.model(),
            s.train_config(),
            &self.plan,
            device,
            s.policy(),
            s.codec(),
            s.sync_mode(),
            self.dp.as_deref(),
        )?;
        self.dp = Some(Arc::new(st));
        self.active.push(device);
        self.active.sort_unstable();
        self.plan = report.new_plan.clone();
        Ok(report)
    }

    /// Record an injected slowdown (nothing replans until the drift
    /// detector fires).
    pub fn inject_slowdown(&mut self, device: usize, factor: f64) {
        self.slowdown.insert(device, factor);
    }

    /// The drift detector flagged `device`: derate it in the evolving
    /// cluster by `factor`, rebuild profiles, and replan the current
    /// membership.  `detection_s` is the observation window the report
    /// charges (computed by the caller — rounds-to-detect in the sim,
    /// wall-clock since injection in the RPC driver).
    pub fn straggler(
        &mut self,
        s: &Session,
        device: usize,
        factor: f64,
        detection_s: f64,
    ) -> Result<RecoveryReport> {
        anyhow::ensure!(
            self.active.contains(&device),
            "churn straggler: device {device} not active"
        );
        self.cluster.devices[device].peak_flops /= factor;
        self.cluster.devices[device].overhead_s *= factor;
        self.table = ProfileTable::new(&self.cluster, s.model());
        self.slowdown.remove(&device);
        self.reschedule_degraded(s, "straggler", detection_s)
    }

    /// A link degraded to `mbps`: rewrite the bandwidth matrix, rebuild
    /// profiles, replan the current membership.
    pub fn link_degrade(
        &mut self,
        s: &Session,
        a: usize,
        b: usize,
        mbps: f64,
    ) -> Result<RecoveryReport> {
        let bytes_per_s = mbps * 1e6 / 8.0;
        self.cluster.bandwidth[a][b] = bytes_per_s;
        self.cluster.bandwidth[b][a] = bytes_per_s;
        self.table = ProfileTable::new(&self.cluster, s.model());
        self.reschedule_degraded(s, "link-degrade", 0.0)
    }

    fn reschedule_degraded(
        &mut self,
        s: &Session,
        mechanism: &'static str,
        detection_s: f64,
    ) -> Result<RecoveryReport> {
        let (report, st) = degraded_reschedule(
            &self.table,
            &self.cluster,
            s.model(),
            s.train_config(),
            &self.plan,
            mechanism,
            detection_s,
            s.policy(),
            s.codec(),
            s.sync_mode(),
        )?;
        // The fresh state was computed on the degraded cluster — the
        // valid chain seed for everything that follows.
        self.dp = Some(Arc::new(st));
        self.plan = report.new_plan.clone();
        Ok(report)
    }

    /// Seconds one round of the current plan takes on the current
    /// (possibly degraded) fleet.
    pub fn round_latency(&self, s: &Session) -> f64 {
        let sim = crate::sim::price(
            &crate::sim::PriceRequest::new(&self.table, &self.cluster, s.model(), &self.plan)
                .policy(s.policy())
                .codec(*s.codec())
                .sync(s.sync_mode()),
        );
        self.plan.samples_per_round() as f64 / sim.throughput
    }
}
