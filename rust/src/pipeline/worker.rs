//! Asteroid Worker (paper Fig. 11): one per (stage, replica slot).
//!
//! Each worker thread owns its own PJRT runtime (XLA handles are not
//! `Send`), its stage's parameters, optimizer state, and an in-memory
//! task pool.  It executes its device's `schedule::ComputeOp` script —
//! derived once from the plan's `schedule::Schedule` by the training
//! orchestrator — blocking on the inputs each scripted op needs.  The
//! worker itself contains **no scheduling logic**: 1F1B order and the
//! K_p warm-up window are properties of the script, not of this loop.
//! After the script it accumulates gradients across the HPP-Round,
//! AllReduces within its replica group, applies the optimizer, then
//! reports to the coordinator and waits for the next round.
//!
//! Intra-stage data parallelism assigns whole micro-batches round-robin
//! across the group (micro m -> slot m mod g, the Schedule IR's
//! `Sharding::RoundRobin`): batch-level DP with identical gradient math
//! to sample sharding (gradients average over the same mini-batch),
//! chosen because the AOT stage executables are shape-specialised to
//! the planned micro-batch size.  DESIGN.md documents this
//! substitution.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::from_manifest::ManifestModel;
use crate::pipeline::channel::{Rx, Tx};
use crate::pipeline::collective::GroupComm;
use crate::pipeline::optimizer::{Optimizer, OptimizerCfg};
use crate::runtime::{init_layer_params, LayerParams, ParamStash, Runtime, Tensor};
use crate::schedule::ComputeOp;
use crate::util::rng::Rng;

/// Messages between workers / coordinator.
#[derive(Debug)]
pub enum Msg {
    /// Stage input for a micro-batch (activations, or raw data for
    /// stage 0).
    Act { micro: usize, t: Tensor },
    /// Gradient w.r.t. this stage's output for a micro-batch.
    Grad { micro: usize, t: Tensor },
    /// Labels/targets for the head stage.
    Targets { micro: usize, t: Tensor },
    /// Begin the next HPP-Round.
    NextRound,
    /// Shut down cleanly.
    Stop,
}

/// Worker -> coordinator reports.
#[derive(Debug)]
pub enum Report {
    RoundDone {
        stage: usize,
        slot: usize,
        /// Sum of per-micro losses (head stage only; 0 elsewhere).
        loss_sum: f64,
        micros: usize,
    },
    /// Final parameter values, sent on clean shutdown (slot 0 of each
    /// stage only): (global layer index, tensors).  This is the live
    /// checkpoint stream the fault-tolerance machinery consumes.
    FinalParams { layer: usize, values: Vec<Tensor> },
    Fatal { stage: usize, slot: usize, error: String },
}

/// Static description of one worker.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub stage: usize,
    /// Layer range [lo, hi) into the manifest layer list.
    pub layers: (usize, usize),
    pub slot: usize,
    /// This device's ordered FP/BP work for one HPP-Round, from
    /// `Schedule::compute_script(stage, slot)` — the single source of
    /// 1F1B/K_p ordering.
    pub script: Vec<ComputeOp>,
    /// Bounded-staleness weight-stash ring depth (the schedule's
    /// effective admission window, K_p + sigma).  0 = synchronous
    /// policy: gradients accumulate across the round and no stash
    /// exists.  > 0 switches the worker to version-tagged parameter
    /// reads/writes: one update per backward, each backward computed
    /// against the snapshot its forward read (`runtime::ParamStash`),
    /// and the round barrier reconciling replicas by parameter
    /// averaging instead of gradient AllReduce.
    pub stash_slots: usize,
    pub num_micro: usize,
    pub is_first: bool,
    pub is_last: bool,
    pub seed: u64,
    pub opt: OptimizerCfg,
    /// Warm-start parameters by global layer index (fault-tolerance
    /// restore / checkpoint resume); layers not present use fresh init.
    pub initial_params: Option<Arc<std::collections::BTreeMap<usize, Vec<Tensor>>>>,
}

/// Run the worker loop (call from a dedicated thread).  `next`/`prev`
/// are per-destination (possibly bandwidth-shaped) send handles.
pub fn run_worker(
    spec: WorkerSpec,
    model: ManifestModel,
    rx: Rx<Msg>,
    next: Vec<Tx<Msg>>,
    prev: Vec<Tx<Msg>>,
    report: std::sync::mpsc::Sender<Report>,
    group: Arc<GroupComm>,
) {
    let outcome = worker_loop(&spec, &model, &rx, &next, &prev, &report, &group);
    if let Err(e) = outcome {
        let _ = report.send(Report::Fatal {
            stage: spec.stage,
            slot: spec.slot,
            error: format!("{e:#}"),
        });
    }
}

fn worker_loop(
    spec: &WorkerSpec,
    model: &ManifestModel,
    rx: &Rx<Msg>,
    next: &[Tx<Msg>],
    prev: &[Tx<Msg>],
    report: &std::sync::mpsc::Sender<Report>,
    group: &Arc<GroupComm>,
) -> Result<()> {
    let (lo, hi) = spec.layers;
    let layers = &model.layers[lo..hi];

    // Compile exactly the artifacts this stage needs.
    let mut names: Vec<&str> = Vec::new();
    for l in layers {
        for n in [l.artifact_fwd.as_str(), l.artifact_bwd.as_str()] {
            if !names.contains(&n) {
                names.push(n);
            }
        }
    }
    let rt = Runtime::load(model, &names)
        .with_context(|| format!("stage {} slot {} runtime", spec.stage, spec.slot))?;

    // Layer-seeded init: replicas of the same layer get identical
    // parameters (required for DP correctness).  Warm-start values (a
    // restore after a device failure, or a checkpoint resume) override
    // the fresh init per layer.
    let mut params: Vec<LayerParams> = layers
        .iter()
        .enumerate()
        .map(|(k, l)| {
            let mut rng = Rng::new(spec.seed ^ ((lo + k) as u64).wrapping_mul(0x9E37_79B9));
            let mut p = init_layer_params(l, &mut rng);
            if let Some(init) = spec.initial_params.as_ref().and_then(|m| m.get(&(lo + k))) {
                assert_eq!(init.len(), p.values.len(), "warm-start arity for {}", l.name);
                p.values = init.clone();
            }
            p
        })
        .collect();
    let sizes: Vec<usize> = params
        .iter()
        .flat_map(|p| p.values.iter().map(|t| t.elements()))
        .collect();
    let mut opt = Optimizer::new(spec.opt, &sizes);
    let async_updates = spec.stash_slots > 0;
    // The stash pins the already-converted parameter *literals* per
    // weight version, so a version-tagged backward never re-pays the
    // tensor-to-literal conversion (the engine's documented top
    // hot-path cost).
    let mut stash: ParamStash<Vec<Vec<xla::Literal>>> = ParamStash::new(spec.stash_slots);
    let mut version: u64 = 0;

    let mut lits = Arc::new(build_lits(&params)?);

    loop {
        let loss_sum = run_round(
            spec, layers, &rt, &mut params, &mut lits, &mut opt, &sizes, &mut stash,
            &mut version, rx, next, prev,
        )?;

        if async_updates {
            // Bounded staleness already applied one update per backward
            // inside the round; the round barrier is the sigma-bounded
            // group sync.  Replicas of a DP group drifted micro-by-micro
            // (no per-micro gradient AllReduce), so reconcile by
            // parameter averaging instead of gradient summing.
            if group.size() > 1 {
                let red = group.allreduce_sum(&flat_values(&params));
                let g = group.size() as f32;
                let mut off = 0;
                for p in &mut params {
                    for t in &mut p.values {
                        for v in t.as_f32_mut()? {
                            *v = red[off] / g;
                            off += 1;
                        }
                    }
                }
                lits = Arc::new(build_lits(&params)?);
                // The averaging rewrote the weights out-of-band: the
                // next round's forwards must not alias the pre-average
                // snapshot recorded under the same version number.
                stash.invalidate_last();
            }
        } else {
            // ---- gradient AllReduce (sum across replicas), one
            // optimizer step over the 1/M-scaled round gradient.
            let reduced = group.allreduce_sum(&flat_grads(&params));
            apply_update(&mut params, &sizes, &mut opt, reduced, 1.0 / spec.num_micro as f32)?;
            for p in &mut params {
                p.zero_grads();
            }
            lits = Arc::new(build_lits(&params)?);
        }

        let assigned = spec.script.iter().filter(|op| op.is_fwd()).count();
        report
            .send(Report::RoundDone {
                stage: spec.stage,
                slot: spec.slot,
                loss_sum,
                micros: assigned,
            })
            .ok();

        // Wait for the coordinator's round barrier.
        loop {
            match rx.recv()? {
                Msg::NextRound => break,
                Msg::Stop => {
                    // Clean shutdown: slot 0 streams its stage weights
                    // back (the coordinator-side checkpoint).
                    if spec.slot == 0 {
                        for (k, p) in params.iter().enumerate() {
                            report
                                .send(Report::FinalParams {
                                    layer: lo + k,
                                    values: p.values.clone(),
                                })
                                .ok();
                        }
                    }
                    return Ok(());
                }
                other => bail!("unexpected message between rounds: {other:?}"),
            }
        }
    }
}

/// Pump one message from the inbox into the per-kind buffers.
fn pump(
    rx: &Rx<Msg>,
    acts: &mut BTreeMap<usize, Tensor>,
    grads_in: &mut BTreeMap<usize, Tensor>,
    targets: &mut BTreeMap<usize, Tensor>,
) -> Result<()> {
    match rx.recv()? {
        Msg::Act { micro, t } => {
            acts.insert(micro, t);
        }
        Msg::Grad { micro, t } => {
            grads_in.insert(micro, t);
        }
        Msg::Targets { micro, t } => {
            targets.insert(micro, t);
        }
        Msg::Stop => bail!("stopped mid-round"),
        Msg::NextRound => bail!("unexpected NextRound mid-round"),
    }
    Ok(())
}

/// Convert the live parameter values to cached XLA literals.
/// Parameter literals are cached across weight versions and rebuilt
/// only after an optimizer step: converting ~MBs of weights per layer
/// on EVERY micro-batch execution was the engine's top hot-path cost
/// (EXPERIMENTS.md §Perf).
fn build_lits(params: &[LayerParams]) -> Result<Vec<Vec<xla::Literal>>> {
    params
        .iter()
        .map(|p| p.values.iter().map(|t| t.to_literal()).collect())
        .collect()
}

/// Flatten the accumulated gradient buffers (AllReduce order).
fn flat_grads(params: &[LayerParams]) -> Vec<f32> {
    params
        .iter()
        .flat_map(|p| p.grads.iter().flat_map(|g| g.as_f32().unwrap().iter().copied()))
        .collect()
}

/// Flatten the live parameter values (parameter-averaging order).
fn flat_values(params: &[LayerParams]) -> Vec<f32> {
    params
        .iter()
        .flat_map(|p| p.values.iter().flat_map(|t| t.as_f32().unwrap().iter().copied()))
        .collect()
}

/// One optimizer step over the live parameters with `grads` scaled by
/// `scale` — the shared write path of the per-round (sync) and
/// per-micro (bounded-staleness) updates.
fn apply_update(
    params: &mut [LayerParams],
    sizes: &[usize],
    opt: &mut Optimizer,
    mut grads: Vec<f32>,
    scale: f32,
) -> Result<()> {
    for v in &mut grads {
        *v *= scale;
    }
    let mut p_refs: Vec<&mut [f32]> = Vec::new();
    for p in params.iter_mut() {
        for t in &mut p.values {
            p_refs.push(t.as_f32_mut()?);
        }
    }
    let mut g_refs: Vec<&[f32]> = Vec::new();
    let mut off = 0;
    for &n in sizes {
        g_refs.push(&grads[off..off + n]);
        off += n;
    }
    opt.step(&mut p_refs, &g_refs);
    Ok(())
}

/// Process one HPP-Round by executing the worker's schedule script;
/// returns the loss sum (head stage only).
///
/// Under a bounded-staleness script (`spec.stash_slots` > 0) this is
/// where the Schedule IR's weight-version tags become real: every
/// `Fwd` pins the literals of the version it read into the bounded
/// stash ring (an `Arc` clone of the cached `lits` — no conversion),
/// every `Bwd` computes against exactly that snapshot and then applies
/// its update to the live weights (advancing the version), so a
/// forward may read weights at most sigma updates behind the frontier
/// — never more, or `ParamStash::record` reports the overrun.
#[allow(clippy::too_many_arguments)]
fn run_round(
    spec: &WorkerSpec,
    layers: &[crate::model::from_manifest::ManifestLayer],
    rt: &Runtime,
    params: &mut [LayerParams],
    lits: &mut Arc<Vec<Vec<xla::Literal>>>,
    opt: &mut Optimizer,
    sizes: &[usize],
    stash: &mut ParamStash<Vec<Vec<xla::Literal>>>,
    version: &mut u64,
    rx: &Rx<Msg>,
    next: &[Tx<Msg>],
    prev: &[Tx<Msg>],
) -> Result<f64> {
    let async_updates = spec.stash_slots > 0;
    let mut acts: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut grads_in: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut targets: BTreeMap<usize, Tensor> = BTreeMap::new();
    // Per-micro stash of layer inputs (for the rematerialising BP) —
    // distinct from the weight-version `ParamStash`.
    let mut input_stash: BTreeMap<usize, Vec<Tensor>> = BTreeMap::new();
    // Split-backward scripts (zero-bubble policies): the AOT backward
    // executable computes input- and weight-gradients fused, so both
    // are accumulated at the Bwd op and the scheduled BwdW is a
    // bookkeeping op that only validates the order.  Accumulation
    // order does not change the summed round gradient, and realising
    // the weight-grad at Bwd avoids holding O(M) deferred gradient
    // copies that no memory model charges.
    let mut bwd_done: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    // Head stage only: boundary activations awaiting their scheduled
    // Bwd (the head artifact fuses its FP with the loss BP, so the
    // head runs at the Bwd position to honour the script order under
    // any policy — fill-drain included).
    let mut head_acts: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut loss_sum = 0.0f64;

    let head_is_here = spec.is_last;

    for op in &spec.script {
        match *op {
            ComputeOp::Fwd(m) => {
                // Block until this op's inputs are in (the script order
                // already respects 1F1B and the K_p/staleness window).
                while !acts.contains_key(&m) {
                    pump(rx, &mut acts, &mut grads_in, &mut targets)?;
                }
                // Version-tagged read: pin the literals this forward
                // uses (an Arc clone of the cached conversion — free),
                // so its backward runs against the same version after
                // intervening per-micro updates.
                if async_updates {
                    stash.record(m, *version, || lits.clone())?;
                }
                let x = acts.remove(&m).unwrap();
                if head_is_here {
                    let n = layers.len();
                    let (cur, inputs) =
                        forward_through(&layers[..n - 1], rt, &lits[..n - 1], x)?;
                    input_stash.insert(m, inputs);
                    head_acts.insert(m, cur);
                } else {
                    let (out, inputs) = forward_through(layers, rt, &lits[..], x)?;
                    input_stash.insert(m, inputs);
                    let bytes = out.byte_len();
                    next[m % next.len()].send(bytes, Msg::Act { micro: m, t: out })?;
                }
            }
            ComputeOp::Bwd(m) => {
                let gx = {
                    // Version-tagged weights for this backward: the
                    // stashed literals its forward read (bounded
                    // staleness), or the round-constant literals (sync).
                    // Either way pre-converted — no per-micro
                    // tensor-to-literal cost here.
                    let snap = if async_updates {
                        Some(
                            stash
                                .take(m)
                                .with_context(|| format!("no stashed weights for micro {m}"))?,
                        )
                    } else {
                        None
                    };
                    let bwd_lits: &[Vec<xla::Literal>] = match &snap {
                        Some((_, weights)) => &weights[..],
                        None => &lits[..],
                    };
                    if head_is_here {
                        // Fused head FP+BP on the stashed boundary
                        // activation, then BP through the stashed layers.
                        while !targets.contains_key(&m) {
                            pump(rx, &mut acts, &mut grads_in, &mut targets)?;
                        }
                        let tgt = targets.remove(&m).unwrap();
                        let cur = head_acts
                            .remove(&m)
                            .with_context(|| format!("no head activation for micro {m}"))?;
                        let inputs = input_stash
                            .remove(&m)
                            .with_context(|| format!("no stashed inputs for micro {m}"))?;
                        let (loss, gx) =
                            head_backward(layers, rt, params, bwd_lits, cur, &tgt, &inputs)?;
                        loss_sum += loss as f64;
                        gx
                    } else {
                        while !grads_in.contains_key(&m) {
                            pump(rx, &mut acts, &mut grads_in, &mut targets)?;
                        }
                        let g = grads_in.remove(&m).unwrap();
                        let inputs = input_stash
                            .remove(&m)
                            .with_context(|| format!("no stashed inputs for micro {m}"))?;
                        backward_through(layers, rt, params, bwd_lits, &inputs, g)?
                    }
                };
                bwd_done.insert(m);
                if !spec.is_first {
                    let t = gx.context("non-first stage must produce an input gradient")?;
                    let bytes = t.byte_len();
                    prev[m % prev.len()].send(bytes, Msg::Grad { micro: m, t })?;
                }
                // Version-tagged write: a bounded-staleness worker
                // applies this micro's gradient immediately, advancing
                // the weight version the next forward reads.
                if async_updates {
                    let grads = flat_grads(params);
                    apply_update(params, sizes, opt, grads, 1.0 / spec.num_micro as f32)?;
                    for p in params.iter_mut() {
                        p.zero_grads();
                    }
                    *version += 1;
                    *lits = Arc::new(build_lits(params)?);
                }
            }
            ComputeOp::BwdW(m) => {
                // Scheduled weight-gradient slot of a split backward.
                // The fused AOT executable already accumulated it at
                // this micro's Bwd; a BwdW whose Bwd has not run is a
                // schedule the engine cannot execute — report it as
                // such, not as a policy-name mismatch.
                anyhow::ensure!(
                    bwd_done.contains(&m),
                    "unsupported op order: BwdW({m}) before its Bwd \
                     (stage {} slot {})",
                    spec.stage,
                    spec.slot
                );
            }
        }
    }
    Ok(loss_sum)
}

/// FP through all non-head layers; returns (stage output, stashed
/// per-layer inputs).
fn forward_through(
    layers: &[crate::model::from_manifest::ManifestLayer],
    rt: &Runtime,
    lits: &[Vec<xla::Literal>],
    x: Tensor,
) -> Result<(Tensor, Vec<Tensor>)> {
    let mut cur = x;
    let mut inputs = Vec::with_capacity(layers.len());
    for (k, l) in layers.iter().enumerate() {
        if l.kind == "head" {
            bail!("head layer in forward_through");
        }
        let cur_lit = cur.to_literal()?;
        let mut refs: Vec<&xla::Literal> = lits[k].iter().collect();
        refs.push(&cur_lit);
        let mut out = rt
            .execute_literals(&l.artifact_fwd, &refs)
            .with_context(|| format!("fwd {}", l.name))?;
        inputs.push(cur);
        cur = out.remove(0);
    }
    Ok((cur, inputs))
}

/// Fused head FP+BP on the stashed boundary activation `cur`, then BP
/// back through this stage's stashed non-head layers.  Returns (loss,
/// gradient for the previous stage if any).
fn head_backward(
    layers: &[crate::model::from_manifest::ManifestLayer],
    rt: &Runtime,
    params: &mut [LayerParams],
    lits: &[Vec<xla::Literal>],
    cur: Tensor,
    targets: &Tensor,
    inputs: &[Tensor],
) -> Result<(f32, Option<Tensor>)> {
    let n = layers.len();
    let head = &layers[n - 1];
    if head.kind != "head" {
        bail!("last layer of head stage must be kind=head, got {}", head.kind);
    }

    // head_fwdbwd: (params..., x, targets) -> (loss, g_params..., g_x)
    let cur_lit = cur.to_literal()?;
    let tgt_lit = targets.to_literal()?;
    let mut refs: Vec<&xla::Literal> = lits[n - 1].iter().collect();
    refs.push(&cur_lit);
    refs.push(&tgt_lit);
    let mut out = rt
        .execute_literals(&head.artifact_fwd, &refs)
        .with_context(|| format!("head {}", head.name))?;
    let n_p = params[n - 1].values.len();
    anyhow::ensure!(out.len() == n_p + 2, "head output arity");
    let loss = out.remove(0).scalar_f32()?;
    let gx = out.pop().unwrap();
    params[n - 1].accumulate(&out)?;

    // BP back through the stashed non-head layers.
    let gx = backward_through(&layers[..n - 1], rt, params, lits, inputs, gx)?;
    Ok((loss, gx))
}

/// BP through `layers` (reversed) given stashed inputs and the output
/// gradient; accumulates parameter gradients.  Returns the input
/// gradient unless the first layer consumes it (embed/stem bwd with no
/// g_x output).
fn backward_through(
    layers: &[crate::model::from_manifest::ManifestLayer],
    rt: &Runtime,
    params: &mut [LayerParams],
    lits: &[Vec<xla::Literal>],
    inputs: &[Tensor],
    g: Tensor,
) -> Result<Option<Tensor>> {
    let mut g = Some(g);
    for k in (0..layers.len()).rev() {
        let l = &layers[k];
        let grad_in = g.take().context("gradient chain broken")?;
        let x_lit = inputs[k].to_literal()?;
        let g_lit = grad_in.to_literal()?;
        let mut refs: Vec<&xla::Literal> = lits[k].iter().collect();
        refs.push(&x_lit);
        refs.push(&g_lit);
        let mut out = rt
            .execute_literals(&l.artifact_bwd, &refs)
            .with_context(|| format!("bwd {}", l.name))?;
        let n_p = params[k].values.len();
        if out.len() == n_p + 1 {
            g = Some(out.pop().unwrap());
        } else if out.len() == n_p {
            g = None; // first layer (embed/stem): no input gradient
        } else {
            bail!("bwd {}: unexpected arity {}", l.name, out.len());
        }
        params[k].accumulate(&out)?;
    }
    Ok(g)
}
