//! Asteroid Worker (paper Fig. 11): one per (stage, replica slot),
//! in-process thread flavour.
//!
//! Each worker thread owns its own PJRT runtime (XLA handles are not
//! `Send`), its stage's parameters, optimizer state, and an in-memory
//! task pool.  It executes its device's `schedule::ComputeOp` script —
//! derived once from the plan's `schedule::Schedule` by the training
//! orchestrator — through the transport-agnostic step core of
//! [`crate::pipeline::step`]: the [`PjrtStage`] here implements
//! [`StageCompute`] over the AOT executables, and the channel pair
//! implements [`DataPlane`].  The worker itself contains **no
//! scheduling logic**: 1F1B order and the K_p warm-up window are
//! properties of the script, not of this loop.  After the script it
//! accumulates gradients across the HPP-Round, AllReduces within its
//! replica group, applies the optimizer, then reports to the
//! coordinator and waits for the next round.
//!
//! Intra-stage data parallelism assigns whole micro-batches round-robin
//! across the group (micro m -> slot m mod g, the Schedule IR's
//! `Sharding::RoundRobin`): batch-level DP with identical gradient math
//! to sample sharding (gradients average over the same mini-batch),
//! chosen because the AOT stage executables are shape-specialised to
//! the planned micro-batch size.  DESIGN.md documents this
//! substitution.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::codec::Codec;
use crate::model::from_manifest::{ManifestLayer, ManifestModel};
use crate::pipeline::channel::{Rx, Tx};
use crate::pipeline::collective::GroupComm;
use crate::pipeline::optimizer::Optimizer;
use crate::pipeline::step::{run_script_round, DataMsg, DataPlane, StageCompute};
use crate::runtime::{init_layer_params, LayerParams, ParamStash, Runtime, Tensor};

pub use crate::pipeline::step::WorkerSpec;

/// Messages between workers / coordinator.
#[derive(Debug)]
pub enum Msg {
    /// Stage input for a micro-batch (activations, or raw data for
    /// stage 0).
    Act { micro: usize, t: Tensor },
    /// Gradient w.r.t. this stage's output for a micro-batch.
    Grad { micro: usize, t: Tensor },
    /// Labels/targets for the head stage.
    Targets { micro: usize, t: Tensor },
    /// Begin the next HPP-Round.
    NextRound,
    /// Shut down cleanly.
    Stop,
}

/// Worker -> coordinator reports.
#[derive(Debug)]
pub enum Report {
    RoundDone {
        stage: usize,
        slot: usize,
        /// Sum of per-micro losses (head stage only; 0 elsewhere).
        loss_sum: f64,
        micros: usize,
    },
    /// Final parameter values, sent on clean shutdown (slot 0 of each
    /// stage only): (global layer index, tensors).  This is the live
    /// checkpoint stream the fault-tolerance machinery consumes.
    FinalParams { layer: usize, values: Vec<Tensor> },
    Fatal { stage: usize, slot: usize, error: String },
}

/// Run the worker loop (call from a dedicated thread).  `next`/`prev`
/// are per-destination (possibly bandwidth-shaped) send handles.
/// `codecs` = (activation, gradient) wire codec for this stage's
/// outbound boundaries: sends transcode through the codec so the
/// receiving stage computes on exactly the wire's numerics.
pub fn run_worker(
    spec: WorkerSpec,
    model: ManifestModel,
    rx: Rx<Msg>,
    next: Vec<Tx<Msg>>,
    prev: Vec<Tx<Msg>>,
    codecs: (Codec, Codec),
    report: std::sync::mpsc::Sender<Report>,
    group: Arc<GroupComm>,
) {
    let outcome = worker_loop(&spec, &model, &rx, &next, &prev, codecs, &report, &group);
    if let Err(e) = outcome {
        let _ = report.send(Report::Fatal {
            stage: spec.stage,
            slot: spec.slot,
            error: format!("{e:#}"),
        });
    }
}

/// The channel-backed [`DataPlane`]: receive from the worker's inbox,
/// send over the per-destination (possibly shaped) handles with the
/// round-robin `micro % g` routing.
struct ChannelPlane<'a> {
    rx: &'a Rx<Msg>,
    next: &'a [Tx<Msg>],
    prev: &'a [Tx<Msg>],
    /// Wire codec at this stage's output boundary (activations out).
    codec_act: Codec,
    /// Wire codec at this stage's input boundary (gradients out).
    codec_grad: Codec,
}

impl DataPlane for ChannelPlane<'_> {
    fn recv(&mut self) -> Result<DataMsg> {
        match self.rx.recv()? {
            Msg::Act { micro, t } => Ok(DataMsg::Act { micro, t }),
            Msg::Grad { micro, t } => Ok(DataMsg::Grad { micro, t }),
            Msg::Targets { micro, t } => Ok(DataMsg::Targets { micro, t }),
            Msg::Stop => bail!("stopped mid-round"),
            Msg::NextRound => bail!("unexpected NextRound mid-round"),
        }
    }

    fn send_act(&mut self, micro: usize, t: Tensor) -> Result<()> {
        // Encode-then-decode at the send so the receiver computes on
        // the wire's numerics; the shaper charges the compressed size.
        let t = self.codec_act.transcode(&t);
        let bytes = self.codec_act.wire_bytes(t.byte_len() as u64, t.dtype()) as usize;
        self.next[micro % self.next.len()].send(bytes, Msg::Act { micro, t })
    }

    fn send_grad(&mut self, micro: usize, t: Tensor) -> Result<()> {
        let t = self.codec_grad.transcode(&t);
        let bytes = self.codec_grad.wire_bytes(t.byte_len() as u64, t.dtype()) as usize;
        self.prev[micro % self.prev.len()].send(bytes, Msg::Grad { micro, t })
    }
}

fn worker_loop(
    spec: &WorkerSpec,
    model: &ManifestModel,
    rx: &Rx<Msg>,
    next: &[Tx<Msg>],
    prev: &[Tx<Msg>],
    codecs: (Codec, Codec),
    report: &std::sync::mpsc::Sender<Report>,
    group: &Arc<GroupComm>,
) -> Result<()> {
    let (lo, hi) = spec.layers;
    let layers = &model.layers[lo..hi];

    // Compile exactly the artifacts this stage needs.
    let mut names: Vec<&str> = Vec::new();
    for l in layers {
        for n in [l.artifact_fwd.as_str(), l.artifact_bwd.as_str()] {
            if !names.contains(&n) {
                names.push(n);
            }
        }
    }
    let rt = Runtime::load(model, &names)
        .with_context(|| format!("stage {} slot {} runtime", spec.stage, spec.slot))?;

    // Layer-seeded init: replicas of the same layer get identical
    // parameters (required for DP correctness).  Warm-start values (a
    // restore after a device failure, or a checkpoint resume) override
    // the fresh init per layer.
    let params: Vec<LayerParams> = layers
        .iter()
        .enumerate()
        .map(|(k, l)| {
            let mut rng =
                crate::util::rng::Rng::new(spec.seed ^ ((lo + k) as u64).wrapping_mul(0x9E37_79B9));
            let mut p = init_layer_params(l, &mut rng);
            if let Some(init) = spec.initial_params.as_ref().and_then(|m| m.get(&(lo + k))) {
                assert_eq!(init.len(), p.values.len(), "warm-start arity for {}", l.name);
                p.values = init.clone();
            }
            p
        })
        .collect();
    let sizes: Vec<usize> = params
        .iter()
        .flat_map(|p| p.values.iter().map(|t| t.elements()))
        .collect();
    let opt = Optimizer::new(spec.opt, &sizes);
    let async_updates = spec.stash_slots > 0;
    let lits = Arc::new(build_lits(&params)?);

    let mut stage = PjrtStage {
        spec,
        layers,
        rt: &rt,
        params,
        lits,
        opt,
        sizes,
        // The stash pins the already-converted parameter *literals* per
        // weight version, so a version-tagged backward never re-pays
        // the tensor-to-literal conversion (the engine's documented top
        // hot-path cost).
        stash: ParamStash::new(spec.stash_slots.max(1)),
        version: 0,
        input_stash: BTreeMap::new(),
        head_acts: BTreeMap::new(),
        bwd_done: Default::default(),
    };

    loop {
        let loss_sum = {
            let mut plane =
                ChannelPlane { rx, next, prev, codec_act: codecs.0, codec_grad: codecs.1 };
            run_script_round(&spec.script, spec.is_first, spec.is_last, &mut stage, &mut plane)?
        };

        if async_updates {
            // Bounded staleness already applied one update per backward
            // inside the round; the round barrier is the sigma-bounded
            // group sync.  Replicas of a DP group drifted micro-by-micro
            // (no per-micro gradient AllReduce), so reconcile by
            // parameter averaging instead of gradient summing.
            if group.size() > 1 {
                let red = group.allreduce_sum(&flat_values(&stage.params));
                let g = group.size() as f32;
                let mut off = 0;
                for p in &mut stage.params {
                    for t in &mut p.values {
                        for v in t.as_f32_mut()? {
                            *v = red[off] / g;
                            off += 1;
                        }
                    }
                }
                stage.lits = Arc::new(build_lits(&stage.params)?);
                // The averaging rewrote the weights out-of-band: the
                // next round's forwards must not alias the pre-average
                // snapshot recorded under the same version number.
                stage.stash.invalidate_last();
            }
        } else {
            // ---- gradient AllReduce (sum across replicas), one
            // optimizer step over the 1/M-scaled round gradient.
            let reduced = group.allreduce_sum(&flat_grads(&stage.params));
            apply_update(
                &mut stage.params,
                &stage.sizes,
                &mut stage.opt,
                reduced,
                1.0 / spec.num_micro as f32,
            )?;
            for p in &mut stage.params {
                p.zero_grads();
            }
            stage.lits = Arc::new(build_lits(&stage.params)?);
        }
        stage.bwd_done.clear();

        let assigned = spec.script.iter().filter(|op| op.is_fwd()).count();
        report
            .send(Report::RoundDone {
                stage: spec.stage,
                slot: spec.slot,
                loss_sum,
                micros: assigned,
            })
            .ok();

        // Wait for the coordinator's round barrier.
        loop {
            match rx.recv()? {
                Msg::NextRound => break,
                Msg::Stop => {
                    // Clean shutdown: slot 0 streams its stage weights
                    // back (the coordinator-side checkpoint).
                    if spec.slot == 0 {
                        for (k, p) in stage.params.iter().enumerate() {
                            report
                                .send(Report::FinalParams {
                                    layer: lo + k,
                                    values: p.values.clone(),
                                })
                                .ok();
                        }
                    }
                    return Ok(());
                }
                other => bail!("unexpected message between rounds: {other:?}"),
            }
        }
    }
}

/// Convert the live parameter values to cached XLA literals.
/// Parameter literals are cached across weight versions and rebuilt
/// only after an optimizer step: converting ~MBs of weights per layer
/// on EVERY micro-batch execution was the engine's top hot-path cost
/// (EXPERIMENTS.md §Perf).
fn build_lits(params: &[LayerParams]) -> Result<Vec<Vec<xla::Literal>>> {
    params
        .iter()
        .map(|p| p.values.iter().map(|t| t.to_literal()).collect())
        .collect()
}

/// Flatten the accumulated gradient buffers (AllReduce order).
fn flat_grads(params: &[LayerParams]) -> Vec<f32> {
    params
        .iter()
        .flat_map(|p| p.grads.iter().flat_map(|g| g.as_f32().unwrap().iter().copied()))
        .collect()
}

/// Flatten the live parameter values (parameter-averaging order).
fn flat_values(params: &[LayerParams]) -> Vec<f32> {
    params
        .iter()
        .flat_map(|p| p.values.iter().flat_map(|t| t.as_f32().unwrap().iter().copied()))
        .collect()
}

/// One optimizer step over the live parameters with `grads` scaled by
/// `scale` — the shared write path of the per-round (sync) and
/// per-micro (bounded-staleness) updates.
fn apply_update(
    params: &mut [LayerParams],
    sizes: &[usize],
    opt: &mut Optimizer,
    mut grads: Vec<f32>,
    scale: f32,
) -> Result<()> {
    for v in &mut grads {
        *v *= scale;
    }
    let mut p_refs: Vec<&mut [f32]> = Vec::new();
    for p in params.iter_mut() {
        for t in &mut p.values {
            p_refs.push(t.as_f32_mut()?);
        }
    }
    let mut g_refs: Vec<&[f32]> = Vec::new();
    let mut off = 0;
    for &n in sizes {
        g_refs.push(&grads[off..off + n]);
        off += n;
    }
    opt.step(&mut p_refs, &g_refs);
    Ok(())
}

/// The PJRT [`StageCompute`]: this stage's compiled executables,
/// parameters and (under bounded staleness) the literal-pinning
/// weight-version stash.
///
/// Under a bounded-staleness script (`spec.stash_slots` > 0) this is
/// where the Schedule IR's weight-version tags become real: every
/// `Fwd` pins the literals of the version it read into the bounded
/// stash ring (an `Arc` clone of the cached `lits` — no conversion),
/// every `Bwd` computes against exactly that snapshot and then applies
/// its update to the live weights (advancing the version), so a
/// forward may read weights at most sigma updates behind the frontier
/// — never more, or `ParamStash::record` reports the overrun.
struct PjrtStage<'a> {
    spec: &'a WorkerSpec,
    layers: &'a [ManifestLayer],
    rt: &'a Runtime,
    params: Vec<LayerParams>,
    lits: Arc<Vec<Vec<xla::Literal>>>,
    opt: Optimizer,
    sizes: Vec<usize>,
    stash: ParamStash<Vec<Vec<xla::Literal>>>,
    version: u64,
    /// Per-micro stash of layer inputs (for the rematerialising BP) —
    /// distinct from the weight-version `ParamStash`.
    input_stash: BTreeMap<usize, Vec<Tensor>>,
    /// Head stage only: boundary activations awaiting their scheduled
    /// Bwd (the head artifact fuses its FP with the loss BP, so the
    /// head runs at the Bwd position to honour the script order under
    /// any policy — fill-drain included).
    head_acts: BTreeMap<usize, Tensor>,
    /// Split-backward scripts (zero-bubble policies): the AOT backward
    /// executable computes input- and weight-gradients fused, so both
    /// are accumulated at the Bwd op and the scheduled BwdW is a
    /// bookkeeping op that only validates the order.
    bwd_done: std::collections::BTreeSet<usize>,
}

/// One pinned weight version: (version, cached parameter literals).
type PinnedLits = (u64, Arc<Vec<Vec<xla::Literal>>>);

impl PjrtStage<'_> {
    fn async_updates(&self) -> bool {
        self.spec.stash_slots > 0
    }

    /// The stashed-or-live literal set a backward must use, plus the
    /// post-backward per-micro update for bounded-staleness scripts.
    fn take_bwd_lits(&mut self, micro: usize) -> Result<Option<PinnedLits>> {
        if self.async_updates() {
            Ok(Some(
                self.stash
                    .take(micro)
                    .with_context(|| format!("no stashed weights for micro {micro}"))?,
            ))
        } else {
            Ok(None)
        }
    }

    fn post_backward(&mut self, micro: usize) -> Result<()> {
        self.bwd_done.insert(micro);
        // Version-tagged write: a bounded-staleness worker applies this
        // micro's gradient immediately, advancing the weight version
        // the next forward reads.
        if self.async_updates() {
            let grads = flat_grads(&self.params);
            apply_update(
                &mut self.params,
                &self.sizes,
                &mut self.opt,
                grads,
                1.0 / self.spec.num_micro as f32,
            )?;
            for p in self.params.iter_mut() {
                p.zero_grads();
            }
            self.version += 1;
            self.lits = Arc::new(build_lits(&self.params)?);
        }
        Ok(())
    }
}

impl StageCompute for PjrtStage<'_> {
    fn forward(&mut self, micro: usize, x: Tensor) -> Result<Option<Tensor>> {
        // Version-tagged read: pin the literals this forward uses (an
        // Arc clone of the cached conversion — free), so its backward
        // runs against the same version after intervening per-micro
        // updates.
        if self.async_updates() {
            let lits = self.lits.clone();
            self.stash.record(micro, self.version, || lits)?;
        }
        if self.spec.is_last {
            let n = self.layers.len();
            let (cur, inputs) =
                forward_through(&self.layers[..n - 1], self.rt, &self.lits[..n - 1], x)?;
            self.input_stash.insert(micro, inputs);
            self.head_acts.insert(micro, cur);
            Ok(None)
        } else {
            let (out, inputs) = forward_through(self.layers, self.rt, &self.lits[..], x)?;
            self.input_stash.insert(micro, inputs);
            Ok(Some(out))
        }
    }

    fn backward(&mut self, micro: usize, g: Tensor) -> Result<Option<Tensor>> {
        let snap = self.take_bwd_lits(micro)?;
        let gx = {
            // Version-tagged weights for this backward: the stashed
            // literals its forward read (bounded staleness), or the
            // round-constant literals (sync).  Either way pre-converted
            // — no per-micro tensor-to-literal cost here.
            let bwd_lits: &[Vec<xla::Literal>] = match &snap {
                Some((_, weights)) => &weights[..],
                None => &self.lits[..],
            };
            let inputs = self
                .input_stash
                .remove(&micro)
                .with_context(|| format!("no stashed inputs for micro {micro}"))?;
            backward_through(self.layers, self.rt, &mut self.params, bwd_lits, &inputs, g)?
        };
        self.post_backward(micro)?;
        Ok(gx)
    }

    fn backward_head(&mut self, micro: usize, targets: Tensor) -> Result<(f64, Option<Tensor>)> {
        let snap = self.take_bwd_lits(micro)?;
        let (loss, gx) = {
            let bwd_lits: &[Vec<xla::Literal>] = match &snap {
                Some((_, weights)) => &weights[..],
                None => &self.lits[..],
            };
            // Fused head FP+BP on the stashed boundary activation, then
            // BP through the stashed layers.
            let cur = self
                .head_acts
                .remove(&micro)
                .with_context(|| format!("no head activation for micro {micro}"))?;
            let inputs = self
                .input_stash
                .remove(&micro)
                .with_context(|| format!("no stashed inputs for micro {micro}"))?;
            head_backward(
                self.layers,
                self.rt,
                &mut self.params,
                bwd_lits,
                cur,
                &targets,
                &inputs,
            )?
        };
        self.post_backward(micro)?;
        Ok((loss as f64, gx))
    }

    fn backward_weights(&mut self, micro: usize) -> Result<()> {
        // Scheduled weight-gradient slot of a split backward.  The
        // fused AOT executable already accumulated it at this micro's
        // Bwd; a BwdW whose Bwd has not run is a schedule the engine
        // cannot execute — report it as such, not as a policy-name
        // mismatch.
        anyhow::ensure!(
            self.bwd_done.contains(&micro),
            "unsupported op order: BwdW({micro}) before its Bwd \
             (stage {} slot {})",
            self.spec.stage,
            self.spec.slot
        );
        Ok(())
    }
}

/// FP through all non-head layers; returns (stage output, stashed
/// per-layer inputs).
fn forward_through(
    layers: &[ManifestLayer],
    rt: &Runtime,
    lits: &[Vec<xla::Literal>],
    x: Tensor,
) -> Result<(Tensor, Vec<Tensor>)> {
    let mut cur = x;
    let mut inputs = Vec::with_capacity(layers.len());
    for (k, l) in layers.iter().enumerate() {
        if l.kind == "head" {
            bail!("head layer in forward_through");
        }
        let cur_lit = cur.to_literal()?;
        let mut refs: Vec<&xla::Literal> = lits[k].iter().collect();
        refs.push(&cur_lit);
        let mut out = rt
            .execute_literals(&l.artifact_fwd, &refs)
            .with_context(|| format!("fwd {}", l.name))?;
        inputs.push(cur);
        cur = out.remove(0);
    }
    Ok((cur, inputs))
}

/// Fused head FP+BP on the stashed boundary activation `cur`, then BP
/// back through this stage's stashed non-head layers.  Returns (loss,
/// gradient for the previous stage if any).
fn head_backward(
    layers: &[ManifestLayer],
    rt: &Runtime,
    params: &mut [LayerParams],
    lits: &[Vec<xla::Literal>],
    cur: Tensor,
    targets: &Tensor,
    inputs: &[Tensor],
) -> Result<(f32, Option<Tensor>)> {
    let n = layers.len();
    let head = &layers[n - 1];
    if head.kind != "head" {
        bail!("last layer of head stage must be kind=head, got {}", head.kind);
    }

    // head_fwdbwd: (params..., x, targets) -> (loss, g_params..., g_x)
    let cur_lit = cur.to_literal()?;
    let tgt_lit = targets.to_literal()?;
    let mut refs: Vec<&xla::Literal> = lits[n - 1].iter().collect();
    refs.push(&cur_lit);
    refs.push(&tgt_lit);
    let mut out = rt
        .execute_literals(&head.artifact_fwd, &refs)
        .with_context(|| format!("head {}", head.name))?;
    let n_p = params[n - 1].values.len();
    anyhow::ensure!(out.len() == n_p + 2, "head output arity");
    let loss = out.remove(0).scalar_f32()?;
    let gx = out.pop().unwrap();
    params[n - 1].accumulate(&out)?;

    // BP back through the stashed non-head layers.
    let gx = backward_through(&layers[..n - 1], rt, params, lits, inputs, gx)?;
    Ok((loss, gx))
}

/// BP through `layers` (reversed) given stashed inputs and the output
/// gradient; accumulates parameter gradients.  Returns the input
/// gradient unless the first layer consumes it (embed/stem bwd with no
/// g_x output).
fn backward_through(
    layers: &[ManifestLayer],
    rt: &Runtime,
    params: &mut [LayerParams],
    lits: &[Vec<xla::Literal>],
    inputs: &[Tensor],
    g: Tensor,
) -> Result<Option<Tensor>> {
    let mut g = Some(g);
    for k in (0..layers.len()).rev() {
        let l = &layers[k];
        let grad_in = g.take().context("gradient chain broken")?;
        let x_lit = inputs[k].to_literal()?;
        let g_lit = grad_in.to_literal()?;
        let mut refs: Vec<&xla::Literal> = lits[k].iter().collect();
        refs.push(&x_lit);
        refs.push(&g_lit);
        let mut out = rt
            .execute_literals(&l.artifact_bwd, &refs)
            .with_context(|| format!("bwd {}", l.name))?;
        let n_p = params[k].values.len();
        if out.len() == n_p + 1 {
            g = Some(out.pop().unwrap());
        } else if out.len() == n_p {
            g = None; // first layer (embed/stem): no input gradient
        } else {
            bail!("bwd {}: unexpected arity {}", l.name, out.len());
        }
        params[k].accumulate(&out)?;
    }
    Ok(g)
}
