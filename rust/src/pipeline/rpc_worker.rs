//! The `asteroid-worker` serve loop: one pipeline stage slot as a
//! standalone TCP peer.
//!
//! A worker binds **one** listening socket.  Every inbound connection
//! introduces itself with an `RpcMsg::Hello` frame: the driver's
//! control connection (assignment, round control, heartbeat backchannel,
//! parameter fetch, fault injection) or a peer worker's data connection
//! (activations from the previous stage, gradients from the next).
//! Outbound data connections are dialled after [`crate::comm::rpc::AssignSpec`]
//! arrives, toward the peer addresses it names.
//!
//! The compute itself is the transport-agnostic core of
//! [`crate::pipeline::step`]: the worker executes its device's schedule
//! script over a [`ReferenceStage`] kernel and never re-derives
//! 1F1B/K_p/staleness ordering.
//!
//! Fault semantics are *real* here: `RpcMsg::Die` makes the process
//! exit unclean mid-round (when [`ServeOpts::die_for_real`]), peers
//! observe EOF, the driver's heartbeat monitor observes silence, and a
//! re-`Assign` later rebuilds the stage (optionally warm-started from
//! the driver's checkpoint) with fresh data links.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::codec::Codec;
use crate::comm::collective::{ring_all_reduce, SyncMode};
use crate::comm::rpc::{
    recv_msg, send_msg, send_msg_streamed, send_ring_chunk, worker_action, AssignSpec, ConnRole,
    LayerState, RpcMsg, WorkerAction, WorkerPhase,
};
use crate::pipeline::step::{run_script_round, DataMsg, DataPlane, ReferenceStage};

/// How long a worker keeps re-dialling a peer data address before
/// giving up (covers slow peer start in CI).
const PEER_DIAL_TIMEOUT: Duration = Duration::from_secs(20);

/// How long a send-failure reconnect may re-dial before the round is
/// declared failed (shorter than the first dial: the peer was already
/// up once, so either it is rebinding its port — PR 9's warm restart —
/// or it is dead and the driver's abort will resolve the round).
const RECONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Options for one serve run.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// `RpcMsg::Die` terminates the *process* (the real fault the
    /// integration pipeline injects).  Disabled when the serve loop
    /// runs on a thread inside a test process: there the serve loop
    /// returns [`ServeOutcome::Died`] silently (data links dropped; a
    /// thread cannot sever its process's remaining sockets, so the
    /// caller should drop or exit promptly).
    pub die_for_real: bool,
    /// Log lifecycle events to stderr.
    pub verbose: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { die_for_real: true, verbose: false }
    }
}

/// How a serve loop ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeOutcome {
    /// Driver sent `Exit`; `Bye` was answered.
    Clean,
    /// Driver sent `Die` with `die_for_real` off (thread mode): the
    /// caller should drop everything, as a process exit would have.
    Died,
}

/// One item of the worker's single inbox: every reader thread funnels
/// here, so the main loop (and the in-round data plane) has one place
/// to block on.  Data items carry their sender's assignment
/// generation — the data plane drops frames from other generations
/// (stale in-flight tensors of a round aborted before a re-task).
enum Inbox {
    Ctrl(RpcMsg),
    Data(u64, DataMsg),
    /// One ring AllReduce segment from the ring predecessor, tagged
    /// with its assignment generation like data frames.
    Ring { gen: u64, step: usize, seg: usize, flat: Vec<f32> },
    /// A connection's reader ended (EOF or error).
    Closed(ConnRole),
}

/// Marker error: thread-mode (`die_for_real` off) death injection
/// observed mid-round — the serve loop turns it into
/// [`ServeOutcome::Died`] instead of a round failure.
#[derive(Debug)]
struct DieMidRound;

impl std::fmt::Display for DieMidRound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("death injected mid-round")
    }
}

impl std::error::Error for DieMidRound {}

/// Serve one worker on `listener` until the driver says `Exit`/`Die`
/// or the control connection dies.
pub fn serve(listener: TcpListener, opts: ServeOpts) -> Result<ServeOutcome> {
    let local = listener.local_addr()?;
    let (tx, rx) = std::sync::mpsc::channel::<Inbox>();
    let control_writer: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));

    // Accept loop: classify each connection by its Hello frame and
    // spawn a reader thread for it.
    {
        let tx = tx.clone();
        let control_writer = control_writer.clone();
        let opts_c = opts.clone();
        std::thread::spawn(move || loop {
            let (conn, _) = match listener.accept() {
                Ok(c) => c,
                Err(_) => return, // listener dropped: process exiting
            };
            let _ = conn.set_nodelay(true);
            let tx = tx.clone();
            let control_writer = control_writer.clone();
            let opts = opts_c.clone();
            std::thread::spawn(move || read_connection(conn, tx, control_writer, opts));
        });
    }

    if opts.verbose {
        eprintln!("asteroid-worker: listening on {local}");
    }

    let mut state = WorkerState {
        rx,
        control_writer,
        assigned: None,
        carryover: VecDeque::new(),
        ring_buf: VecDeque::new(),
        pending_ctrl: VecDeque::new(),
        throttle: 1.0,
        opts,
    };
    state.main_loop()
}

/// Reader thread of one inbound connection.
fn read_connection(
    mut conn: TcpStream,
    tx: Sender<Inbox>,
    control_writer: Arc<Mutex<Option<TcpStream>>>,
    opts: ServeOpts,
) {
    let role = match recv_msg(&mut conn) {
        Ok(RpcMsg::Hello { role }) => role,
        _ => return, // not a peer: drop silently
    };
    if role == ConnRole::Control {
        match conn.try_clone() {
            Ok(w) => *control_writer.lock().unwrap() = Some(w),
            Err(_) => return,
        }
    }
    loop {
        match recv_msg(&mut conn) {
            Ok(RpcMsg::Act { gen, micro, t }) => {
                if tx.send(Inbox::Data(gen, DataMsg::Act { micro, t })).is_err() {
                    return;
                }
            }
            Ok(RpcMsg::Grad { gen, micro, t }) => {
                if tx.send(Inbox::Data(gen, DataMsg::Grad { micro, t })).is_err() {
                    return;
                }
            }
            Ok(RpcMsg::Targets { gen, micro, t }) => {
                if tx.send(Inbox::Data(gen, DataMsg::Targets { micro, t })).is_err() {
                    return;
                }
            }
            Ok(RpcMsg::RingChunk { gen, step, seg, flat }) => {
                if tx.send(Inbox::Ring { gen, step, seg, flat }).is_err() {
                    return;
                }
            }
            Ok(RpcMsg::Die) if opts.die_for_real => {
                // The injected device exit: disappear *now*, unclean,
                // exactly as a powered-off edge device would.  Peers
                // and driver learn from EOF + heartbeat silence.
                eprintln!("asteroid-worker: Die injected — exiting unclean");
                std::process::exit(86);
            }
            Ok(msg) => {
                if tx.send(Inbox::Ctrl(msg)).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = tx.send(Inbox::Closed(role));
                return;
            }
        }
    }
}

/// One applied assignment: the stage kernel plus its outbound links
/// and heartbeat thread.
struct Assigned {
    spec: AssignSpec,
    stage: ReferenceStage,
    next: Vec<PeerLink>,
    prev: Vec<PeerLink>,
    /// Outbound link to the ring successor (`ring[(ring_index+1) % g]`)
    /// when this assignment syncs over the worker-to-worker ring.
    ring: Option<PeerLink>,
    hb_stop: Arc<AtomicBool>,
}

/// A persistent outbound peer link: the dialled address, the Hello
/// role to replay, and the live stream.  A send failure triggers one
/// reconnect-and-resend cycle — under churn a peer may warm-restart on
/// the same port (PR 9), killing the old socket while the address
/// stays valid.  The resent frame is a whole message, and receivers
/// filter by assignment generation, so a duplicate delivered across
/// the ambiguity window of a failed write is dropped, not double-
/// applied.
struct PeerLink {
    addr: String,
    role: ConnRole,
    conn: TcpStream,
}

impl PeerLink {
    fn dial(addr: &str, role: ConnRole, timeout: Duration) -> Result<PeerLink> {
        let mut conn =
            dial_with_retry(addr, timeout).with_context(|| format!("dialling peer {addr}"))?;
        conn.set_nodelay(true).ok();
        send_msg(&mut conn, &RpcMsg::Hello { role })?;
        Ok(PeerLink { addr: addr.to_string(), role, conn })
    }

    /// Run one framed send, reconnecting once on failure.  Returns the
    /// wire bytes written.
    fn send_with(
        &mut self,
        f: impl Fn(&mut TcpStream) -> Result<u64>,
        what: &str,
    ) -> Result<u64> {
        match f(&mut self.conn) {
            Ok(n) => Ok(n),
            Err(_) => {
                let mut conn = dial_with_retry(&self.addr, RECONNECT_TIMEOUT)
                    .with_context(|| format!("reconnecting to peer {}", self.addr))?;
                conn.set_nodelay(true).ok();
                send_msg(&mut conn, &RpcMsg::Hello { role: self.role })?;
                self.conn = conn;
                f(&mut self.conn).with_context(|| format!("{what} after reconnect"))
            }
        }
    }

    /// Streamed (zero-copy framed) message send with reconnect.
    fn send(&mut self, msg: &RpcMsg, codec: Codec) -> Result<u64> {
        let kind = msg.kind();
        self.send_with(|w| send_msg_streamed(w, msg, codec), kind)
    }

    /// Ring-segment send straight from the borrowed slice.
    fn send_ring(
        &mut self,
        gen: u64,
        step: usize,
        seg: usize,
        flat: &[f32],
        codec: Codec,
    ) -> Result<u64> {
        self.send_with(|w| send_ring_chunk(w, gen, step, seg, flat, codec), "RingChunk")
    }
}

impl Drop for Assigned {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::Relaxed);
    }
}

struct WorkerState {
    rx: Receiver<Inbox>,
    control_writer: Arc<Mutex<Option<TcpStream>>>,
    assigned: Option<Assigned>,
    /// Data frames that arrived while idle (a fast upstream peer may
    /// start its round before our `StartRound` lands), tagged with the
    /// sender's assignment generation — consumed first by the next
    /// round's data plane, which drops other generations.
    carryover: VecDeque<(u64, DataMsg)>,
    /// Early ring segments, buffered like data carryover: a faster
    /// group member may enter its round sync (and send its first
    /// reduce-scatter chunk) while we are still computing or idle.
    /// Per-connection FIFO + one sender per ring edge means chunks of
    /// one generation arrive in exchange order.
    ring_buf: VecDeque<(u64, usize, usize, Vec<f32>)>,
    /// Control frames observed while draining stale data.
    pending_ctrl: VecDeque<RpcMsg>,
    /// Injected compute slowdown (`RpcMsg::Throttle`): rounds are
    /// stretched to `factor x` their natural duration.  1.0 = full
    /// speed.  Survives re-assignment — the throttle models degraded
    /// hardware, not a property of one stage task.
    throttle: f64,
    opts: ServeOpts,
}

impl WorkerState {
    fn send_ctrl(&self, msg: &RpcMsg) -> Result<()> {
        let mut guard = self.control_writer.lock().unwrap();
        let w = guard.as_mut().context("no control connection")?;
        send_msg(w, msg)
    }

    fn next_event(&mut self) -> Result<Inbox> {
        if let Some(m) = self.pending_ctrl.pop_front() {
            return Ok(Inbox::Ctrl(m));
        }
        self.rx.recv().map_err(|_| anyhow!("worker inbox closed"))
    }

    fn main_loop(&mut self) -> Result<ServeOutcome> {
        loop {
            match self.next_event()? {
                Inbox::Data(g, d) => self.carryover.push_back((g, d)),
                Inbox::Ring { gen, step, seg, flat } => {
                    self.ring_buf.push_back((gen, step, seg, flat))
                }
                Inbox::Closed(ConnRole::Control) => {
                    bail!("driver control connection lost");
                }
                // Peer churn is fine while idle, for data and ring alike.
                Inbox::Closed(ConnRole::Data { .. } | ConnRole::Ring { .. }) => {}
                // Dispatch through the declarative machine in
                // `comm::rpc` — the table picks the transition, the
                // arms below only bind payloads and run it.
                Inbox::Ctrl(msg) => match (worker_action(WorkerPhase::Idle, msg.kind()), msg) {
                    (Some(WorkerAction::ApplyAssign), RpcMsg::Assign(spec)) => {
                        self.apply_assign(*spec)?
                    }
                    (Some(WorkerAction::BeginRound), RpcMsg::StartRound { round }) => {
                        if self.run_round(round)? {
                            return Ok(ServeOutcome::Died);
                        }
                    }
                    (Some(WorkerAction::SendParams), RpcMsg::FetchParams) => {
                        let layers = match &self.assigned {
                            Some(a) => a
                                .stage
                                .layer_states()
                                .into_iter()
                                .map(|(layer, scale, bias)| LayerState { layer, scale, bias })
                                .collect(),
                            None => Vec::new(),
                        };
                        self.send_ctrl(&RpcMsg::Params { layers })?;
                    }
                    (Some(WorkerAction::AckAbort), RpcMsg::AbortRound) => {
                        // Idle abort: the driver is tearing a round down
                        // that we already finished (or never started) —
                        // drop stale in-flight data and acknowledge by
                        // reporting idle-failure once.
                        self.discard_round_state();
                        if let Some(a) = &self.assigned {
                            let _ = self.send_ctrl(&RpcMsg::RoundFailed {
                                device: a.spec.device,
                                error: "aborted while idle".into(),
                            });
                        }
                    }
                    (Some(WorkerAction::ApplyThrottle), RpcMsg::Throttle { factor }) => {
                        self.throttle = if factor.is_finite() { factor.max(1.0) } else { 1.0 };
                        if self.opts.verbose {
                            eprintln!("asteroid-worker: throttled to {}x", self.throttle);
                        }
                    }
                    (Some(WorkerAction::ExitClean), RpcMsg::Exit) => {
                        let _ = self.send_ctrl(&RpcMsg::Bye);
                        return Ok(ServeOutcome::Clean);
                    }
                    (Some(WorkerAction::DieNow), RpcMsg::Die) => {
                        // Only reachable with die_for_real off (thread
                        // mode): emulate process death by dropping
                        // every connection.
                        return Ok(ServeOutcome::Died);
                    }
                    // IgnoreIdle — plus the unreachable leftovers: the
                    // reader thread routes tensor frames to Inbox::Data
                    // before they can surface as control messages.
                    (_, other) => {
                        if self.opts.verbose {
                            eprintln!("asteroid-worker: ignoring {} while idle", other.kind());
                        }
                    }
                },
            }
        }
    }

    fn discard_round_state(&mut self) {
        self.carryover.clear();
        self.ring_buf.clear();
        if let Some(a) = &mut self.assigned {
            a.stage.abort_round();
        }
        // Drain whatever already sits in the inbox: stale data or
        // closed-peer notices.  Control frames are preserved in order.
        while let Ok(item) = self.rx.try_recv() {
            match item {
                Inbox::Ctrl(m) => self.pending_ctrl.push_back(m),
                Inbox::Data(..)
                | Inbox::Ring { .. }
                | Inbox::Closed(ConnRole::Data { .. } | ConnRole::Ring { .. }) => {}
                Inbox::Closed(ConnRole::Control) => {
                    self.pending_ctrl.push_back(RpcMsg::Exit);
                }
            }
        }
    }

    fn apply_assign(&mut self, spec: AssignSpec) -> Result<()> {
        // Tear down any previous assignment (stops its heartbeat and
        // drops its out-links) and flush stale round state first.
        self.assigned = None;
        self.discard_round_state();

        let mut stage = ReferenceStage::new(
            &spec.layers,
            spec.seed,
            spec.opt,
            spec.stash_slots,
            spec.microbatch,
            spec.num_micro,
        )?;
        if !spec.warm_start.is_empty() {
            let states: Vec<(usize, Vec<f32>, Vec<f32>)> = spec
                .warm_start
                .iter()
                .map(|s| (s.layer, s.scale.clone(), s.bias.clone()))
                .collect();
            stage.load_layer_states(&states)?;
        }

        let me = ConnRole::Data { stage: spec.stage, slot: spec.slot };
        let next = dial_peers(&spec.next, me)?;
        let prev = dial_peers(&spec.prev, me)?;
        // Ring sync: dial the successor once per assignment.  Every
        // member dials its successor and is dialled by its predecessor;
        // the predecessor's chunks arrive through the ordinary inbound
        // accept loop as `Inbox::Ring` items.
        let ring = if spec.sync == SyncMode::Ring && spec.ring.len() > 1 {
            let succ = &spec.ring[(spec.ring_index + 1) % spec.ring.len()];
            Some(
                PeerLink::dial(
                    succ,
                    ConnRole::Ring { stage: spec.stage, index: spec.ring_index },
                    PEER_DIAL_TIMEOUT,
                )
                .with_context(|| format!("dialling ring successor {succ}"))?,
            )
        } else {
            None
        };

        // (Re)start the heartbeat: one thread per assignment, writing
        // through the shared control writer at the driver-configured
        // period (the same interval the sim's detection model charges).
        let hb_stop = Arc::new(AtomicBool::new(false));
        {
            let stop = hb_stop.clone();
            let writer = self.control_writer.clone();
            let device = spec.device;
            let period = Duration::from_millis(spec.heartbeat_ms.max(1));
            std::thread::spawn(move || {
                let mut seq = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(period);
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    let mut guard = writer.lock().unwrap();
                    let Some(w) = guard.as_mut() else { return };
                    if send_msg(w, &RpcMsg::Heartbeat { device, seq }).is_err() {
                        return;
                    }
                    seq += 1;
                }
            });
        }

        let device = spec.device;
        self.assigned = Some(Assigned { spec, stage, next, prev, ring, hb_stop });
        self.send_ctrl(&RpcMsg::Ready { device })?;
        if self.opts.verbose {
            eprintln!("asteroid-worker: device {device} assigned and ready");
        }
        Ok(())
    }

    /// Run one round.  Returns `true` when a thread-mode death
    /// injection ended it (the serve loop then reports
    /// [`ServeOutcome::Died`]).
    fn run_round(&mut self, round: usize) -> Result<bool> {
        let Some(mut a) = self.assigned.take() else {
            bail!("StartRound before Assign");
        };
        let t0 = Instant::now();
        let outcome = round_body(
            &mut a,
            &mut self.carryover,
            &mut self.ring_buf,
            &self.rx,
            &self.control_writer,
        );
        if self.throttle > 1.0 {
            // Straggler injection: stretch the round to `factor x` its
            // natural duration, so the driver's timing-drift detector
            // sees exactly what a derated device would produce.
            let stretch = (self.throttle - 1.0) * t0.elapsed().as_secs_f64();
            std::thread::sleep(Duration::from_secs_f64(stretch.min(10.0)));
        }
        let compute_s = t0.elapsed().as_secs_f64();
        let device = a.spec.device;
        match outcome {
            Ok(done) => {
                let micros = a.spec.script.iter().filter(|op| op.is_fwd()).count();
                self.assigned = Some(a);
                self.send_ctrl(&RpcMsg::RoundDone {
                    device,
                    round,
                    loss_sum: done.loss_sum,
                    micros,
                    compute_s,
                    logical_bytes: done.logical_bytes,
                    wire_bytes: done.wire_bytes,
                    sync_bytes: done.sync_bytes,
                    sync_wall_s: done.sync_wall_s,
                })?;
            }
            Err(e) if e.is::<DieMidRound>() => {
                // Thread-mode death: say nothing, drop the assignment
                // (and with it the data links) — as close to a process
                // exit as a thread can get.
                drop(a);
                return Ok(true);
            }
            Err(e) => {
                // Peer loss or a driver abort: return to idle cleanly —
                // the driver decides what happens next (re-assign for
                // recovery, or shutdown).
                a.stage.abort_round();
                self.assigned = Some(a);
                self.discard_round_state();
                let _ = self.send_ctrl(&RpcMsg::RoundFailed {
                    device,
                    error: format!("{e:#}"),
                });
            }
        }
        Ok(false)
    }
}

/// What one completed round reports back to the driver.
struct RoundOutcome {
    loss_sum: f64,
    /// Data-plane tensor payload bytes sent, before the wire codec.
    logical_bytes: u64,
    /// The same payloads as the codec put them on the wire.
    wire_bytes: u64,
    /// Round-sync wire bytes this worker transmitted (ring chunks, or
    /// the star-mode `SyncRequest` upload) — each sync byte is counted
    /// once, at its sender, matching the Eq. 5 per-device volume
    /// convention (`2(g-1)/g x W` on the ring, `W` up the star).
    sync_bytes: u64,
    /// Wall-clock of the round-sync exchange.
    sync_wall_s: f64,
}

/// One round: script execution plus the replicated-stage round sync
/// (the collective selected by `AssignSpec::sync`).
fn round_body(
    a: &mut Assigned,
    carryover: &mut VecDeque<(u64, DataMsg)>,
    ring_buf: &mut VecDeque<(u64, usize, usize, Vec<f32>)>,
    rx: &Receiver<Inbox>,
    control_writer: &Arc<Mutex<Option<TcpStream>>>,
) -> Result<RoundOutcome> {
    let is_first = a.spec.stage == 0;
    let is_last = a.spec.stage + 1 == a.spec.num_stages;
    let (loss_sum, logical_bytes, wire_bytes) = {
        let mut dp = RpcDataPlane {
            gen: a.spec.generation,
            carryover,
            ring_buf,
            rx,
            next: &mut a.next,
            prev: &mut a.prev,
            codec_act: a.spec.codec_act,
            codec_grad: a.spec.codec_grad,
            logical_bytes: 0,
            wire_bytes: 0,
        };
        let loss = run_script_round(&a.spec.script, is_first, is_last, &mut a.stage, &mut dp)?;
        (loss, dp.logical_bytes, dp.wire_bytes)
    };

    let mut sync_bytes = 0u64;
    let mut sync_wall_s = 0.0f64;
    if a.spec.group_size > 1 {
        // Replicated-stage round sync: summed gradients under a
        // synchronous policy, parameter averaging under bounded
        // staleness (replicas drifted per micro).
        let t_sync = Instant::now();
        let asynchronous = a.spec.stash_slots > 0;
        let (kind, mut flat) = if asynchronous {
            (1u8, a.stage.flat_params())
        } else {
            (0u8, a.stage.flat_grads())
        };
        let reduced = match a.spec.sync {
            SyncMode::Ring => {
                // Worker-to-worker ring AllReduce on the data plane:
                // 2(g-1) chunk exchanges with the ring neighbours, the
                // driver not involved at all.  Chunks ride the sync
                // codec like star flats do.
                let gen = a.spec.generation;
                let codec = a.spec.codec_sync;
                let group = a.spec.group_size;
                let index = a.spec.ring_index;
                let link = a.ring.as_mut().context("ring sync without a ring link")?;
                ring_all_reduce(
                    &mut flat,
                    index,
                    group,
                    |step, seg, chunk| {
                        sync_bytes += link.send_ring(gen, step, seg, chunk, codec)?;
                        Ok(())
                    },
                    |step, seg| recv_ring_chunk(gen, step, seg, ring_buf, carryover, rx),
                )?;
                // The ring leaves the element-wise SUM on every member;
                // parameter averaging divides locally (the star's
                // driver did this at the hub).
                if asynchronous {
                    let g = group as f32;
                    for v in &mut flat {
                        *v /= g;
                    }
                }
                flat
            }
            SyncMode::DriverStar => {
                // Degraded fallback: the driver mediates, summing (and
                // for parameters averaging) the whole group's flats.
                // The sync rides the control link; O(group) driver
                // messages per round.
                {
                    let mut guard = control_writer.lock().unwrap();
                    let w =
                        guard.as_mut().context("no control connection for round sync")?;
                    sync_bytes += send_msg_streamed(
                        w,
                        &RpcMsg::SyncRequest { device: a.spec.device, kind, flat },
                        a.spec.codec_sync,
                    )?;
                }
                wait_sync_result(carryover, ring_buf, rx)?
            }
        };
        if asynchronous {
            a.stage.set_flat_params(&reduced)?;
        } else {
            a.stage.apply_round_gradients(&reduced)?;
        }
        sync_wall_s = t_sync.elapsed().as_secs_f64();
    } else {
        a.stage.end_round_local()?;
    }
    Ok(RoundOutcome { loss_sum, logical_bytes, wire_bytes, sync_bytes, sync_wall_s })
}

/// Block until the ring predecessor's chunk for exchange (`step`,
/// `seg`) of generation `gen` arrives.  Early chunks were buffered in
/// `ring_buf`; stale-generation chunks (in flight across an aborted
/// round's re-task) are dropped; data frames are buffered for the next
/// round.  Chunks of one generation arrive in exchange order (single
/// sender, FIFO connection), so an in-generation mismatch is a
/// protocol error, not a reordering.
fn recv_ring_chunk(
    gen: u64,
    step: usize,
    seg: usize,
    ring_buf: &mut VecDeque<(u64, usize, usize, Vec<f32>)>,
    carryover: &mut VecDeque<(u64, DataMsg)>,
    rx: &Receiver<Inbox>,
) -> Result<Vec<f32>> {
    loop {
        while let Some((g, st, sg, flat)) = ring_buf.pop_front() {
            if g != gen {
                continue; // stale generation
            }
            anyhow::ensure!(
                (st, sg) == (step, seg),
                "ring chunk out of order: got step {st} seg {sg}, expected {step}/{seg}"
            );
            return Ok(flat);
        }
        match rx.recv().map_err(|_| anyhow!("worker inbox closed"))? {
            Inbox::Ring { gen: g, step: st, seg: sg, flat } => {
                ring_buf.push_back((g, st, sg, flat));
            }
            Inbox::Data(g, d) => carryover.push_back((g, d)),
            Inbox::Ctrl(msg) => match worker_action(WorkerPhase::Syncing, msg.kind()) {
                Some(WorkerAction::FailAbort) => bail!("round aborted during ring sync"),
                _ => bail!("unexpected {} during ring sync", msg.kind()),
            },
            Inbox::Closed(ConnRole::Control) => bail!("driver lost during ring sync"),
            Inbox::Closed(ConnRole::Ring { stage, index }) => {
                // The predecessor died mid-ring: the chunks it owed us
                // never arrive.  Fail the round — the driver's
                // heartbeat detection + AbortRound + churn replay path
                // resolves it.
                bail!("ring peer (stage {stage} member {index}) lost mid-sync");
            }
            Inbox::Closed(ConnRole::Data { .. }) => {} // peer churn: driver decides
        }
    }
}

/// Block until the driver's `SyncResult` arrives, buffering any early
/// next-round data frames.
fn wait_sync_result(
    carryover: &mut VecDeque<(u64, DataMsg)>,
    ring_buf: &mut VecDeque<(u64, usize, usize, Vec<f32>)>,
    rx: &Receiver<Inbox>,
) -> Result<Vec<f32>> {
    loop {
        match rx.recv().map_err(|_| anyhow!("worker inbox closed"))? {
            Inbox::Ctrl(msg) => match (worker_action(WorkerPhase::Syncing, msg.kind()), msg) {
                (Some(WorkerAction::DeliverSync), RpcMsg::SyncResult { flat }) => return Ok(flat),
                (Some(WorkerAction::FailAbort), _) => bail!("round aborted during sync"),
                (_, other) => bail!("unexpected {} during round sync", other.kind()),
            },
            Inbox::Data(g, d) => carryover.push_back((g, d)),
            Inbox::Ring { gen, step, seg, flat } => {
                ring_buf.push_back((gen, step, seg, flat))
            }
            Inbox::Closed(ConnRole::Control) => bail!("driver lost during round sync"),
            // Peer churn: the driver decides.
            Inbox::Closed(ConnRole::Data { .. } | ConnRole::Ring { .. }) => {}
        }
    }
}

/// The worker-side [`DataPlane`]: receive from the funnel inbox
/// (buffered carryover first), send over the per-peer framed streams
/// with the same `micro % g` routing as the in-process engine.  Every
/// outgoing frame carries this assignment's generation; incoming
/// frames from other generations are dropped (stale tensors of an
/// aborted round that were still in flight across a recovery
/// re-task).
struct RpcDataPlane<'a> {
    gen: u64,
    carryover: &'a mut VecDeque<(u64, DataMsg)>,
    /// Ring chunks arriving mid-round: a faster group member already
    /// finished its script and entered the round sync — buffer its
    /// chunks for our own sync phase.
    ring_buf: &'a mut VecDeque<(u64, usize, usize, Vec<f32>)>,
    rx: &'a Receiver<Inbox>,
    next: &'a mut [PeerLink],
    prev: &'a mut [PeerLink],
    /// Wire codec for outbound activations (stage output boundary).
    codec_act: Codec,
    /// Wire codec for outbound gradients (stage input boundary).
    codec_grad: Codec,
    /// Outbound tensor payload bytes before compression.
    logical_bytes: u64,
    /// The same payloads as the codec put them on the wire.
    wire_bytes: u64,
}

impl DataPlane for RpcDataPlane<'_> {
    fn recv(&mut self) -> Result<DataMsg> {
        while let Some((g, d)) = self.carryover.pop_front() {
            if g == self.gen {
                return Ok(d);
            }
        }
        loop {
            match self.rx.recv().map_err(|_| anyhow!("worker inbox closed"))? {
                Inbox::Data(g, d) => {
                    if g == self.gen {
                        return Ok(d);
                    }
                    // Stale generation: a frame the aborted round left
                    // in flight — drop it.
                }
                Inbox::Ring { gen, step, seg, flat } => {
                    self.ring_buf.push_back((gen, step, seg, flat))
                }
                Inbox::Ctrl(msg) => match worker_action(WorkerPhase::InRound, msg.kind()) {
                    Some(WorkerAction::FailAbort) => bail!("round aborted by driver"),
                    Some(WorkerAction::DieNow) => return Err(anyhow::Error::new(DieMidRound)),
                    Some(WorkerAction::FailExit) => bail!("shutdown requested mid-round"),
                    _ => bail!("unexpected control message {} mid-round", msg.kind()),
                },
                Inbox::Closed(ConnRole::Control) => bail!("driver lost mid-round"),
                // A data or ring connection ended.  This is either
                // churn from a superseded assignment (stale peers
                // closing after a recovery re-task — harmless) or a
                // genuinely dead peer — in which case the tensors it
                // owed us never arrive and the driver's abort/timeout
                // resolves the round.  Either way the driver owns the
                // verdict; keep waiting.
                Inbox::Closed(ConnRole::Data { .. } | ConnRole::Ring { .. }) => continue,
            }
        }
    }

    fn send_act(&mut self, micro: usize, t: crate::runtime::Tensor) -> Result<()> {
        anyhow::ensure!(!self.next.is_empty(), "no next-stage links to send to");
        let i = micro % self.next.len();
        let logical = t.byte_len() as u64;
        self.logical_bytes += logical;
        self.wire_bytes += self.codec_act.wire_bytes(logical, t.dtype());
        self.next[i]
            .send(&RpcMsg::Act { gen: self.gen, micro, t }, self.codec_act)
            .with_context(|| format!("sending activation of micro {micro}"))?;
        Ok(())
    }

    fn send_grad(&mut self, micro: usize, t: crate::runtime::Tensor) -> Result<()> {
        anyhow::ensure!(!self.prev.is_empty(), "no prev-stage links to send to");
        let i = micro % self.prev.len();
        let logical = t.byte_len() as u64;
        self.logical_bytes += logical;
        self.wire_bytes += self.codec_grad.wire_bytes(logical, t.dtype());
        self.prev[i]
            .send(&RpcMsg::Grad { gen: self.gen, micro, t }, self.codec_grad)
            .with_context(|| format!("sending gradient of micro {micro}"))?;
        Ok(())
    }
}

/// Dial every peer address with retry (peers may still be starting).
fn dial_peers(addrs: &[String], me: ConnRole) -> Result<Vec<PeerLink>> {
    addrs.iter().map(|addr| PeerLink::dial(addr, me, PEER_DIAL_TIMEOUT)).collect()
}

/// Connect with retry until `timeout`.
pub fn dial_with_retry(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(c) => return Ok(c),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to {addr}"));
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}
